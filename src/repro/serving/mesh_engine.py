"""Mesh-sharded serving engine: the batch-of-requests cache across devices.

One :class:`~repro.serving.engine.Engine` binds the whole serving path to a
single device, so row capacity and aggregate decode throughput stop at one
device's memory and FLOPs.  :class:`ShardedEngine` partitions the
batch-of-requests cache's *row* axis over a mesh axis (blocked layout —
global row ``r`` lives on shard ``r // (B/S)`` at local row ``r % (B/S)``)
and runs the four hot primitives of the serving loop — run insertion
(after the host-side ``codec.decode_chunk_runs``), coalesced TEXT
recompute (``prefill_extend_rows``), stacked generation
(``decode_step_rows``), and the row-pool reset/restore — under
``shard_map``, with partition specs derived from the logical-axis rule set
(``models.sharding.use_rules`` / ``logical_to_spec``, logical axis
``"cache_rows"``).

Because every primitive is row-parallel (each row attends over its own
prefix; the inactive-row where-merge, the window merge of
``insert_codec_runs``, and save/restore/reset are all row-local), the
shard bodies are collective-free and perform exactly the unsharded
kernels' per-row arithmetic — which is what keeps a mesh of 1 bit-identical
to the plain Engine, and per-request caches/tokens bit-identical at any
shard count.  ``save_row`` needs no sharded variant: slicing a
``NamedSharding`` array is addressable from the host.

Row counts must divide by the shard count on the sharded path; the
schedulers size their pool cache via ``Engine.cache_rows``.  Calls whose
cache batch is *not* divisible (e.g. a batch-1 ``ServeSession`` cache)
transparently fall back to the inherited single-device callables, so the
single-session path keeps working unchanged on a sharded engine.

``kv_heads``-along-``model`` tensor parallelism inside each row is left
replicated here (it needs a psum over the attention out-projection —
tracked as a ROADMAP follow-on); the mesh's win is rows, decode width, and
per-shard fetch bandwidth.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import lm, sharding
from repro.models.lm import Caches
from repro.serving import kv_layout
from repro.serving.engine import Engine

__all__ = ["ShardedEngine"]


class ShardedEngine(Engine):
    """Engine whose batch-of-requests cache rows are sharded over a mesh.

    ``mesh`` must carry the axis the ``"cache_rows"`` rule maps to (the
    ``"data"`` axis of ``launch.mesh.make_serving_mesh`` /
    ``make_test_mesh``); ``rules`` overlays the default logical-axis rule
    set.  With a one-device mesh the engine is bit-identical to the plain
    :class:`Engine` through every entry point (held by
    tests/test_mesh_serving.py).
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        cache_capacity: int = 4096,
        *,
        mesh: Mesh,
        rules: Optional[Dict[str, object]] = None,
    ):
        super().__init__(cfg, params, cache_capacity)
        if self._decode_rows is None or self._extend_rows is None:
            raise ValueError(
                f"ShardedEngine needs a KV-cache attention family, got "
                f"{cfg.family!r}"
            )
        self.mesh = mesh
        # Partition specs come from the logical rule set, so re-mapping
        # "cache_rows" re-distributes the whole serving path without
        # touching this module.
        with sharding.use_rules(mesh, rules):
            self._cache_spec = sharding.logical_to_spec(
                ("layers", "cache_rows", "kv_seq", "kv_heads", "head_dim")
            )
            self._rows_spec = sharding.logical_to_spec(("cache_rows",))
        part = self._rows_spec[0]
        axes = () if part is None else (
            (part,) if isinstance(part, str) else tuple(part)
        )
        if len(axes) > 1:
            raise ValueError(
                f"cache_rows maps to {axes} on this mesh; row sharding "
                f"supports exactly one mesh axis — overlay a rule like "
                f"{{'cache_rows': 'data'}}"
            )
        self.row_axis: Optional[str] = axes[0] if axes else None
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.n_shards = int(axis_sizes[self.row_axis]) if self.row_axis else 1

        ax = self.row_axis
        c_spec = self._cache_spec  # (L, B, cap, Hkv, Dh)
        r_spec = self._rows_spec  # (B,)
        rows2 = P(*(list(r_spec) + [None]))  # (B, 1) tokens / (B, Tc) texts
        logits3 = P(*(list(r_spec) + [None, None]))  # (B, T, V)
        rep = P()

        def _sm(body, in_specs, out_specs):
            return shard_map(
                body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=False,
            )

        # --- decode_step_rows: stacked generation step per shard ---------
        def _decode_rows_body(params_, tokens, kv_k, kv_v, length, active):
            full = lm.Caches(
                kv_k=kv_k, kv_v=kv_v, length=length,
                mamba_conv=None, mamba_ssm=None, shared_k=None, shared_v=None,
            )
            logits, new = lm.decode_step(self.cfg, params_, tokens, full)
            sel = active[None, :, None, None, None]
            return (
                logits,
                jnp.where(sel, new.kv_k, kv_k),
                jnp.where(sel, new.kv_v, kv_v),
                jnp.where(active, new.length, length),
            )

        sm_decode_rows = jax.jit(_sm(
            _decode_rows_body,
            in_specs=(rep, rows2, c_spec, c_spec, r_spec, r_spec),
            out_specs=(logits3, c_spec, c_spec, r_spec),
        ))

        # --- prefill_extend_rows: width-masked TEXT recompute per shard --
        def _extend_rows_body(params_, tokens, kv_k, kv_v, length, widths):
            caches = lm.Caches(
                kv_k=kv_k, kv_v=kv_v, length=length,
                mamba_conv=None, mamba_ssm=None, shared_k=None, shared_v=None,
            )
            logits, new = lm.prefill_extend(
                self.cfg, params_, tokens, caches, widths=widths
            )
            return logits, new.kv_k, new.kv_v, new.length

        sm_extend_leaves = _sm(
            _extend_rows_body,
            in_specs=(rep, rows2, c_spec, c_spec, r_spec, r_spec),
            out_specs=(logits3, c_spec, c_spec, r_spec),
        )

        def _extend_rows_outer(params_, tokens, caches, widths):
            logits, k, v, ln = sm_extend_leaves(
                params_, tokens, caches.kv_k, caches.kv_v, caches.length,
                widths,
            )
            return logits, caches._replace(kv_k=k, kv_v=v, length=ln)

        sm_extend_rows = jax.jit(_extend_rows_outer)

        # --- insert_runs: decoded-run landing per shard ------------------
        @functools.partial(jax.jit, static_argnames=("run_tokens",))
        def sm_insert_runs(kv_k, kv_v, length, kv_new, rows, starts, *,
                           run_tokens):
            body = functools.partial(
                kv_layout.insert_codec_runs_local,
                run_tokens=run_tokens, axis=ax,
            )
            return _sm(
                body,
                in_specs=(c_spec, c_spec, r_spec, rep, rep, rep),
                out_specs=(c_spec, c_spec, r_spec),
            )(kv_k, kv_v, length, kv_new, rows, starts)

        # --- row-pool restore / reset ------------------------------------
        def sm_restore_impl(kv_k, kv_v, length, k_row, v_row, row):
            body = functools.partial(kv_layout.restore_row_local, axis=ax)
            return _sm(
                body,
                in_specs=(c_spec, c_spec, r_spec, rep, rep, rep),
                out_specs=(c_spec, c_spec, r_spec),
            )(kv_k, kv_v, length, k_row, v_row, row)

        sm_restore_row = jax.jit(sm_restore_impl)

        def sm_reset_impl(kv_k, kv_v, length, rows):
            body = functools.partial(kv_layout.reset_rows_local, axis=ax)
            return _sm(
                body,
                in_specs=(c_spec, c_spec, r_spec, rep),
                out_specs=(c_spec, c_spec, r_spec),
            )(kv_k, kv_v, length, rows)

        sm_reset_rows = jax.jit(sm_reset_impl)

        # Dispatch: sharded callables serve caches whose row count divides
        # into whole shards (every scheduler cache, via ``cache_rows``);
        # anything else — batch-1 ServeSession caches, replication
        # experiments — falls back to the inherited single-device path.
        def _pick(base_fn, sharded_fn, batch_of):
            if self.n_shards == 1 and self.row_axis is None:
                return sharded_fn

            def call(*args, **kwargs):
                b = batch_of(*args, **kwargs)
                fn = sharded_fn if b % self.n_shards == 0 else base_fn
                return fn(*args, **kwargs)

            return call

        cache_b = lambda *a, **kw: a[2].shape[1]  # noqa: E731 (params, tokens, kv_k, ...)
        leading_b = lambda *a, **kw: a[0].shape[1]  # noqa: E731 (kv_k, ...)
        self._decode_rows = _pick(self._decode_rows, sm_decode_rows, cache_b)
        self._extend_rows = _pick(
            self._extend_rows, sm_extend_rows,
            lambda params_, tokens, caches, widths: caches.kv_k.shape[1],
        )
        self._insert_runs = _pick(self._insert_runs, sm_insert_runs, leading_b)
        self._restore_row = _pick(self._restore_row, sm_restore_row, leading_b)
        self._reset_rows = _pick(self._reset_rows, sm_reset_rows, leading_b)

    # ------------------------------------------------------------------

    def shard_of(self, row: int, batch: int) -> int:
        """Shard owning global ``row`` of a ``batch``-row sharded cache."""
        return int(row) // (int(batch) // self.n_shards)

    def empty_caches(self, batch: int) -> Caches:
        """A fresh batch-of-requests cache, row-sharded over the mesh.

        ``batch`` must divide into whole shards for the sharded layout
        (schedulers round up via :meth:`cache_rows`); other batches come
        back unsharded, served by the fallback single-device callables.
        """
        caches = kv_layout.alloc_caches(self.cfg, batch, self.capacity)
        if self.n_shards == 1 or batch % self.n_shards:
            return caches
        sh_cache = NamedSharding(self.mesh, self._cache_spec)
        sh_rows = NamedSharding(self.mesh, self._rows_spec)
        return caches._replace(
            kv_k=jax.device_put(caches.kv_k, sh_cache),
            kv_v=jax.device_put(caches.kv_v, sh_cache),
            length=jax.device_put(caches.length, sh_rows),
        )
