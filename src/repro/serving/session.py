"""Closed-loop adaptive serving session over real codec bitstreams.

Session / simulator split
-------------------------
``streaming/pipeline.simulate_stream`` is a *byte-count* model: it walks
Algorithm 1 (paper §5.3) over chunk metadata and a bandwidth trace, charging
``nbytes / decode_bytes_per_s`` for decode and a cost-model callable for
recompute, and never touches a bitstream.  :class:`ServeSession` is the
live counterpart of the same loop: identical per-chunk decisions against the
identical trace-driven virtual clock (both drive the *same* loop body —
``pipeline.StreamClock`` — with policies built by ``adaptation.make_policy``,
so decisions match by construction; the differential harness in
tests/test_session.py cross-checks them), but every bitstream chunk is
actually fetched from the :class:`~repro.streaming.storage.KVStore`,
validated against the plan (``codec.peek_chunk_header``: level, token count,
chunk identity), decoded through the fused batched path
(``codec.decode_chunks`` → ``Engine.decode_to_cache``), and every TEXT
chunk is actually recomputed with ``Engine.prefill_extend`` on top of the
already-materialized prefix.

Fetch/decode overlap uses the streamer's double-buffered
:class:`~repro.streaming.streamer.RunSegmenter`: fetched chunks accumulate
until ``max_run_tokens``, then the run is dispatched as one batched decode
(JAX dispatch is asynchronous on accelerator backends, so the decode of a
full buffer proceeds while the loop keeps fetching the next buffer).  A TEXT
chunk force-flushes the buffer first — its ``prefill_extend`` reads the
cache at its own token offset, so all earlier chunks must have landed; the
session asserts contiguous segment coverage with a host-side token counter
(reading ``caches.length`` back would sync the device per segment).

The session emits :class:`~repro.streaming.pipeline.ChunkTimeline`-
compatible records (``SessionResult.stream_result()``), so everything that
consumes simulator output — SLO accounting, figure scripts — reads session
output unchanged, and the simulator becomes a cross-check rather than the
only story.  Virtual time (``ttft_s``) stays simulator-comparable; realized
host time is reported separately (``wall_*``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as kvcodec
from repro.models.lm import Caches
from repro.serving.engine import Engine
from repro.streaming.adaptation import TEXT, make_policy
from repro.streaming.calibration import measured_decode_bytes_per_s
from repro.streaming.network import NetworkModel
from repro.streaming.pipeline import ChunkTimeline, StreamClock, StreamResult
from repro.streaming.streamer import CacheGenStreamer, PlanSegment, RunSegmenter

__all__ = ["ServeSession", "SessionResult"]


@dataclasses.dataclass
class SessionResult:
    """Outcome of one closed-loop context load.

    ``timelines``/``ttft_s`` use the trace-driven virtual clock (fetch) plus
    the simulator's compute charging — directly comparable to
    ``simulate_stream`` output.  ``caches`` is the real materialized serving
    cache; ``wall_*`` are realized host seconds (decode dispatch is
    asynchronous, so per-category times are dispatch times and
    ``wall_total_s`` — measured through a final blocking sync — is the
    end-to-end truth).
    """

    timelines: List[ChunkTimeline]
    configs: List[int]
    ttft_s: float
    slo_s: float
    caches: Caches
    wall_decode_s: float
    wall_recompute_s: float
    wall_total_s: float
    n_runs: int

    @property
    def slo_violated(self) -> bool:
        return self.ttft_s > self.slo_s

    @property
    def total_bytes(self) -> float:
        return sum(t.nbytes for t in self.timelines)

    def level_histogram(self) -> Dict[int, int]:
        """Realized streaming-config histogram (TEXT keyed as -1)."""
        hist: Dict[int, int] = {}
        for c in self.configs:
            hist[c] = hist.get(c, 0) + 1
        return hist

    def stream_result(self) -> StreamResult:
        """ChunkTimeline-compatible view for simulator-consuming code."""
        return StreamResult(
            timelines=list(self.timelines),
            ttft_s=self.ttft_s,
            configs=list(self.configs),
            slo_s=self.slo_s,
        )


class ServeSession:
    """Bandwidth-adaptive context load: decide → fetch → decode/recompute.

    One instance is reusable across requests (it holds no per-request
    state); each :meth:`run` builds a fresh policy and serving cache.
    """

    def __init__(
        self,
        streamer: CacheGenStreamer,
        engine: Engine,
        *,
        slo_s: float,
        recompute_s: Callable[[int, int], float],  # (chunk_tokens, prefix) -> s
        decode_bytes_per_s: Optional[float] = None,
        default_level: Optional[int] = None,
        allow_text: bool = True,
        adapt: bool = True,
        fixed_level: Optional[int] = None,
        hedge_after_s: Optional[float] = None,
        final_step_s: float = 0.0,
        max_run_tokens: Optional[int] = None,
        validate_blobs: bool = True,
    ):
        self.streamer = streamer
        self.engine = engine
        self.slo_s = slo_s
        self.recompute_s = recompute_s
        self.decode_bytes_per_s = (
            decode_bytes_per_s
            if decode_bytes_per_s is not None
            else measured_decode_bytes_per_s()
        )
        self.default_level = default_level
        self.allow_text = allow_text
        self.adapt = adapt
        self.fixed_level = fixed_level
        self.hedge_after_s = hedge_after_s
        self.final_step_s = final_step_s
        self.max_run_tokens = max_run_tokens
        self.validate_blobs = validate_blobs

    # ------------------------------------------------------------------

    def run(
        self,
        context_id: str,
        tokens: np.ndarray,  # (B, T) full context tokens (for TEXT chunks)
        network: NetworkModel,
        *,
        batch: int = 1,
        prior_throughput_gbps: Optional[float] = None,
        start_t: float = 0.0,
    ) -> SessionResult:
        store = self.streamer.store
        metas = store.meta(context_id)
        policy = make_policy(
            store.tables.config.n_levels,
            slo_s=self.slo_s,
            default_level=self.default_level,
            prior_throughput_gbps=prior_throughput_gbps,
            allow_text=self.allow_text,
            adapt=self.adapt,
            fixed_level=self.fixed_level,
        )
        caches = self.engine.empty_caches(batch)
        if caches.kv_k is None:
            raise ValueError(
                f"ServeSession needs a KV-cache family, got {self.engine.cfg.family}"
            )
        segmenter = RunSegmenter(self.max_run_tokens)
        # the simulator's per-chunk loop body, verbatim: decide -> fetch
        # (hedging included) -> charge the virtual compute window -> observe
        clock = StreamClock(
            policy=policy,
            network=network,
            decode_bytes_per_s=self.decode_bytes_per_s,
            recompute_s=self.recompute_s,
            hedge_after_s=self.hedge_after_s,
            start_t=start_t,
        )
        timelines: List[ChunkTimeline] = []
        state = _ExecState()
        wall0 = time.perf_counter()

        for i, m in enumerate(metas):
            tl = clock.step(metas, i)
            timelines.append(tl)

            # --- real work: fetch blob, segment, decode/recompute ----------
            if tl.config == TEXT:
                segs = segmenter.push(m, TEXT)
            else:
                blob = store.get_kv(context_id, m.chunk_idx, tl.config)
                if self.validate_blobs:
                    self._validate_blob(blob, m, tl.config)
                segs = segmenter.push(m, tl.config, blob)
            caches = self._execute(segs, caches, tokens, state)

        caches = self._execute(segmenter.flush(), caches, tokens, state)
        if caches.kv_k is not None:
            jax.block_until_ready(caches.kv_k)
        wall_total = time.perf_counter() - wall0
        return SessionResult(
            timelines=timelines,
            configs=[t.config for t in timelines],
            ttft_s=clock.ttft_s(timelines, self.final_step_s),
            slo_s=self.slo_s,
            caches=caches,
            wall_decode_s=state.decode_s,
            wall_recompute_s=state.recompute_s,
            wall_total_s=wall_total,
            n_runs=state.runs,
        )

    # ------------------------------------------------------------------

    def _validate_blob(self, blob: bytes, meta, level: int) -> None:
        h = kvcodec.peek_chunk_header(blob)
        # chunk_idx is present on store-written blobs; standalone encodes
        # (no identity known) skip that part of the check.  Missing v1 keys
        # (foreign/corrupt producer) are a mismatch, not a KeyError.
        idx = h.get("chunk_idx", meta.chunk_idx)
        if (
            h.get("level") != level
            or h.get("n_tokens") != meta.n_tokens
            or idx != meta.chunk_idx
        ):
            raise ValueError(
                f"storage returned a mismatched bitstream for chunk "
                f"{meta.chunk_idx}: header level={h.get('level')} "
                f"tokens={h.get('n_tokens')} chunk_idx={h.get('chunk_idx')}, "
                f"plan wants level={level} tokens={meta.n_tokens}"
            )

    def _execute(
        self,
        segs: List[PlanSegment],
        caches: Caches,
        tokens: np.ndarray,
        state: "_ExecState",
    ) -> Caches:
        store = self.streamer.store
        for seg in segs:
            # positional bookkeeping: every segment must start exactly where
            # the materialized prefix ends (host-side counter — reading
            # caches.length here would force a device sync per segment and
            # stall the decode/fetch overlap)
            if seg.start != state.offset:
                raise AssertionError(
                    f"segment starts at token {seg.start} but {state.offset} "
                    "tokens are materialized; decoded/recomputed chunk "
                    "interleaving lost sync"
                )
            state.offset = seg.end
            if seg.kind == "text":
                t0 = time.perf_counter()
                _, caches = self.engine.prefill_extend(
                    jnp.asarray(tokens[:, seg.start : seg.end], jnp.int32), caches
                )
                state.recompute_s += time.perf_counter() - t0
            else:
                t0 = time.perf_counter()
                kv_run = kvcodec.decode_chunks(
                    seg.blobs, store.tables, out_dtype=caches.kv_k.dtype
                )
                caches = self.engine.decode_to_cache(caches, kv_run, seg.start)
                state.decode_s += time.perf_counter() - t0
                state.runs += 1
        return caches


@dataclasses.dataclass
class _ExecState:
    """Mutable per-run execution state: wall-clock accumulators plus the
    positional-bookkeeping cursor (`offset` = tokens materialized so far)."""

    decode_s: float = 0.0
    recompute_s: float = 0.0
    runs: int = 0
    offset: int = 0
