"""Closed-loop adaptive serving session over real codec bitstreams.

Session / simulator split
-------------------------
``streaming/pipeline.simulate_stream`` is a *byte-count* model: it walks
Algorithm 1 (paper §5.3) over chunk metadata and a bandwidth trace, charging
``nbytes / decode_bytes_per_s`` for decode and a cost-model callable for
recompute, and never touches a bitstream.  :class:`ServeSession` is the
live counterpart of the same loop: identical per-chunk decisions against the
identical trace-driven virtual clock (both drive the *same* loop body —
``pipeline.StreamClock`` — with policies built by ``adaptation.make_policy``,
so decisions match by construction; the differential harness in
tests/test_session.py cross-checks them), but every bitstream chunk is
actually fetched from the :class:`~repro.streaming.storage.KVStore`,
validated against the plan (``codec.peek_chunk_header``: level, token count,
chunk identity), decoded through the fused batched path
(``codec.decode_chunks`` → ``Engine.decode_to_cache``), and every TEXT
chunk is actually recomputed with ``Engine.prefill_extend`` on top of the
already-materialized prefix.

Session / scheduler split (PR 3)
--------------------------------
The per-chunk loop body lives in :class:`SessionTask`: one in-flight context
load that owns its policy, ``StreamClock``, trace, and double-buffered
:class:`~repro.streaming.streamer.RunSegmenter`, and that ``step()``-s one
chunk at a time, emitting typed *work items* — :class:`RunWork` (a run of
fetched bitstream chunks to decode and land at a token offset) and
:class:`TextWork` (a text chunk to recompute).  :class:`ServeSession` is the
single-request consumer: it executes each item immediately against its own
cache (``decode_chunks`` → ``decode_to_cache`` / ``prefill_extend``).  The
multi-request consumer is ``serving.scheduler.ConcurrentScheduler``, which
steps N tasks against one shared Engine and drains their work items into
*cross-request batched* executions; at N=1 it degenerates to exactly this
file's loop (the differential tests in tests/test_scheduler.py hold it to
bit-exactness).  Decisions stay per-request either way — each task keeps
its own clock and policy, so every load remains simulator-differential.

Transport split (ISSUE 4)
-------------------------
Bitstream fetches go through a pluggable
:class:`~repro.streaming.transport.Transport`: the task *issues* a chunk's
fetch (``fetch_run`` → cancellable handle, I/O on a worker thread) in one
step and *resolves* it in the next, so the returned work items' decode
dispatches genuinely overlap the in-flight fetch — and a hedged duplicate
fetch is real duplicated I/O whose loser is cancelled, with the losing
attempt's bytes surfaced as ``SessionResult.duplicate_bytes``.  The default
transport is :class:`~repro.streaming.transport.SimTransport` over the
request's ``NetworkModel``, whose completion timing is the simulator's own
``fetch_outcome`` arithmetic — which is what keeps the session
differential-exact against ``simulate_stream`` (same trace in, same
decisions out).  TEXT chunks never touch storage; their modeled transfer is
charged straight on the virtual clock (``StreamClock.virtual_fetch``).

Fetch/decode overlap additionally uses the segmenter's double buffering:
fetched chunks accumulate until ``max_run_tokens``, then the run is
dispatched as one batched decode (JAX dispatch is asynchronous on
accelerator backends, so the decode of a full buffer proceeds while the
loop keeps fetching the next buffer).  A TEXT chunk force-flushes the
buffer first — its ``prefill_extend`` reads the cache at its own token
offset, so all earlier chunks must have landed; the task asserts contiguous
segment coverage with a host-side token counter (reading ``caches.length``
back would sync the device per segment).

Fault tolerance (ISSUE 6)
-------------------------
With a ``retry_policy`` (:class:`~repro.streaming.transport.RetryPolicy`)
the task survives injected and real fetch faults: every resolved blob is
checksum-gated before decode, failed attempts are classified
(``transport.classify_failure``), retried with exponential backoff charged
to the ``StreamClock`` (Algorithm-1 re-planning sees the lost time), then
the chunk is re-decided with the failed level and everything finer
excluded — coarser levels, ultimately TEXT recompute — and only when every
configuration is exhausted does the task finish with a clean
``SessionResult.status == "failed"`` carrying the realized prefix.  Without
a policy the legacy behavior is unchanged: the first fetch error raises
straight through ``run()``.

Byte-range resume (ISSUE 8)
---------------------------
With a range-capable transport (``supports_range``), a fetch that fails,
times out, is preempted, or is cancelled mid-chunk no longer loses its
realized bytes.  The task verifies the partial payload against the chunk's
out-of-band segment index (``bitstream.SegmentIndex.verified_prefix``) and
carries the verified prefix across attempts — and across suspend/resume —
in a per-chunk salvage slot.  The next attempt then issues a *byte-range*
fetch: ``resume`` (same level — refetch only ``[verified_end, total)``),
``compose`` (degraded to a different lossy level — keep the level-invariant
anchor segment, refetch only that level's delta suffix, and splice
``synthesized head + salvaged anchor + new suffix`` into a blob that must
pass the whole-chunk CRC gate before decode), or ``full`` (nothing
salvageable — the PR 6 behavior).  ``adaptation.salvage_credit`` tells
Algorithm 1 what the prefix is worth per level so re-decisions price only
the bytes still owed.  With ``replan_factor`` set, a fetch running far past
the live throughput estimate is cancelled *mid-chunk* on the virtual clock
(§C.1): the prefix is salvaged, the collapsed throughput is observed, and
``choose_config`` re-decides the remainder — possibly at a coarser level
(compose) or as TEXT recompute (whole chunk: rANS lanes span the full token
axis, so a byte prefix cannot shorten the recompute).  Accounting
reconciles per chunk: ``salvaged_bytes + refetched_bytes == wire_bytes``.

Load → generate lifecycle (ISSUE 9)
-----------------------------------
A task's life no longer ends at TTFT.  Loading a context is *phase one*:
``SessionTask`` owns the row while chunks stream in, and ``done`` marks the
instant the full context is resident — the ``SessionResult`` snapshot
(decisions, timelines, the extracted cache) is frozen right there, so
everything above stays exactly the PR 8 story.  When the request carries a
:class:`~repro.serving.generation.GenerationSpec`, the continuous scheduler
then keeps the row and hands it to a
:class:`~repro.serving.generation.GenerationTask` — *phase two*: the
session's loaded KV becomes the prefix that batched
``Engine.decode_step_rows`` steps extend token by token, stacked with every
other generating row and charged to the same virtual clock the loads run
on.  Suspension is phase-aware: a *loading* row suspends through
``SessionTask.suspend`` (fetch handle cancelled, realized chunk rows
snapshotted), a *generating* row through ``GenerationTask.suspend`` (the
``RowSnapshot`` spans context + emitted tokens and the next input token
rides host-side) — both re-enter the same admission queue and resume
bit-exactly.  A finished ``SessionTask`` is never mutated by phase two:
generation timing and tokens live on the scheduler's ``RequestTimeline``
(``tokens_out`` / ``token_ts`` / TPOT), not on the session result.

The session emits :class:`~repro.streaming.pipeline.ChunkTimeline`-
compatible records (``SessionResult.stream_result()``), so everything that
consumes simulator output — SLO accounting, figure scripts — reads session
output unchanged, and the simulator becomes a cross-check rather than the
only story.  Virtual time (``ttft_s``) stays simulator-comparable; realized
host time is reported separately (``wall_*``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitstream
from repro.core import codec as kvcodec
from repro.models.lm import Caches
from repro.serving.engine import Engine
from repro.streaming.adaptation import (
    TEXT,
    NoFeasibleConfigError,
    make_policy,
    salvage_credit,
)
from repro.streaming.calibration import measured_decode_bytes_per_s
from repro.streaming.network import NetworkModel
from repro.streaming.pipeline import ChunkTimeline, StreamClock, StreamResult
from repro.streaming.streamer import CacheGenStreamer, PlanSegment, RunSegmenter
from repro.streaming.transport import (
    RetryPolicy,
    Salvage,
    SimTransport,
    Transport,
    classify_failure,
)

__all__ = [
    "ServeSession",
    "SessionResult",
    "SessionTask",
    "RunWork",
    "TextWork",
    "validate_blob",
]

# level 0 is lossless-after-8bit: its anchor stream uses different rANS
# tables, so lossy anchor bytes never compose with it (and vice versa)
_LOSSLESS_LEVEL = 0


@dataclasses.dataclass
class SessionResult:
    """Outcome of one closed-loop context load.

    ``timelines``/``ttft_s`` use the trace-driven virtual clock (fetch) plus
    the simulator's compute charging — directly comparable to
    ``simulate_stream`` output.  ``caches`` is the real materialized serving
    cache; ``wall_*`` are realized host seconds (decode dispatch is
    asynchronous, so per-category times are dispatch times and
    ``wall_total_s`` — measured through a final blocking sync — is the
    end-to-end truth).
    """

    timelines: List[ChunkTimeline]
    configs: List[int]
    ttft_s: float
    slo_s: float
    caches: Caches
    wall_decode_s: float
    wall_recompute_s: float
    wall_total_s: float
    n_runs: int
    # fault tolerance (ISSUE 6): "ok" or "failed"; a failed load's caches
    # hold only the realized prefix and ttft_s is +inf (an SLO miss)
    status: str = "ok"
    failure: Optional[str] = None
    n_retries: int = 0  # failed attempts that were retried
    n_degrades: int = 0  # level re-decisions forced by exhausted retries
    n_fault_text: int = 0  # chunks that fell all the way back to TEXT
    n_failed_attempts: int = 0  # every fetch attempt that did not deliver
    fault_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    # byte-range resume (ISSUE 8): verified partial bytes reused instead of
    # refetched, byte-range continuations issued, and §C.1 mid-chunk
    # cancel→re-plan events.  wire/refetched are the full realized ledger
    # (clean chunks contribute their blob size to both); per chunk,
    # salvaged + refetched == wire.
    salvaged_bytes: float = 0.0
    n_resumes: int = 0
    n_mid_chunk_replans: int = 0
    refetched_bytes: float = 0.0
    wire_bytes: float = 0.0

    @property
    def failed(self) -> bool:
        return self.status != "ok"

    @property
    def slo_violated(self) -> bool:
        return self.ttft_s > self.slo_s

    @property
    def total_bytes(self) -> float:
        return sum(t.nbytes for t in self.timelines)

    @property
    def duplicate_bytes(self) -> float:
        """Wire bytes the cancelled hedge losers transferred (hedged I/O
        overhead; 0 when no hedge fired)."""
        return sum(t.duplicate_bytes for t in self.timelines)

    @property
    def n_hedged(self) -> int:
        return sum(1 for t in self.timelines if t.hedged)

    @property
    def n_cold_hits(self) -> int:
        """Chunk fetches that touched the tiered store's cold tier (their
        slower realized timing already fed the throughput estimator)."""
        return sum(1 for t in self.timelines if t.cold_hit)

    def level_histogram(self) -> Dict[int, int]:
        """Realized streaming-config histogram (TEXT keyed as -1)."""
        hist: Dict[int, int] = {}
        for c in self.configs:
            hist[c] = hist.get(c, 0) + 1
        return hist

    def stream_result(self) -> StreamResult:
        """ChunkTimeline-compatible view for simulator-consuming code."""
        return StreamResult(
            timelines=list(self.timelines),
            ttft_s=self.ttft_s,
            configs=list(self.configs),
            slo_s=self.slo_s,
        )


# ---------------------------------------------------------------------------
# Work items: the unit of execution shared by session and scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunWork:
    """A run of consecutive fetched bitstream chunks, ready to decode and
    land in the cache at ``[start, end)`` of row ``row``."""

    row: int
    start: int
    end: int
    blobs: List[bytes]
    tables: kvcodec.CodecTables

    @property
    def n_tokens(self) -> int:
        return self.end - self.start


@dataclasses.dataclass
class TextWork:
    """A text chunk ready to recompute (``prefill_extend``) at its own
    token offset on row ``row``.  ``tokens`` is the (batch, Tc) slice."""

    row: int
    start: int
    end: int
    tokens: np.ndarray

    @property
    def n_tokens(self) -> int:
        return self.end - self.start


def validate_blob(blob: bytes, meta, level: int) -> None:
    """Reject a fetched bitstream that does not match its plan entry.

    The checksum gate runs first: a corrupted blob raises
    ``bitstream.IntegrityError`` here, *before* any header parse or decode
    touches the bytes (corruption is detected, never interpreted).
    """
    kvcodec.verify_chunk(blob)
    h = kvcodec.peek_chunk_header(blob)
    # chunk_idx is present on store-written blobs; standalone encodes
    # (no identity known) skip that part of the check.  Missing v1 keys
    # (foreign/corrupt producer) are a mismatch, not a KeyError.
    idx = h.get("chunk_idx", meta.chunk_idx)
    if (
        h.get("level") != level
        or h.get("n_tokens") != meta.n_tokens
        or idx != meta.chunk_idx
    ):
        raise ValueError(
            f"storage returned a mismatched bitstream for chunk "
            f"{meta.chunk_idx}: header level={h.get('level')} "
            f"tokens={h.get('n_tokens')} chunk_idx={h.get('chunk_idx')}, "
            f"plan wants level={level} tokens={meta.n_tokens}"
        )


@dataclasses.dataclass
class _ChunkSalvage:
    """Verified partial bytes of the *current* chunk, carried across fetch
    attempts — and across suspend/resume — until the chunk lands (or falls
    back to TEXT) and :meth:`SessionTask._advance` clears it.

    ``data`` always starts at blob offset 0 and is trimmed to
    ``verified_end`` (a segment boundary of ``index``); bytes past the last
    complete segment are never kept — they re-travel on the resume fetch.
    """

    level: int  # encoding level the salvaged bytes belong to
    data: bytes  # verified prefix, from blob offset 0
    verified_end: int  # == len(data); a SegmentIndex boundary
    index: bitstream.SegmentIndex  # full-blob index at `level`
    total: int  # full blob length at `level`


class SessionTask:
    """One in-flight context load, stepped one chunk at a time.

    Owns everything *per-request*: the Algorithm 1 policy, the trace-driven
    ``StreamClock`` (decide → fetch → charge compute → observe), the
    double-buffered segmenter, and the positional-bookkeeping cursor.  Each
    :meth:`step` advances one chunk and returns the work items whose inputs
    are now fully resolved (possibly none while the double buffer fills).
    The caller decides *how* to execute them: ``ServeSession`` runs each
    immediately; the concurrent scheduler batches items from many tasks.

    ``compute_scale`` (optional callable) is the live contention hook: the
    clock stretches this task's charged decode/recompute seconds — and the
    remaining-recompute estimate feeding ``choose_config`` — by its current
    value (``pipeline.ContentionModel``), so adaptation under a loaded
    engine sheds compute (TEXT) work exactly like it sheds bytes under a
    collapsing link.

    Stepping is two-phase per bitstream chunk: one :meth:`step` decides the
    chunk's config and *issues* its fetch through the transport (returning
    no work yet — the I/O is now in flight on a worker thread), the next
    resolves the handle, accounts the realized timing on the clock, and
    emits the work items whose inputs are complete.  TEXT chunks resolve in
    a single step (no storage I/O).

    Preemption (ISSUE 5): a task is resumable mid-load.  :meth:`suspend`
    cancels the in-flight fetch handle (an un-accounted chunk simply gets
    re-decided later — ``decide`` mutates nothing, so rewinding is dropping
    ``_pending``) and freezes the task; :meth:`resume` hands it a new cache
    row and advances the clock frontiers to the resumption instant, with
    everything realized so far — timelines, policy state, the segmenter's
    half-filled buffer — carried across untouched.  The *cache* side of a
    suspension (saving/restoring the realized row prefix) belongs to the
    continuous scheduler via ``Engine.save_row``/``restore_row``.
    """

    def __init__(
        self,
        session: "ServeSession",
        context_id: str,
        tokens: np.ndarray,
        network: NetworkModel,
        *,
        row: int = 0,
        prior_throughput_gbps: Optional[float] = None,
        start_t: float = 0.0,
        compute_scale: Optional[Callable[[], float]] = None,
        text_scale: Optional[Callable[[], float]] = None,
        transport: Optional[Transport] = None,
        label: Optional[str] = None,
    ):
        self.session = session
        self.context_id = context_id
        self.tokens = tokens
        self.row = row
        self.label = label if label is not None else context_id
        store = session.streamer.store
        self.store = store
        self.metas = store.meta(context_id)
        policy = make_policy(
            store.tables.config.n_levels,
            slo_s=session.slo_s,
            default_level=session.default_level,
            prior_throughput_gbps=prior_throughput_gbps,
            allow_text=session.allow_text,
            adapt=session.adapt,
            fixed_level=session.fixed_level,
        )
        # the simulator's per-chunk loop body, verbatim: decide -> fetch
        # (hedging included) -> charge the virtual compute window -> observe
        self.clock = StreamClock(
            policy=policy,
            network=network,
            decode_bytes_per_s=session.decode_bytes_per_s,
            recompute_s=session.recompute_s,
            hedge_after_s=session.hedge_after_s,
            start_t=start_t,
            compute_scale=compute_scale,
            text_scale=text_scale,
        )
        self.segmenter = RunSegmenter(session.max_run_tokens)
        # the fetch path: explicit transport, or the session's; default is
        # the simulator-exact SimTransport over this request's NetworkModel
        t = transport if transport is not None else session.transport
        self.transport: Transport = (
            t if t is not None else SimTransport(store, network)
        )
        self.timelines: List[ChunkTimeline] = []
        self._i = 0
        self._offset = 0  # tokens whose work items have been emitted
        self._pending = None  # (handle, meta, config, nbytes, scale) in flight
        # preemption bookkeeping (continuous scheduler)
        self.suspended_at: Optional[float] = None
        self.n_preemptions = 0
        self.n_resumes = 0
        self.cancelled_fetches: List[tuple] = []  # (chunk_idx, config)
        # fault-tolerance bookkeeping (ISSUE 6; active when the session has
        # a retry_policy — without one the legacy raise-through path runs)
        self._failure: Optional[str] = None
        self._banned: set = set()  # configs excluded for the current chunk
        self._attempt = 0  # attempts at the current chunk's current config
        self._chunk_retries = 0  # retries across the current chunk's configs
        self._issue_wall: Optional[float] = None
        self.n_retries = 0
        self.n_degrades = 0
        self.n_fault_text = 0
        self.n_failed_attempts = 0
        self.fault_counts: Dict[str, int] = {}
        # byte-range resume (ISSUE 8).  _measure: the transport computes
        # segment indexes so partial deliveries are *measurable* (wire
        # ledger); _resumable: verified prefixes are actually *reused*
        # (resume/compose byte-range refetches) instead of thrown away —
        # session.resume_fetch=False keeps the PR 6 whole-blob retry
        # behavior while still measuring the wire, which is what the
        # resume-vs-whole-blob benchmark compares.
        self._measure = (
            session.retry_policy is not None
            and bool(getattr(self.transport, "supports_range", False))
        )
        self._resumable = self._measure and bool(
            getattr(session, "resume_fetch", True)
        )
        self._salvage: Optional[_ChunkSalvage] = None
        self._chunk_wire = 0.0  # realized wire bytes of past attempts
        self._pending_mode = "full"  # issue mode of the in-flight fetch
        self._pending_range: Optional[tuple] = None  # (offset, total)
        self._replanned = False  # one mid-chunk re-plan per chunk
        self.n_fetch_resumes = 0
        self.n_mid_chunk_replans = 0
        self.salvaged_bytes = 0.0
        self.refetched_bytes = 0.0
        self.wire_bytes = 0.0

    @property
    def done(self) -> bool:
        if self._failure is not None:
            return True
        return self._i >= len(self.metas) and self._pending is None

    @property
    def failed(self) -> bool:
        return self._failure is not None

    @property
    def fetch_ready(self) -> bool:
        """True when :meth:`step` would not block on in-flight wall-real
        I/O: no fetch pending, the pending handle already completed, or the
        transport resolves on the virtual clock (blocking costs ~no wall
        time).  The concurrent scheduler uses this to keep a straggling
        socket fetch from convoying other sessions' ready work."""
        if self._pending is None or self._pending[0].done():
            return True
        return not getattr(self.transport, "realtime", False)

    @property
    def next_fetch_t(self) -> float:
        """When this task's next chunk fetch would start (virtual clock)."""
        return self.clock.fetch_t

    @property
    def suspended(self) -> bool:
        return self.suspended_at is not None

    @property
    def realized_tokens(self) -> int:
        """Tokens whose work items have been emitted (and, under the
        schedulers' execute-in-emitting-round discipline, executed) — the
        prefix a row snapshot must cover at suspension."""
        return self._offset

    @property
    def deadline_t(self) -> float:
        """Absolute virtual instant of this request's TTFT SLO."""
        return self.clock.start_t + self.session.slo_s

    def begin_at(self, t: float) -> None:
        """Advance the clock's busy-until frontiers to the admission instant.

        A request admitted later than it arrived (``start_t``) keeps its SLO
        anchored at arrival — ``elapsed_s`` then includes the queue wait —
        but cannot fetch or compute before it holds a row.  No-op when
        ``t <= start_t`` (immediate admission), which is what keeps the
        all-arrivals-at-t0 path bit-identical to the wave scheduler.
        """
        self.clock.fetch_t = max(self.clock.fetch_t, float(t))
        self.clock.compute_t = max(self.clock.compute_t, float(t))

    def peek_pending_end_t(self) -> Optional[float]:
        """Completion instant of the in-flight fetch, when knowable without
        blocking on wall-real I/O: the handle already completed, or the
        transport resolves on the virtual clock.  ``None`` while a wall-real
        fetch is still streaming (its completion is genuinely unknown) or
        when nothing is pending; a failed fetch also reads ``None`` here —
        its error surfaces through :meth:`step`."""
        if self._pending is None:
            return None
        handle = self._pending[0]
        if not handle.done() and getattr(self.transport, "realtime", False):
            return None
        try:
            return handle.result().end_t
        except Exception:
            return None

    def horizon_t(self) -> float:
        """Virtual instant this task next acts: its pending fetch's
        completion when peekable, else its next fetch start — the continuous
        scheduler's admission frontier is the minimum of these over the live
        set."""
        end = self.peek_pending_end_t()
        return self.next_fetch_t if end is None else end

    def suspend(self, now_t: float) -> None:
        """Preempt this task: cancel the in-flight fetch (real I/O stops;
        the chunk is re-decided from scratch on resume) and mark the task
        suspended.  The caller owns the row snapshot (``Engine.save_row``
        over :attr:`realized_tokens`) and the row's release."""
        if self.done:
            raise RuntimeError(
                f"preempting request {self.label!r}: session already "
                f"finished (all {len(self.metas)} chunks realized)"
            )
        if self.suspended:
            raise RuntimeError(
                f"preempting request {self.label!r}: already suspended at "
                f"t={self.suspended_at:.6f}"
            )
        if self._pending is not None:
            handle, m, config, _nbytes, _scale = self._pending
            mode = self._pending_mode
            self._pending = None
            if self._measure:
                # the cancelled fetch's realized prefix survives the
                # preemption: verify it now and park it in the salvage
                # slot — the post-resume re-decision resumes from it
                salv = handle.cancel(float(now_t))
                self._absorb_salvage(salv, config, mode)
            else:
                handle.cancel()
            self.cancelled_fetches.append((m.chunk_idx, config))
        self.suspended_at = float(now_t)
        self.n_preemptions += 1

    def resume(self, row: int, resume_t: float) -> None:
        """Take a (possibly different) row and continue from the suspended
        state: the next :meth:`step` re-decides the interrupted chunk at the
        resumption instant — elapsed SLO time includes the suspension."""
        if not self.suspended:
            state = "finished" if self.done else f"live on row {self.row}"
            raise RuntimeError(
                f"resuming request {self.label!r}: not suspended "
                f"(state: {state})"
            )
        self.row = row
        self.suspended_at = None
        self.n_resumes += 1
        self.begin_at(resume_t)

    def _advance(self, m, config: int, blob: Optional[bytes]) -> List[object]:
        """Segment one accounted chunk and emit any completed work items."""
        if config == TEXT:
            segs = self.segmenter.push(m, TEXT)
        else:
            segs = self.segmenter.push(m, config, blob)
        self._i += 1
        # per-chunk fault state resets once the chunk lands
        self._banned.clear()
        self._attempt = 0
        self._chunk_retries = 0
        self._salvage = None
        self._chunk_wire = 0.0
        self._replanned = False
        self._pending_mode = "full"
        self._pending_range = None
        if self._i == len(self.metas):
            segs = segs + self.segmenter.flush()
        return [self._to_work(s) for s in segs]

    def step(self) -> List[object]:
        """Advance one phase: resolve the in-flight fetch, or decide the
        next chunk (issuing its fetch through the transport).

        Returns the work items now ready to execute (in order); a step that
        only *issues* I/O returns none.  The last chunk also flushes the
        segmenter, so once :attr:`done` every item has been emitted.
        """
        if self.suspended:
            raise RuntimeError(
                f"stepping request {self.label!r}: suspended at "
                f"t={self.suspended_at:.6f}; resume() it onto a row first"
            )
        policy = self.session.retry_policy
        if self._pending is not None:
            handle, m, config, nbytes, scale = self._pending
            if policy is None:
                # legacy path: any fetch failure raises straight through
                self._pending = None
                res = handle.result()
                if self.session.validate_blobs:
                    validate_blob(res.blobs[0], m, config)
                tl = self.clock.account(m, config, nbytes, res, scale)
                tl.cold_hit = getattr(res, "cold_entries", 0) > 0
                self.timelines.append(tl)
                return self._advance(m, config, res.blobs[0])
            return self._resolve_with_policy(
                policy, handle, m, config, nbytes, scale
            )
        if self.done:
            return []
        i = self._i
        m = self.metas[i]
        if policy is not None and (self._banned or self._salvage is not None):
            try:
                config, nbytes, scale = self.clock.decide(
                    self.metas,
                    i,
                    exclude=self._banned,
                    credit=self._credit(m),
                )
            except NoFeasibleConfigError as e:
                return self._fail(e)
            if config == TEXT and self._banned:
                self.n_fault_text += 1
        else:
            config, nbytes, scale = self.clock.decide(self.metas, i)
        if config == TEXT:
            # text is already local — its transfer is modeled, not fetched
            outcome = self.clock.virtual_fetch(nbytes, m.chunk_idx)
            tl = self.clock.account(m, config, nbytes, outcome, scale)
            if policy is not None:
                # any salvaged bitstream bytes are dead weight here (TEXT
                # recomputes the whole chunk); the ledger still counts them
                wire = self._chunk_wire + float(nbytes)
                if self._chunk_wire > 0.0 or self._replanned:
                    tl.wire_bytes = wire
                    tl.refetched_bytes = wire
                    tl.replanned = self._replanned
                self.wire_bytes += wire
                self.refetched_bytes += wire
            self.timelines.append(tl)
            return self._advance(m, TEXT, None)
        self._issue_fetch(m, config, nbytes, scale)
        return []

    def _issue_fetch(self, m, config: int, nbytes: float, scale: float) -> None:
        byte_range = None
        mode = "full"
        sv = self._salvage
        if sv is not None and self._resumable:
            if config == sv.level and 0 < sv.verified_end < sv.total:
                # same level: refetch only the unverified suffix
                byte_range = (sv.verified_end, None)
                mode = "resume"
            elif (
                config != sv.level
                and config != _LOSSLESS_LEVEL
                and sv.level != _LOSSLESS_LEVEL
                and sv.index.anchor_end > sv.index.head.end
                and sv.verified_end >= sv.index.anchor_end
            ):
                # degraded to another lossy level with the whole anchor in
                # hand: keep it, refetch only that level's delta suffix.
                # The range is expressed in the *fine* blob's coordinates;
                # lossy heads re-pack to identical bytes (only the level
                # int changes, same width), so the offsets coincide — and
                # if a pathological table ever breaks that, the composed
                # blob fails the whole-chunk CRC gate and the chunk falls
                # back to a full refetch.
                byte_range = (sv.index.anchor_end, None)
                mode = "compose"
        kw = {}
        if self._measure:
            kw["resumable"] = True
            if byte_range is not None:
                kw["byte_range"] = byte_range
        handle = self.transport.fetch_run(
            self.context_id,
            [(m.chunk_idx, config)],
            start_t=self.clock.fetch_t,
            hedge_after_s=self.session.hedge_after_s,
            **kw,
        )
        self._pending = (handle, m, config, nbytes, scale)
        self._pending_mode = mode
        self._pending_range = (
            (byte_range[0], sv.total) if byte_range is not None else None
        )
        if mode != "full":
            self.n_fetch_resumes += 1
        if self.session.retry_policy is not None:
            self._issue_wall = time.perf_counter()

    # -- fault-tolerant resolve (retry_policy set) -------------------------

    def _resolve_with_policy(
        self, policy: RetryPolicy, handle, m, config, nbytes, scale
    ) -> List[object]:
        realtime = bool(getattr(self.transport, "realtime", False))
        timeout = policy.wall_timeout_s if realtime else None
        mode = self._pending_mode
        try:
            res = handle.result(timeout=timeout)
        except Exception as e:
            return self._on_fetch_failure(e, handle, m, config, nbytes, scale)
        # §C.1 mid-chunk re-plan (virtual clock only): the fetch ran far
        # past what the live estimator predicted — a client watching the
        # socket would have cancelled partway in, kept the verified prefix,
        # and re-decided the remainder
        rf = getattr(self.session, "replan_factor", None)
        est = self.clock.policy.throughput_gbps
        if (
            rf is not None
            and not realtime
            and self._resumable
            and not self._replanned
            and est is not None
            and est > 0.0
        ):
            exp_bytes = (
                float(nbytes)
                if self._pending_range is None
                else float(max(self._pending_range[1] - self._pending_range[0], 1))
            )
            predicted = (
                float(getattr(self.clock.network, "rtt_s", 0.0))
                + exp_bytes * 8.0 / (est * 1e9)
            )
            if res.end_t - res.start_t > rf * predicted:
                return self._replan_mid_chunk(
                    handle, m, config, res, mode, rf * predicted
                )
        # assemble: splice the salvaged prefix in front of a resumed or
        # composed suffix before any verification touches the bytes
        raw = res.blobs[0]
        sv = self._salvage
        blob: Optional[bytes] = raw
        credit_used = 0.0
        if mode == "resume" and sv is not None:
            blob = sv.data[: sv.verified_end] + raw
            credit_used = float(sv.verified_end)
        elif mode == "compose" and sv is not None:
            try:
                head = self._synthesize_head(sv, config, res.seg_index)
                blob = (
                    head
                    + sv.data[sv.index.head.end : sv.index.anchor_end]
                    + raw
                )
                credit_used = float(sv.index.anchor_end - sv.index.head.end)
            except Exception:
                blob = None  # unreadable salvage header — integrity failure
        attempt_wire = float(res.nbytes)
        try:
            if blob is None:
                raise bitstream.IntegrityError(
                    f"chunk {m.chunk_idx}: could not compose salvaged "
                    f"anchor with the level-{config} delta suffix"
                )
            # checksum first (corruption is detected, never interpreted),
            # then the plan match — even with validate_blobs off, corrupt
            # bytes must not reach the rANS decoder.  For resume/compose
            # this whole-blob CRC is also the composition gate: a spliced
            # blob that does not hash like a clean whole-blob fetch never
            # reaches decode.
            kvcodec.verify_chunk(blob)
            if self.session.validate_blobs:
                validate_blob(blob, m, config)
        except ValueError as e:
            if mode != "full":
                # the salvage poisoned the assembly: drop it so the retry
                # ladder refetches the whole blob from byte 0
                self._salvage = None
            self._chunk_wire += attempt_wire
            return self._on_fetch_failure(
                e, handle, m, config, nbytes, scale, res=res, harvest=False
            )
        if (
            policy.timeout_s is not None
            and not realtime
            and res.end_t - res.start_t > policy.timeout_s
        ):
            # virtual-clock stall past the attempt budget: the client would
            # have given up timeout_s in, not waited out the whole stall
            return self._on_fetch_failure(
                TimeoutError(
                    f"fetch of chunk {m.chunk_idx} level {config} took "
                    f"{res.end_t - res.start_t:.3f}s virtual "
                    f"(> timeout {policy.timeout_s}s)"
                ),
                handle, m, config, nbytes, scale, res=res,
            )
        self._pending = None
        tl = self.clock.account(m, config, nbytes, res, scale)
        tl.n_retries = self._chunk_retries
        tl.fault_fallback = bool(self._banned)
        tl.cold_hit = getattr(res, "cold_entries", 0) > 0
        if self._measure:
            wire = self._chunk_wire + attempt_wire
            if self._chunk_wire > 0.0 or mode != "full" or self._replanned:
                tl.wire_bytes = wire
                tl.salvaged_bytes = credit_used
                tl.refetched_bytes = wire - credit_used
                tl.resumed = mode != "full"
                tl.replanned = self._replanned
            self.salvaged_bytes += credit_used
            self.wire_bytes += wire
            self.refetched_bytes += wire - credit_used
        self.timelines.append(tl)
        return self._advance(m, config, blob)

    def _on_fetch_failure(
        self, err, handle, m, config, nbytes, scale, *, res=None, harvest=True
    ) -> List[object]:
        """Classify a failed attempt; retry, degrade, or fail the session.

        Before the retry ladder runs, the attempt's realized bytes are
        harvested (ISSUE 8): from the error's attached :class:`Salvage`
        (truncate faults carry one), or by asking the handle for the prefix
        realized at the failure/timeout instant.  ``harvest=False`` is the
        verification-failure path — the bytes arrived whole but are
        untrustworthy, so only the wire ledger was charged (by the caller).
        """
        policy = self.session.retry_policy
        kind = classify_failure(err)
        if kind == "fatal":
            raise err  # programming error — never masked by retries
        mode = self._pending_mode
        self._pending = None
        salv: Optional[Salvage] = None
        if self._measure and harvest:
            salv = getattr(err, "salvage", None)
            if salv is None:
                if kind == "timeout" and policy.timeout_s is not None and res is not None:
                    at_t = res.start_t + policy.timeout_s
                else:
                    ft = getattr(err, "fail_t", None)
                    at_t = float(ft) if ft is not None else None
                try:
                    salv = handle.salvage_at(at_t)
                except Exception:
                    salv = None
        if kind == "timeout" and not handle.done():
            # the stalled attempt keeps no claim on the link; its realized
            # prefix (if any) was captured above
            cancelled = handle.cancel()
            if salv is None and self._measure and harvest:
                salv = cancelled
        self._absorb_salvage(salv, config, mode)
        self.n_failed_attempts += 1
        self.fault_counts[kind] = self.fault_counts.get(kind, 0) + 1
        self._attempt += 1

        # detection latency on this task's clock: wall-derived on realtime
        # transports, the timeout budget for a timed-out virtual attempt,
        # else the transport-reported failure instant
        if kind == "timeout" and policy.timeout_s is not None and res is not None:
            detect_s = policy.timeout_s
        elif self._issue_wall is not None and bool(
            getattr(self.transport, "realtime", False)
        ):
            detect_s = max(time.perf_counter() - self._issue_wall, 0.0)
        else:
            fail_t = getattr(err, "fail_t", None)
            if fail_t is None and res is not None:
                fail_t = res.end_t
            detect_s = (
                max(float(fail_t) - self.clock.fetch_t, 0.0)
                if fail_t is not None
                else 0.0
            )

        # "missing" is permanent at this level — retrying the same key
        # cannot succeed, go straight to the degrade ladder
        if kind != "missing" and self._attempt < policy.max_attempts:
            backoff = policy.backoff(self._attempt)
            self.clock.charge_failure(detect_s + backoff)
            if getattr(self.transport, "realtime", False) and backoff > 0:
                time.sleep(min(backoff, 1.0))  # tcp: reconnect with backoff
            self.n_retries += 1
            self._chunk_retries += 1
            self._issue_fetch(m, config, nbytes, scale)
            return []

        self.clock.charge_failure(detect_s)
        if not policy.degrade:
            return self._fail(err)
        # degrade: ban the failed level and everything finer (a coarser
        # level is a different stored blob and a smaller transfer; TEXT is
        # fetch-free and never banned here) and let Algorithm 1 re-decide
        order = list(self.clock.policy.levels_quality_order)
        if config in order:
            self._banned.update(order[: order.index(config) + 1])
        else:
            self._banned.add(config)
        self._attempt = 0
        self.n_degrades += 1
        return []

    # -- byte-range resume machinery (ISSUE 8) -----------------------------

    def _replan_mid_chunk(
        self, handle, m, config, res, mode, cancel_after_s
    ) -> List[object]:
        """Cancel the in-flight chunk on the virtual clock, keep the
        verified prefix, observe the collapsed throughput, and let the next
        :meth:`step` re-decide the remainder (§C.1 generalized)."""
        t_cancel = res.start_t + cancel_after_s
        self._pending = None
        self._replanned = True
        self.n_mid_chunk_replans += 1
        try:
            salv = handle.salvage_at(t_cancel)
        except Exception:
            salv = None
        self._absorb_salvage(salv, config, mode)
        # the spent window is charged like a failed attempt (elapsed_s
        # grows, so the re-decision sees the lost time) ...
        self.clock.charge_failure(max(t_cancel - self.clock.fetch_t, 0.0))
        # ... and the collapse itself is observed: realized prefix bytes
        # over the cancelled window feed the estimator, which is exactly
        # the signal that makes choose_config pick a coarser remainder
        if salv is not None and salv.nbytes_wire > 0 and t_cancel > res.start_t:
            self.clock.policy.observe_throughput(
                float(salv.nbytes_wire) * 8.0 / ((t_cancel - res.start_t) * 1e9)
            )
        return []

    def _absorb_salvage(self, salv: Optional[Salvage], level, mode) -> None:
        """Fold a partial attempt's realized bytes into the chunk's wire
        ledger and — when they verify against the segment index — into the
        cross-attempt salvage slot.

        Corruption is never kept: a complete-but-corrupt segment raises
        inside ``verified_prefix`` and the new bytes are discarded (any
        previously verified salvage stays).  A resumed suffix extends the
        existing prefix; a composed suffix *upgrades* the slot to the new
        level by splicing head+anchor+suffix and re-verifying from byte 0.
        """
        if salv is None:
            return
        self._chunk_wire += float(salv.nbytes_wire)
        if not self._resumable or salv.index is None or not salv.data:
            return
        idx = salv.index
        sv = self._salvage
        try:
            if (
                mode == "resume"
                and sv is not None
                and level == sv.level
                and salv.offset == sv.verified_end
            ):
                data = sv.data[: sv.verified_end] + bytes(salv.data)
            elif mode == "compose" and sv is not None and salv.offset > 0:
                head = self._synthesize_head(sv, level, idx)
                anchor = sv.data[sv.index.head.end : sv.index.anchor_end]
                if len(head) + len(anchor) != salv.offset:
                    return  # geometry mismatch: splice would not align
                data = head + anchor + bytes(salv.data)
            elif salv.offset == 0:
                data = bytes(salv.data)
            else:
                return  # an offset we cannot anchor to anything verified
            ve = idx.verified_prefix(data)
        except bitstream.IntegrityError:
            return  # corrupt partial: keep whatever salvage already exists
        except Exception:
            return
        total = int(salv.total)
        if ve <= 0 or total <= 0:
            return
        self._salvage = _ChunkSalvage(
            level=int(level),
            data=data[:ve],
            verified_end=int(ve),
            index=idx,
            total=total,
        )

    def _synthesize_head(self, sv: _ChunkSalvage, level, idx) -> bytes:
        """Rebuild the target level's head bytes (msgpack framing + header)
        from the salvaged blob's header with only the level swapped —
        byte-exact for lossy↔lossy because the header is a flat map of
        small ints and every lossy level packs to the same width."""
        hdr = dict(kvcodec.peek_chunk_header(bytes(sv.data)))
        hdr["level"] = int(level)
        n_arrays = idx.n_arrays if idx is not None else sv.index.n_arrays
        return bitstream.synthesize_head(hdr, n_arrays)

    def _credit(self, m) -> Optional[Dict[int, float]]:
        """``adaptation.salvage_credit`` for the current chunk, or None."""
        sv = self._salvage
        if sv is None or not self._resumable:
            return None
        return salvage_credit(
            {lvl: float(s) for lvl, s in m.sizes.items()},
            sv.level,
            sv.verified_end,
            sv.index.head.end,
            sv.index.anchor_end,
            lossless_level=_LOSSLESS_LEVEL,
        )

    def _fail(self, err) -> List[object]:
        """Terminal failure: record it, flush the segmenter, and emit the
        valid realized prefix (the schedulers then release this task's row
        without poisoning any batch)."""
        kind = (
            "exhausted"
            if isinstance(err, NoFeasibleConfigError)
            else classify_failure(err)
        )
        self._failure = f"{kind}: {err}"
        self._pending = None
        # the failed chunk's partial deliveries stay on the ledger (all
        # refetched — nothing landed to credit them against)
        if self._chunk_wire > 0.0:
            self.wire_bytes += self._chunk_wire
            self.refetched_bytes += self._chunk_wire
            self._chunk_wire = 0.0
        segs = self.segmenter.flush()
        return [self._to_work(s) for s in segs]

    def _to_work(self, seg: PlanSegment):
        # positional bookkeeping: every segment must start exactly where
        # the materialized prefix ends (host-side counter — reading
        # caches.length here would force a device sync per segment and
        # stall the decode/fetch overlap)
        if seg.start != self._offset:
            raise AssertionError(
                f"segment starts at token {seg.start} but {self._offset} "
                "tokens are materialized; decoded/recomputed chunk "
                "interleaving lost sync"
            )
        self._offset = seg.end
        if seg.kind == "text":
            return TextWork(
                row=self.row,
                start=seg.start,
                end=seg.end,
                tokens=self.tokens[:, seg.start : seg.end],
            )
        return RunWork(
            row=self.row,
            start=seg.start,
            end=seg.end,
            blobs=list(seg.blobs),
            tables=self.store.tables,
        )

    def result(
        self,
        caches: Caches,
        *,
        wall_decode_s: float,
        wall_recompute_s: float,
        wall_total_s: float,
        n_runs: int,
    ) -> SessionResult:
        return SessionResult(
            timelines=list(self.timelines),
            configs=[t.config for t in self.timelines],
            # a failed load never produced a first token: ttft is +inf, so
            # failures always count as SLO misses downstream
            ttft_s=(
                float("inf")
                if self.failed
                else self.clock.ttft_s(self.timelines, self.session.final_step_s)
            ),
            slo_s=self.session.slo_s,
            caches=caches,
            wall_decode_s=wall_decode_s,
            wall_recompute_s=wall_recompute_s,
            wall_total_s=wall_total_s,
            n_runs=n_runs,
            status="failed" if self.failed else "ok",
            failure=self._failure,
            n_retries=self.n_retries,
            n_degrades=self.n_degrades,
            n_fault_text=self.n_fault_text,
            n_failed_attempts=self.n_failed_attempts,
            fault_counts=dict(self.fault_counts),
            salvaged_bytes=self.salvaged_bytes,
            n_resumes=self.n_fetch_resumes,
            n_mid_chunk_replans=self.n_mid_chunk_replans,
            refetched_bytes=self.refetched_bytes,
            wire_bytes=self.wire_bytes,
        )


class ServeSession:
    """Bandwidth-adaptive context load: decide → fetch → decode/recompute.

    One instance is reusable across requests (it holds no per-request
    state); each :meth:`run` builds a fresh :class:`SessionTask` (policy +
    clock + segmenter) and serving cache, and executes the task's work items
    one at a time.  For N concurrent loads sharing one Engine, hand the
    session(s) to ``serving.scheduler.ConcurrentScheduler`` instead, which
    executes the same work items batched across requests.
    """

    def __init__(
        self,
        streamer: CacheGenStreamer,
        engine: Engine,
        *,
        slo_s: float,
        recompute_s: Callable[[int, int], float],  # (chunk_tokens, prefix) -> s
        decode_bytes_per_s: Optional[float] = None,
        default_level: Optional[int] = None,
        allow_text: bool = True,
        adapt: bool = True,
        fixed_level: Optional[int] = None,
        hedge_after_s: Optional[float] = None,
        final_step_s: float = 0.0,
        max_run_tokens: Optional[int] = None,
        validate_blobs: bool = True,
        transport: Optional[Transport] = None,
        retry_policy: Optional[RetryPolicy] = None,
        resume_fetch: bool = True,
        replan_factor: Optional[float] = None,
    ):
        self.streamer = streamer
        self.engine = engine
        # None -> each run builds a SimTransport over that run's NetworkModel
        # (simulator-differential default); pass LocalTransport/TcpTransport
        # for direct reads or a real socket link
        self.transport = transport
        self.slo_s = slo_s
        self.recompute_s = recompute_s
        self.decode_bytes_per_s = (
            decode_bytes_per_s
            if decode_bytes_per_s is not None
            else measured_decode_bytes_per_s()
        )
        self.default_level = default_level
        self.allow_text = allow_text
        self.adapt = adapt
        self.fixed_level = fixed_level
        self.hedge_after_s = hedge_after_s
        self.final_step_s = final_step_s
        self.max_run_tokens = max_run_tokens
        self.validate_blobs = validate_blobs
        # None -> legacy behavior: any fetch failure raises straight through
        # the caller's run loop (pinned by tests).  A RetryPolicy arms the
        # full ISSUE-6 machinery: classify -> bounded retries with backoff
        # charged to the StreamClock -> degrade to coarser levels / TEXT ->
        # clean failure status, never an uncaught exception.
        self.retry_policy = retry_policy
        # byte-range resume (ISSUE 8; needs retry_policy + a range-capable
        # transport).  resume_fetch=False keeps PR 6 whole-blob retries
        # (the benchmark baseline) while still measuring the wire ledger.
        # replan_factor arms §C.1 mid-chunk re-planning on virtual-clock
        # transports: an in-flight fetch whose realized duration exceeds
        # replan_factor × the live-estimate prediction is cancelled at that
        # instant, its verified prefix salvaged, and the remainder
        # re-decided (at most once per chunk).  None = off (bit-identical
        # to the pre-resume timing).
        self.resume_fetch = resume_fetch
        self.replan_factor = replan_factor

    # ------------------------------------------------------------------

    def run(
        self,
        context_id: str,
        tokens: np.ndarray,  # (B, T) full context tokens (for TEXT chunks)
        network: NetworkModel,
        *,
        batch: int = 1,
        prior_throughput_gbps: Optional[float] = None,
        start_t: float = 0.0,
        transport: Optional[Transport] = None,
    ) -> SessionResult:
        caches = self.engine.empty_caches(batch)
        if caches.kv_k is None:
            raise ValueError(
                f"ServeSession needs a KV-cache family, got {self.engine.cfg.family}"
            )
        task = SessionTask(
            self,
            context_id,
            tokens,
            network,
            prior_throughput_gbps=prior_throughput_gbps,
            start_t=start_t,
            transport=transport,
        )
        state = _ExecState()
        wall0 = time.perf_counter()
        while not task.done:
            for work in task.step():
                caches = self._execute_one(work, caches, state)
        if caches.kv_k is not None:
            jax.block_until_ready(caches.kv_k)
        wall_total = time.perf_counter() - wall0
        return task.result(
            caches,
            wall_decode_s=state.decode_s,
            wall_recompute_s=state.recompute_s,
            wall_total_s=wall_total,
            n_runs=state.runs,
        )

    # ------------------------------------------------------------------

    def _execute_one(
        self, work, caches: Caches, state: "_ExecState"
    ) -> Caches:
        """Single-request execution of one work item (the scheduler's
        cross-request batched executors are the N>1 counterpart)."""
        if isinstance(work, TextWork):
            t0 = time.perf_counter()
            _, caches = self.engine.prefill_extend(
                jnp.asarray(work.tokens, jnp.int32), caches
            )
            state.recompute_s += time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            kv_run = kvcodec.decode_chunks(
                work.blobs, work.tables, out_dtype=caches.kv_k.dtype
            )
            caches = self.engine.decode_to_cache(caches, kv_run, work.start)
            state.decode_s += time.perf_counter() - t0
            state.runs += 1
        return caches


@dataclasses.dataclass
class _ExecState:
    """Mutable per-run execution state: wall-clock accumulators."""

    decode_s: float = 0.0
    recompute_s: float = 0.0
    runs: int = 0
