"""Bridges between the serving engine's KV cache pytrees and the codec's
(L, 2, T, C) tensor layout, plus cache allocation helpers."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.lm import Caches

__all__ = [
    "caches_to_codec_kv",
    "codec_kv_to_caches",
    "insert_codec_run",
    "alloc_caches",
    "kv_cache_bytes",
]


def insert_codec_run(
    kv_k: jnp.ndarray,  # (L, B, cap, Hkv, Dh) serving cache, donatable
    kv_v: jnp.ndarray,
    length: jnp.ndarray,  # (B,) int32
    kv_new: jnp.ndarray,  # (L, 2, T, C) decoded run (codec.decode_chunks)
    start: jnp.ndarray,  # scalar int32 token offset
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Write a decoded codec run into the serving cache at ``[start, start+T)``.

    Pure function meant to be jitted with the cache buffers donated
    (``Engine.decode_to_cache``): the reshape to the attention layout
    ``(L, B, T, Hkv, Dh)`` is a view, the batch broadcast fuses into the
    ``dynamic_update_slice`` write, and with donation XLA updates the cache
    in place instead of copying O(cache_size) per insertion.  ``length``
    advances monotonically (``maximum``) so interleaved TEXT/bitstream chunk
    orders can never shrink the cache.
    """
    L, B, _, Hkv, Dh = kv_k.shape
    T = kv_new.shape[2]
    kt = jnp.broadcast_to(
        kv_new[:, 0].reshape(L, 1, T, Hkv, Dh).astype(kv_k.dtype), (L, B, T, Hkv, Dh)
    )
    vt = jnp.broadcast_to(
        kv_new[:, 1].reshape(L, 1, T, Hkv, Dh).astype(kv_v.dtype), (L, B, T, Hkv, Dh)
    )
    start = start.astype(jnp.int32)
    zero = jnp.int32(0)
    kv_k = jax.lax.dynamic_update_slice(kv_k, kt, (zero, zero, start, zero, zero))
    kv_v = jax.lax.dynamic_update_slice(kv_v, vt, (zero, zero, start, zero, zero))
    length = jnp.maximum(length, start + T)
    return kv_k, kv_v, length


def caches_to_codec_kv(caches: Caches, batch_index: int, n_tokens: int) -> np.ndarray:
    """Extract one request's KV as (L, 2, T, C) float32 for encoding."""
    k = np.asarray(caches.kv_k[:, batch_index, :n_tokens], dtype=np.float32)
    v = np.asarray(caches.kv_v[:, batch_index, :n_tokens], dtype=np.float32)
    L, T, Hkv, Dh = k.shape
    k = k.reshape(L, T, Hkv * Dh)
    v = v.reshape(L, T, Hkv * Dh)
    return np.stack([k, v], axis=1)  # (L, 2, T, C)


def codec_kv_to_caches(
    kv: np.ndarray,  # (L, 2, T, C)
    cfg: ArchConfig,
    *,
    batch: int = 1,
    capacity: Optional[int] = None,
    dtype=jnp.bfloat16,
) -> Caches:
    """Materialize decoded KV into a serving cache (single request, replicated
    across ``batch`` rows for batched generation experiments)."""
    L, two, T, C = kv.shape
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    assert C == Hkv * Dh, f"C={C} != {Hkv}x{Dh}"
    cap = capacity or T
    k = jnp.zeros((L, batch, cap, Hkv, Dh), dtype)
    v = jnp.zeros((L, batch, cap, Hkv, Dh), dtype)
    kt = jnp.asarray(kv[:, 0].reshape(L, T, Hkv, Dh), dtype)
    vt = jnp.asarray(kv[:, 1].reshape(L, T, Hkv, Dh), dtype)
    k = k.at[:, :, :T].set(kt[:, None])
    v = v.at[:, :, :T].set(vt[:, None])
    return Caches(
        kv_k=k,
        kv_v=v,
        length=jnp.full((batch,), T, jnp.int32),
        mamba_conv=None,
        mamba_ssm=None,
        shared_k=None,
        shared_v=None,
    )


def alloc_caches(cfg: ArchConfig, batch: int, capacity: int, dtype=jnp.bfloat16) -> Caches:
    """Empty caches for attention families."""
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return Caches(
        kv_k=jnp.zeros((L, batch, capacity, Hkv, Dh), dtype),
        kv_v=jnp.zeros((L, batch, capacity, Hkv, Dh), dtype),
        length=jnp.zeros((batch,), jnp.int32),
        mamba_conv=None,
        mamba_ssm=None,
        shared_k=None,
        shared_v=None,
    )


def kv_cache_bytes(cfg: ArchConfig, n_tokens: int, dtype_bytes: int = 2) -> int:
    """Raw KV cache size for one request (the paper's '25 GB for 16K' figure)."""
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // max(cfg.shared_block_every, 1)
        return n_apps * 2 * n_tokens * cfg.kv_channels * dtype_bytes
    if not cfg.has_kv_cache:
        return 0
    L = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    return L * 2 * n_tokens * cfg.kv_channels * dtype_bytes
