"""Bridges between the serving engine's KV cache pytrees and the codec's
(L, 2, T, C) tensor layout, plus cache allocation helpers and the row-pool
primitives (save / restore / reset of a single request's row) that let the
continuous-admission scheduler recycle rows of one batch-of-requests cache
across sessions and suspend a preempted session's realized KV for later
resumption.

Shard-aware row addressing (mesh-sharded serving).  Schedulers and every
public entry point name rows by *global* index ``r`` in ``[0, B)``.  When
the cache's row axis is split over a mesh axis of ``S`` shards (blocked
layout, matching ``NamedSharding`` partitioning), global row ``r`` lives on
shard ``r // (B / S)`` at local row ``r % (B / S)``.  The ``*_local``
kernels below are the per-shard shard_map bodies of the global primitives:
each receives its shard's ``(L, B/S, cap, Hkv, Dh)`` cache slice plus the
*replicated* global row operands, recovers local indices from
``jax.lax.axis_index``, and masks out rows that belong to other shards —
so every shard performs exactly the row-local arithmetic of the unsharded
kernel, byte for byte, and runs addressed to foreign shards are dropped via
a discarded scratch row rather than branching."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.lm import Caches, masked_window_update

__all__ = [
    "RowSnapshot",
    "caches_to_codec_kv",
    "codec_kv_to_caches",
    "insert_codec_run",
    "insert_codec_runs",
    "insert_codec_runs_local",
    "extract_row",
    "save_row",
    "restore_row",
    "restore_row_local",
    "reset_rows",
    "reset_rows_local",
    "alloc_caches",
    "kv_cache_bytes",
]


def insert_codec_run(
    kv_k: jnp.ndarray,  # (L, B, cap, Hkv, Dh) serving cache, donatable
    kv_v: jnp.ndarray,
    length: jnp.ndarray,  # (B,) int32
    kv_new: jnp.ndarray,  # (L, 2, T, C) decoded run (codec.decode_chunks)
    start: jnp.ndarray,  # scalar int32 token offset
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Write a decoded codec run into the serving cache at ``[start, start+T)``.

    Pure function meant to be jitted with the cache buffers donated
    (``Engine.decode_to_cache``): the reshape to the attention layout
    ``(L, B, T, Hkv, Dh)`` is a view, the batch broadcast fuses into the
    ``dynamic_update_slice`` write, and with donation XLA updates the cache
    in place instead of copying O(cache_size) per insertion.  ``length``
    advances monotonically (``maximum``) so interleaved TEXT/bitstream chunk
    orders can never shrink the cache.
    """
    L, B, _, Hkv, Dh = kv_k.shape
    T = kv_new.shape[2]
    kt = jnp.broadcast_to(
        kv_new[:, 0].reshape(L, 1, T, Hkv, Dh).astype(kv_k.dtype), (L, B, T, Hkv, Dh)
    )
    vt = jnp.broadcast_to(
        kv_new[:, 1].reshape(L, 1, T, Hkv, Dh).astype(kv_v.dtype), (L, B, T, Hkv, Dh)
    )
    start = start.astype(jnp.int32)
    zero = jnp.int32(0)
    kv_k = jax.lax.dynamic_update_slice(kv_k, kt, (zero, zero, start, zero, zero))
    kv_v = jax.lax.dynamic_update_slice(kv_v, vt, (zero, zero, start, zero, zero))
    length = jnp.maximum(length, start + T)
    return kv_k, kv_v, length


def insert_codec_runs(
    kv_k: jnp.ndarray,  # (L, B, cap, Hkv, Dh) batch-of-requests cache, donatable
    kv_v: jnp.ndarray,
    length: jnp.ndarray,  # (B,) int32
    kv_new: jnp.ndarray,  # (L, 2, sum_T, C) decoded concat of all runs
    rows: jnp.ndarray,  # (R,) int32 cache row per run (distinct)
    starts: jnp.ndarray,  # (R,) int32 token offset per run
    run_tokens: Tuple[int, ...],  # static: token count per run, concat order
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Write R decoded runs — one per *request* — into their cache rows.

    The multi-session counterpart of :func:`insert_codec_run`: the cache's
    batch axis holds different requests (one row per live session), and each
    run lands at its own row and token offset in a single dispatch — a
    vmap'd per-row-offset ``dynamic_update_slice`` instead of one dispatch
    per request per run.  Meant to be jitted with the cache buffers donated
    (``Engine.insert_runs``).

    Only run geometry (``run_tokens``, and the batch/capacity shapes) is
    static; ``rows`` and ``starts`` are data, so which session received
    which run never retraces the program.  Rows not named in ``rows`` are
    written back byte-identically (their window merge keeps every current
    value).  Requires ``cap >= max(run_tokens)``; rows whose window would
    overhang the capacity are handled exactly via a shifted in-window merge
    (``dynamic_update_slice`` clamps the window start; the merge re-aligns
    the new tokens inside it).
    """
    L, B, cap, Hkv, Dh = kv_k.shape
    R = len(run_tokens)
    t_max = max(run_tokens)
    # per-run padded updates in the attention layout, stacked: (R, L, Tm, ...)
    off = 0
    ks, vs = [], []
    for T in run_tokens:
        piece = kv_new[:, :, off : off + T].reshape(L, 2, T, Hkv, Dh)
        pad = ((0, 0), (0, 0), (0, t_max - T), (0, 0), (0, 0))
        piece = jnp.pad(piece, pad)
        ks.append(piece[:, 0])
        vs.append(piece[:, 1])
        off += T
    k_upd = jnp.stack(ks).astype(kv_k.dtype)  # (R, L, Tm, Hkv, Dh)
    v_upd = jnp.stack(vs).astype(kv_v.dtype)
    rows = rows.astype(jnp.int32)
    starts = starts.astype(jnp.int32)
    widths = jnp.asarray(run_tokens, jnp.int32)

    # scatter run payloads/offsets to their cache rows (inactive rows: width 0)
    row_k = jnp.zeros((B, L, t_max, Hkv, Dh), kv_k.dtype).at[rows].set(k_upd)
    row_v = jnp.zeros((B, L, t_max, Hkv, Dh), kv_v.dtype).at[rows].set(v_upd)
    row_start = jnp.zeros((B,), jnp.int32).at[rows].set(starts)
    row_width = jnp.zeros((B,), jnp.int32).at[rows].set(widths)

    # one shifted read-merge-write window per (row, layer): rows not named
    # in `rows` have width 0 and are written back verbatim; a run whose
    # padded window overhangs the capacity is re-aligned inside it (see
    # lm.masked_window_update, the single shared implementation)
    _one_row = jax.vmap(  # over layers: cache_row (L, cap, ...), upd (L, Tm, ...)
        masked_window_update, in_axes=(0, 0, None, None)
    )
    vrow = jax.vmap(_one_row, in_axes=(1, 0, 0, 0), out_axes=1)
    kv_k = vrow(kv_k, row_k, row_start, row_width)
    kv_v = vrow(kv_v, row_v, row_start, row_width)
    length = jnp.maximum(length, row_start + row_width)
    return kv_k, kv_v, length


def _local_rows(rows: jnp.ndarray, b_loc: int, axis: Optional[str]):
    """Map replicated global row ids to this shard's local indices.

    Returns ``(local, mine)``: foreign rows get the out-of-range local
    index ``b_loc`` (a scratch/drop slot — never a wrapped negative index,
    which jnp scatter would interpret Python-style)."""
    shard = jax.lax.axis_index(axis) if axis is not None else 0
    local = rows.astype(jnp.int32) - shard * b_loc
    mine = (local >= 0) & (local < b_loc)
    return jnp.where(mine, local, b_loc), mine


def insert_codec_runs_local(
    kv_k: jnp.ndarray,  # (L, B/S, cap, Hkv, Dh) this shard's cache slice
    kv_v: jnp.ndarray,
    length: jnp.ndarray,  # (B/S,) int32 this shard's lengths
    kv_new: jnp.ndarray,  # (L, 2, sum_T, C) decoded concat, replicated
    rows: jnp.ndarray,  # (R,) int32 *global* cache row per run, replicated
    starts: jnp.ndarray,  # (R,) int32 token offset per run, replicated
    run_tokens: Tuple[int, ...],  # static: token count per run
    axis: Optional[str],  # mesh axis the row dim is split over
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-shard shard_map body of :func:`insert_codec_runs`.

    Identical merge arithmetic to the global kernel, restricted to this
    shard's rows: runs addressed to other shards are scattered into an
    extra scratch row at index ``B/S`` (sliced off before the window
    merge), so local rows they would otherwise alias keep width 0 and are
    written back byte-identically.  Every run's payload is replicated to
    all shards (runs are small — a few chunks — next to the cache), which
    keeps the body collective-free.
    """
    L, b_loc, cap, Hkv, Dh = kv_k.shape
    t_max = max(run_tokens)
    off = 0
    ks, vs = [], []
    for T in run_tokens:
        piece = kv_new[:, :, off : off + T].reshape(L, 2, T, Hkv, Dh)
        pad = ((0, 0), (0, 0), (0, t_max - T), (0, 0), (0, 0))
        piece = jnp.pad(piece, pad)
        ks.append(piece[:, 0])
        vs.append(piece[:, 1])
        off += T
    k_upd = jnp.stack(ks).astype(kv_k.dtype)  # (R, L, Tm, Hkv, Dh)
    v_upd = jnp.stack(vs).astype(kv_v.dtype)
    local, _ = _local_rows(rows, b_loc, axis)
    starts = starts.astype(jnp.int32)
    widths = jnp.asarray(run_tokens, jnp.int32)

    # scatter into B/S + 1 rows: foreign runs pile into the scratch row
    # (duplicate-index scatter there is unspecified but discarded)
    row_k = (
        jnp.zeros((b_loc + 1, L, t_max, Hkv, Dh), kv_k.dtype)
        .at[local].set(k_upd)[:b_loc]
    )
    row_v = (
        jnp.zeros((b_loc + 1, L, t_max, Hkv, Dh), kv_v.dtype)
        .at[local].set(v_upd)[:b_loc]
    )
    row_start = jnp.zeros((b_loc + 1,), jnp.int32).at[local].set(starts)[:b_loc]
    row_width = jnp.zeros((b_loc + 1,), jnp.int32).at[local].set(widths)[:b_loc]

    _one_row = jax.vmap(masked_window_update, in_axes=(0, 0, None, None))
    vrow = jax.vmap(_one_row, in_axes=(1, 0, 0, 0), out_axes=1)
    kv_k = vrow(kv_k, row_k, row_start, row_width)
    kv_v = vrow(kv_v, row_v, row_start, row_width)
    length = jnp.maximum(length, row_start + row_width)
    return kv_k, kv_v, length


def restore_row_local(
    kv_k: jnp.ndarray,  # (L, B/S, cap, Hkv, Dh) this shard's cache slice
    kv_v: jnp.ndarray,
    length: jnp.ndarray,  # (B/S,) int32
    k_row: jnp.ndarray,  # (L, T, Hkv, Dh) saved tokens, replicated
    v_row: jnp.ndarray,
    row: jnp.ndarray,  # scalar int32 *global* target row, replicated
    axis: Optional[str],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-shard shard_map body of :func:`restore_row`: the shard owning
    the global row writes the snapshot at its local index; every other
    shard round-trips the addressed slot's current bytes (a masked
    read-merge-write, so no branch and no cross-shard traffic)."""
    L, b_loc, cap, Hkv, Dh = kv_k.shape
    T = k_row.shape[1]
    local, mine = _local_rows(row.reshape(1), b_loc, axis)
    li = jnp.minimum(local[0], b_loc - 1)  # clamp the foreign scratch index
    own = mine[0]
    zero = jnp.int32(0)
    cur_k = jax.lax.dynamic_slice(
        kv_k, (zero, li, zero, zero, zero), (L, 1, T, Hkv, Dh)
    )
    cur_v = jax.lax.dynamic_slice(
        kv_v, (zero, li, zero, zero, zero), (L, 1, T, Hkv, Dh)
    )
    new_k = jnp.where(own, k_row[:, None].astype(kv_k.dtype), cur_k)
    new_v = jnp.where(own, v_row[:, None].astype(kv_v.dtype), cur_v)
    kv_k = jax.lax.dynamic_update_slice(kv_k, new_k, (zero, li, zero, zero, zero))
    kv_v = jax.lax.dynamic_update_slice(kv_v, new_v, (zero, li, zero, zero, zero))
    length = length.at[li].set(jnp.where(own, jnp.int32(T), length[li]))
    return kv_k, kv_v, length


def reset_rows_local(
    kv_k: jnp.ndarray,  # (L, B/S, cap, Hkv, Dh) this shard's cache slice
    kv_v: jnp.ndarray,
    length: jnp.ndarray,  # (B/S,) int32
    rows: jnp.ndarray,  # (R,) int32 *global* rows to recycle, replicated
    axis: Optional[str],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-shard shard_map body of :func:`reset_rows`: each shard zeroes
    the recycled rows it owns; foreign rows map to the out-of-range scratch
    index and their scatter updates are dropped."""
    b_loc = kv_k.shape[1]
    local, _ = _local_rows(rows, b_loc, axis)
    kv_k = kv_k.at[:, local].set(jnp.zeros((), kv_k.dtype), mode="drop")
    kv_v = kv_v.at[:, local].set(jnp.zeros((), kv_v.dtype), mode="drop")
    length = length.at[local].set(0, mode="drop")
    return kv_k, kv_v, length


@dataclasses.dataclass
class RowSnapshot:
    """A suspended session's realized KV: the first ``n_tokens`` tokens of
    its cache row, sliced out as standalone device arrays (independent of
    the pool cache's buffers, so later donated inserts into the pool cannot
    invalidate it).  Restored — possibly into a *different* row — by
    :func:`restore_row`."""

    kv_k: jnp.ndarray  # (L, T, Hkv, Dh)
    kv_v: jnp.ndarray  # (L, T, Hkv, Dh)
    n_tokens: int


def save_row(caches: Caches, row: int, n_tokens: int) -> RowSnapshot:
    """Snapshot the realized prefix of one request's cache row.

    The slices force their own buffers, so the snapshot survives any number
    of donated-buffer updates to the pool cache afterwards; the exact bytes
    come back via :func:`restore_row` (suspend→resume is a bit-exact round
    trip — held to that by tests/test_continuous.py).
    """
    n = int(n_tokens)
    return RowSnapshot(
        kv_k=caches.kv_k[:, row, :n],
        kv_v=caches.kv_v[:, row, :n],
        n_tokens=n,
    )


def restore_row(
    kv_k: jnp.ndarray,  # (L, B, cap, Hkv, Dh) pool cache, donatable
    kv_v: jnp.ndarray,
    length: jnp.ndarray,  # (B,) int32
    k_row: jnp.ndarray,  # (L, T, Hkv, Dh) saved tokens (RowSnapshot.kv_k)
    v_row: jnp.ndarray,
    row: jnp.ndarray,  # scalar int32 target row (data, not static)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Re-insert a suspended session's saved tokens at ``[0, T)`` of ``row``.

    Meant to be jitted with the cache buffers donated (``Engine.
    restore_row``); the target row is data, so resuming into whichever row
    freed does not retrace.  The row must have been reset (length 0) before
    restoring — the pool hands out recycled rows zeroed.
    """
    T = k_row.shape[1]
    row = row.astype(jnp.int32)
    zero = jnp.int32(0)
    kv_k = jax.lax.dynamic_update_slice(
        kv_k, k_row[:, None].astype(kv_k.dtype), (zero, row, zero, zero, zero)
    )
    kv_v = jax.lax.dynamic_update_slice(
        kv_v, v_row[:, None].astype(kv_v.dtype), (zero, row, zero, zero, zero)
    )
    length = length.at[row].set(jnp.int32(T))
    return kv_k, kv_v, length


def reset_rows(
    kv_k: jnp.ndarray,  # (L, B, cap, Hkv, Dh) pool cache, donatable
    kv_v: jnp.ndarray,
    length: jnp.ndarray,  # (B,) int32
    rows: jnp.ndarray,  # (R,) int32 rows to recycle
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Zero recycled rows before a new session takes them.

    A recycled row must look exactly like a row of a fresh
    :func:`alloc_caches` cache: zero KV and zero length — the length reset
    matters doubly because run insertion advances length *monotonically*
    (``jnp.maximum``), so a stale tenant's length would corrupt the new
    tenant's offsets.  Row membership is data (no retrace per row set).
    """
    rows = rows.astype(jnp.int32)
    kv_k = kv_k.at[:, rows].set(jnp.zeros((), kv_k.dtype))
    kv_v = kv_v.at[:, rows].set(jnp.zeros((), kv_v.dtype))
    length = length.at[rows].set(0)
    return kv_k, kv_v, length


def extract_row(caches: Caches, row: int) -> Caches:
    """One request's batch-1 view of a batch-of-requests cache (device
    slices; no copy forced)."""
    sl = slice(row, row + 1)
    return caches._replace(
        kv_k=None if caches.kv_k is None else caches.kv_k[:, sl],
        kv_v=None if caches.kv_v is None else caches.kv_v[:, sl],
        length=None if caches.length is None else caches.length[sl],
        mamba_conv=None if caches.mamba_conv is None else caches.mamba_conv[:, sl],
        mamba_ssm=None if caches.mamba_ssm is None else caches.mamba_ssm[:, sl],
        shared_k=None if caches.shared_k is None else caches.shared_k[:, sl],
        shared_v=None if caches.shared_v is None else caches.shared_v[:, sl],
    )


def caches_to_codec_kv(caches: Caches, batch_index: int, n_tokens: int) -> np.ndarray:
    """Extract one request's KV as (L, 2, T, C) float32 for encoding."""
    k = np.asarray(caches.kv_k[:, batch_index, :n_tokens], dtype=np.float32)
    v = np.asarray(caches.kv_v[:, batch_index, :n_tokens], dtype=np.float32)
    L, T, Hkv, Dh = k.shape
    k = k.reshape(L, T, Hkv * Dh)
    v = v.reshape(L, T, Hkv * Dh)
    return np.stack([k, v], axis=1)  # (L, 2, T, C)


def codec_kv_to_caches(
    kv: np.ndarray,  # (L, 2, T, C)
    cfg: ArchConfig,
    *,
    batch: int = 1,
    capacity: Optional[int] = None,
    dtype=jnp.bfloat16,
) -> Caches:
    """Materialize decoded KV into a serving cache (single request, replicated
    across ``batch`` rows for batched generation experiments)."""
    L, two, T, C = kv.shape
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    assert C == Hkv * Dh, f"C={C} != {Hkv}x{Dh}"
    cap = capacity or T
    k = jnp.zeros((L, batch, cap, Hkv, Dh), dtype)
    v = jnp.zeros((L, batch, cap, Hkv, Dh), dtype)
    kt = jnp.asarray(kv[:, 0].reshape(L, T, Hkv, Dh), dtype)
    vt = jnp.asarray(kv[:, 1].reshape(L, T, Hkv, Dh), dtype)
    k = k.at[:, :, :T].set(kt[:, None])
    v = v.at[:, :, :T].set(vt[:, None])
    return Caches(
        kv_k=k,
        kv_v=v,
        length=jnp.full((batch,), T, jnp.int32),
        mamba_conv=None,
        mamba_ssm=None,
        shared_k=None,
        shared_v=None,
    )


def alloc_caches(cfg: ArchConfig, batch: int, capacity: int, dtype=jnp.bfloat16) -> Caches:
    """Empty caches for attention families."""
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return Caches(
        kv_k=jnp.zeros((L, batch, capacity, Hkv, Dh), dtype),
        kv_v=jnp.zeros((L, batch, capacity, Hkv, Dh), dtype),
        length=jnp.zeros((batch,), jnp.int32),
        mamba_conv=None,
        mamba_ssm=None,
        shared_k=None,
        shared_v=None,
    )


def kv_cache_bytes(cfg: ArchConfig, n_tokens: int, dtype_bytes: int = 2) -> int:
    """Raw KV cache size for one request (the paper's '25 GB for 16K' figure)."""
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // max(cfg.shared_block_every, 1)
        return n_apps * 2 * n_tokens * cfg.kv_channels * dtype_bytes
    if not cfg.has_kv_cache:
        return 0
    L = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    return L * 2 * n_tokens * cfg.kv_channels * dtype_bytes
