from repro.serving.engine import Engine  # noqa: F401


def __getattr__(name):
    # Lazy: session pulls in the streaming package (which itself imports
    # repro.serving submodules) — deferring keeps the import graph acyclic
    # regardless of which package a user imports first.
    if name in ("ServeSession", "SessionResult"):
        from repro.serving import session

        return getattr(session, name)
    raise AttributeError(name)
