from repro.serving.engine import Engine  # noqa: F401


def __getattr__(name):
    # Lazy: session/scheduler pull in the streaming package (which itself
    # imports repro.serving submodules) — deferring keeps the import graph
    # acyclic regardless of which package a user imports first.
    if name in ("ServeSession", "SessionResult", "SessionTask", "RunWork",
                "TextWork"):
        from repro.serving import session

        return getattr(session, name)
    if name in ("ConcurrentScheduler", "SessionRequest", "SchedulerResult",
                "ContinuousScheduler", "ContinuousResult", "PreemptionPolicy",
                "RequestTimeline", "RowPool"):
        from repro.serving import scheduler

        return getattr(scheduler, name)
    raise AttributeError(name)
