"""Concurrent multi-session serving: N adaptive context loads, one Engine.

The paper's serving setting (§8.3, Fig. 13) loads many contexts at once;
running them as back-to-back :class:`~repro.serving.session.ServeSession`
calls pays N sequential decode/recompute dispatch chains.  This module keeps
*decisions* per-request — every load owns its ``StreamClock``, Algorithm 1
policy, bandwidth trace and double-buffered segmenter, exactly as in the
single-session loop — but drains the resolved work of all loads into a
shared execution queue that batches the compute hot path *across requests*:

  * **decode** — ready runs from different requests are stacked into a
    single ``codec.decode_chunk_runs`` call: one pair of lane-stacked rANS
    scans and one jitted assemble for all of them, with run geometry (not
    request identity) shaping the jit signature;
  * **insert** — the decoded concat lands in a *batch-of-requests* cache
    (one row per live session) through ``Engine.insert_runs``: a vmap'd
    per-row-offset ``dynamic_update_slice``, one dispatch for all runs;
  * **recompute** — TEXT chunks from different requests with a common token
    count coalesce into one padded, width-masked ``Engine.
    prefill_extend_rows`` forward (rows without a TEXT chunk ride along
    with width 0 and are untouched).

Contention feedback closes the loop: each task's clock charges
decode/recompute seconds scaled by ``ContentionModel.factor(n_active)``
(measured from the microbench's stacked-decode numbers via
``calibration.measured_contention_factors``; conservative ``factor(n) = n``
when unmeasured), and the same factor inflates the remaining-recompute
estimate inside ``choose_config`` — so a loaded engine pushes adaptation
away from TEXT recompute exactly like a collapsing link pushes it toward
coarser levels.  ``factor(1) == 1.0`` exactly, which is what makes the N=1
scheduler bit-identical to ``ServeSession`` (tests/test_scheduler.py).

Rounds are virtual-time ordered: each round steps every unfinished task
once (earliest next fetch first), then executes the round's queue —
decodes/inserts before recomputes, preserving each session's segment order
(a task emits at most one run followed by at most one TEXT item per round).
Since the transport split (ISSUE 4), a task's step may instead *issue* a
chunk fetch through its :class:`~repro.streaming.transport.Transport`
(returning no work): while the scheduler steps the other sessions, that
fetch — and any hedged duplicate the transport races against it — is real
I/O in flight on worker threads, resolved on the task's next turn.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as kvcodec
from repro.models.lm import Caches
from repro.serving.engine import Engine
from repro.serving.kv_layout import extract_row
from repro.serving.session import (
    RunWork,
    ServeSession,
    SessionResult,
    SessionTask,
    TextWork,
)
from repro.streaming.network import NetworkModel
from repro.streaming.pipeline import ContentionModel

__all__ = ["SessionRequest", "SchedulerResult", "ConcurrentScheduler"]


@dataclasses.dataclass
class SessionRequest:
    """One context load: a session's knobs bound to a request's inputs.

    ``session`` carries the per-request configuration (SLO, cost model,
    adaptation knobs, streamer/store) and must share the scheduler's Engine;
    ``tokens`` is the (1, T) context for TEXT recomputes.
    """

    session: ServeSession
    context_id: str
    tokens: np.ndarray
    network: NetworkModel
    prior_throughput_gbps: Optional[float] = None
    start_t: float = 0.0
    # any Transport (Local/Sim/Tcp) for this request's fetches; None falls
    # back to the session's transport, else to a per-request SimTransport
    # over ``network`` (see SessionTask.__init__)
    transport: Optional[object] = None


@dataclasses.dataclass
class SchedulerResult:
    """N per-request results plus scheduler-level batching counters.

    ``sessions[r].caches`` is request ``r``'s batch-1 view of the shared
    batch-of-requests cache (``caches`` holds the full batch).  Virtual
    times (``ttft_s``) are per-request and contention-aware; ``wall_*`` on
    the scheduler are realized host seconds for the whole batch run, and
    each session's ``wall_*`` is its token-weighted share of the batched
    dispatches it participated in.
    """

    sessions: List[SessionResult]
    caches: Caches
    wall_total_s: float
    wall_decode_s: float
    wall_recompute_s: float
    n_rounds: int
    n_decode_batches: int
    n_text_batches: int
    n_runs: int


class ConcurrentScheduler:
    """Run N adaptive context loads concurrently against one shared Engine.

    ``contention=None`` calibrates from this host's measured stacked-decode
    throughput (``ContentionModel.measured()``); pass an explicit
    :class:`~repro.streaming.pipeline.ContentionModel` to pin the factors
    (e.g. ``ContentionModel({})`` for the conservative fully-serialized
    model, or ``ContentionModel({1: 1.0, 8: 1.0})`` for an idealized
    perfectly-batching engine).
    """

    def __init__(
        self,
        engine: Engine,
        *,
        contention: Optional[ContentionModel] = None,
    ):
        self.engine = engine
        self.contention = (
            contention if contention is not None else ContentionModel.measured()
        )
        self._n_active = 1

    # ------------------------------------------------------------------

    def run(self, requests: List[SessionRequest]) -> SchedulerResult:
        if not requests:
            raise ValueError("ConcurrentScheduler.run needs at least one request")
        for r in requests:
            if r.session.engine is not self.engine:
                raise ValueError(
                    "every request's session must share the scheduler's Engine"
                )
            if r.tokens.ndim != 2 or r.tokens.shape[0] != 1:
                raise ValueError(
                    f"scheduler requests are single-row: tokens must be (1, T), "
                    f"got {r.tokens.shape}"
                )
        n = len(requests)
        caches = self.engine.empty_caches(n)
        if caches.kv_k is None:
            raise ValueError(
                f"scheduler needs a KV-cache family, got {self.engine.cfg.family}"
            )
        scale = lambda: self.contention.factor(self._n_active)  # noqa: E731
        tasks = [
            SessionTask(
                r.session,
                r.context_id,
                r.tokens,
                r.network,
                row=i,
                prior_throughput_gbps=r.prior_throughput_gbps,
                start_t=r.start_t,
                compute_scale=scale,
                transport=r.transport,
            )
            for i, r in enumerate(requests)
        ]
        acct = [_SessionAccount() for _ in tasks]
        stats = _BatchStats()
        self._n_active = n
        wall0 = time.perf_counter()
        while True:
            live = [t for t in tasks if not t.done]
            if not live:
                break
            stats.n_rounds += 1
            # step in virtual-time order: the session whose next fetch
            # completes first resolves its chunk first (matches how a real
            # shared frontend would see arrivals).  Over wall-real
            # transports (tcp / paced sim), a task whose in-flight fetch
            # hasn't landed yet is deferred to a later round rather than
            # blocked on — one straggling socket must not convoy the other
            # sessions' ready work; when nothing is ready, block on the
            # virtual-earliest fetch (the round has no other work to do).
            live.sort(key=lambda t: t.next_fetch_t)
            ready = [t for t in live if t.fetch_ready]
            round_runs: List[RunWork] = []
            round_texts: List[TextWork] = []
            for t in ready if ready else live[:1]:
                self._n_active = sum(1 for x in tasks if not x.done)
                for w in t.step():
                    (round_runs if isinstance(w, RunWork) else round_texts).append(w)
            # drain: decodes/inserts land before recomputes — a task emits
            # at most [run, text] per round, so this preserves its order
            caches = self._execute_runs(round_runs, caches, acct, stats)
            caches = self._execute_texts(round_texts, caches, acct, stats)
        jax.block_until_ready(caches.kv_k)
        wall_total = time.perf_counter() - wall0

        sessions = [
            t.result(
                extract_row(caches, i),
                wall_decode_s=acct[i].decode_s,
                wall_recompute_s=acct[i].recompute_s,
                wall_total_s=wall_total,
                n_runs=acct[i].runs,
            )
            for i, t in enumerate(tasks)
        ]
        return SchedulerResult(
            sessions=sessions,
            caches=caches,
            wall_total_s=wall_total,
            wall_decode_s=stats.decode_s,
            wall_recompute_s=stats.recompute_s,
            n_rounds=stats.n_rounds,
            n_decode_batches=stats.n_decode_batches,
            n_text_batches=stats.n_text_batches,
            n_runs=stats.n_runs,
        )

    # ------------------------------------------------------------------

    def _execute_runs(
        self,
        runs: List[RunWork],
        caches: Caches,
        acct: List["_SessionAccount"],
        stats: "_BatchStats",
    ) -> Caches:
        """Cross-request stacked decode + one batched insert per table set."""
        if not runs:
            return caches
        groups: Dict[int, List[RunWork]] = {}
        for w in runs:
            groups.setdefault(id(w.tables), []).append(w)
        for group in groups.values():
            t0 = time.perf_counter()
            # token counts come from the plan (validated against every
            # fetched blob's header at fetch time); decode_chunk_runs
            # cross-checks the decoded total against them
            kv, spans = kvcodec.decode_chunk_runs(
                [w.blobs for w in group],
                group[0].tables,
                out_dtype=caches.kv_k.dtype,
                run_tokens=[w.n_tokens for w in group],
            )
            caches = self.engine.insert_runs(
                caches,
                kv,
                rows=[w.row for w in group],
                starts=[w.start for w in group],
                run_tokens=[n for _, n in spans],
            )
            dt = time.perf_counter() - t0
            stats.decode_s += dt
            stats.n_decode_batches += 1
            stats.n_runs += len(group)
            total = sum(w.n_tokens for w in group)
            for w in group:
                acct[w.row].decode_s += dt * w.n_tokens / total
                acct[w.row].runs += 1
        return caches

    def _execute_texts(
        self,
        texts: List[TextWork],
        caches: Caches,
        acct: List["_SessionAccount"],
        stats: "_BatchStats",
    ) -> Caches:
        """Coalesced TEXT recompute: one padded masked forward per chunk
        width (rows whose request has no TEXT chunk this round are masked
        out with width 0)."""
        if not texts:
            return caches
        n = caches.length.shape[0]
        by_tc: Dict[int, List[TextWork]] = {}
        for w in texts:
            by_tc.setdefault(w.n_tokens, []).append(w)
        for tc, group in sorted(by_tc.items()):
            t0 = time.perf_counter()
            if 2 * len(group) >= n:
                # most (or all) rows recompute: width-masked full-batch
                # forward — non-participating rows ride along with width 0,
                # no gather/scatter traffic
                toks = np.zeros((n, tc), np.int32)
                widths = np.zeros((n,), np.int32)
                for w in group:
                    toks[w.row] = np.asarray(w.tokens[0], np.int32)
                    widths[w.row] = tc
                _, caches = self.engine.prefill_extend_rows(
                    jnp.asarray(toks), caches, widths
                )
            else:
                # a small subset: gather the participating rows into a
                # compact sub-batch so compute scales with them, not the
                # full batch
                toks = np.stack(
                    [np.asarray(w.tokens[0], np.int32) for w in group]
                )
                _, caches = self.engine.prefill_extend_gather(
                    jnp.asarray(toks), caches, [w.row for w in group]
                )
            dt = time.perf_counter() - t0
            stats.recompute_s += dt
            stats.n_text_batches += 1
            for w in group:
                acct[w.row].recompute_s += dt / len(group)
        return caches


@dataclasses.dataclass
class _SessionAccount:
    """Per-session share of the batched dispatch times."""

    decode_s: float = 0.0
    recompute_s: float = 0.0
    runs: int = 0


@dataclasses.dataclass
class _BatchStats:
    decode_s: float = 0.0
    recompute_s: float = 0.0
    n_rounds: int = 0
    n_decode_batches: int = 0
    n_text_batches: int = 0
    n_runs: int = 0
