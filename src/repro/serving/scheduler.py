"""Multi-session serving on one shared Engine: closed waves and the
continuous-admission event loop over the full load→generate session
lifecycle.

A session's life on the engine has two phases.  **Loading** (the paper's
scope): the context KV streams in — decode, insert, TEXT recompute —
until the row holds the realized prefix and TTFT is measured.  **Generating**
(ISSUE 9): if the request carries a
:class:`~repro.serving.generation.GenerationSpec`, the session does not
exit at TTFT — it keeps its row and emits output tokens on the *same*
shared Engine, its decode steps stacked with every other generating
session's into one ``Engine.decode_step_rows`` dispatch per step
(continuous batching: sessions join and leave the decode batch at step
boundaries), interleaved with other sessions' context loads on the virtual
clock.  Per-token times surface as :class:`RequestTimeline` tokens-out /
TPOT fields, so the open-loop benchmark measures end-to-end tokens/s under
SLO rather than context-load latency alone.

Two schedulers share one execution substrate:

* :class:`ConcurrentScheduler` — the closed-wave form (ISSUE 3): N requests
  are all admitted at once and the wave drains to empty.  It remains the
  continuous scheduler's differential oracle, and the N=1 oracle is
  ``ServeSession`` itself (and ``Engine.generate_with_kv`` for the
  generation phase).
* :class:`ContinuousScheduler` — the open-loop form (ISSUE 5): requests
  *arrive* over virtual time (``SessionRequest.start_t`` is the arrival
  instant), an admission queue — FIFO by default, earliest-SLO-deadline
  first with ``admission="edf"`` — feeds a fixed-capacity :class:`RowPool`
  over one batch-of-requests cache, and rows are recycled to waiting
  requests the moment a session finishes loading (no generation requested)
  or finishes generating.

Either way, *decisions* are per-request — every load owns its
``StreamClock``, Algorithm 1 policy, bandwidth trace and double-buffered
segmenter, exactly as in the single-session loop — while the resolved work
of all live loads drains into cross-request batched execution:

  * **decode** — ready runs from different requests are stacked into a
    single ``codec.decode_chunk_runs`` call (one pair of lane-stacked rANS
    scans + one jitted assemble, run geometry — not request identity —
    shaping the jit signature);
  * **insert** — the decoded concat lands in the batch-of-requests cache
    through ``Engine.insert_runs`` (vmap'd per-row-offset
    ``dynamic_update_slice``, one dispatch for all runs);
  * **recompute** — TEXT chunks with a common token count coalesce into one
    padded width-masked ``Engine.prefill_extend_rows`` forward, or a
    gather→compact→scatter ``prefill_extend_gather`` for small subsets.

Event loop (continuous form).  Each iteration is keyed on the three things
that can unblock work — arrivals, fetch completions, and generation step
boundaries:

  1. **admission** — the virtual frontier is the earliest instant any live
     task next acts (its pending fetch's completion when peekable, else its
     fetch start).  Waiting requests whose arrival (or suspension) instant
     has passed the frontier take free rows in ``(ready_t, index)`` order;
     a request admitted to a row that has been free since before it arrived
     is backdated to its exact arrival instant, so noticing an arrival a
     round late costs nothing on the virtual clock.  Recycled rows are
     zeroed first (``Engine.reset_rows``).
  2. **preemption** (optional, :class:`PreemptionPolicy`) — when a ready
     waiter finds no free row, a live session whose in-flight fetch is
     *known* to land past its own SLO deadline (+ margin) can be preempted:
     its ``FetchHandle`` is cancelled (real I/O stops; the chunk is
     re-decided on resume), its realized row prefix is suspended into a
     :class:`~repro.serving.kv_layout.RowSnapshot` (``Engine.save_row``),
     and the tight-deadline waiter takes the row instead of convoying.  The
     suspended session re-enters the admission queue and is restored
     (``Engine.restore_row`` — bit-exact round trip, possibly into a
     different row) when a row next frees.  Victim selection is pluggable:
     ``victim="straggler"`` (default, PR 5 behavior) evicts the
     latest-landing doomed fetch; ``victim="least_work"`` is cost-aware —
     it evicts the eligible session with the least *realized* work
     (loaders' realized prefix tokens vs. generating sessions' context +
     emitted tokens), counting generating rows as always eligible since
     their residual work suspends losslessly through the same snapshot
     path (``current_token`` carries the next decode input host-side).
  3. **generation step** — when the earliest generation step boundary
     precedes every live loader's next fetch, all generating sessions that
     are ready at that instant stack into one ``Engine.decode_step_rows``
     dispatch; each participant's next token is picked host-side
     (greedy, or seeded sampling), the step's virtual duration is
     ``gen_step_s × ContentionModel.gen_factor(M)`` (measured stacked
     decode-step curve, decode-curve fallback), and every participant's
     next boundary advances to the step's end — late finishers join the
     *next* step, which is exactly continuous batching.
  4. **round** — exactly the wave scheduler's round: live loading tasks
     step in virtual-time order (wall-real transports whose fetch hasn't
     landed are deferred, not blocked on), and the emitted work executes
     batched, decodes/inserts before recomputes.

Contention feedback runs off the *time-varying live-session count* —
loading **and** generating: every decision samples
``ContentionModel.factor(n_live)`` for decode and
``ContentionModel.text_factor(n_live)`` for TEXT recompute (separately
measured prefill-concurrency curve; decode-curve fallback), so a fresh
admission — or a session entering its generation phase — immediately
inflates every other session's projected compute (Algorithm-1 adaptation
sees decode pressure), and a completion immediately relaxes it.

Mesh sharding (shard-aware row addressing).  Both schedulers run unchanged
on a ``serving.mesh_engine.ShardedEngine``, whose batch-of-requests cache
splits its row axis over S mesh devices in blocked ranges — global row
``r`` lives on shard ``r // (B/S)``.  The schedulers see global row ids
throughout (the engine's shard_map kernels translate); what changes is
capacity and pricing: caches round up to whole row shards
(``Engine.cache_rows``), the continuous pool becomes a
:class:`ShardedRowPool` that balances admissions across shards, contention
reads the measured curves at the per-shard width (``factor_sharded`` —
each shard is its own compute domain; a stacked generation step charges
the busiest shard's width), and optional ``shard_transports`` give every
shard its own fetch-bandwidth domain.  At S=1 each of these degenerates
exactly, keeping the unsharded behavior bit-identical.

Failure isolation (ISSUE 6).  When a request's session carries a
``retry_policy``, every fetch fault is absorbed *inside* its own
``SessionTask`` — classified, retried with backoff charged to that task's
clock, degraded to coarser levels / TEXT — and a task whose chunk fails
past all fallbacks simply reads ``done`` with ``status == "failed"``: its
final step emits only the flushed valid prefix, so nothing corrupt ever
enters a cross-request decode/insert batch; the continuous loop's normal
completion handling then releases its row to waiters like any other
finish.  Co-scheduled tenants see at most the contention relaxing.  The
per-result failure status and retry/degrade/fallback counters surface in
``sessions[i]`` and aggregate as ``n_failed``.  Without a retry policy the
legacy contract stands: a fetch error raises out of ``run()`` (pinned by
tests), taking the wave with it — opt in to isolation per session.

Differential invariants (held by tests/test_continuous.py and
tests/test_generation.py): with every arrival at t=0, preemption disabled
and the pool sized to the request count (``rows=None``, the default), the
continuous loop degenerates to exactly the wave scheduler — same admission
order, same rounds, same batched dispatches, bit-identical caches and
decisions — and at N=1 both degenerate to ``ServeSession``.  (An over-sized
pool keeps per-request decisions and caches equivalent but may route small
TEXT groups through the gather path, whose dispatch split keys on the pool
size.)  Generation is strictly opt-in: a request with ``generation=None``
(or a zero-token spec) takes the load-only path bit-identically — same
rounds, same caches, same TTFTs — and N=1 continuous generation is
token-identical to the ``Engine.generate_with_kv`` greedy oracle.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as kvcodec
from repro.models.lm import Caches
from repro.serving.engine import Engine
from repro.serving.generation import GenerationSpec, GenerationTask
from repro.serving.kv_layout import extract_row
from repro.serving.session import (
    RunWork,
    ServeSession,
    SessionResult,
    SessionTask,
    TextWork,
)
from repro.streaming.network import NetworkModel
from repro.streaming.pipeline import ContentionModel

__all__ = [
    "SessionRequest",
    "SchedulerResult",
    "ConcurrentScheduler",
    "RowPool",
    "ShardedRowPool",
    "PreemptionPolicy",
    "RequestTimeline",
    "ContinuousResult",
    "ContinuousScheduler",
]


@dataclasses.dataclass
class SessionRequest:
    """One context load: a session's knobs bound to a request's inputs.

    ``session`` carries the per-request configuration (SLO, cost model,
    adaptation knobs, streamer/store) and must share the scheduler's Engine;
    ``tokens`` is the (1, T) context for TEXT recomputes.  ``start_t`` is
    the request's *arrival* instant on the virtual clock: the wave scheduler
    starts the clock there outright; the continuous scheduler anchors the
    SLO there and admits the request when a row frees (TTFT then includes
    queueing delay).
    """

    session: ServeSession
    context_id: str
    tokens: np.ndarray
    network: NetworkModel
    prior_throughput_gbps: Optional[float] = None
    start_t: float = 0.0
    # any Transport (Local/Sim/Tcp) for this request's fetches; None falls
    # back to the session's transport, else to a per-request SimTransport
    # over ``network`` (see SessionTask.__init__)
    transport: Optional[object] = None
    # what to generate once the load completes (continuous scheduler only);
    # None or a zero-token spec = load-only, the pre-ISSUE-9 lifecycle
    generation: Optional[GenerationSpec] = None


@dataclasses.dataclass
class SchedulerResult:
    """N per-request results plus scheduler-level batching counters.

    ``sessions[r].caches`` is request ``r``'s batch-1 view of the shared
    batch-of-requests cache (``caches`` holds the full batch).  Virtual
    times (``ttft_s``) are per-request and contention-aware; ``wall_*`` on
    the scheduler are realized host seconds for the whole batch run, and
    each session's ``wall_*`` is its token-weighted share of the batched
    dispatches it participated in.
    """

    sessions: List[SessionResult]
    caches: Caches
    wall_total_s: float
    wall_decode_s: float
    wall_recompute_s: float
    n_rounds: int
    n_decode_batches: int
    n_text_batches: int
    n_runs: int

    @property
    def n_failed(self) -> int:
        """Requests that finished with a failure status (isolated, not
        raised): their rows were recycled and no batch was poisoned."""
        return sum(1 for s in self.sessions if s.status != "ok")


# ---------------------------------------------------------------------------
# Shared batched executors (wave + continuous)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _SessionAccount:
    """Per-session share of the batched dispatch times."""

    decode_s: float = 0.0
    recompute_s: float = 0.0
    runs: int = 0


@dataclasses.dataclass
class _BatchStats:
    decode_s: float = 0.0
    recompute_s: float = 0.0
    gen_s: float = 0.0  # wall seconds in stacked generation steps
    n_rounds: int = 0
    n_decode_batches: int = 0
    n_text_batches: int = 0
    n_runs: int = 0
    n_gen_steps: int = 0
    n_gen_tokens: int = 0


def _execute_runs(
    engine: Engine,
    runs: List[RunWork],
    caches: Caches,
    acct_by_row: Mapping[int, _SessionAccount],
    stats: _BatchStats,
) -> Caches:
    """Cross-request stacked decode + one batched insert per table set."""
    if not runs:
        return caches
    groups: Dict[int, List[RunWork]] = {}
    for w in runs:
        groups.setdefault(id(w.tables), []).append(w)
    for group in groups.values():
        t0 = time.perf_counter()
        # token counts come from the plan (validated against every
        # fetched blob's header at fetch time); decode_chunk_runs
        # cross-checks the decoded total against them
        kv, spans = kvcodec.decode_chunk_runs(
            [w.blobs for w in group],
            group[0].tables,
            out_dtype=caches.kv_k.dtype,
            run_tokens=[w.n_tokens for w in group],
        )
        caches = engine.insert_runs(
            caches,
            kv,
            rows=[w.row for w in group],
            starts=[w.start for w in group],
            run_tokens=[n for _, n in spans],
        )
        dt = time.perf_counter() - t0
        stats.decode_s += dt
        stats.n_decode_batches += 1
        stats.n_runs += len(group)
        total = sum(w.n_tokens for w in group)
        for w in group:
            acct_by_row[w.row].decode_s += dt * w.n_tokens / total
            acct_by_row[w.row].runs += 1
    return caches


def _execute_texts(
    engine: Engine,
    texts: List[TextWork],
    caches: Caches,
    acct_by_row: Mapping[int, _SessionAccount],
    stats: _BatchStats,
) -> Caches:
    """Coalesced TEXT recompute: one padded masked forward per chunk width
    (rows whose request has no TEXT chunk this round are masked out with
    width 0)."""
    if not texts:
        return caches
    n = caches.length.shape[0]
    by_tc: Dict[int, List[TextWork]] = {}
    for w in texts:
        by_tc.setdefault(w.n_tokens, []).append(w)
    for tc, group in sorted(by_tc.items()):
        t0 = time.perf_counter()
        if 2 * len(group) >= n:
            # most (or all) rows recompute: width-masked full-batch
            # forward — non-participating rows ride along with width 0,
            # no gather/scatter traffic
            toks = np.zeros((n, tc), np.int32)
            widths = np.zeros((n,), np.int32)
            for w in group:
                toks[w.row] = np.asarray(w.tokens[0], np.int32)
                widths[w.row] = tc
            _, caches = engine.prefill_extend_rows(
                jnp.asarray(toks), caches, widths
            )
        else:
            # a small subset: gather the participating rows into a
            # compact sub-batch so compute scales with them, not the
            # full batch
            toks = np.stack(
                [np.asarray(w.tokens[0], np.int32) for w in group]
            )
            _, caches = engine.prefill_extend_gather(
                jnp.asarray(toks), caches, [w.row for w in group]
            )
        dt = time.perf_counter() - t0
        stats.recompute_s += dt
        stats.n_text_batches += 1
        # token-weighted share, mirroring the decode accounting (groups are
        # same-width today, so this equals an even split — but the share
        # rule must not silently change if grouping ever mixes widths)
        total = sum(w.n_tokens for w in group)
        for w in group:
            acct_by_row[w.row].recompute_s += dt * w.n_tokens / total
    return caches


def _validate_requests(engine: Engine, requests: List[SessionRequest]) -> None:
    for r in requests:
        if r.session.engine is not engine:
            raise ValueError(
                "every request's session must share the scheduler's Engine"
            )
        if r.tokens.ndim != 2 or r.tokens.shape[0] != 1:
            raise ValueError(
                f"scheduler requests are single-row: tokens must be (1, T), "
                f"got {r.tokens.shape}"
            )


def _req_label(idx: int, r: SessionRequest) -> str:
    return f"req{idx}:{r.context_id}"


# ---------------------------------------------------------------------------
# Closed waves (ISSUE 3) — the continuous scheduler's differential oracle
# ---------------------------------------------------------------------------


class ConcurrentScheduler:
    """Run N adaptive context loads concurrently against one shared Engine,
    as one closed wave: all requests admitted up front, the wave drains to
    empty.

    ``contention=None`` calibrates from this host's measured stacked-decode
    throughput (``ContentionModel.measured()``); pass an explicit
    :class:`~repro.streaming.pipeline.ContentionModel` to pin the factors
    (e.g. ``ContentionModel({})`` for the conservative fully-serialized
    model, or ``ContentionModel({1: 1.0, 8: 1.0})`` for an idealized
    perfectly-batching engine).

    On a mesh-sharded engine (``engine.n_shards > 1``) the wave prices
    contention per shard — N live loads spread over S row shards read the
    measured curve at ``ceil(N/S)`` — and ``shard_transports`` (one
    Transport per shard) gives each shard its own fetch bandwidth domain:
    a request without its own transport fetches through its row's shard
    transport.  On an unsharded engine both are exact no-ops.
    """

    def __init__(
        self,
        engine: Engine,
        *,
        contention: Optional[ContentionModel] = None,
        shard_transports: Optional[Sequence[object]] = None,
    ):
        self.engine = engine
        self.contention = (
            contention if contention is not None else ContentionModel.measured()
        )
        self.shard_transports = (
            list(shard_transports) if shard_transports is not None else None
        )
        n_shards = max(int(getattr(engine, "n_shards", 1)), 1)
        if self.shard_transports is not None and len(self.shard_transports) != n_shards:
            raise ValueError(
                f"shard_transports carries {len(self.shard_transports)} "
                f"transports for a {n_shards}-shard engine — one per shard"
            )
        self._n_active = 1

    # ------------------------------------------------------------------

    def run(self, requests: List[SessionRequest]) -> SchedulerResult:
        if not requests:
            raise ValueError("ConcurrentScheduler.run needs at least one request")
        _validate_requests(self.engine, requests)
        n = len(requests)
        # a sharded engine's cache rounds up to whole row shards; the extra
        # rows stay inactive (width 0 / never decoded) for the whole wave
        n_cache = self.engine.cache_rows(n)
        caches = self.engine.empty_caches(n_cache)
        if caches.kv_k is None:
            raise ValueError(
                f"scheduler needs a KV-cache family, got {self.engine.cfg.family}"
            )
        n_shards = max(int(getattr(self.engine, "n_shards", 1)), 1)
        rows_per_shard = n_cache // n_shards
        scale = lambda: self.contention.factor_sharded(  # noqa: E731
            self._n_active, n_shards
        )
        tscale = lambda: self.contention.text_factor_sharded(  # noqa: E731
            self._n_active, n_shards
        )

        def _transport(i: int, r: SessionRequest):
            if r.transport is not None or self.shard_transports is None:
                return r.transport
            return self.shard_transports[i // rows_per_shard]

        tasks = [
            SessionTask(
                r.session,
                r.context_id,
                r.tokens,
                r.network,
                row=i,
                prior_throughput_gbps=r.prior_throughput_gbps,
                start_t=r.start_t,
                compute_scale=scale,
                text_scale=tscale,
                transport=_transport(i, r),
                label=_req_label(i, r),
            )
            for i, r in enumerate(requests)
        ]
        acct = [_SessionAccount() for _ in tasks]
        acct_by_row = {i: a for i, a in enumerate(acct)}
        stats = _BatchStats()
        self._n_active = n
        wall0 = time.perf_counter()
        while True:
            live = [t for t in tasks if not t.done]
            if not live:
                break
            stats.n_rounds += 1
            # step in virtual-time order: the session whose next fetch
            # completes first resolves its chunk first (matches how a real
            # shared frontend would see arrivals).  Over wall-real
            # transports (tcp / paced sim), a task whose in-flight fetch
            # hasn't landed yet is deferred to a later round rather than
            # blocked on — one straggling socket must not convoy the other
            # sessions' ready work; when nothing is ready, block on the
            # virtual-earliest fetch (the round has no other work to do).
            live.sort(key=lambda t: t.next_fetch_t)
            ready = [t for t in live if t.fetch_ready]
            round_runs: List[RunWork] = []
            round_texts: List[TextWork] = []
            for t in ready if ready else live[:1]:
                self._n_active = sum(1 for x in tasks if not x.done)
                for w in t.step():
                    (round_runs if isinstance(w, RunWork) else round_texts).append(w)
            # drain: decodes/inserts land before recomputes — a task emits
            # at most [run, text] per round, so this preserves its order
            caches = _execute_runs(self.engine, round_runs, caches, acct_by_row, stats)
            caches = _execute_texts(self.engine, round_texts, caches, acct_by_row, stats)
        jax.block_until_ready(caches.kv_k)
        wall_total = time.perf_counter() - wall0

        sessions = [
            t.result(
                extract_row(caches, i),
                wall_decode_s=acct[i].decode_s,
                wall_recompute_s=acct[i].recompute_s,
                wall_total_s=wall_total,
                n_runs=acct[i].runs,
            )
            for i, t in enumerate(tasks)
        ]
        return SchedulerResult(
            sessions=sessions,
            caches=caches,
            wall_total_s=wall_total,
            wall_decode_s=stats.decode_s,
            wall_recompute_s=stats.recompute_s,
            n_rounds=stats.n_rounds,
            n_decode_batches=stats.n_decode_batches,
            n_text_batches=stats.n_text_batches,
            n_runs=stats.n_runs,
        )


# ---------------------------------------------------------------------------
# Row pool
# ---------------------------------------------------------------------------


class RowPool:
    """Fixed-capacity free-list over the batch-of-requests cache's rows.

    Lowest free row first (deterministic recycling), with per-row
    bookkeeping the continuous scheduler needs: since when a row has been
    free (so a backdated admission charges no phantom queueing) and whether
    it carries a previous tenant's data (so recycled rows — and only those —
    are zeroed).  Misuse raises with the request id and the pool state
    named: double allocation beyond capacity, releasing an unallocated row,
    releasing another request's row.

    Shard-aware row addressing: the base pool is one shard — every row maps
    to shard 0.  :class:`ShardedRowPool` partitions the row space into
    blocked per-shard ranges matching the sharded engine's cache layout and
    balances allocation across them.
    """

    n_shards: int = 1

    def __init__(self, n_rows: int):
        if n_rows < 1:
            raise ValueError(f"RowPool needs at least one row, got {n_rows}")
        self.n_rows = int(n_rows)
        self.rows_per_shard = self.n_rows
        self._free = list(range(self.n_rows))  # heap, ascending
        self._owner: Dict[int, str] = {}
        self._free_since = {r: 0.0 for r in range(self.n_rows)}
        self._dirty: set = set()

    def shard_of(self, row: int) -> int:
        """Shard owning ``row`` under the blocked layout (always 0 here)."""
        return 0

    def _peek_next(self) -> int:
        """The row :meth:`allocate` would hand out next (lowest free)."""
        return self._free[0]

    def _pop_next(self) -> int:
        return heapq.heappop(self._free)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def next_free_since(self) -> float:
        """Free instant of the row :meth:`allocate` would hand out next —
        the admission-policy frontier when nothing is live: every waiter
        arrived by then is an EDF candidate."""
        if not self._free:
            raise RuntimeError(f"no free rows ({self.describe()})")
        return self._free_since[self._peek_next()]

    def describe(self) -> str:
        occupied = ", ".join(
            f"row {r} -> {o!r}" for r, o in sorted(self._owner.items())
        )
        return (
            f"{self.n_free}/{self.n_rows} rows free"
            + (f"; occupied: {occupied}" if occupied else "")
        )

    def allocate(self, owner: str) -> Tuple[int, float, bool]:
        """Take the next free row (lowest; sharded pools balance shard load
        first) for ``owner``.

        Returns ``(row, free_since_t, needs_reset)``; the caller must zero
        the row (``Engine.reset_rows``) when ``needs_reset`` — it carries a
        previous tenant's KV and length.
        """
        if not self._free:
            raise RuntimeError(
                f"admitting request {owner!r} beyond row-pool capacity: "
                f"{self.describe()}"
            )
        row = self._pop_next()
        if row in self._owner:  # internal invariant, should be unreachable
            raise RuntimeError(
                f"row pool corrupt: free row {row} already owned by "
                f"{self._owner[row]!r} ({self.describe()})"
            )
        self._owner[row] = owner
        dirty = row in self._dirty
        self._dirty.discard(row)
        return row, self._free_since[row], dirty

    def release(self, row: int, owner: str, now_t: float) -> None:
        """Return ``owner``'s row to the free list at virtual instant
        ``now_t`` (session finished or was preempted)."""
        if row not in self._owner:
            raise RuntimeError(
                f"releasing row {row} for request {owner!r}: row is not "
                f"allocated ({self.describe()})"
            )
        if self._owner[row] != owner:
            raise RuntimeError(
                f"releasing row {row} for request {owner!r}: row is owned "
                f"by {self._owner[row]!r} ({self.describe()})"
            )
        del self._owner[row]
        self._free_since[row] = float(now_t)
        self._dirty.add(row)
        heapq.heappush(self._free, row)


class ShardedRowPool(RowPool):
    """Row pool over a mesh-sharded cache: rows map to shards in blocked
    ranges (row ``r`` → shard ``r // rows_per_shard``, the layout of
    ``serving.mesh_engine.ShardedEngine``), and allocation balances *load*
    across shards — the free row on the least-occupied shard, lowest row
    breaking ties — so stacked decode steps and per-shard transports see
    even per-shard widths instead of piling the first arrivals onto
    shard 0.  On one shard this degenerates to the base pool's
    lowest-free-row order exactly."""

    def __init__(self, n_rows: int, *, n_shards: int):
        if n_shards < 1:
            raise ValueError(
                f"ShardedRowPool needs n_shards >= 1, got {n_shards}"
            )
        if n_rows % n_shards:
            raise ValueError(
                f"ShardedRowPool: {n_rows} rows do not split over "
                f"{n_shards} shards (whole shards required — size the cache "
                f"with Engine.cache_rows)"
            )
        super().__init__(n_rows)
        self.n_shards = int(n_shards)
        self.rows_per_shard = self.n_rows // self.n_shards

    def shard_of(self, row: int) -> int:
        return int(row) // self.rows_per_shard

    def _peek_next(self) -> int:
        load = [0] * self.n_shards
        for r in self._owner:
            load[self.shard_of(r)] += 1
        return min(self._free, key=lambda r: (load[self.shard_of(r)], r))

    def _pop_next(self) -> int:
        row = self._peek_next()
        self._free.remove(row)
        heapq.heapify(self._free)
        return row


# ---------------------------------------------------------------------------
# Continuous admission (ISSUE 5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """When may a waiting request evict a live session, and which one?

    A live *loading* session is preemptible when its in-flight fetch's
    completion is knowable (peeked from the handle / the virtual clock) and
    lands more than ``margin_s`` past the session's own SLO deadline — it
    will blow its SLO regardless, so holding the row only convoys the
    queue.  With ``require_waiting_headroom`` (default) the waiter must
    still have SLO headroom at the preemption instant; a waiter that has
    already blown its own deadline gains nothing from thrashing another
    session's row.

    ``victim`` picks among the eligible candidates:

    * ``"straggler"`` (default, PR 5 behavior) — evict the latest-landing
      doomed fetch; only doomed loaders are candidates.
    * ``"least_work"`` — cost-aware: evict the candidate with the least
      *realized* work (tokens materialized in its row), so the cheapest
      state to re-establish leaves first.  Generating sessions join the
      candidate set here — their TTFT is already served and their residual
      state suspends losslessly (bit-exact row snapshot + host-side next
      token) — but since their realized work includes the whole context
      plus emitted tokens, they are evicted only when no cheaper doomed
      loader exists.  Under either rule a generating candidate must have
      emitted at least one token since it (re)started — a freshly resumed
      (or just-transitioned) generation is not instantly re-evictable,
      which is what keeps two generating rows from livelocking by swapping
      one row back and forth at a single virtual instant (the multi-row
      pools of the mesh-sharded engine make this case the norm).

    ``gen_slo`` additionally makes a *generating* session eligible (under
    either victim rule) once it has already missed its per-token SLO
    (``GenerationSpec.gen_slo_s``, realized TPOT over the limit) on a token
    emitted since its last resume — it is demonstrably not meeting its
    latency target, so a ready waiter may take its row rather than convoy.
    The since-resume gate stops a freshly restored task from being
    re-evicted for pre-suspension misses before it takes a single step.
    Such rows carry an infinite ``end_t``, so the straggler rule prefers
    them over any doomed loader (a fetch that lands late still lands; a
    missed gen-SLO never un-misses).
    """

    margin_s: float = 0.0
    require_waiting_headroom: bool = True
    victim: str = "straggler"
    gen_slo: bool = False

    def __post_init__(self):
        if self.victim not in ("straggler", "least_work"):
            raise ValueError(
                f"PreemptionPolicy.victim must be 'straggler' or "
                f"'least_work', got {self.victim!r}"
            )


@dataclasses.dataclass(frozen=True)
class _VictimCandidate:
    """One preemption-eligible session (eligibility already filtered)."""

    obj: object  # SessionTask (loading) or GenerationTask (generating)
    is_gen: bool
    end_t: float  # doomed fetch's landing instant (inf for generating rows)
    preempt_t: float  # the instant the eviction would take effect
    work: int  # realized tokens in the row (context + emitted for gen)


def _select_victim(
    policy: PreemptionPolicy, candidates: List[_VictimCandidate]
) -> Optional[_VictimCandidate]:
    """Pick the eviction victim among eligible candidates.

    ``straggler`` takes the latest-landing fetch, ``least_work`` the least
    realized work; both break ties in candidate order (which the caller
    builds in live-list order, keeping the straggler path's choice
    bit-identical to the PR 5 inline loop).
    """
    if not candidates:
        return None
    best = candidates[0]
    if policy.victim == "least_work":
        for c in candidates[1:]:
            if c.work < best.work:
                best = c
        return best
    for c in candidates[1:]:
        if c.end_t > best.end_t:
            best = c
    return best


@dataclasses.dataclass
class RequestTimeline:
    """Admission-level life of one request on the virtual clock.

    ``finish_t`` is the *load*'s completion (the TTFT instant).  When the
    request generates, ``tokens_out`` / ``token_ts`` record each emitted
    token and its virtual emission instant, and ``gen_finish_t`` the last
    token's — so TPOT and end-to-end latency both read off the timeline.
    ``gen_slo_miss`` counts emitted tokens whose realized TPOT exceeded the
    request's ``GenerationSpec.gen_slo_s`` (0 when no per-token SLO was
    set).
    """

    index: int
    arrival_t: float
    admit_t: float = float("nan")
    finish_t: float = float("nan")
    rows_used: List[int] = dataclasses.field(default_factory=list)
    preempt_ts: List[float] = dataclasses.field(default_factory=list)
    resume_ts: List[float] = dataclasses.field(default_factory=list)
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    token_ts: List[float] = dataclasses.field(default_factory=list)
    gen_finish_t: float = float("nan")
    gen_slo_miss: int = 0

    @property
    def queue_wait_s(self) -> float:
        return self.admit_t - self.arrival_t

    @property
    def n_preemptions(self) -> int:
        return len(self.preempt_ts)

    @property
    def n_tokens_out(self) -> int:
        return len(self.tokens_out)

    @property
    def tpot_s(self) -> List[float]:
        """Per-output-token latencies: the first token is measured from the
        load's finish (the TTFT instant), each later token from the
        previous one — suspension time between tokens is included."""
        if not self.token_ts:
            return []
        prev = [self.finish_t] + self.token_ts[:-1]
        return [t - p for t, p in zip(self.token_ts, prev)]

    @property
    def mean_tpot_s(self) -> float:
        tp = self.tpot_s
        return sum(tp) / len(tp) if tp else float("nan")


@dataclasses.dataclass
class ContinuousResult:
    """Per-request results (request order) plus open-loop counters.

    ``sessions[i].ttft_s`` is measured from request ``i``'s *arrival* —
    queueing and suspension time included.  ``occupancy`` samples the live
    loading-row count per round ``(virtual_t, n_live)`` and
    ``gen_occupancy`` the stacked-step width per generation step
    ``(virtual_t, n_generating)``; preemption/resume counts aggregate the
    per-request ``timeline`` entries.  ``wall_gen_s`` is realized host
    seconds inside stacked ``decode_step_rows`` dispatches (per-step token
    sync included), so ``n_gen_tokens / wall_gen_s`` is the engine's
    realized aggregate generation throughput.
    """

    sessions: List[SessionResult]
    timeline: List[RequestTimeline]
    occupancy: List[Tuple[float, int]]
    n_rows: int
    wall_total_s: float
    wall_decode_s: float
    wall_recompute_s: float
    n_rounds: int
    n_decode_batches: int
    n_text_batches: int
    n_runs: int
    n_preemptions: int
    n_resumes: int
    gen_occupancy: List[Tuple[float, int]] = dataclasses.field(default_factory=list)
    wall_gen_s: float = 0.0
    n_gen_steps: int = 0
    n_gen_tokens: int = 0

    @property
    def n_failed(self) -> int:
        """Requests that finished with a failure status (isolated, not
        raised): their rows were recycled and no batch was poisoned."""
        return sum(1 for s in self.sessions if s.status != "ok")

    @property
    def n_gen_slo_miss(self) -> int:
        """Emitted tokens (across all requests) whose realized TPOT missed
        the request's per-token generation SLO."""
        return sum(t.gen_slo_miss for t in self.timeline)


class ContinuousScheduler:
    """Open-loop serving: arrivals feed a row pool; rows recycle on finish.

    ``rows=None`` sizes the pool to the request count (pure continuous
    batching with no queueing — and, with every arrival at t=0 and
    preemption off, exact wave-scheduler degeneration).  ``preemption=None``
    disables preemption; pass a :class:`PreemptionPolicy` to let
    tight-deadline waiters evict sessions whose in-flight fetches straggle
    past their SLO (``victim="least_work"`` for cost-aware selection with
    generating rows eligible).  ``admission`` orders the ready waiters:
    ``"fifo"`` (default) by ``(ready_t, index)``, ``"edf"`` by SLO deadline
    (``start_t + slo_s``) — earliest deadline takes the next free row.
    ``contention`` as in :class:`ConcurrentScheduler`, driven here by the
    time-varying live-session count (loading + generating).  ``gen_step_s``
    is the virtual duration of one uncontended generation decode step;
    stacked steps of M rows charge ``gen_step_s ×
    contention.gen_factor(M)``.

    On a mesh-sharded engine (``engine.n_shards > 1``) the pool rounds up
    to whole row shards and balances admissions across them
    (:class:`ShardedRowPool`), contention prices per shard (the measured
    curves read at the even-spread per-shard width, and a stacked step at
    the *busiest shard's* participant count — shards step in lockstep, so
    the widest shard sets the step's duration), and ``shard_transports``
    (one Transport per shard) fans fetch bandwidth out per shard: a request
    without its own transport fetches through whichever shard its current
    row lives on, re-bound on every resume.  At one shard every one of
    these degenerates exactly to the unsharded behavior.
    """

    # hard backstop against a pathological preempt/resume livelock: any
    # legitimate workload preempts orders of magnitude less than this
    MAX_PREEMPTIONS = 100_000

    def __init__(
        self,
        engine: Engine,
        *,
        rows: Optional[int] = None,
        contention: Optional[ContentionModel] = None,
        preemption: Optional[PreemptionPolicy] = None,
        admission: str = "fifo",
        gen_step_s: float = 2e-3,
        shard_transports: Optional[Sequence[object]] = None,
    ):
        if rows is not None and rows < 1:
            raise ValueError(f"ContinuousScheduler needs rows >= 1, got {rows}")
        if admission not in ("fifo", "edf"):
            raise ValueError(
                f"ContinuousScheduler admission must be 'fifo' or 'edf', "
                f"got {admission!r}"
            )
        if gen_step_s <= 0:
            raise ValueError(
                f"ContinuousScheduler needs gen_step_s > 0, got {gen_step_s}"
            )
        self.engine = engine
        self.rows = rows
        self.contention = (
            contention if contention is not None else ContentionModel.measured()
        )
        self.preemption = preemption
        self.admission = admission
        self.gen_step_s = float(gen_step_s)
        self.shard_transports = (
            list(shard_transports) if shard_transports is not None else None
        )
        n_shards = max(int(getattr(engine, "n_shards", 1)), 1)
        if self.shard_transports is not None and len(self.shard_transports) != n_shards:
            raise ValueError(
                f"shard_transports carries {len(self.shard_transports)} "
                f"transports for a {n_shards}-shard engine — one per shard"
            )
        self._n_active = 1

    # ------------------------------------------------------------------

    def run(self, requests: List[SessionRequest]) -> ContinuousResult:
        if not requests:
            raise ValueError("ContinuousScheduler.run needs at least one request")
        _validate_requests(self.engine, requests)
        n_shards = max(int(getattr(self.engine, "n_shards", 1)), 1)
        n_rows = self.rows if self.rows is not None else len(requests)
        # sharded caches allocate whole row shards; the rounded-up rows are
        # real pool capacity (admittable), not dead padding
        n_rows = self.engine.cache_rows(n_rows)
        caches = self.engine.empty_caches(n_rows)
        if caches.kv_k is None:
            raise ValueError(
                f"scheduler needs a KV-cache family, got {self.engine.cfg.family}"
            )
        pool = (
            ShardedRowPool(n_rows, n_shards=n_shards)
            if n_shards > 1
            else RowPool(n_rows)
        )
        scale = lambda: self.contention.factor_sharded(  # noqa: E731
            self._n_active, n_shards
        )
        tscale = lambda: self.contention.text_factor_sharded(  # noqa: E731
            self._n_active, n_shards
        )

        tasks: List[Optional[SessionTask]] = [None] * len(requests)
        snaps: Dict[int, object] = {}  # request idx -> RowSnapshot
        acct = [_SessionAccount() for _ in requests]
        timeline = [
            RequestTimeline(index=i, arrival_t=float(r.start_t))
            for i, r in enumerate(requests)
        ]
        results: List[Optional[SessionResult]] = [None] * len(requests)
        stats = _BatchStats()
        occupancy: List[Tuple[float, int]] = []
        n_preempt = n_resume = 0

        # admission queue: arrivals up front, suspended sessions re-enter
        # at their suspension instant; (ready_t, index) heap order
        waiting: List[Tuple[float, int]] = [
            (float(r.start_t), i) for i, r in enumerate(requests)
        ]
        heapq.heapify(waiting)
        live: List[SessionTask] = []
        acct_by_row: Dict[int, _SessionAccount] = {}
        row_owner: Dict[int, int] = {}  # row -> request idx

        # generation phase: sessions that finished loading and now emit
        # output tokens on their row; suspended generations park here and
        # re-enter through the same waiting queue as suspended loads
        generating: List[GenerationTask] = []
        parked_gen: Dict[int, GenerationTask] = {}
        gen_occupancy: List[Tuple[float, int]] = []
        gen_busy_t = 0.0  # the engine's generation-step frontier

        def _slo_deadline(idx: int) -> float:
            return float(requests[idx].start_t) + requests[idx].session.slo_s

        def peek_next_waiter(frontier: float) -> Tuple[float, int]:
            """The waiter the admission policy would admit next among those
            ready by ``frontier`` (FIFO: earliest ready; EDF: earliest SLO
            deadline, FIFO order breaking ties)."""
            if self.admission == "edf":
                ready = [w for w in waiting if w[0] <= frontier]
                return min(ready, key=lambda w: (_slo_deadline(w[1]), w))
            return waiting[0]

        def pop_next_waiter(frontier: float) -> Tuple[float, int]:
            if self.admission == "edf":
                best = peek_next_waiter(frontier)
                waiting.remove(best)
                heapq.heapify(waiting)
                return best
            return heapq.heappop(waiting)

        def row_transport(row: int, r: SessionRequest):
            """The transport a session on ``row`` fetches through: its own
            if the request pinned one, else its row shard's transport (the
            per-shard fetch-bandwidth domain), else the session fallback."""
            if r.transport is not None or self.shard_transports is None:
                return r.transport
            return self.shard_transports[pool.shard_of(row)]

        def admit(idx: int, ready_t: float) -> None:
            nonlocal caches, n_resume
            r = requests[idx]
            row, free_since, dirty = pool.allocate(_req_label(idx, r))
            if dirty:
                caches = self.engine.reset_rows(caches, [row])
            # a row free since before the request was ready charges no
            # phantom queueing: admission is backdated to ready_t itself
            admit_t = max(ready_t, free_since)
            g = parked_gen.pop(idx, None)
            if g is not None:
                # a suspended *generation* resumes: restore the snapshot
                # (context + emitted KV, bit-exact) and rejoin the decode
                # batch at the next step boundary
                caches = self.engine.restore_row(caches, snaps.pop(idx), row)
                g.resume(row, admit_t)
                generating.append(g)
                timeline[idx].resume_ts.append(admit_t)
                n_resume += 1
                timeline[idx].rows_used.append(row)
                row_owner[row] = idx
                acct_by_row[row] = acct[idx]
                return
            t = tasks[idx]
            if t is None:
                t = SessionTask(
                    r.session,
                    r.context_id,
                    r.tokens,
                    r.network,
                    row=row,
                    prior_throughput_gbps=r.prior_throughput_gbps,
                    start_t=r.start_t,
                    compute_scale=scale,
                    text_scale=tscale,
                    transport=row_transport(row, r),
                    label=_req_label(idx, r),
                )
                t.begin_at(admit_t)
                tasks[idx] = t
                timeline[idx].admit_t = admit_t
            else:
                t.resume(row, admit_t)
                if r.transport is None and self.shard_transports is not None:
                    # the resumed row may live on a different shard: fetches
                    # from here on go through that shard's transport
                    t.transport = self.shard_transports[pool.shard_of(row)]
                caches = self.engine.restore_row(caches, snaps.pop(idx), row)
                timeline[idx].resume_ts.append(admit_t)
                n_resume += 1
            timeline[idx].rows_used.append(row)
            row_owner[row] = idx
            acct_by_row[row] = acct[idx]
            live.append(t)

        def preempt(victim: SessionTask, now_t: float) -> None:
            nonlocal caches, n_preempt
            idx = row_owner[victim.row]
            row = victim.row
            snaps[idx] = self.engine.save_row(caches, row, victim.realized_tokens)
            victim.suspend(now_t)  # cancels the in-flight fetch handle
            live.remove(victim)
            del row_owner[row]
            del acct_by_row[row]
            pool.release(row, victim.label, now_t)
            timeline[idx].preempt_ts.append(now_t)
            n_preempt += 1
            if n_preempt > self.MAX_PREEMPTIONS:
                raise RuntimeError(
                    f"preemption runaway: {n_preempt} preemptions "
                    f"({pool.describe()})"
                )
            heapq.heappush(waiting, (now_t, idx))

        def preempt_gen(g: GenerationTask, now_t: float) -> None:
            nonlocal caches, n_preempt
            idx = g.index
            row = g.row
            # the snapshot spans context + emitted tokens; current_token
            # rides host-side, so the resumed decode is bit-exact
            snaps[idx] = self.engine.save_row(caches, row, g.realized_tokens)
            g.suspend(now_t)
            # surface the running miss count while parked (the completion
            # handler writes the final one)
            timeline[idx].gen_slo_miss = g.slo_misses
            generating.remove(g)
            parked_gen[idx] = g
            del row_owner[row]
            del acct_by_row[row]
            pool.release(row, g.label, now_t)
            timeline[idx].preempt_ts.append(now_t)
            n_preempt += 1
            if n_preempt > self.MAX_PREEMPTIONS:
                raise RuntimeError(
                    f"preemption runaway: {n_preempt} preemptions "
                    f"({pool.describe()})"
                )
            heapq.heappush(waiting, (now_t, idx))

        def start_generation(idx: int, t: SessionTask, finish_t: float) -> bool:
            """Transition a finished load into the generating phase on its
            row.  False (no transition) for load-only or failed requests."""
            spec = requests[idx].generation
            if spec is None or spec.n_tokens <= 0 or t.failed:
                return False
            generating.append(
                GenerationTask(
                    spec,
                    index=idx,
                    label=t.label,
                    row=t.row,
                    start_t=finish_t,
                    context_tokens=t.realized_tokens,
                    capacity=self.engine.capacity,
                )
            )
            return True

        def gen_next_t() -> float:
            """Virtual instant of the next stacked generation step: the
            engine frontier, or the earliest ready row if later."""
            return max(gen_busy_t, min(g.ready_t for g in generating))

        def gen_step() -> None:
            """One stacked decode step: every generating row that is ready
            at the step instant advances one token in a single
            ``decode_step_rows`` dispatch; rows mid-resume join the next
            step (continuous batching at step boundaries)."""
            nonlocal caches, gen_busy_t
            step_t = gen_next_t()
            part = [g for g in generating if g.ready_t <= step_t]
            tokens = np.zeros((n_rows, 1), np.int32)
            active = np.zeros((n_rows,), bool)
            for g in part:
                tokens[g.row, 0] = g.current_token
                active[g.row] = True
            t0 = time.perf_counter()
            logits, caches = self.engine.decode_step_rows(
                jnp.asarray(tokens), caches, jnp.asarray(active)
            )
            # host sync per step: the sampled tokens are the next inputs
            last = np.asarray(logits[:, -1], np.float32)
            dt = time.perf_counter() - t0
            m = len(part)
            # the shards step in lockstep, so the step's virtual duration is
            # the busiest shard's stacked width (== m on one shard)
            if n_shards > 1:
                per_shard = [0] * n_shards
                for g in part:
                    per_shard[pool.shard_of(g.row)] += 1
                width = max(per_shard)
            else:
                width = m
            end_t = step_t + self.gen_step_s * self.contention.gen_factor(width)
            stats.gen_s += dt
            stats.n_gen_steps += 1
            stats.n_gen_tokens += m
            gen_occupancy.append((step_t, m))
            for g in part:
                g.record(g.next_token(last[g.row]), end_t)
            gen_busy_t = end_t
            for g in [x for x in part if x.done]:
                idx = g.index
                timeline[idx].tokens_out = list(g.tokens_out)
                timeline[idx].token_ts = list(g.token_ts)
                timeline[idx].gen_finish_t = end_t
                timeline[idx].gen_slo_miss = g.slo_misses
                generating.remove(g)
                del row_owner[g.row]
                del acct_by_row[g.row]
                pool.release(g.row, g.label, end_t)

        wall0 = time.perf_counter()
        while live or waiting or generating:
            # --- admission + preemption at the virtual frontier ------------
            if waiting:
                if live or generating:
                    horizons = [t.horizon_t() for t in live]
                    if generating:
                        horizons.append(gen_next_t())
                    frontier = min(horizons)
                else:
                    # nothing live: the next admission happens at the freed
                    # row's release instant (or the earliest arrival if the
                    # row freed before anyone arrived), so every waiter
                    # arrived by then is an admission candidate — EDF must
                    # rank them all, not just the earliest arrival
                    frontier = max(waiting[0][0], pool.next_free_since)
                while waiting and waiting[0][0] <= frontier and pool.n_free > 0:
                    ready_t, idx = pop_next_waiter(frontier)
                    admit(idx, ready_t)
                while (
                    self.preemption is not None
                    and waiting
                    and pool.n_free == 0
                    and waiting[0][0] <= frontier
                ):
                    policy = self.preemption
                    head_ready, head_idx = peek_next_waiter(frontier)
                    head_deadline = _slo_deadline(head_idx)
                    cands: List[_VictimCandidate] = []
                    for t in live:
                        end = t.peek_pending_end_t()
                        if end is None:
                            continue
                        # a candidate's eviction instant: when the waiter
                        # became ready, but never before the candidate's
                        # in-flight fetch started (the engine cannot cancel
                        # in the past)
                        preempt_t = max(head_ready, t.next_fetch_t)
                        if end <= t.deadline_t + policy.margin_s:
                            continue  # fetch lands within the SLO: keep it
                        if (
                            policy.require_waiting_headroom
                            and preempt_t >= head_deadline
                        ):
                            continue  # waiter would start already expired
                        cands.append(_VictimCandidate(
                            obj=t, is_gen=False, end_t=end,
                            preempt_t=preempt_t, work=t.realized_tokens,
                        ))
                    # generating rows are eligible under the cost-aware rule
                    # (TTFT already served, residual work suspends
                    # losslessly — no doomed-fetch test applies), and under
                    # either rule with ``gen_slo`` once they have missed
                    # their per-token SLO on a post-resume token
                    for g in generating:
                        # anti-thrash guard: a generation that has not
                        # emitted a token since it (re)started is not
                        # evictable — without this, two generating rows
                        # under ``least_work`` livelock (the evicted task
                        # re-enters as head waiter and evicts the other at
                        # the same virtual instant, forever)
                        if g.tokens_since_resume <= 0:
                            continue
                        slo_doomed = policy.gen_slo and g.slo_missed
                        if policy.victim != "least_work" and not slo_doomed:
                            continue
                        preempt_t = max(head_ready, g.ready_t)
                        if (
                            policy.require_waiting_headroom
                            and preempt_t >= head_deadline
                        ):
                            continue
                        cands.append(_VictimCandidate(
                            obj=g, is_gen=True, end_t=float("inf"),
                            preempt_t=preempt_t, work=g.realized_tokens,
                        ))
                    victim = _select_victim(policy, cands)
                    if victim is None:
                        break
                    pop_next_waiter(frontier)
                    if victim.is_gen:
                        preempt_gen(victim.obj, victim.preempt_t)
                    else:
                        preempt(victim.obj, victim.preempt_t)
                    admit(head_idx, head_ready)
            if not live and not generating:
                continue  # admission above is guaranteed to make progress

            # --- generation step vs. load round: earliest event first ------
            if generating and (
                not live or gen_next_t() <= min(t.next_fetch_t for t in live)
            ):
                gen_step()
                continue

            # --- one wave-identical round over the live set ----------------
            stats.n_rounds += 1
            round_t = min(t.next_fetch_t for t in live)
            ordered = sorted(live, key=lambda t: t.next_fetch_t)
            ready = [t for t in ordered if t.fetch_ready]
            round_runs: List[RunWork] = []
            round_texts: List[TextWork] = []
            for t in ready if ready else ordered[:1]:
                self._n_active = (
                    sum(1 for x in live if not x.done) + len(generating)
                )
                for w in t.step():
                    (round_runs if isinstance(w, RunWork) else round_texts).append(w)
            caches = _execute_runs(self.engine, round_runs, caches, acct_by_row, stats)
            caches = _execute_texts(self.engine, round_texts, caches, acct_by_row, stats)

            # --- completions: extract the row, then generate or recycle ----
            for t in [x for x in live if x.done]:
                idx = row_owner[t.row]
                finish_t = max(t.clock.fetch_t, t.clock.compute_t)
                results[idx] = t.result(
                    extract_row(caches, t.row),
                    wall_decode_s=acct[idx].decode_s,
                    wall_recompute_s=acct[idx].recompute_s,
                    wall_total_s=0.0,  # filled with the realized total below
                    n_runs=acct[idx].runs,
                )
                timeline[idx].finish_t = finish_t
                live.remove(t)
                if start_generation(idx, t, finish_t):
                    continue  # row stays: the session now generates on it
                del row_owner[t.row]
                del acct_by_row[t.row]
                pool.release(t.row, t.label, finish_t)
            occupancy.append((round_t, len(live)))
        jax.block_until_ready(caches.kv_k)
        wall_total = time.perf_counter() - wall0
        assert all(r is not None for r in results)
        for r in results:
            r.wall_total_s = wall_total
        return ContinuousResult(
            sessions=list(results),
            timeline=timeline,
            occupancy=occupancy,
            n_rows=n_rows,
            wall_total_s=wall_total,
            wall_decode_s=stats.decode_s,
            wall_recompute_s=stats.recompute_s,
            n_rounds=stats.n_rounds,
            n_decode_batches=stats.n_decode_batches,
            n_text_batches=stats.n_text_batches,
            n_runs=stats.n_runs,
            n_preemptions=n_preempt,
            n_resumes=n_resume,
            gen_occupancy=gen_occupancy,
            wall_gen_s=stats.gen_s,
            n_gen_steps=stats.n_gen_steps,
            n_gen_tokens=stats.n_gen_tokens,
        )
