"""Continuous batched generation: output tokens join the serving engine.

The paper stops at TTFT — once the context's KV cache is loaded, CacheGen's
pipeline ends.  Production serving doesn't: the loaded cache exists to be
*decoded against*.  This module holds the per-session generation state that
lets a completed context load transition into a *generating* state on the
same shared :class:`~repro.serving.engine.Engine` row instead of exiting.

Split of responsibilities:

* :class:`GenerationSpec` — what the caller asked for: how many output
  tokens, the first input token (the argmax of the context prefill's last
  logits, i.e. the token the TTFT measurement produced), an optional
  per-output-token latency SLO, and an optional sampling seed (``None``
  means greedy argmax, which is what keeps continuous generation
  bit-identical to the ``Engine.generate_with_kv`` oracle).
* :class:`GenerationTask` — the scheduler-side state machine for one
  generating session: current input token, emitted tokens + their virtual
  timestamps, the cache row it occupies, and the virtual instant it is next
  ready to take a decode step.  The scheduler stacks every ready task into
  one ``Engine.decode_step_rows`` dispatch per step.

Suspension is lossless and bit-exact: a generating row snapshots through the
same ``kv_layout.RowSnapshot`` path as a loading row (the snapshot spans
context + emitted tokens), and ``current_token`` carries the next input
host-side, so a preempted generation resumes mid-stream with token-identical
output.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

__all__ = ["GenerationSpec", "GenerationTask"]


@dataclasses.dataclass(frozen=True)
class GenerationSpec:
    """What to generate once a session's context load completes.

    ``n_tokens == 0`` (or a ``None`` spec on the request) means load-only —
    the session exits at TTFT exactly as before this subsystem existed.
    ``first_token`` is the first decode input: by convention the argmax of
    the context prefill's last-position logits, which the serving loader
    already produces as its TTFT artifact.  ``sample_seed=None`` selects
    greedy argmax decoding; an integer seed selects deterministic softmax
    sampling (seeded per request, so runs reproduce bit-for-bit).
    """

    n_tokens: int
    first_token: int
    gen_slo_s: Optional[float] = None  # per-output-token latency SLO (TPOT)
    sample_seed: Optional[int] = None  # None = greedy (oracle-identical)

    def __post_init__(self):
        if self.n_tokens < 0:
            raise ValueError(f"GenerationSpec: n_tokens {self.n_tokens} < 0")
        if self.gen_slo_s is not None and self.gen_slo_s <= 0:
            raise ValueError(f"GenerationSpec: gen_slo_s {self.gen_slo_s} <= 0")


class GenerationTask:
    """One session's generation-in-progress on a shared engine row.

    Tracks the host-side decode state: the next input token, the tokens
    emitted so far with their virtual emission times, and ``ready_t`` — the
    virtual instant this task can next participate in a stacked decode
    step.  ``cache_tokens`` (context + emitted) is the row's realized
    length: it is what ``Engine.save_row`` snapshots on preemption and what
    capacity validation checks against.
    """

    def __init__(
        self,
        spec: GenerationSpec,
        *,
        index: int,
        label: str,
        row: int,
        start_t: float,
        context_tokens: int,
        capacity: int,
    ):
        if context_tokens + spec.n_tokens > capacity:
            raise ValueError(
                f"generation for request {label!r}: {context_tokens} context "
                f"+ {spec.n_tokens} output tokens exceeds cache capacity "
                f"{capacity} — every generated token needs a KV slot"
            )
        self.spec = spec
        self.index = index
        self.label = label
        self.row = row
        self.start_t = float(start_t)
        self.ready_t = float(start_t)
        self.context_tokens = int(context_tokens)
        self.current_token = int(spec.first_token)
        self.tokens_out: List[int] = []
        self.token_ts: List[float] = []
        # gen-SLO enforcement (per-token): realized TPOT over the SLO bumps
        # slo_misses; tokens_since_resume gates preemption eligibility so a
        # freshly resumed task is not re-evicted for its pre-suspension
        # misses before it takes a single step
        self.slo_misses = 0
        self.tokens_since_resume = 0
        self._rng = (
            None
            if spec.sample_seed is None
            else np.random.default_rng(spec.sample_seed + index)
        )

    # ------------------------------------------------------------------

    @property
    def done(self) -> bool:
        return len(self.tokens_out) >= self.spec.n_tokens

    @property
    def realized_tokens(self) -> int:
        """Row tokens realized so far: context prefix + emitted output."""
        return self.context_tokens + len(self.tokens_out)

    def next_token(self, logits_row: np.ndarray) -> int:
        """Pick the next token from this row's last-position logits.

        Greedy argmax unless the spec carries a sampling seed, in which
        case a seeded host-side softmax sample (float64 for stable
        normalization across platforms).
        """
        if self._rng is None:
            return int(np.argmax(logits_row))
        z = np.asarray(logits_row, np.float64)
        z = z - z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(p.shape[0], p=p))

    @property
    def slo_missed(self) -> bool:
        """Whether any emitted token's realized TPOT exceeded the spec's
        per-token SLO (suspension time between tokens included — queueing
        is latency the caller observed)."""
        return self.slo_misses > 0

    def record(self, token: int, emit_t: float) -> None:
        """Commit one emitted token: it becomes the next decode input.

        When the spec carries a ``gen_slo_s``, the token's realized TPOT —
        emission minus the previous emission (or the generation start for
        the first token), so suspension gaps count — is checked against it
        and misses accumulate in ``slo_misses``.
        """
        prev_t = self.token_ts[-1] if self.token_ts else self.start_t
        self.tokens_out.append(int(token))
        self.token_ts.append(float(emit_t))
        self.current_token = int(token)
        self.ready_t = float(emit_t)
        self.tokens_since_resume += 1
        if self.spec.gen_slo_s is not None and (
            float(emit_t) - prev_t > self.spec.gen_slo_s
        ):
            self.slo_misses += 1

    # ------------------------------------------------------------------
    # Preemption (row suspends via the engine's bit-exact RowSnapshot path)
    # ------------------------------------------------------------------

    def suspend(self, now_t: float) -> None:
        """Leave the engine: the row snapshot (taken by the scheduler) holds
        context + emitted KV; ``current_token`` carries the next input."""
        if self.done:
            raise ValueError(
                f"suspending generation for request {self.label!r}: "
                f"already emitted all {self.spec.n_tokens} tokens"
            )
        self.row = -1
        self.ready_t = float(now_t)

    def resume(self, row: int, resume_t: float) -> None:
        """Rejoin the engine on ``row`` (possibly a different one): the
        restored snapshot reads exactly as at suspension, so decoding
        continues bit-exactly from ``current_token``."""
        self.row = int(row)
        self.ready_t = float(resume_t)
        self.tokens_since_resume = 0
