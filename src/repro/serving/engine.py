"""Serving engine: the paper's two LLM interfaces plus chunked prefill.

Implements (paper §6):
  * ``calculate_kv(context) -> KVCache``  — prefill without generation;
  * ``generate_with_kv(KVCache) -> text`` — generation that skips context
    prefill entirely;
plus ``prefill_extend`` — compute a text chunk's KV on top of already-loaded
chunk KV (the streamer's recompute fallback, paper §5.3 fn. 6) — and a
greedy generation loop used by the examples and quality benchmarks.

All steps are jit-compiled once per (batch, capacity) signature and cached.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.lm import Caches
from repro.serving import kv_layout

__all__ = ["Engine"]


class Engine:
    def __init__(self, cfg: ArchConfig, params, cache_capacity: int = 4096):
        self.cfg = cfg
        self.params = params
        self.capacity = cache_capacity
        self._prefill = jax.jit(
            functools.partial(lm.prefill, cfg), static_argnames=("pad_to",)
        )
        self._decode = jax.jit(functools.partial(lm.decode_step, cfg))
        if cfg.family in ("dense", "moe", "vlm"):
            self._extend = jax.jit(functools.partial(lm.prefill_extend, cfg))
        else:
            self._extend = None
        # Decoded-run insertion: donate the cache buffers so XLA performs an
        # in-place dynamic_update_slice instead of copying the whole cache
        # per insertion (donation is a no-op hint on CPU, where XLA warns).
        donate = () if jax.default_backend() == "cpu" else (0, 1)
        self._insert_run = jax.jit(kv_layout.insert_codec_run, donate_argnums=donate)

    # ------------------------------------------------------------------
    # Paper interfaces
    # ------------------------------------------------------------------

    def calculate_kv(self, batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Caches]:
        """Prefill the context; returns (last logits, caches)."""
        return self._prefill(self.params, batch, pad_to=self.capacity)

    def generate_with_kv(
        self, caches: Caches, first_token: jnp.ndarray, n_tokens: int
    ) -> np.ndarray:
        """Greedy generation from a (possibly codec-decoded) KV cache.

        first_token: (B,) int32.  Returns (B, n_tokens) generated ids.
        """
        tok = first_token[:, None].astype(jnp.int32)
        out = []
        for _ in range(n_tokens):
            logits, caches = self._decode(self.params, tok, caches)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out.append(np.asarray(tok[:, 0]))
        return np.stack(out, axis=1)

    def logits_with_kv(
        self, caches: Caches, tokens: np.ndarray
    ) -> Tuple[np.ndarray, Caches]:
        """Teacher-forced stepping: returns per-step logits (B, T, V).

        Used by the quality benchmarks (perplexity / argmax-agreement of
        compressed vs. uncompressed caches).
        """
        outs = []
        for t in range(tokens.shape[1]):
            logits, caches = self._decode(
                self.params, jnp.asarray(tokens[:, t : t + 1], jnp.int32), caches
            )
            outs.append(np.asarray(logits[:, 0], dtype=np.float32))
        return np.stack(outs, axis=1), caches

    # ------------------------------------------------------------------
    # Streamer support
    # ------------------------------------------------------------------

    def prefill_extend(
        self, tokens: jnp.ndarray, caches: Caches
    ) -> Tuple[jnp.ndarray, Caches]:
        """Text-chunk recompute on top of loaded KV (fallback config)."""
        if self._extend is None:
            raise ValueError(f"no chunked prefill for family {self.cfg.family}")
        return self._extend(self.params, tokens, caches)

    def empty_caches(self, batch: int) -> Caches:
        return kv_layout.alloc_caches(self.cfg, batch, self.capacity)

    def decode_to_cache(self, caches: Caches, kv_new, start: int) -> Caches:
        """Write a decoded codec run ``(L, 2, T, C)`` into the serving cache.

        Fast path for ``streamer.materialize``: one jitted, donated-buffer
        ``dynamic_update_slice`` per run of decoded chunks — the run tensor
        (``codec.decode_chunks`` output) never leaves the device and the
        cache is not copied per chunk.
        """
        k, v, ln = self._insert_run(
            caches.kv_k, caches.kv_v, caches.length, jnp.asarray(kv_new),
            jnp.int32(start),
        )
        return caches._replace(kv_k=k, kv_v=v, length=ln)

    # ------------------------------------------------------------------
    # Cost model hooks (used by the streaming simulator)
    # ------------------------------------------------------------------

    def prefill_flops(self, n_tokens: int, kv_prefix: int = 0) -> float:
        """Approximate forward FLOPs to prefill ``n_tokens`` given a prefix."""
        cfg = self.cfg
        L = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
        d, ff = cfg.d_model, cfg.d_ff
        if cfg.family == "moe":
            ff_eff = ff * (cfg.moe_topk + cfg.n_shared_experts)
        else:
            ff_eff = ff
        per_tok = 2 * (
            d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head  # qkv
            + cfg.n_heads * cfg.d_head * d  # out proj
            + 3 * d * ff_eff  # gated mlp
        )
        attn = 2 * 2 * cfg.n_heads * cfg.d_head * (
            n_tokens * kv_prefix + n_tokens * (n_tokens + 1) // 2
        )
        return float(L) * (per_tok * n_tokens + attn) + 2.0 * n_tokens * d * cfg.vocab_size
