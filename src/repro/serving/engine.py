"""Serving engine: the paper's two LLM interfaces plus chunked prefill.

Implements (paper §6):
  * ``calculate_kv(context) -> KVCache``  — prefill without generation;
  * ``generate_with_kv(KVCache) -> text`` — generation that skips context
    prefill entirely;
plus ``prefill_extend`` — compute a text chunk's KV on top of already-loaded
chunk KV (the streamer's recompute fallback, paper §5.3 fn. 6) — and a
greedy generation loop used by the examples and quality benchmarks.

One Engine serves many concurrent context loads *and* generations: a single
instance (params, jit caches, one device) is shared by every
``serving.session.ServeSession`` and by the schedulers in
``serving.scheduler``, which allocate a *batch-of-requests* cache (one row
per live session) and drive the batched entry points — ``insert_runs``
(several requests' decoded runs landed at per-row offsets in one dispatch),
``prefill_extend_rows`` (different requests' TEXT recomputes coalesced into
one padded, width-masked forward), and ``decode_step_rows`` (all currently
*generating* sessions' next-token decode stacked into one forward over the
shared cache, per-row length offsets, inactive rows bit-preserved).  The
per-request entry points (``decode_to_cache``, ``prefill_extend``,
``generate_with_kv``) remain the single-session path and the schedulers'
N=1 differential oracles.

All steps are jit-compiled once per (batch, capacity[, run-geometry])
signature and cached.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.lm import Caches
from repro.serving import kv_layout

__all__ = ["Engine"]


class Engine:
    # Shard-aware row addressing: the base engine is a single shard, so the
    # global row space and the local one coincide.  The mesh-sharded
    # subclass (serving.mesh_engine.ShardedEngine) overrides these — the
    # schedulers consult them to size caches (``cache_rows``) and to place
    # rows into per-shard contention/transport domains.
    n_shards: int = 1

    def cache_rows(self, n: int) -> int:
        """Smallest cache batch >= ``n`` this engine can allocate (rounded
        up to a whole number of row shards)."""
        return -(-int(n) // self.n_shards) * self.n_shards

    def __init__(self, cfg: ArchConfig, params, cache_capacity: int = 4096):
        self.cfg = cfg
        self.params = params
        self.capacity = cache_capacity
        self._prefill = jax.jit(
            functools.partial(lm.prefill, cfg), static_argnames=("pad_to",)
        )
        self._decode = jax.jit(functools.partial(lm.decode_step, cfg))
        if cfg.family in ("dense", "moe", "vlm"):
            self._extend = jax.jit(functools.partial(lm.prefill_extend, cfg))
            self._extend_rows = jax.jit(
                lambda params, tokens, caches, widths: lm.prefill_extend(
                    self.cfg, params, tokens, caches, widths=widths
                )
            )

            # Stacked generation step over the batch-of-requests cache: one
            # full-batch decode_step (every row reads/writes at its *own*
            # length offset), then inactive rows' KV/length are merged back
            # so only the generating rows advance.  The merge also
            # neutralizes decode_step's at-capacity clamp for full inactive
            # rows.  Not donated — callers (microbench, oracles) reuse the
            # input caches across steps, matching ``self._decode``.
            def _decode_rows_impl(params, tokens, kv_k, kv_v, length, active):
                full = lm.Caches(
                    kv_k=kv_k, kv_v=kv_v, length=length,
                    mamba_conv=None, mamba_ssm=None, shared_k=None, shared_v=None,
                )
                logits, new = lm.decode_step(self.cfg, params, tokens, full)
                sel = active[None, :, None, None, None]
                return (
                    logits,
                    jnp.where(sel, new.kv_k, kv_k),
                    jnp.where(sel, new.kv_v, kv_v),
                    jnp.where(active, new.length, length),
                )

            self._decode_rows = jax.jit(_decode_rows_impl)
        else:
            self._extend = None
            self._extend_rows = None
            self._decode_rows = None
        # Decoded-run insertion: donate the cache buffers so XLA performs an
        # in-place dynamic_update_slice instead of copying the whole cache
        # per insertion (donation is a no-op hint on CPU, where XLA warns).
        donate = () if jax.default_backend() == "cpu" else (0, 1)
        self._insert_run = jax.jit(kv_layout.insert_codec_run, donate_argnums=donate)
        self._insert_runs = jax.jit(
            kv_layout.insert_codec_runs,
            donate_argnums=donate,
            static_argnames=("run_tokens",),
        )
        # row-pool support (continuous admission): suspend/resume a row's
        # realized prefix and recycle freed rows in place
        self._restore_row = jax.jit(kv_layout.restore_row, donate_argnums=donate)
        self._reset_rows = jax.jit(kv_layout.reset_rows, donate_argnums=donate)
        if self._extend is not None:
            # gather -> compact prefill_extend -> scatter back: coalesced
            # TEXT recompute that only computes the participating rows
            # (cache buffers donated so the row scatter updates in place)
            gather_donate = () if jax.default_backend() == "cpu" else (2, 3)

            def _extend_gather_impl(params, tokens, kv_k, kv_v, length, rows):
                sub = lm.Caches(
                    kv_k=kv_k[:, rows], kv_v=kv_v[:, rows], length=length[rows],
                    mamba_conv=None, mamba_ssm=None, shared_k=None, shared_v=None,
                )
                logits, sub = lm.prefill_extend(self.cfg, params, tokens, sub)
                return (
                    logits,
                    kv_k.at[:, rows].set(sub.kv_k),
                    kv_v.at[:, rows].set(sub.kv_v),
                    length.at[rows].set(sub.length),
                )

            self._extend_gather = jax.jit(
                _extend_gather_impl, donate_argnums=gather_donate
            )
        else:
            self._extend_gather = None

    # ------------------------------------------------------------------
    # Paper interfaces
    # ------------------------------------------------------------------

    def calculate_kv(self, batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Caches]:
        """Prefill the context; returns (last logits, caches)."""
        return self._prefill(self.params, batch, pad_to=self.capacity)

    def generate_with_kv(
        self, caches: Caches, first_token: jnp.ndarray, n_tokens: int
    ) -> np.ndarray:
        """Greedy generation from a (possibly codec-decoded) KV cache.

        first_token: (B,) int32.  Returns (B, n_tokens) generated ids.
        """
        tok = first_token[:, None].astype(jnp.int32)
        out = []
        for _ in range(n_tokens):
            logits, caches = self._decode(self.params, tok, caches)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out.append(np.asarray(tok[:, 0]))
        return np.stack(out, axis=1)

    def logits_with_kv(
        self, caches: Caches, tokens: np.ndarray
    ) -> Tuple[np.ndarray, Caches]:
        """Teacher-forced stepping: returns per-step logits (B, T, V).

        Used by the quality benchmarks (perplexity / argmax-agreement of
        compressed vs. uncompressed caches).
        """
        outs = []
        for t in range(tokens.shape[1]):
            logits, caches = self._decode(
                self.params, jnp.asarray(tokens[:, t : t + 1], jnp.int32), caches
            )
            outs.append(np.asarray(logits[:, 0], dtype=np.float32))
        return np.stack(outs, axis=1), caches

    # ------------------------------------------------------------------
    # Streamer support
    # ------------------------------------------------------------------

    def prefill_extend(
        self, tokens: jnp.ndarray, caches: Caches
    ) -> Tuple[jnp.ndarray, Caches]:
        """Text-chunk recompute on top of loaded KV (fallback config)."""
        if self._extend is None:
            raise ValueError(f"no chunked prefill for family {self.cfg.family}")
        return self._extend(self.params, tokens, caches)

    def empty_caches(self, batch: int) -> Caches:
        return kv_layout.alloc_caches(self.cfg, batch, self.capacity)

    def decode_to_cache(self, caches: Caches, kv_new, start: int) -> Caches:
        """Write a decoded codec run ``(L, 2, T, C)`` into the serving cache.

        Fast path for ``streamer.materialize``: one jitted, donated-buffer
        ``dynamic_update_slice`` per run of decoded chunks — the run tensor
        (``codec.decode_chunks`` output) never leaves the device and the
        cache is not copied per chunk.
        """
        k, v, ln = self._insert_run(
            caches.kv_k, caches.kv_v, caches.length, jnp.asarray(kv_new),
            jnp.int32(start),
        )
        return caches._replace(kv_k=k, kv_v=v, length=ln)

    # ------------------------------------------------------------------
    # Concurrent-scheduler support (batch-of-requests cache)
    # ------------------------------------------------------------------

    def insert_runs(
        self,
        caches: Caches,
        kv_new,  # (L, 2, sum_T, C): all runs' decoded tokens, concat order
        rows: Sequence[int],  # cache row per run (distinct)
        starts: Sequence[int],  # token offset per run
        run_tokens: Sequence[int],  # token count per run
    ) -> Caches:
        """Land several requests' decoded runs in one batched dispatch.

        ``kv_new`` is the cross-request concat from
        ``codec.decode_chunk_runs``; run ``i`` (spanning ``run_tokens[i]``
        tokens of it) is written into cache row ``rows[i]`` at token offset
        ``starts[i]`` via one vmap'd per-row-offset ``dynamic_update_slice``
        over the whole batch — replacing one ``decode_to_cache`` dispatch
        per request per run.  Rows not named keep their contents
        byte-identically.  Only run geometry is static for jit; row
        assignment and offsets are data.
        """
        if not (len(rows) == len(starts) == len(run_tokens)):
            raise ValueError(
                f"insert_runs: {len(rows)} rows, {len(starts)} starts, "
                f"{len(run_tokens)} runs — one of each per run required"
            )
        if len(set(rows)) != len(rows):
            raise ValueError(f"insert_runs: duplicate cache rows in {rows}")
        n_rows = caches.kv_k.shape[1]
        if any(not 0 <= int(r) < n_rows for r in rows):
            # out of range would hit XLA's silent scatter-drop inside jit
            raise ValueError(
                f"insert_runs: rows {list(rows)} out of range for a "
                f"{n_rows}-row cache"
            )
        t_max = max(run_tokens)
        if t_max > self.capacity:
            raise ValueError(
                f"run of {t_max} tokens exceeds cache capacity {self.capacity}"
            )
        for s, t in zip(starts, run_tokens):
            # the insert kernel's shifted-window merge masks out-of-capacity
            # positions rather than writing them, so an overhanging run
            # would silently drop tokens while still advancing length
            if int(s) + int(t) > self.capacity:
                raise ValueError(
                    f"run of {t} tokens at offset {s} overhangs cache "
                    f"capacity {self.capacity}"
                )
        k, v, ln = self._insert_runs(
            caches.kv_k, caches.kv_v, caches.length, jnp.asarray(kv_new),
            jnp.asarray(list(rows), jnp.int32),
            jnp.asarray(list(starts), jnp.int32),
            run_tokens=tuple(int(t) for t in run_tokens),
        )
        return caches._replace(kv_k=k, kv_v=v, length=ln)

    # ------------------------------------------------------------------
    # Row-pool support (continuous admission / preemption)
    # ------------------------------------------------------------------

    def save_row(self, caches: Caches, row: int, n_tokens: int):
        """Snapshot the first ``n_tokens`` realized tokens of one cache row
        (suspending a preempted session).  The snapshot owns its buffers, so
        the pool cache may be freely recycled/donated afterwards."""
        n_rows = caches.kv_k.shape[1]
        if not 0 <= int(row) < n_rows:
            raise ValueError(
                f"save_row: row {row} out of range for a {n_rows}-row cache"
            )
        if not 0 <= int(n_tokens) <= self.capacity:
            raise ValueError(
                f"save_row: {n_tokens} tokens out of range for capacity "
                f"{self.capacity}"
            )
        return kv_layout.save_row(caches, int(row), int(n_tokens))

    def restore_row(self, caches: Caches, snapshot, row: int) -> Caches:
        """Re-insert a suspended session's snapshot into (possibly another)
        ``row`` of the pool cache: one donated-buffer write, then the row
        reads exactly as it did at suspension (length included)."""
        n_rows = caches.kv_k.shape[1]
        if not 0 <= int(row) < n_rows:
            raise ValueError(
                f"restore_row: row {row} out of range for a {n_rows}-row cache"
            )
        if snapshot.n_tokens > self.capacity:
            raise ValueError(
                f"restore_row: snapshot of {snapshot.n_tokens} tokens exceeds "
                f"cache capacity {self.capacity}"
            )
        k, v, ln = self._restore_row(
            caches.kv_k, caches.kv_v, caches.length,
            snapshot.kv_k, snapshot.kv_v, jnp.int32(row),
        )
        return caches._replace(kv_k=k, kv_v=v, length=ln)

    def reset_rows(self, caches: Caches, rows: Sequence[int]) -> Caches:
        """Zero recycled rows (KV and length) before new tenants take them —
        a recycled row must be indistinguishable from a fresh cache's row."""
        n_rows = caches.kv_k.shape[1]
        if any(not 0 <= int(r) < n_rows for r in rows):
            raise ValueError(
                f"reset_rows: rows {list(rows)} out of range for a "
                f"{n_rows}-row cache"
            )
        k, v, ln = self._reset_rows(
            caches.kv_k, caches.kv_v, caches.length,
            jnp.asarray(list(rows), jnp.int32),
        )
        return caches._replace(kv_k=k, kv_v=v, length=ln)

    def prefill_extend_rows(
        self, tokens: jnp.ndarray, caches: Caches, widths
    ) -> Tuple[jnp.ndarray, Caches]:
        """Coalesced TEXT recompute: one padded, width-masked batched
        ``prefill_extend`` over the batch-of-requests cache.

        ``tokens`` is (B, Tc) with each participating row's text chunk (rows
        with ``widths[b] == 0`` carry padding and are untouched — garbage
        logits, no cache write, no length advance).  Each row writes at its
        *own* ``caches.length[b]`` offset.
        """
        if self._extend_rows is None:
            raise ValueError(f"no chunked prefill for family {self.cfg.family}")
        return self._extend_rows(
            self.params, tokens, caches, jnp.asarray(widths, jnp.int32)
        )

    def prefill_extend_gather(
        self, tokens: jnp.ndarray, caches: Caches, rows
    ) -> Tuple[jnp.ndarray, Caches]:
        """Compact coalesced TEXT recompute for a *subset* of cache rows.

        Gathers rows ``rows`` of the batch-of-requests cache into a
        sub-batch, runs the plain full-width ``prefill_extend`` on it
        (``tokens`` is (len(rows), Tc), one text chunk per gathered row),
        and scatters the updated rows back.  Complements
        :meth:`prefill_extend_rows`: same semantics, but compute scales with
        the participating rows instead of the full batch — the scheduler
        picks this when only a few sessions recompute in a round.  Row
        membership is data (no retrace per row set); only (k, Tc) shape the
        jit signature.
        """
        if self._extend_gather is None:
            raise ValueError(f"no chunked prefill for family {self.cfg.family}")
        n_rows = caches.kv_k.shape[1]
        if any(not 0 <= int(r) < n_rows for r in rows):
            # out of range would clamp inside jit and corrupt the last row
            raise ValueError(
                f"prefill_extend_gather: rows {list(rows)} out of range for "
                f"a {n_rows}-row cache"
            )
        logits, k, v, ln = self._extend_gather(
            self.params, tokens, caches.kv_k, caches.kv_v, caches.length,
            jnp.asarray(list(rows), jnp.int32),
        )
        return logits, caches._replace(kv_k=k, kv_v=v, length=ln)

    def decode_step_rows(
        self, tokens: jnp.ndarray, caches: Caches, active
    ) -> Tuple[jnp.ndarray, Caches]:
        """Stacked generation step: all generating rows' next token in one
        forward over the batch-of-requests cache.

        ``tokens`` is (B, 1) with each generating row's current token (rows
        with ``active[b] == False`` carry padding); ``active`` is (B,) bool.
        Each active row attends over its own realized prefix (per-row
        ``caches.length[b]`` offsets), writes its token's KV at that offset,
        and advances its length by one; inactive rows' KV and length are
        bit-preserved.  Returns (logits (B, 1, V), caches) — inactive rows'
        logits are garbage, mirroring :meth:`prefill_extend_rows`.

        Active rows must have ``length < capacity`` before the step (the
        written token needs a slot); callers validate this host-side when
        scheduling generation.
        """
        if self._decode_rows is None:
            raise ValueError(f"no cached generation for family {self.cfg.family}")
        n_rows = caches.kv_k.shape[1]
        tokens = jnp.asarray(tokens, jnp.int32)
        if tokens.shape != (n_rows, 1):
            raise ValueError(
                f"decode_step_rows: tokens shape {tokens.shape} != "
                f"({n_rows}, 1) for a {n_rows}-row cache"
            )
        active = jnp.asarray(active, bool)
        if active.shape != (n_rows,):
            raise ValueError(
                f"decode_step_rows: active shape {active.shape} != "
                f"({n_rows},) for a {n_rows}-row cache"
            )
        logits, k, v, ln = self._decode_rows(
            self.params, tokens, caches.kv_k, caches.kv_v, caches.length, active
        )
        return logits, caches._replace(kv_k=k, kv_v=v, length=ln)

    # ------------------------------------------------------------------
    # Cost model hooks (used by the streaming simulator)
    # ------------------------------------------------------------------

    def prefill_flops(self, n_tokens: int, kv_prefix: int = 0) -> float:
        """Approximate forward FLOPs to prefill ``n_tokens`` given a prefix."""
        cfg = self.cfg
        L = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
        d, ff = cfg.d_model, cfg.d_ff
        if cfg.family == "moe":
            ff_eff = ff * (cfg.moe_topk + cfg.n_shared_experts)
        else:
            ff_eff = ff
        per_tok = 2 * (
            d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head  # qkv
            + cfg.n_heads * cfg.d_head * d  # out proj
            + 3 * d * ff_eff  # gated mlp
        )
        attn = 2 * 2 * cfg.n_heads * cfg.d_head * (
            n_tokens * kv_prefix + n_tokens * (n_tokens + 1) // 2
        )
        return float(L) * (per_tok * n_tokens + attn) + 2.0 * n_tokens * d * cfg.vocab_size
