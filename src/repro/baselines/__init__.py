from repro.baselines.quantization import int8_wire_bytes, uniform_quantize_kv  # noqa: F401
from repro.baselines.context_compression import h2o_select, llmlingua_select  # noqa: F401
