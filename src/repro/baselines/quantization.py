"""'Default quantization' baseline (paper §7.1, from FlexGen [102]):

uniform per-group 8-bit (or k-bit) quantization of the KV cache with the
same level for every layer — no deltas, no entropy coding.  Wire size is the
packed symbols + scales; reconstruction is the dequantized tensor.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["uniform_quantize_kv", "int8_wire_bytes"]


def uniform_quantize_kv(
    kv: np.ndarray, bits: int = 8, group: int = 64
) -> Tuple[np.ndarray, int]:
    """kv (L, 2, T, C) -> (dequantized kv, wire_bytes).

    Symmetric per-(L,2,T,group-of-channels) absmax quantization.
    """
    L, two, T, C = kv.shape
    qmax = 2 ** (bits - 1) - 1
    G = max(C // group, 1)
    x = kv.reshape(L, two, T, G, -1).astype(np.float32)
    scale = np.maximum(np.abs(x).max(axis=-1, keepdims=True) / qmax, 1e-12)
    scale = scale.astype(np.float16).astype(np.float32)
    q = np.clip(np.round(x / scale), -qmax, qmax)
    deq = (q * scale).reshape(L, two, T, C)
    n_sym = L * two * T * C
    wire = n_sym * bits // 8 + L * two * T * G * 2  # packed symbols + f16 scales
    return deq, wire


def int8_wire_bytes(L: int, T: int, C: int, group: int = 64, bits: int = 8) -> int:
    G = max(C // group, 1)
    return L * 2 * T * C * bits // 8 + L * 2 * T * G * 2
