"""Context-compression baselines: H2O and an LLMLingua-style token pruner.

* ``h2o_select`` — Heavy-Hitter Oracle [131]: keep the tokens with the
  highest cumulative attention scores (plus a recent-token window).  As in
  the paper's evaluation, this is the *idealized* offline variant: the
  attention scores come from the full prefill (the paper grants H2O the
  prompt's query tensors offline; we grant the context's own self-attention
  scores).

* ``llmlingua_select`` — prompt-compression-style pruning in *text* space:
  drop the tokens whose next-token log-likelihood under the model is highest
  (most predictable = least informative), keeping a target fraction.
  This mirrors LLMLingua's perplexity-based token filtering [67] without the
  budget controller.

Both return kept-token indices; CacheGen composes with them by encoding the
*pruned* KV cache (paper §7.2 "CacheGen on H2O/LLMLingua").
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["h2o_select", "llmlingua_select", "attention_scores_for_h2o"]


def attention_scores_for_h2o(
    kv_k: np.ndarray,  # (L, T, H, D) post-rope keys for one request
    q_all: np.ndarray,  # (L, T, H, D) post-rope queries
) -> np.ndarray:
    """Cumulative causal attention mass per token, averaged over layers/heads."""
    L, T, H, D = kv_k.shape
    acc = np.zeros(T, np.float64)
    scale = 1.0 / np.sqrt(D)
    for l in range(L):
        for h in range(H):
            s = (q_all[l, :, h] @ kv_k[l, :, h].T) * scale  # (Tq, Tk)
            mask = np.tril(np.ones((T, T), bool))
            s = np.where(mask, s, -np.inf)
            s = s - s.max(axis=-1, keepdims=True)
            p = np.exp(s)
            p /= p.sum(axis=-1, keepdims=True)
            acc += p.sum(axis=0)  # column mass = how much this token is attended
    return acc / (L * H)


def h2o_select(
    scores: np.ndarray,  # (T,) cumulative attention mass
    keep_ratio: float,
    recent_window: int = 32,
) -> np.ndarray:
    """Indices (sorted) of tokens kept by the heavy-hitter policy."""
    T = scores.shape[0]
    n_keep = max(int(round(T * keep_ratio)), min(T, recent_window))
    keep = set(range(max(0, T - recent_window), T))  # always keep recent
    order = np.argsort(-scores)
    for idx in order:
        if len(keep) >= n_keep:
            break
        keep.add(int(idx))
    return np.asarray(sorted(keep), np.int64)


def llmlingua_select(
    token_logprobs: np.ndarray,  # (T,) log p(tok_t | tok_<t)) under the LM
    keep_ratio: float,
    protect_last: int = 16,
) -> np.ndarray:
    """Keep the least-predictable tokens (lowest logprob = most informative)."""
    T = token_logprobs.shape[0]
    n_keep = max(int(round(T * keep_ratio)), min(T, protect_last))
    keep = set(range(max(0, T - protect_last), T))
    order = np.argsort(token_logprobs)  # ascending: least predictable first
    for idx in order:
        if len(keep) >= n_keep:
            break
        keep.add(int(idx))
    return np.asarray(sorted(keep), np.int64)
