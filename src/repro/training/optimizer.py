"""AdamW (+ global-norm clipping) on raw pytrees — no optax dependency.

Moments are kept in float32 regardless of param dtype; the update math runs
in f32 and casts back.  The optimizer state is a plain pytree so the
launcher can shard it over the data axis (ZeRO-1) with out_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "apply_updates", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def apply_updates(
    params, grads, state: OptState, cfg: AdamWConfig
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state.mu)
    flat_nu = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(mu=new_mu, nu=new_nu, step=step), metrics
