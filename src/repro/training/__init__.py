from repro.training.optimizer import AdamWConfig, apply_updates, init_opt_state  # noqa: F401
from repro.training.trainer import Trainer, TrainState, make_train_step  # noqa: F401
