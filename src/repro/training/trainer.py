"""Training loop: jit'd train step + fault-tolerant outer loop.

The outer loop is preemption-safe: state is checkpointed every
``ckpt_every`` steps through the atomic CheckpointManager and the loop
resumes bitwise-identically from LATEST (tests kill and restart it).
The data iterator is seeded per-step from the global step, so resumption
regenerates the identical batch sequence without persisting iterator state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.models.model import Model
from repro.training import optimizer as opt_lib
from repro.training.grad_compress import ef_compress, ef_init

__all__ = ["TrainState", "make_train_step", "Trainer"]


class TrainState(NamedTuple):
    params: Any
    opt: opt_lib.OptState
    ef_error: Optional[Any]  # error-feedback residuals (None if disabled)
    step: jnp.ndarray


def init_train_state(model: Model, key, *, grad_compression: bool = False) -> TrainState:
    params = model.init_params(key)
    return TrainState(
        params=params,
        opt=opt_lib.init_opt_state(params),
        ef_error=ef_init(params) if grad_compression else None,
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    model: Model, opt_cfg: opt_lib.AdamWConfig, *, grad_compression: bool = False
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict]]:
    def train_step(state: TrainState, batch):
        def lossf(params):
            loss, metrics = model.loss_fn(params, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lossf, has_aux=True)(state.params)
        ef_error = state.ef_error
        if grad_compression:
            grads, ef_error = ef_compress(grads, ef_error)
        params, opt, om = opt_lib.apply_updates(state.params, grads, state.opt, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return (
            TrainState(params=params, opt=opt, ef_error=ef_error, step=state.step + 1),
            metrics,
        )

    return train_step


@dataclasses.dataclass
class Trainer:
    model: Model
    opt_cfg: opt_lib.AdamWConfig
    batch_fn: Callable[[int], Dict[str, np.ndarray]]  # step -> batch (restart-safe)
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 50
    grad_compression: bool = False
    log_every: int = 10
    log_fn: Callable[[str], None] = print

    def init_or_restore(self, seed: int = 0) -> TrainState:
        state = init_train_state(
            self.model, jax.random.PRNGKey(seed), grad_compression=self.grad_compression
        )
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                state = self.ckpt.restore(latest, state)
                self.log_fn(f"[trainer] resumed from step {latest}")
        return state

    def run(self, state: TrainState, n_steps: int) -> Tuple[TrainState, Dict[str, list]]:
        step_fn = jax.jit(
            make_train_step(self.model, self.opt_cfg, grad_compression=self.grad_compression)
        )
        history: Dict[str, list] = {"loss": [], "step": []}
        start = int(state.step)
        t0 = time.time()
        for s in range(start, n_steps):
            batch = {k: jnp.asarray(v) for k, v in self.batch_fn(s).items()}
            state, metrics = step_fn(state, batch)
            if (s + 1) % self.log_every == 0 or s == start:
                loss = float(metrics["loss"])
                history["loss"].append(loss)
                history["step"].append(s + 1)
                rate = (s + 1 - start) / max(time.time() - t0, 1e-9)
                self.log_fn(
                    f"[trainer] step {s+1}/{n_steps} loss={loss:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.2f} ({rate:.1f} it/s)"
                )
            if self.ckpt is not None and (s + 1) % self.ckpt_every == 0:
                self.ckpt.save(s + 1, state)
        if self.ckpt is not None and int(state.step) > (self.ckpt.latest_step() or -1):
            self.ckpt.save(int(state.step), state)
        return state, history
