"""Gradient compression with error feedback (beyond-paper distributed trick).

Two pieces:
  * :func:`ef_compress` — int8 symmetric quantization with error-feedback
    residual carry (1-bit-Adam family; unit-tested for contraction).
  * :func:`compressed_psum` — a cross-axis gradient reduction whose *wire*
    tensor is int8: quantize locally with a shared (pmax'd) scale, all_gather
    the int8 payload over the axis, dequantize + sum locally.  For the small
    cross-pod axis (2 pods) this cuts the inter-pod gradient bytes 4x vs a
    bf16 ring all-reduce, directly visible in the roofline collective term.

CacheGen tie-in: this reuses the codec's insight that DNN tensors tolerate
aggressive quantization when the error is fed back — the KV codec quantizes
activations spatially; this quantizes gradients temporally.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_compress", "ef_init", "compressed_psum"]


def ef_init(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def ef_compress(grads, errors, bits: int = 8) -> Tuple[Any, Any]:
    """Quantize (grad + carried error); return (dequantized grads, new error)."""
    qmax = float(2 ** (bits - 1) - 1)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / qmax, 1e-12)
        q = jnp.round(x / scale)
        q = jnp.clip(q, -qmax, qmax)
        xhat = q * scale
        return xhat.astype(g.dtype), x - xhat

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree_util.tree_unflatten(tdef, [o[0] for o in out]),
        jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
    )


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean-reduce ``x`` over ``axis_name`` with an int8 wire format.

    Must be called inside shard_map/pmap where ``axis_name`` is bound.
    """
    n = jax.lax.psum(1, axis_name)
    absmax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q, axis_name)  # int8 on the wire
    total = jnp.sum(gathered.astype(jnp.float32), axis=0) * scale
    return (total / n).astype(x.dtype)
