"""Discrete-event simulation of chunked KV streaming with decode pipelining.

Models the paper's §6 "speed optimization": transmission of chunk *i* is
pipelined with the decode of chunk *i-1*; decode (rANS + dequant) and
text-chunk prefill recompute share the accelerator, so they serialize on a
single compute resource.  Per-chunk configuration comes from the
AdaptationPolicy (Algorithm 1); throughput estimates update per completed
chunk from the trace ("measured throughput when sending the previous
chunk").

Straggler mitigation: a hedged duplicate fetch is issued if a chunk's fetch
exceeds ``hedge_after_s``; the effective arrival is the min of the two
(tail-latency hedging, standard practice at 1000-node scale).  The hedging
arithmetic itself lives in ``NetworkModel.fetch_outcome`` — one source of
truth shared by this simulator and the real-I/O ``SimTransport``
(streaming/transport.py), which is what keeps transport-backed sessions
differential-exact against this model.  ``StreamClock`` is split into
``decide`` (Algorithm 1 choice at the current virtual instant) and
``account`` (charge a resolved fetch + its compute window); ``step``
composes the two through the virtual-clock fetch, while the live session
feeds ``account`` with a transport's realized :class:`FetchOutcome`.

Compute contention (multi-session serving): when N sessions share one
engine, each session's decode/recompute seconds stretch by a *measured*
factor — :class:`ContentionModel`, calibrated from the microbench's
cross-request stacked-decode numbers (``calibration.
measured_contention_factors``).  ``StreamClock`` takes an optional
``compute_scale`` callable (the concurrent scheduler wires it to the live
count of active sessions) and applies the factor both to the charged compute
windows *and* to the remaining-recompute estimate that feeds
``choose_config`` — adaptation reacts to compute pressure, not just
bandwidth.  With no callable (or a factor of exactly 1.0, the single-session
case) the clock is bit-identical to the pre-contention behavior.  TEXT
recompute is priced by its own measured concurrency curve
(``ContentionModel.text_factor`` from the microbench's stacked-prefill
section, via the clock's separate ``text_scale`` hook) instead of reusing
the decode curve; with no prefill measurement it falls back to the decode
factors, bit-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional

from repro.streaming.adaptation import TEXT, AdaptationPolicy
from repro.streaming.calibration import (
    measured_contention_factors,
    measured_decode_bytes_per_s,
    measured_generation_contention_factors,
    measured_text_contention_factors,
)
from repro.streaming.network import FetchOutcome, NetworkModel
from repro.streaming.storage import ChunkMeta

__all__ = [
    "ChunkTimeline",
    "ContentionModel",
    "StreamResult",
    "StreamClock",
    "remaining_work",
    "simulate_stream",
]


@dataclasses.dataclass(frozen=True)
class ContentionModel:
    """Per-session compute slowdown as a function of concurrently active
    sessions sharing the engine.

    ``factors`` maps measured concurrency points (from the microbench's
    stacked-decode section) to slowdown; between points the factor is
    interpolated linearly in N, beyond the last point it extrapolates the
    marginal per-session cost of the last measured interval.  An empty map
    falls back to ``factor(n) = n`` — fully serialized compute, the
    conservative model when no stacked measurement exists.  ``factor(1)`` is
    exactly 1.0 by construction, so a single session under a ContentionModel
    is bit-identical to one without.

    TEXT recompute does not stack like decode (a width-masked batched
    ``prefill_extend_rows`` forward has its own concurrency curve), so the
    TEXT side carries a separate measured map: ``text_factors`` comes from
    the microbench's stacked-prefill section
    (``calibration.measured_text_contention_factors``) and is read through
    :meth:`text_factor`; when no prefill measurement exists it falls back to
    the decode curve (the pre-split behavior, bit-identical).  Generation
    decode steps stack differently again (one token per row per dispatch,
    the whole realized prefix attended over), so the stacked-step slowdown
    carries a third map: ``gen_factors`` comes from the microbench's
    stacked-decode-step section
    (``calibration.measured_generation_contention_factors``) and is read
    through :meth:`gen_factor`, with the same decode-curve fallback.

    The continuous scheduler drives all factors with the *time-varying*
    live-session count (loading + generating): ``n_active`` is whatever
    number of sessions currently holds a cache row, re-sampled at every
    decision, so admission, completion, and a session entering its
    generation phase immediately reprice every other session's projected
    compute — including the remaining-recompute estimate inside
    ``choose_config``.
    """

    factors: Mapping[int, float] = dataclasses.field(default_factory=dict)
    text_factors: Mapping[int, float] = dataclasses.field(default_factory=dict)
    gen_factors: Mapping[int, float] = dataclasses.field(default_factory=dict)

    @staticmethod
    def measured(path: Optional[str] = None) -> "ContentionModel":
        """Calibrated from this host's BENCH_codec.json stacked sections."""
        return ContentionModel(
            measured_contention_factors(path),
            measured_text_contention_factors(path),
            measured_generation_contention_factors(path),
        )

    @staticmethod
    def _interp(factors: Mapping[int, float], n: int) -> Optional[float]:
        """Linear interpolation over measured points; None when unmeasured."""
        pts = sorted((int(k), float(v)) for k, v in factors.items())
        pts = [(k, v) for k, v in pts if k >= 1]
        if not pts:
            return None
        if pts[0][0] != 1:
            pts.insert(0, (1, 1.0))
        for (n0, f0), (n1, f1) in zip(pts, pts[1:]):
            if n <= n1:
                if n <= n0:
                    return f0
                w = (n - n0) / (n1 - n0)
                return f0 + w * (f1 - f0)
        # beyond the last measurement: extend the last marginal slope
        if len(pts) >= 2:
            (n0, f0), (n1, f1) = pts[-2], pts[-1]
            slope = (f1 - f0) / (n1 - n0)
        else:
            (n1, f1), slope = pts[-1], 0.0
        return max(1.0, f1 + slope * (n - n1))

    def factor(self, n_active: int) -> float:
        n = max(int(n_active), 1)
        if n == 1:
            return 1.0
        v = self._interp(self.factors, n)
        # fully serialized: no batching benefit assumed when unmeasured
        return float(n) if v is None else v

    def text_factor(self, n_active: int) -> float:
        """TEXT-recompute slowdown at ``n_active`` sessions; falls back to
        the decode curve when no prefill-concurrency measurement exists."""
        n = max(int(n_active), 1)
        if n == 1:
            return 1.0
        v = self._interp(self.text_factors, n)
        return self.factor(n) if v is None else v

    def gen_factor(self, n_active: int) -> float:
        """Stacked generation-step slowdown at ``n_active`` generating rows
        (one ``decode_step_rows`` dispatch of that width vs. width 1); falls
        back to the decode curve when no stacked-step measurement exists."""
        n = max(int(n_active), 1)
        if n == 1:
            return 1.0
        v = self._interp(self.gen_factors, n)
        return self.factor(n) if v is None else v

    # -- per-shard variants (mesh-sharded serving engine) ----------------
    #
    # With the batch-of-requests cache's rows split over S shards, the N
    # live sessions contend only *within* their shard — each shard is its
    # own compute/contention domain — so the per-session slowdown reads the
    # measured curve at the even-spread per-shard width ceil(N / S).  At
    # S = 1 each variant degenerates exactly to its unsharded reading,
    # which is what keeps the mesh=1 scheduler bit-identical.

    @staticmethod
    def _per_shard(n_active: int, n_shards: int) -> int:
        s = max(int(n_shards), 1)
        return -(-max(int(n_active), 1) // s)

    def factor_sharded(self, n_active: int, n_shards: int) -> float:
        """Decode slowdown with ``n_active`` sessions spread (evenly, the
        row pool's balancing invariant) over ``n_shards`` row shards."""
        return self.factor(self._per_shard(n_active, n_shards))

    def text_factor_sharded(self, n_active: int, n_shards: int) -> float:
        return self.text_factor(self._per_shard(n_active, n_shards))

    def gen_factor_sharded(self, n_active: int, n_shards: int) -> float:
        return self.gen_factor(self._per_shard(n_active, n_shards))


@dataclasses.dataclass
class ChunkTimeline:
    chunk_idx: int
    config: int  # TEXT or level
    nbytes: float
    fetch_start: float
    fetch_end: float
    compute_start: float  # decode or recompute
    compute_end: float
    hedged: bool = False
    duplicate_bytes: float = 0.0  # bytes the cancelled hedge loser moved
    n_retries: int = 0  # failed fetch attempts retried before this one landed
    fault_fallback: bool = False  # config was re-decided after fetch failures
    cold_hit: bool = False  # any entry of this fetch was served cold (tiered)
    # byte-range resume (ISSUE 8); defaults keep simulator output unchanged.
    # wire_bytes stays 0.0 for an untroubled chunk (its wire cost is just
    # ``nbytes``) — it is filled only when partial deliveries made the
    # realized wire cost differ, and then salvaged + refetched == wire.
    salvaged_bytes: float = 0.0  # verified prefix bytes reused, not refetched
    wire_bytes: float = 0.0  # realized wire bytes across every attempt
    refetched_bytes: float = 0.0  # wire bytes paid beyond the salvage credit
    resumed: bool = False  # landed via a byte-range continuation
    replanned: bool = False  # a mid-chunk cancel→re-plan preceded the landing


@dataclasses.dataclass
class StreamResult:
    timelines: List[ChunkTimeline]
    ttft_s: float
    configs: List[int]
    slo_s: float

    @property
    def slo_violated(self) -> bool:
        return self.ttft_s > self.slo_s

    @property
    def total_bytes(self) -> float:
        return sum(t.nbytes for t in self.timelines)

    @property
    def duplicate_bytes(self) -> float:
        """Wire bytes paid for by hedging (losing fetches, cancelled)."""
        return sum(t.duplicate_bytes for t in self.timelines)


def remaining_work(
    metas: List[ChunkMeta],
    i: int,
    prefix_tokens: int,
    recompute_s: Callable[[int, int], float],
) -> tuple:
    """Algorithm 1 decision inputs for chunk ``i``: (per-level remaining
    bytes, remaining text bytes, remaining recompute seconds).

    Shared by :func:`simulate_stream` and ``serving.session.ServeSession``
    so the live loop's per-chunk decisions match the simulator's by
    construction (the differential harness in tests/test_session.py then
    checks the *rest* of each loop, not two re-implementations of this).
    """
    levels = list(metas[0].sizes.keys()) if metas else []
    remaining = metas[i:]
    remaining_sizes = {
        lvl: float(sum(r.sizes[lvl] for r in remaining)) for lvl in levels
    }
    remaining_text = float(sum(r.text_bytes for r in remaining))
    rem_recompute = 0.0
    ptoks = prefix_tokens
    for r in remaining:
        rem_recompute += recompute_s(r.n_tokens, ptoks)
        ptoks += r.n_tokens
    return remaining_sizes, remaining_text, rem_recompute


@dataclasses.dataclass
class StreamClock:
    """The Algorithm 1 per-chunk loop body on the virtual clock: decide →
    fetch (with hedging) → charge the compute window → observe throughput.

    Single implementation shared by :func:`simulate_stream` and the live
    ``serving.session.ServeSession`` — the session's decisions and TTFT
    accounting match the simulator *by construction*; the differential
    harness in tests/test_session.py then checks what each loop does
    around this step, not two copies of the step itself.
    """

    policy: AdaptationPolicy
    network: NetworkModel
    decode_bytes_per_s: float
    recompute_s: Callable[[int, int], float]  # (chunk_tokens, prefix) -> s
    hedge_after_s: Optional[float] = None
    start_t: float = 0.0
    # live compute-pressure hook: returns the current per-session slowdown
    # (ContentionModel.factor(n_active)); None == 1.0 == uncontended
    compute_scale: Optional[Callable[[], float]] = None
    # TEXT-recompute counterpart (ContentionModel.text_factor(n_active));
    # None falls back to compute_scale — the decode curve priced TEXT too
    # before the prefill-concurrency measurement existed
    text_scale: Optional[Callable[[], float]] = None

    def __post_init__(self):
        self.fetch_t = self.start_t  # network busy-until
        self.compute_t = self.start_t  # accelerator busy-until
        self.prefix_tokens = 0

    def decide(
        self, metas: List[ChunkMeta], i: int, exclude=(), credit=None
    ) -> tuple:
        """Algorithm 1 choice for chunk ``i`` at the current virtual instant.

        ``exclude`` removes configurations that already failed past their
        retry budget for this chunk (the failure-fallback ladder, ISSUE 6).
        ``credit`` (``adaptation.salvage_credit`` output, ISSUE 8) is a
        per-level byte credit for the current chunk's verified partial
        bytes — subtracted from ``remaining_sizes`` so the projection
        prices only the bytes still to be moved; ``None`` (the default)
        leaves the decision bit-identical to the simulator's.

        Returns ``(config, nbytes, scale)``; ``scale`` is the contention
        factor sampled *now* (decision time) for the chosen config's compute
        category — the TEXT factor for a TEXT chunk, the decode factor
        otherwise — and must be passed back to :meth:`account` so the
        charged compute window uses the same value even when the fetch
        resolves later (async transports).
        """
        m = metas[i]
        scale = 1.0 if self.compute_scale is None else float(self.compute_scale())
        tscale = scale if self.text_scale is None else float(self.text_scale())
        remaining_sizes, remaining_text, rem_recompute = remaining_work(
            metas, i, self.prefix_tokens, self.recompute_s
        )
        if credit:
            remaining_sizes = {
                lvl: max(sz - float(credit.get(lvl, 0.0)), 0.0)
                for lvl, sz in remaining_sizes.items()
            }
        cfg = self.policy.next_config(
            elapsed_s=self.fetch_t - self.start_t,
            remaining_sizes=remaining_sizes,
            remaining_text_bytes=remaining_text,
            remaining_recompute_s=rem_recompute * tscale,
            exclude=exclude,
        )
        nbytes = float(m.text_bytes if cfg.config == TEXT else m.sizes[cfg.config])
        return cfg.config, nbytes, (tscale if cfg.config == TEXT else scale)

    def charge_failure(self, lost_s: float) -> None:
        """Advance the network clock past a failed fetch attempt plus its
        retry backoff, *without* observing throughput — the next Algorithm-1
        decision then sees the lost time in ``elapsed_s`` and can re-plan
        (e.g. pick a coarser level to still make the SLO)."""
        self.fetch_t += max(float(lost_s), 0.0)

    def virtual_fetch(self, nbytes: float, chunk_idx: int) -> FetchOutcome:
        """The decided chunk's fetch, resolved purely on the virtual clock
        (the simulator path, and the session's TEXT chunks — their bytes are
        modeled, never read from storage)."""
        return self.network.fetch_outcome(
            nbytes,
            self.fetch_t,
            chunk_idx=chunk_idx,
            hedge_after_s=self.hedge_after_s,
        )

    def account(
        self,
        m: ChunkMeta,
        config: int,
        nbytes: float,
        outcome: FetchOutcome,
        scale: float = 1.0,
    ) -> ChunkTimeline:
        """Charge a resolved fetch plus its compute window; observe
        throughput for the next decision.  ``outcome`` may come from
        :meth:`virtual_fetch` or from a transport's realized I/O — anything
        with ``end_t`` / ``hedged`` / ``duplicate_bytes`` /
        ``throughput_gbps``."""
        fetch_start = self.fetch_t
        fetch_end = outcome.end_t
        self.fetch_t = fetch_end

        # --- compute (decode or recompute), pipelined with next fetch ------
        # contention: N active sessions stretch this session's compute window
        if config == TEXT:
            dur = self.recompute_s(m.n_tokens, self.prefix_tokens) * scale
        else:
            dur = nbytes / self.decode_bytes_per_s * scale
        compute_start = max(fetch_end, self.compute_t)
        compute_end = compute_start + dur
        self.compute_t = compute_end

        timeline = ChunkTimeline(
            chunk_idx=m.chunk_idx,
            config=config,
            nbytes=nbytes,
            fetch_start=fetch_start,
            fetch_end=fetch_end,
            compute_start=compute_start,
            compute_end=compute_end,
            hedged=outcome.hedged,
            duplicate_bytes=outcome.duplicate_bytes,
        )
        self.prefix_tokens += m.n_tokens
        self.policy.observe_throughput(outcome.throughput_gbps)
        return timeline

    def step(self, metas: List[ChunkMeta], i: int) -> ChunkTimeline:
        config, nbytes, scale = self.decide(metas, i)
        outcome = self.virtual_fetch(nbytes, metas[i].chunk_idx)
        return self.account(metas[i], config, nbytes, outcome, scale)

    def ttft_s(self, timelines: List[ChunkTimeline], final_step_s: float) -> float:
        last = timelines[-1].compute_end if timelines else self.start_t
        return last + final_step_s - self.start_t


def simulate_stream(
    metas: List[ChunkMeta],
    policy: AdaptationPolicy,
    network: NetworkModel,
    *,
    decode_bytes_per_s: Optional[float] = None,
    recompute_s: Callable[[int, int], float],  # (chunk_tokens, prefix_tokens) -> s
    final_step_s: float = 0.0,
    hedge_after_s: Optional[float] = None,
    start_t: float = 0.0,
) -> StreamResult:
    # default: this host's measured fused-decode throughput (BENCH_codec.json)
    if decode_bytes_per_s is None:
        decode_bytes_per_s = measured_decode_bytes_per_s()
    clock = StreamClock(
        policy=policy,
        network=network,
        decode_bytes_per_s=decode_bytes_per_s,
        recompute_s=recompute_s,
        hedge_after_s=hedge_after_s,
        start_t=start_t,
    )
    timelines = [clock.step(metas, i) for i in range(len(metas))]
    return StreamResult(
        timelines=timelines,
        ttft_s=clock.ttft_s(timelines, final_step_s),
        configs=[t.config for t in timelines],
        slo_s=policy.slo_s,
    )
