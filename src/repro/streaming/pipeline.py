"""Discrete-event simulation of chunked KV streaming with decode pipelining.

Models the paper's §6 "speed optimization": transmission of chunk *i* is
pipelined with the decode of chunk *i-1*; decode (rANS + dequant) and
text-chunk prefill recompute share the accelerator, so they serialize on a
single compute resource.  Per-chunk configuration comes from the
AdaptationPolicy (Algorithm 1); throughput estimates update per completed
chunk from the trace ("measured throughput when sending the previous
chunk").

Straggler mitigation: a hedged duplicate fetch is issued if a chunk's fetch
exceeds ``hedge_after_s``; the effective arrival is the min of the two
(tail-latency hedging, standard practice at 1000-node scale).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.streaming.adaptation import TEXT, AdaptationPolicy
from repro.streaming.calibration import measured_decode_bytes_per_s
from repro.streaming.network import NetworkModel
from repro.streaming.storage import ChunkMeta

__all__ = ["ChunkTimeline", "StreamResult", "simulate_stream"]


@dataclasses.dataclass
class ChunkTimeline:
    chunk_idx: int
    config: int  # TEXT or level
    nbytes: float
    fetch_start: float
    fetch_end: float
    compute_start: float  # decode or recompute
    compute_end: float
    hedged: bool = False


@dataclasses.dataclass
class StreamResult:
    timelines: List[ChunkTimeline]
    ttft_s: float
    configs: List[int]
    slo_s: float

    @property
    def slo_violated(self) -> bool:
        return self.ttft_s > self.slo_s

    @property
    def total_bytes(self) -> float:
        return sum(t.nbytes for t in self.timelines)


def simulate_stream(
    metas: List[ChunkMeta],
    policy: AdaptationPolicy,
    network: NetworkModel,
    *,
    decode_bytes_per_s: Optional[float] = None,
    recompute_s: Callable[[int, int], float],  # (chunk_tokens, prefix_tokens) -> s
    final_step_s: float = 0.0,
    hedge_after_s: Optional[float] = None,
    start_t: float = 0.0,
) -> StreamResult:
    # default: this host's measured fused-decode throughput (BENCH_codec.json)
    if decode_bytes_per_s is None:
        decode_bytes_per_s = measured_decode_bytes_per_s()
    n = len(metas)
    levels = list(metas[0].sizes.keys()) if n else []
    timelines: List[ChunkTimeline] = []
    fetch_t = start_t  # network busy-until
    compute_t = start_t  # accelerator busy-until
    prefix_tokens = 0

    for i, m in enumerate(metas):
        remaining = metas[i:]
        remaining_sizes = {
            lvl: float(sum(r.sizes[lvl] for r in remaining)) for lvl in levels
        }
        remaining_text = float(sum(r.text_bytes for r in remaining))
        rem_recompute = 0.0
        ptoks = prefix_tokens
        for r in remaining:
            rem_recompute += recompute_s(r.n_tokens, ptoks)
            ptoks += r.n_tokens
        cfg = policy.next_config(
            elapsed_s=fetch_t - start_t,
            remaining_sizes=remaining_sizes,
            remaining_text_bytes=remaining_text,
            remaining_recompute_s=rem_recompute,
        )
        nbytes = float(m.text_bytes if cfg.config == TEXT else m.sizes[cfg.config])

        # --- fetch (network resource), with optional hedging ---------------
        base_fetch = network.fetch_time(nbytes, fetch_t)
        hedged = False
        if hedge_after_s is not None and base_fetch > hedge_after_s:
            hedged_fetch = hedge_after_s + network.fetch_time(
                nbytes, fetch_t + hedge_after_s, straggle=False
            )
            if hedged_fetch < base_fetch:
                base_fetch = hedged_fetch
                hedged = True
        fetch_start = fetch_t
        fetch_end = fetch_t + base_fetch
        fetch_t = fetch_end

        # --- compute (decode or recompute), pipelined with next fetch ------
        if cfg.config == TEXT:
            dur = recompute_s(m.n_tokens, prefix_tokens)
        else:
            dur = nbytes / decode_bytes_per_s
        compute_start = max(fetch_end, compute_t)
        compute_end = compute_start + dur
        compute_t = compute_end

        timelines.append(
            ChunkTimeline(
                chunk_idx=i,
                config=cfg.config,
                nbytes=nbytes,
                fetch_start=fetch_start,
                fetch_end=fetch_end,
                compute_start=compute_start,
                compute_end=compute_end,
                hedged=hedged,
            )
        )
        prefix_tokens += m.n_tokens
        policy.observe_throughput(
            network.trace.measured_throughput_gbps(max(nbytes, 1.0), fetch_start)
        )

    ttft = (timelines[-1].compute_end if timelines else start_t) + final_step_s - start_t
    return StreamResult(
        timelines=timelines,
        ttft_s=ttft,
        configs=[t.config for t in timelines],
        slo_s=policy.slo_s,
    )
