"""Measured codec-throughput calibration for the streaming simulator.

The TTFT simulator charges ``nbytes / decode_bytes_per_s`` for every
bitstream chunk, so the constant directly shapes every simulated TTFT /
SLO number.  Rather than a hard-coded guess, the default is wired to the
*measured* fused-decode throughput of this host: ``benchmarks/microbench.py``
times the batched fused decode path (``codec.decode_chunks``) and writes
``BENCH_codec.json`` at the repo root; this module reads it back.

The same report's ``stacked`` section (cross-request stacked decode: M
requests' runs in one scan vs. M separate calls) calibrates the *contention*
model: :func:`measured_contention_factors` turns the measured batching
efficiency into per-session compute slowdown factors that
``pipeline.ContentionModel`` charges when N sessions share one engine.

Lookup order: ``$CACHEGEN_BENCH_CODEC`` (explicit file), ``BENCH_codec.json``
in the current working directory, then the repo root next to this package.
Falls back to :data:`DEFAULT_DECODE_BYTES_PER_S` (GB/s-class, the paper's
GPU-decoder ballpark) when no measurement exists yet.

Results are memoized per (candidate list, backend, file signature); the
signature includes each candidate's mtime and size, so re-pointing
``$CACHEGEN_BENCH_CODEC`` at a rewritten file — or the microbench rewriting
``BENCH_codec.json`` in place — is picked up without an explicit reset.
:func:`clear_calibration_cache` drops the memo entirely (tests, benchmarks).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

__all__ = [
    "DEFAULT_DECODE_BYTES_PER_S",
    "BENCH_CODEC_FILENAME",
    "BENCH_SESSION_FILENAME",
    "bench_codec_candidates",
    "bench_session_candidates",
    "clear_calibration_cache",
    "measured_decode_bytes_per_s",
    "measured_contention_factors",
    "measured_generation_contention_factors",
    "measured_level_priorities",
    "measured_text_contention_factors",
    "sharded_contention_factors",
]

DEFAULT_DECODE_BYTES_PER_S = 4e9
BENCH_CODEC_FILENAME = "BENCH_codec.json"
BENCH_SESSION_FILENAME = "BENCH_session.json"
_ENV_VAR = "CACHEGEN_BENCH_CODEC"
_ENV_SESSION = "CACHEGEN_BENCH_SESSION"


def _candidates(env_var: str, filename: str) -> List[str]:
    cands = []
    env = os.environ.get(env_var)
    if env:
        cands.append(env)
    cands.append(os.path.join(os.getcwd(), filename))
    repo_root = os.path.dirname(  # streaming/ -> repro/ -> src/ -> repo
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    )
    cands.append(os.path.join(repo_root, filename))
    return cands


def bench_codec_candidates() -> List[str]:
    """Candidate paths for the microbench's codec throughput report."""
    return _candidates(_ENV_VAR, BENCH_CODEC_FILENAME)


def bench_session_candidates() -> List[str]:
    """Candidate paths for the session benchmark's scenario report."""
    return _candidates(_ENV_SESSION, BENCH_SESSION_FILENAME)


_MEMO: dict = {}


def clear_calibration_cache() -> None:
    """Forget every memoized measurement.

    The memo already keys on file mtime/size, so normal rewrites are picked
    up automatically; this is the explicit reset for cases the signature
    cannot see (same-mtime rewrites on coarse-clock filesystems, tests that
    monkeypatch the readers).
    """
    _MEMO.clear()


def _file_sig(path: str) -> Optional[Tuple[int, int]]:
    """(mtime_ns, size) of ``path``, or None if unreadable — part of the memo
    key so a report rewritten *in place* invalidates stale values."""
    try:
        st = os.stat(path)
        return (st.st_mtime_ns, st.st_size)
    except OSError:
        return None


def _first_measurement(cands: Tuple[str, ...], backend: str, extract):
    """First candidate report yielding a usable value via ``extract``.

    Candidates that are unreadable, unparseable, from another backend, *or*
    parseable but missing/invalid for this extractor all fall through to the
    next candidate (a partial report in the CWD must not shadow a complete
    one at the repo root).
    """
    for p in cands:
        try:
            with open(p) as f:
                report = json.load(f)
            if report.get("host_backend") not in (None, backend):
                continue
            v = extract(report)
            if v is not None:
                return v
        except (OSError, KeyError, TypeError, ValueError):
            continue
    return None


def _memoized(key, sig, compute):
    """Signature-checked memo: one live entry per key, replaced (not
    accumulated) when the underlying files' (mtime, size) signature moves."""
    hit = _MEMO.get(key)
    if hit is not None and hit[0] == sig:
        return hit[1]
    value = compute()
    _MEMO[key] = (sig, value)
    return value


def measured_decode_bytes_per_s(
    default: float = DEFAULT_DECODE_BYTES_PER_S,
    path: Optional[str] = None,
) -> float:
    """Fused-decode bytes/s measured by the microbench, else ``default``.

    A report is only trusted when its ``host_backend`` matches the current
    JAX backend (a committed CPU measurement must not masquerade as a TPU
    host's decode rate).  Results are memoized per candidate list — figure
    scripts construct cost models repeatedly and must not re-parse files —
    with the files' (mtime, size) signature checked on every hit, so a
    rewritten bench file must not leak stale values.
    """
    import jax  # local: keep module importable without initializing jax

    backend = jax.default_backend()
    cands = tuple([path] if path else bench_codec_candidates())

    def extract(report):
        v = float(report["fused"]["bytes_per_s"])
        return v if v > 0 else None

    def compute():
        v = _first_measurement(cands, backend, extract)
        return float(default) if v is None else v

    sig = tuple(_file_sig(p) for p in cands)
    return _memoized(("decode", cands, backend, float(default)), sig, compute)


def measured_contention_factors(
    path: Optional[str] = None,
) -> Dict[int, float]:
    """Per-session compute slowdown at M concurrent sessions, measured.

    Reads the microbench's ``stacked`` section: for each M it recorded the
    aggregate decode throughput of M requests' runs stacked into one scan.
    With aggregate throughput ``thpt(M)`` the per-session slowdown vs.
    running alone is ``factor(M) = M * thpt(1) / thpt(M)`` — 1.0 when
    batching scales perfectly, M when stacking buys nothing (fully
    serialized).  Returns ``{}`` when no stacked measurement exists; factors
    are clamped to >= 1.0 (a measured super-linear blip must not make the
    cost model charge *less* than the uncontended rate).
    """
    import jax

    backend = jax.default_backend()
    cands = tuple([path] if path else bench_codec_candidates())

    def extract(report):
        rates = {
            int(m): float(row["stacked"]["bytes_per_s"])
            for m, row in report["stacked"].items()
        }
        base = rates.get(1)
        if not base or base <= 0:
            return None
        return {
            m: max(1.0, m * base / r)
            for m, r in sorted(rates.items())
            if r > 0
        }

    def compute():
        factors = _first_measurement(cands, backend, extract)
        return {} if factors is None else factors

    sig = tuple(_file_sig(p) for p in cands)
    return dict(_memoized(("contention", cands, backend), sig, compute))


def measured_level_priorities(
    path: Optional[str] = None,
) -> Dict[int, float]:
    """Per-level hot-tier keep priority from realized session decisions.

    Reads ``BENCH_session.json``'s per-scenario ``levels`` histograms (what
    Algorithm 1 *actually picked* on this host's traces) and returns each
    stored level's pick fraction — the tiered store's eviction seed: levels
    the adapter never chooses get priority 0.0 and leave the hot tier
    first.  TEXT (level ``-1``) recomputes from raw text and occupies no
    store space, so it is excluded.  Returns ``{}`` when no session report
    exists (the store then falls back to pure LRU).
    """
    import jax

    backend = jax.default_backend()
    cands = tuple([path] if path else bench_session_candidates())

    def extract(report):
        counts: Dict[int, int] = {}
        for sc in report["scenarios"]:
            for lvl, n in (sc.get("levels") or {}).items():
                level = int(lvl)
                if level < 0:
                    continue  # TEXT: not stored, nothing to evict
                counts[level] = counts.get(level, 0) + int(n)
        total = sum(counts.values())
        if total <= 0:
            return None
        return {lvl: c / total for lvl, c in sorted(counts.items())}

    def compute():
        pri = _first_measurement(cands, backend, extract)
        return {} if pri is None else pri

    sig = tuple(_file_sig(p) for p in cands)
    return dict(_memoized(("level_priorities", cands, backend), sig, compute))


def measured_text_contention_factors(
    path: Optional[str] = None,
) -> Dict[int, float]:
    """Per-session TEXT-recompute slowdown at M concurrent sessions.

    Reads the microbench's ``stacked_prefill`` section: for each M it
    recorded the aggregate token throughput of M rows' text chunks
    recomputed in one width-masked ``prefill_extend_rows`` forward.  Same
    arithmetic as :func:`measured_contention_factors` — ``factor(M) =
    M * thpt(1) / thpt(M)``, clamped to >= 1.0 — but over the prefill
    concurrency curve, which stacks differently from decode (attention cost
    grows with each row's own prefix, not with the shared scan).  Returns
    ``{}`` when no stacked-prefill measurement exists; callers
    (``pipeline.ContentionModel.text_factor``) then fall back to the decode
    curve.
    """
    import jax

    backend = jax.default_backend()
    cands = tuple([path] if path else bench_codec_candidates())

    def extract(report):
        rates = {
            int(m): float(row["batched"]["tokens_per_s"])
            for m, row in report["stacked_prefill"].items()
        }
        base = rates.get(1)
        if not base or base <= 0:
            return None
        return {
            m: max(1.0, m * base / r)
            for m, r in sorted(rates.items())
            if r > 0
        }

    def compute():
        factors = _first_measurement(cands, backend, extract)
        return {} if factors is None else factors

    sig = tuple(_file_sig(p) for p in cands)
    return dict(_memoized(("text_contention", cands, backend), sig, compute))


def measured_generation_contention_factors(
    path: Optional[str] = None,
) -> Dict[int, float]:
    """Per-session generation-step slowdown at M generating rows.

    Reads the microbench's ``stacked_decode_step`` section: for each M it
    recorded the aggregate token throughput of M generating rows' next
    tokens computed in one ``decode_step_rows`` dispatch.  Same arithmetic
    as :func:`measured_contention_factors` — ``factor(M) = M * thpt(1) /
    thpt(M)``, clamped to >= 1.0 — but over the stacked decode-*step*
    curve, which is its own shape again (one token per row per forward,
    attention over each row's whole realized prefix).  Returns ``{}`` when
    no stacked-step measurement exists; callers
    (``pipeline.ContentionModel.gen_factor``) then fall back to the decode
    curve.
    """
    import jax

    backend = jax.default_backend()
    cands = tuple([path] if path else bench_codec_candidates())

    def extract(report):
        rates = {
            int(m): float(row["batched"]["tokens_per_s"])
            for m, row in report["stacked_decode_step"].items()
        }
        base = rates.get(1)
        if not base or base <= 0:
            return None
        return {
            m: max(1.0, m * base / r)
            for m, r in sorted(rates.items())
            if r > 0
        }

    def compute():
        factors = _first_measurement(cands, backend, extract)
        return {} if factors is None else factors

    sig = tuple(_file_sig(p) for p in cands)
    return dict(_memoized(("gen_contention", cands, backend), sig, compute))


def sharded_contention_factors(
    n_shards: int, path: Optional[str] = None
) -> Dict[int, float]:
    """Effective decode slowdown per live-session count on an S-shard mesh.

    The mesh-sharded serving engine splits its cache rows over ``n_shards``
    contention domains, so N live sessions see the measured single-device
    curve at the even-spread per-shard width ``ceil(N / S)``.  Returns the
    measured curve's support re-read through that mapping — what the mesh
    benchmark records as each shard count's effective contention curve.
    At ``n_shards=1`` this is exactly :func:`measured_contention_factors`.
    """
    if n_shards < 1:
        raise ValueError(f"sharded_contention_factors needs n_shards >= 1, got {n_shards}")
    from repro.streaming.pipeline import ContentionModel  # lazy: avoid cycle

    base = measured_contention_factors(path)
    cm = ContentionModel(base)
    return {
        int(m): cm.factor_sharded(int(m), n_shards) for m in sorted(base)
    }
