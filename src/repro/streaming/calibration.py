"""Measured codec-throughput calibration for the streaming simulator.

The TTFT simulator charges ``nbytes / decode_bytes_per_s`` for every
bitstream chunk, so the constant directly shapes every simulated TTFT /
SLO number.  Rather than a hard-coded guess, the default is wired to the
*measured* fused-decode throughput of this host: ``benchmarks/microbench.py``
times the batched fused decode path (``codec.decode_chunks``) and writes
``BENCH_codec.json`` at the repo root; this module reads it back.

Lookup order: ``$CACHEGEN_BENCH_CODEC`` (explicit file), ``BENCH_codec.json``
in the current working directory, then the repo root next to this package.
Falls back to :data:`DEFAULT_DECODE_BYTES_PER_S` (GB/s-class, the paper's
GPU-decoder ballpark) when no measurement exists yet.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

__all__ = [
    "DEFAULT_DECODE_BYTES_PER_S",
    "BENCH_CODEC_FILENAME",
    "bench_codec_candidates",
    "measured_decode_bytes_per_s",
]

DEFAULT_DECODE_BYTES_PER_S = 4e9
BENCH_CODEC_FILENAME = "BENCH_codec.json"
_ENV_VAR = "CACHEGEN_BENCH_CODEC"


def bench_codec_candidates() -> List[str]:
    """Candidate paths for the microbench's codec throughput report."""
    cands = []
    env = os.environ.get(_ENV_VAR)
    if env:
        cands.append(env)
    cands.append(os.path.join(os.getcwd(), BENCH_CODEC_FILENAME))
    repo_root = os.path.dirname(  # streaming/ -> repro/ -> src/ -> repo
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    )
    cands.append(os.path.join(repo_root, BENCH_CODEC_FILENAME))
    return cands


_MEMO: dict = {}


def measured_decode_bytes_per_s(
    default: float = DEFAULT_DECODE_BYTES_PER_S,
    path: Optional[str] = None,
) -> float:
    """Fused-decode bytes/s measured by the microbench, else ``default``.

    A report is only trusted when its ``host_backend`` matches the current
    JAX backend (a committed CPU measurement must not masquerade as a TPU
    host's decode rate).  Results are memoized per candidate list — figure
    scripts construct cost models repeatedly and must not re-read files.
    """
    import jax  # local: keep module importable without initializing jax

    backend = jax.default_backend()
    cands = tuple([path] if path else bench_codec_candidates())
    key = (cands, backend, float(default))
    if key in _MEMO:
        return _MEMO[key]
    value = float(default)
    for p in cands:
        try:
            with open(p) as f:
                report = json.load(f)
            if report.get("host_backend") not in (None, backend):
                continue
            v = float(report["fused"]["bytes_per_s"])
            if v > 0:
                value = v
                break
        except (OSError, KeyError, TypeError, ValueError):
            continue
    _MEMO[key] = value
    return value
