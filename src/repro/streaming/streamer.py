"""CacheGen streamer facade: store_kv / stream / materialize.

Ties together the codec (core/), the bitstream store, the bandwidth-adaptive
scheduler (Algorithm 1) and the serving engine:

  offline:  caches --store_kv--> per-chunk multi-level bitstreams
  online:   stream()      — simulate fetch under a bandwidth trace, choosing
                            per-chunk configs against the TTFT SLO;
            materialize() — actually decode the chosen bitstreams (and
                            recompute TEXT chunks via the engine) into a
                            serving KV cache, ready for generate_with_kv.

materialize() default (PR 1) is the *fused batched* decode-to-cache
pipeline: consecutive bitstream chunks form a run, each run is decoded in
one batched ``codec.decode_chunks`` call (stacked rANS scans + fused dequant
kernels, mixed levels welcome) and written into the serving cache with one
donated-buffer ``Engine.decode_to_cache`` update — no per-chunk host
round-trips and no per-chunk O(cache) copies.  ``fused=False`` keeps the
seed per-chunk path as the correctness oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import codec as kvcodec
from repro.models.lm import Caches
from repro.serving.engine import Engine
from repro.serving.kv_layout import caches_to_codec_kv
from repro.streaming.adaptation import TEXT, AdaptationPolicy
from repro.streaming.network import NetworkModel
from repro.streaming.pipeline import StreamResult, simulate_stream
from repro.streaming.storage import DEFAULT_CHUNK_TOKENS, ChunkMeta, KVStore

__all__ = ["CacheGenStreamer"]


@dataclasses.dataclass
class FetchPlan:
    context_id: str
    result: StreamResult
    metas: List[ChunkMeta]


class CacheGenStreamer:
    def __init__(self, store: KVStore, cfg: ArchConfig):
        self.store = store
        self.cfg = cfg

    # -- offline -------------------------------------------------------------

    def store_from_caches(
        self,
        context_id: str,
        caches: Caches,
        n_tokens: int,
        *,
        batch_index: int = 0,
        chunk_tokens: int = DEFAULT_CHUNK_TOKENS,
    ) -> List[ChunkMeta]:
        kv = caches_to_codec_kv(caches, batch_index, n_tokens)
        return self.store.store_kv(context_id, kv, chunk_tokens=chunk_tokens)

    # -- online --------------------------------------------------------------

    def stream(
        self,
        context_id: str,
        network: NetworkModel,
        *,
        slo_s: float,
        decode_bytes_per_s: Optional[float] = None,
        recompute_s,
        default_level: Optional[int] = None,
        prior_throughput_gbps: Optional[float] = None,
        allow_text: bool = True,
        adapt: bool = True,
        fixed_level: Optional[int] = None,
        hedge_after_s: Optional[float] = None,
        final_step_s: float = 0.0,
    ) -> FetchPlan:
        metas = self.store.meta(context_id)
        n_levels = self.store.tables.config.n_levels
        quality_order = list(range(n_levels))  # 0 = least loss
        if fixed_level is not None or not adapt:
            lvl = fixed_level if fixed_level is not None else (
                default_level if default_level is not None else 1
            )
            policy = AdaptationPolicy(
                levels_quality_order=[lvl],
                slo_s=slo_s,
                default_level=lvl,
                prior_throughput_gbps=prior_throughput_gbps,
                allow_text=False,
            )
        else:
            policy = AdaptationPolicy(
                levels_quality_order=quality_order,
                slo_s=slo_s,
                default_level=default_level
                if default_level is not None
                else min(1, n_levels - 1),
                prior_throughput_gbps=prior_throughput_gbps,
                allow_text=allow_text,
            )
        result = simulate_stream(
            metas,
            policy,
            network,
            decode_bytes_per_s=decode_bytes_per_s,
            recompute_s=recompute_s,
            final_step_s=final_step_s,
            hedge_after_s=hedge_after_s,
        )
        return FetchPlan(context_id=context_id, result=result, metas=metas)

    # -- materialization (real decode) ----------------------------------------

    def materialize(
        self,
        plan: FetchPlan,
        engine: Engine,
        tokens: np.ndarray,  # (B, T) full context tokens (for TEXT chunks)
        *,
        batch: int = 1,
        fused: bool = True,
    ) -> Caches:
        """Build the serving cache by decoding each chunk at its chosen config.

        ``fused=True`` (default): consecutive bitstream chunks are decoded as
        one batched run (``codec.decode_chunks``) and written with a single
        donated-buffer cache update per run; TEXT chunks are recomputed in
        stream order in between.  ``fused=False``: retained per-chunk
        reference path (decode each blob to host, insert one by one).
        """
        caches = engine.empty_caches(batch)
        if not fused or caches.kv_k is None:
            return self._materialize_reference(plan, engine, tokens, caches, batch)
        items = list(zip(plan.metas, plan.result.configs))
        i = 0
        while i < len(items):
            meta, config = items[i]
            if config == TEXT:
                _, caches = engine.prefill_extend(
                    jnp.asarray(tokens[:, meta.start : meta.end], jnp.int32), caches
                )
                i += 1
                continue
            # run of consecutive bitstream chunks -> one batched decode +
            # one cache insertion
            blobs = []
            j = i
            while j < len(items) and items[j][1] != TEXT:
                m, lvl = items[j]
                blobs.append(self.store.get_kv(plan.context_id, m.chunk_idx, lvl))
                j += 1
            kv_run = kvcodec.decode_chunks(
                blobs, self.store.tables, out_dtype=caches.kv_k.dtype
            )
            caches = engine.decode_to_cache(caches, kv_run, meta.start)
            i = j
        return caches

    def _materialize_reference(
        self,
        plan: FetchPlan,
        engine: Engine,
        tokens: np.ndarray,
        caches: Caches,
        batch: int,
    ) -> Caches:
        """Seed per-chunk path: the fused pipeline's correctness oracle."""
        cfg = self.cfg
        for meta, config in zip(plan.metas, plan.result.configs):
            s, e = meta.start, meta.end
            if config == TEXT:
                _, caches = engine.prefill_extend(
                    jnp.asarray(tokens[:, s:e], jnp.int32), caches
                )
            else:
                blob = self.store.get_kv(plan.context_id, meta.chunk_idx, config)
                kv = self.store.decode(blob)  # (L, 2, Tc, C)
                caches = _insert_codec_kv(cfg, caches, kv, s, batch)
        return caches


def _insert_codec_kv(
    cfg: ArchConfig, caches: Caches, kv: np.ndarray, start: int, batch: int
) -> Caches:
    L, two, Tc, C = kv.shape
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    kt = jnp.asarray(kv[:, 0].reshape(L, Tc, Hkv, Dh), caches.kv_k.dtype)
    vt = jnp.asarray(kv[:, 1].reshape(L, Tc, Hkv, Dh), caches.kv_v.dtype)
    kt = jnp.broadcast_to(kt[:, None], (L, batch, Tc, Hkv, Dh))
    vt = jnp.broadcast_to(vt[:, None], (L, batch, Tc, Hkv, Dh))
    return caches._replace(
        kv_k=caches.kv_k.at[:, :, start : start + Tc].set(kt),
        kv_v=caches.kv_v.at[:, :, start : start + Tc].set(vt),
        # monotone: out-of-order / interleaved chunk insertion must never
        # shrink the valid cache length
        length=jnp.maximum(
            caches.length, jnp.full((batch,), start + Tc, jnp.int32)
        ),
    )
