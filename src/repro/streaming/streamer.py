"""CacheGen streamer facade: store_kv / stream / materialize.

Ties together the codec (core/), the bitstream store, the bandwidth-adaptive
scheduler (Algorithm 1) and the serving engine:

  offline:  caches --store_kv--> per-chunk multi-level bitstreams
  online:   stream()      — simulate fetch under a bandwidth trace, choosing
                            per-chunk configs against the TTFT SLO;
            materialize() — actually decode the chosen bitstreams (and
                            recompute TEXT chunks via the engine) into a
                            serving KV cache, ready for generate_with_kv.

materialize() default (PR 1) is the *fused batched* decode-to-cache
pipeline: consecutive bitstream chunks form a run, each run is decoded in
one batched ``codec.decode_chunks`` call (stacked rANS scans + fused dequant
kernels, mixed levels welcome) and written into the serving cache with one
donated-buffer ``Engine.decode_to_cache`` update — no per-chunk host
round-trips and no per-chunk O(cache) copies.  ``fused=False`` keeps the
seed per-chunk path as the correctness oracle.

Run grouping lives in :class:`RunSegmenter` (PR 2): an *incremental*
double-buffered segmenter that both the offline ``materialize`` (via
:func:`segment_plan`, maximal runs) and the live closed-loop
``serving.session.ServeSession`` (bounded runs, so decode of a full buffer
overlaps the next fetches) drive — one grouping policy, two consumers.

Since the transport split (ISSUE 4), ``materialize`` reads through the
pluggable :class:`~repro.streaming.transport.Transport` handle API: every
run segment's fetch is issued up front (cancellable handles, I/O on worker
threads) and resolved in plan order, so fetches stream concurrently with
the decodes consuming them.  Default is
:class:`~repro.streaming.transport.LocalTransport` over the plan's store.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import codec as kvcodec
from repro.models.lm import Caches
from repro.serving.engine import Engine
from repro.serving.kv_layout import caches_to_codec_kv
from repro.streaming.adaptation import TEXT, make_policy
from repro.streaming.network import NetworkModel
from repro.streaming.pipeline import StreamResult, simulate_stream
from repro.streaming.storage import DEFAULT_CHUNK_TOKENS, ChunkMeta, KVStore

__all__ = ["CacheGenStreamer", "PlanSegment", "RunSegmenter", "segment_plan"]


@dataclasses.dataclass
class FetchPlan:
    context_id: str
    result: StreamResult
    metas: List[ChunkMeta]


@dataclasses.dataclass
class PlanSegment:
    """One executable unit of a (partially) resolved plan: either a run of
    consecutive bitstream chunks (one batched decode + one cache insertion)
    or a single TEXT chunk (one ``prefill_extend`` recompute)."""

    kind: str  # "run" | "text"
    indices: List[int]  # chunk indices, stream order
    configs: List[int]  # per chunk: encoding level, or TEXT
    start: int  # first token covered
    end: int  # one past the last token covered
    blobs: Optional[List[bytes]] = None  # fetched bitstreams (online path)

    @property
    def n_tokens(self) -> int:
        return self.end - self.start


class RunSegmenter:
    """Incremental, double-buffered plan segmenter.

    Chunks are pushed in stream order as their fetches complete.  Bitstream
    chunks accumulate in a pending buffer; a "run" segment is emitted when

      * a TEXT chunk arrives — its recompute reads the cache at its own
        token offset, so all buffered chunks must land in the cache first
        (positional bookkeeping), or
      * the buffer reaches ``max_run_tokens`` — the double-buffer
        granularity: the emitted run's decode can proceed (asynchronously on
        accelerator backends, where JAX dispatch doesn't block the host)
        while subsequent fetches fill the next buffer, or
      * the plan ends (:meth:`flush`).

    ``max_run_tokens=None`` segments only at TEXT boundaries and plan end —
    maximal runs, the offline ``materialize`` default (fewest, largest
    batched decodes; no fetch/decode overlap to exploit offline).
    """

    def __init__(self, max_run_tokens: Optional[int] = None):
        if max_run_tokens is not None and max_run_tokens <= 0:
            raise ValueError("max_run_tokens must be positive or None")
        self.max_run_tokens = max_run_tokens
        self._buf: List[Tuple[ChunkMeta, int, Optional[bytes]]] = []

    def _buffered_tokens(self) -> int:
        return sum(m.n_tokens for m, _, _ in self._buf)

    def push(
        self, meta: ChunkMeta, config: int, blob: Optional[bytes] = None
    ) -> List[PlanSegment]:
        """Feed one resolved chunk; returns the segments now ready to execute."""
        if config == TEXT:
            out = self.flush()
            out.append(
                PlanSegment(
                    kind="text",
                    indices=[meta.chunk_idx],
                    configs=[TEXT],
                    start=meta.start,
                    end=meta.end,
                )
            )
            return out
        self._buf.append((meta, config, blob))
        if (
            self.max_run_tokens is not None
            and self._buffered_tokens() >= self.max_run_tokens
        ):
            return self.flush()
        return []

    def flush(self) -> List[PlanSegment]:
        """Emit the pending run (if any) regardless of buffer fill."""
        if not self._buf:
            return []
        metas = [m for m, _, _ in self._buf]
        blobs = [b for _, _, b in self._buf]
        seg = PlanSegment(
            kind="run",
            indices=[m.chunk_idx for m in metas],
            configs=[c for _, c, _ in self._buf],
            start=metas[0].start,
            end=metas[-1].end,
            blobs=None if any(b is None for b in blobs) else blobs,
        )
        self._buf = []
        return [seg]


def segment_plan(
    metas: Sequence[ChunkMeta],
    configs: Sequence[int],
    max_run_tokens: Optional[int] = None,
) -> List[PlanSegment]:
    """Offline segmentation of a fully resolved plan (metas + chosen configs)."""
    seg = RunSegmenter(max_run_tokens)
    out: List[PlanSegment] = []
    for meta, config in zip(metas, configs):
        out.extend(seg.push(meta, config))
    out.extend(seg.flush())
    return out


class CacheGenStreamer:
    def __init__(self, store: KVStore, cfg: ArchConfig):
        self.store = store
        self.cfg = cfg

    # -- offline -------------------------------------------------------------

    def store_from_caches(
        self,
        context_id: str,
        caches: Caches,
        n_tokens: int,
        *,
        batch_index: int = 0,
        chunk_tokens: int = DEFAULT_CHUNK_TOKENS,
    ) -> List[ChunkMeta]:
        kv = caches_to_codec_kv(caches, batch_index, n_tokens)
        return self.store.store_kv(context_id, kv, chunk_tokens=chunk_tokens)

    # -- online --------------------------------------------------------------

    def stream(
        self,
        context_id: str,
        network: NetworkModel,
        *,
        slo_s: float,
        decode_bytes_per_s: Optional[float] = None,
        recompute_s,
        default_level: Optional[int] = None,
        prior_throughput_gbps: Optional[float] = None,
        allow_text: bool = True,
        adapt: bool = True,
        fixed_level: Optional[int] = None,
        hedge_after_s: Optional[float] = None,
        final_step_s: float = 0.0,
    ) -> FetchPlan:
        metas = self.store.meta(context_id)
        policy = make_policy(
            self.store.tables.config.n_levels,
            slo_s=slo_s,
            default_level=default_level,
            prior_throughput_gbps=prior_throughput_gbps,
            allow_text=allow_text,
            adapt=adapt,
            fixed_level=fixed_level,
        )
        result = simulate_stream(
            metas,
            policy,
            network,
            decode_bytes_per_s=decode_bytes_per_s,
            recompute_s=recompute_s,
            final_step_s=final_step_s,
            hedge_after_s=hedge_after_s,
        )
        return FetchPlan(context_id=context_id, result=result, metas=metas)

    # -- materialization (real decode) ----------------------------------------

    def materialize(
        self,
        plan: FetchPlan,
        engine: Engine,
        tokens: np.ndarray,  # (B, T) full context tokens (for TEXT chunks)
        *,
        batch: int = 1,
        fused: bool = True,
        transport=None,
    ) -> Caches:
        """Build the serving cache by decoding each chunk at its chosen config.

        ``fused=True`` (default): consecutive bitstream chunks are decoded as
        one batched run (``codec.decode_chunks``) and written with a single
        donated-buffer cache update per run; TEXT chunks are recomputed in
        stream order in between.  Run fetches go through ``transport``
        (default: direct :class:`~repro.streaming.transport.LocalTransport`
        reads), issued ``fetch_lookahead`` segments ahead of the decode
        consuming them (double-buffered I/O without holding every run's
        bytes at once) and released as soon as they are decoded.
        ``fused=False``: retained per-chunk reference path (decode each blob
        to host, insert one by one).
        """
        caches = engine.empty_caches(batch)
        if not fused or caches.kv_k is None:
            return self._materialize_reference(plan, engine, tokens, caches, batch)
        if transport is None:
            from repro.streaming.transport import LocalTransport

            transport = LocalTransport(self.store)
        fetch_lookahead = 2
        segs = segment_plan(plan.metas, plan.result.configs)
        handles = {}
        issued = 0

        def issue_until(j_limit):
            nonlocal issued
            while issued <= min(j_limit, len(segs) - 1):
                s = segs[issued]
                if s.kind == "run":
                    handles[issued] = transport.fetch_run(
                        plan.context_id, list(zip(s.indices, s.configs))
                    )
                issued += 1

        for j, seg in enumerate(segs):
            issue_until(j + fetch_lookahead)
            if seg.kind == "text":
                _, caches = engine.prefill_extend(
                    jnp.asarray(tokens[:, seg.start : seg.end], jnp.int32), caches
                )
                continue
            # run of consecutive bitstream chunks -> one batched decode +
            # one cache insertion
            blobs = handles.pop(j).result().blobs
            kv_run = kvcodec.decode_chunks(
                blobs, self.store.tables, out_dtype=caches.kv_k.dtype
            )
            caches = engine.decode_to_cache(caches, kv_run, seg.start)
        return caches

    def _materialize_reference(
        self,
        plan: FetchPlan,
        engine: Engine,
        tokens: np.ndarray,
        caches: Caches,
        batch: int,
    ) -> Caches:
        """Seed per-chunk path: the fused pipeline's correctness oracle."""
        cfg = self.cfg
        for meta, config in zip(plan.metas, plan.result.configs):
            s, e = meta.start, meta.end
            if config == TEXT:
                _, caches = engine.prefill_extend(
                    jnp.asarray(tokens[:, s:e], jnp.int32), caches
                )
            else:
                blob = self.store.get_kv(plan.context_id, meta.chunk_idx, config)
                kv = self.store.decode(blob)  # (L, 2, Tc, C)
                caches = _insert_codec_kv(cfg, caches, kv, s, batch)
        return caches


def _insert_codec_kv(
    cfg: ArchConfig, caches: Caches, kv: np.ndarray, start: int, batch: int
) -> Caches:
    L, two, Tc, C = kv.shape
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    kt = jnp.asarray(kv[:, 0].reshape(L, Tc, Hkv, Dh), caches.kv_k.dtype)
    vt = jnp.asarray(kv[:, 1].reshape(L, Tc, Hkv, Dh), caches.kv_v.dtype)
    kt = jnp.broadcast_to(kt[:, None], (L, batch, Tc, Hkv, Dh))
    vt = jnp.broadcast_to(vt[:, None], (L, batch, Tc, Hkv, Dh))
    return caches._replace(
        kv_k=caches.kv_k.at[:, :, start : start + Tc].set(kt),
        kv_v=caches.kv_v.at[:, :, start : start + Tc].set(vt),
        # monotone: out-of-order / interleaved chunk insertion must never
        # shrink the valid cache length
        length=jnp.maximum(
            caches.length, jnp.full((batch,), start + Tc, jnp.int32)
        ),
    )
