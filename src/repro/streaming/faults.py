"""Deterministic fault injection for the fetch path (ISSUE 6).

A :class:`FaultPlan` is a seeded description of chaos: per fetch *attempt*
it may drop the fetch, stall it past a timeout, corrupt the payload bytes,
or truncate the stream (deliver a valid prefix, then sever — the
salvageable partial delivery ISSUE 8's resume path exists for); per stored
*entry* it may delete the blob or corrupt it at rest.
Every decision is drawn from an RNG keyed on ``(seed, context, chunk,
level, attempt, salt)`` — the ``keyed_straggler_delay`` idiom — so the same
plan replays identically regardless of scheduling order, across the
virtual-clock :class:`~repro.streaming.transport.SimTransport`, a real
:class:`~repro.streaming.transport.TcpStoreServer` socket (pass
``fault_plan=`` to the server), and the property-based test suite.

Two injection points compose with everything ISSUE 4 made pluggable:

  * :class:`FaultyTransport` wraps any ``Transport`` and perturbs in-flight
    fetches (transient faults — a retry re-draws at the next attempt
    index, so a fault can clear);
  * :class:`FaultyBackend` wraps any ``StorageBackend`` and perturbs reads
    (persistent faults — a missing or rotten entry stays that way, which
    is why the retry machinery treats ``KeyError`` as permanent-at-level).

A zero-probability plan injects nothing and leaves every path bit-identical
to the unwrapped transport/backend (the differential tests hold it there).
"""
from __future__ import annotations

import dataclasses
import threading
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.streaming.storage import KVStore, StorageBackend, _missing
from repro.streaming.transport import (
    ChunkLevels,
    FetchError,
    FetchHandle,
    FetchResult,
    Salvage,
    Transport,
)

__all__ = [
    "Fault",
    "FaultPlan",
    "FaultyBackend",
    "FaultyTransport",
    "with_faulty_backend",
]


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected in-flight fault: what happens, and how late it lands."""

    kind: str  # "drop" | "stall" | "corrupt" | "truncate"
    delay_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, order-independent fault schedule.

    Per-attempt (transient, transport layer): ``drop_p`` + ``stall_p`` +
    ``corrupt_p`` + ``truncate_p`` must not exceed 1 — they partition the
    unit draw, so at most one fault fires per attempt.  Per-entry
    (persistent, storage layer): ``missing_p`` deletes, ``store_corrupt_p``
    rots at rest.

    ``drop_detect_s`` bounds how long a dropped fetch takes to be *noticed*
    (connection-reset latency on the virtual clock); ``stall_scale_s`` /
    ``stall_alpha`` shape the Pareto stall; ``wall_cap_s`` bounds the real
    sleep any single injected fault may cost on a realtime transport, so
    chaos tests stay fast.
    """

    seed: int = 0
    drop_p: float = 0.0
    stall_p: float = 0.0
    corrupt_p: float = 0.0
    truncate_p: float = 0.0
    missing_p: float = 0.0
    store_corrupt_p: float = 0.0
    stall_scale_s: float = 0.2
    stall_alpha: float = 1.5
    drop_detect_s: float = 0.02
    wall_cap_s: float = 2.0

    def __post_init__(self):
        total = self.drop_p + self.stall_p + self.corrupt_p + self.truncate_p
        if total > 1.0 + 1e-9:
            raise ValueError(
                f"drop_p + stall_p + corrupt_p + truncate_p = {total} exceeds 1"
            )

    # -- keyed determinism --------------------------------------------------

    def _rng(
        self, cid: str, chunk: int, level: int, attempt: int, salt: int
    ) -> np.random.Generator:
        return np.random.default_rng((
            self.seed & 0xFFFFFFFF,
            zlib.crc32(str(cid).encode()) & 0xFFFFFFFF,
            chunk & 0xFFFFFFFF,
            (level + 8) & 0xFF,  # levels start at TEXT = -1
            attempt & 0xFFFF,
            salt,
        ))

    # -- per-attempt (transport) -------------------------------------------

    def draw(
        self, cid: str, chunk: int, level: int, attempt: int
    ) -> Optional[Fault]:
        """The in-flight fault for one fetch attempt, or None."""
        if (self.drop_p <= 0 and self.stall_p <= 0 and self.corrupt_p <= 0
                and self.truncate_p <= 0):
            return None
        rng = self._rng(cid, chunk, level, attempt, salt=0)
        u = float(rng.random())
        if u < self.drop_p:
            return Fault("drop", delay_s=float(rng.uniform(0.0, self.drop_detect_s)))
        if u < self.drop_p + self.stall_p:
            stall = self.stall_scale_s * (1.0 + float(rng.pareto(self.stall_alpha)))
            return Fault("stall", delay_s=stall)
        if u < self.drop_p + self.stall_p + self.corrupt_p:
            return Fault("corrupt")
        if u < self.drop_p + self.stall_p + self.corrupt_p + self.truncate_p:
            return Fault("truncate")
        return None

    def truncate_fraction(
        self, cid: str, chunk: int, level: int, attempt: int
    ) -> float:
        """How much of the payload a truncate fault delivers before the
        sever — keyed like every other draw, U(0.25, 0.9) so the prefix is
        always substantial enough to exercise salvage but never complete."""
        return float(self._rng(cid, chunk, level, attempt, salt=4).uniform(0.25, 0.9))

    # -- per-entry (storage) ------------------------------------------------

    def missing(self, cid: str, chunk: int, level: int) -> bool:
        """True if this entry is persistently gone from the store."""
        if self.missing_p <= 0:
            return False
        return float(self._rng(cid, chunk, level, 0, salt=1).random()) < self.missing_p

    def corrupt_at_rest(self, cid: str, chunk: int, level: int) -> bool:
        """True if this entry's bytes are persistently rotten."""
        if self.store_corrupt_p <= 0:
            return False
        return (
            float(self._rng(cid, chunk, level, 0, salt=2).random())
            < self.store_corrupt_p
        )

    # -- byte corruption ----------------------------------------------------

    def corrupt_bytes(
        self, blob: bytes, cid: str, chunk: int, level: int, attempt: int = 0
    ) -> bytes:
        """XOR-flip a few keyed positions (distinct, so flips can't cancel)."""
        if not blob:
            return blob
        rng = self._rng(cid, chunk, level, attempt, salt=3)
        out = bytearray(blob)
        positions = rng.choice(len(out), size=min(4, len(out)), replace=False)
        for pos in positions:
            out[int(pos)] ^= 0xFF
        return bytes(out)


# ---------------------------------------------------------------------------
# FaultyBackend: persistent storage faults
# ---------------------------------------------------------------------------


class FaultyBackend:
    """Wrap a :class:`StorageBackend`, injecting persistent read faults.

    Writes pass through untouched; a read of a plan-``missing`` entry raises
    the same descriptive ``KeyError`` a real deletion would, a read of a
    plan-rotten entry returns flipped bytes (the checksum gate upstream
    turns that into an ``IntegrityError``).  ``n_missing_reads`` /
    ``n_corrupt_reads`` count every faulted read for reconciliation.
    """

    def __init__(self, inner: StorageBackend, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.n_missing_reads = 0
        self.n_corrupt_reads = 0
        self._lock = threading.Lock()

    def put(self, context_id: str, chunk_idx: int, level: int, blob: bytes) -> None:
        self.inner.put(context_id, chunk_idx, level, blob)

    def get(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        if self.plan.missing(context_id, chunk_idx, level):
            with self._lock:
                self.n_missing_reads += 1
            raise _missing(context_id, chunk_idx, level, "entry deleted by fault plan")
        blob = self.inner.get(context_id, chunk_idx, level)
        if self.plan.corrupt_at_rest(context_id, chunk_idx, level):
            with self._lock:
                self.n_corrupt_reads += 1
            return self.plan.corrupt_bytes(blob, context_id, chunk_idx, level)
        return blob

    def contains(self, context_id: str, chunk_idx: int, level: int) -> bool:
        if self.plan.missing(context_id, chunk_idx, level):
            return False
        return self.inner.contains(context_id, chunk_idx, level)

    def delete(self, context_id: str, chunk_idx: int, level: int) -> bool:
        return self.inner.delete(context_id, chunk_idx, level)


def with_faulty_backend(store: KVStore, plan: FaultPlan) -> KVStore:
    """A read view of ``store`` whose backend injects ``plan``'s storage
    faults.  Chunk metadata (and therefore fetch pricing) is shared with the
    clean store — faults corrupt bytes, not the catalog.

    Tiered stores (``TieredKVStore``) get their *cold* tier wrapped: the
    plan models durable-storage rot, and the in-process hot tier masks it —
    a fault only reaches a reader whose entry is not (or no longer) hot,
    which is exactly the eviction x faults surface.  The view shares the
    clean store's index state (metadata, refcounts, LRU), so reads/evictions
    through either object see one store; use the view's ``cold`` attribute
    (the :class:`FaultyBackend`) for injection counters.  Note the plan's
    keys are *hash* strings here, not context ids — draws stay deterministic
    per (hash, level), independent of which context reads the blob."""
    from repro.streaming.storage import TieredKVStore

    if isinstance(store, TieredKVStore):
        import copy

        out = copy.copy(store)  # shares _meta/_refcount/_hash_levels/_hot_lru
        out.cold = out.backend = FaultyBackend(store.cold, plan)
        return out
    out = KVStore(store.tables, backend=FaultyBackend(store.backend, plan))
    out._meta = store._meta
    return out


# ---------------------------------------------------------------------------
# FaultyTransport: transient in-flight faults
# ---------------------------------------------------------------------------


class _TransformedHandle(FetchHandle):
    """Proxy a wrapped transport's handle, applying ``transform`` to the
    successful result (stall re-timing, payload corruption).  Errors pass
    through untouched; cancelling the proxy cancels the inner fetch.
    ``extra_wall_s`` delays delivery by real seconds (realtime transports),
    so an injected stall actually out-waits a wall timeout."""

    def __init__(
        self,
        inner: FetchHandle,
        transform,
        *,
        context_id=None,
        chunk_levels=None,
        extra_wall_s: float = 0.0,
        salvage_shift_t: float = 0.0,
        salvageable: bool = True,
    ):
        super().__init__(context_id, chunk_levels)
        self._inner = inner
        self._transform = transform
        self._extra_wall_s = extra_wall_s
        self._salvage_shift_t = salvage_shift_t
        self._salvageable = salvageable
        inner.add_done_callback(self._on_inner_done)

    def salvage_at(self, at_t=None):
        # a stall shifts when bytes land on the virtual clock; a corrupt
        # fault poisons the wire, so its partial bytes are not salvage
        if not self._salvageable:
            return None
        if at_t is not None:
            at_t = at_t - self._salvage_shift_t
        return self._inner.salvage_at(at_t)

    def _abort(self) -> None:
        self._inner.cancel()  # its cancellation error propagates via callback

    def _on_inner_done(self, inner: FetchHandle) -> None:
        def deliver():
            try:
                res = inner.result(timeout=0)
            except BaseException as e:
                self._finish(None, e)
                return
            try:
                self._finish(self._transform(res), None)
            except BaseException as e:  # transform bug — never hang the waiter
                self._finish(None, e)

        if self._extra_wall_s > 0:
            threading.Timer(self._extra_wall_s, deliver).start()
        else:
            deliver()


class FaultyTransport:
    """Wrap any :class:`Transport`, injecting ``plan``'s transient faults.

    Per fetched ``(context, chunk, level)`` key an attempt counter advances
    on every ``fetch_run`` — independent of scheduling order across
    sessions — and keys the plan's draw, so a retry of a dropped fetch
    re-draws at the next attempt index and can succeed.  ``n_injected``
    counts faults by kind for reconciliation against session counters.

    Injected faults apply to the fetch as a whole (a hedged fetch's two
    attempts share the injected fate — the plan models the *request*
    failing, not one socket).
    """

    def __init__(self, inner: Transport, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.n_injected: Dict[str, int] = {
            "drop": 0, "stall": 0, "corrupt": 0, "truncate": 0,
        }
        self._counts: Dict[Tuple[str, int, int], int] = {}
        self._lock = threading.Lock()

    @property
    def realtime(self) -> bool:
        return bool(getattr(self.inner, "realtime", False))

    @property
    def supports_range(self) -> bool:
        return bool(getattr(self.inner, "supports_range", False))

    def _next_attempt(self, cid: str, ci: int, lvl: int) -> int:
        with self._lock:
            n = self._counts.get((cid, ci, lvl), 0)
            self._counts[(cid, ci, lvl)] = n + 1
            return n

    def _count(self, kind: str) -> None:
        with self._lock:
            self.n_injected[kind] += 1

    def fetch_run(
        self,
        context_id: str,
        chunk_levels: ChunkLevels,
        *,
        start_t: float = 0.0,
        hedge_after_s: Optional[float] = None,
        byte_range: Optional[Tuple[int, Optional[int]]] = None,
        resumable: bool = False,
    ) -> FetchHandle:
        chunk_levels = list(chunk_levels)
        kw = dict(start_t=start_t, hedge_after_s=hedge_after_s)
        if byte_range is not None or resumable:
            # only forwarded when requested, so wrapping a pre-range
            # transport stays signature-compatible until a caller opts in
            kw.update(byte_range=byte_range, resumable=resumable)
        if not chunk_levels:
            return self.inner.fetch_run(context_id, chunk_levels, **kw)
        ci, lvl = chunk_levels[0]
        attempt = self._next_attempt(context_id, ci, lvl)
        fault = self.plan.draw(context_id, ci, lvl, attempt)

        if fault is not None and fault.kind == "drop":
            self._count("drop")
            handle = FetchHandle(context_id, chunk_levels)
            err = FetchError(
                f"fetch dropped by fault plan (attempt {attempt})",
                context_id=context_id,
                chunk_levels=chunk_levels,
                fail_t=start_t + fault.delay_s,
            )
            if self.realtime and fault.delay_s > 0:
                threading.Timer(
                    min(fault.delay_s, self.plan.wall_cap_s),
                    lambda: handle._finish(None, err),
                ).start()
            else:
                handle._finish(None, err)
            return handle

        inner = self.inner.fetch_run(context_id, chunk_levels, **kw)
        if fault is None:
            return inner

        if fault.kind == "stall":
            self._count("stall")
            delay = fault.delay_s

            def retime(res: FetchResult) -> FetchResult:
                end_t = res.end_t + delay
                dur = max(end_t - res.start_t, 1e-9)
                return dataclasses.replace(
                    res,
                    end_t=end_t,
                    throughput_gbps=res.nbytes * 8.0 / dur / 1e9,
                    wall_s=res.wall_s + delay,
                )

            return _TransformedHandle(
                inner, retime,
                context_id=context_id, chunk_levels=chunk_levels,
                extra_wall_s=(
                    min(delay, self.plan.wall_cap_s) if self.realtime else 0.0
                ),
                salvage_shift_t=delay,
            )

        if fault.kind == "truncate":
            # deliver a valid payload prefix, then sever: the completed
            # inner result becomes a FetchError *carrying* the prefix as
            # salvage — resumable callers keep it, legacy callers see the
            # same io failure a real mid-stream sever produces
            self._count("truncate")
            frac = self.plan.truncate_fraction(context_id, ci, lvl, attempt)

            def truncate(res: FetchResult) -> FetchResult:
                payload = res.blobs[0]
                k = max(1, int(len(payload) * frac))
                fail_t = res.start_t + frac * max(res.end_t - res.start_t, 0.0)
                raise FetchError(
                    f"stream truncated by fault plan at {k}/{len(payload)} "
                    f"bytes (attempt {attempt})",
                    context_id=context_id,
                    chunk_levels=chunk_levels,
                    fail_t=fail_t,
                    salvage=Salvage(
                        data=payload[:k],
                        offset=res.range_offset,
                        total=res.range_total or len(payload),
                        index=res.seg_index,
                        nbytes_wire=float(k),
                    ),
                )

            return _TransformedHandle(
                inner, truncate,
                context_id=context_id, chunk_levels=chunk_levels,
                salvageable=False,  # the truncate error itself carries it
            )

        # corrupt: flip payload bytes after the (clean) transfer completes
        self._count("corrupt")

        def corrupt(res: FetchResult) -> FetchResult:
            blobs = [
                self.plan.corrupt_bytes(b, context_id, c, l, attempt)
                for b, (c, l) in zip(res.blobs, chunk_levels)
            ]
            return dataclasses.replace(res, blobs=blobs)

        return _TransformedHandle(
            inner, corrupt,
            context_id=context_id, chunk_levels=chunk_levels,
            salvageable=False,  # poisoned wire: partial bytes untrustworthy
        )

    def close(self) -> None:
        self.inner.close()
