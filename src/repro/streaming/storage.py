"""KV bitstream store: chunk_id -> {level -> encoded bytes} (paper §6).

Storage split (ISSUE 4): :class:`KVStore` is a thin write/metadata frontend
over a :class:`StorageBackend` — the byte-addressed ``(context, chunk,
level) -> blob`` map.  Two backends ship: :class:`MemoryBackend` (dict) and
:class:`DirectoryBackend` (one file per chunk-level); both raise a
descriptive ``KeyError`` naming the missing (context, chunk, level).  The
*read path over a link* lives one layer up, in ``streaming/transport.py``:
a ``Transport`` fronts a store (directly, trace-paced, or over a socket)
and returns cancellable fetch handles — backends and transports compose
(any transport over any backend).

``store_kv`` splits a context's KV along the token axis into chunks
(default 1.5K tokens, paper §5.3), pre-encodes every chunk at every level
via the codec, and records per-(chunk, level) sizes; ``get_kv`` returns the
bitstream for a (chunk, level).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core import codec as kvcodec

__all__ = [
    "ChunkMeta",
    "DirectoryBackend",
    "KVStore",
    "MemoryBackend",
    "StorageBackend",
    "split_chunks",
    "DEFAULT_CHUNK_TOKENS",
]

DEFAULT_CHUNK_TOKENS = 1536  # paper: ~1.5K tokens


def split_chunks(n_tokens: int, chunk_tokens: int) -> List[Tuple[int, int]]:
    """[(start, end)) chunk boundaries."""
    out = []
    s = 0
    while s < n_tokens:
        out.append((s, min(s + chunk_tokens, n_tokens)))
        s += chunk_tokens
    return out


@dataclasses.dataclass
class ChunkMeta:
    context_id: str
    chunk_idx: int
    start: int
    end: int
    sizes: Dict[int, int]  # level -> encoded bytes
    text_bytes: int  # raw text fallback size (~4 B/token)

    @property
    def n_tokens(self) -> int:
        return self.end - self.start


def _missing(cid: str, ci: int, lvl: int, detail: str = "") -> KeyError:
    extra = f" ({detail})" if detail else ""
    return KeyError(
        f"no stored bitstream for context {cid!r} chunk {ci} level {lvl}{extra}"
    )


@runtime_checkable
class StorageBackend(Protocol):
    """Byte-addressed KV-bitstream map: ``(context, chunk, level) -> blob``.

    ``get`` must raise a ``KeyError`` whose message names the missing
    context/chunk/level (not a bare tuple or an opaque file path).
    """

    def put(self, context_id: str, chunk_idx: int, level: int, blob: bytes) -> None:
        ...

    def get(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        ...

    def contains(self, context_id: str, chunk_idx: int, level: int) -> bool:
        ...

    def delete(self, context_id: str, chunk_idx: int, level: int) -> bool:
        """Remove one entry; True if it existed (no error when absent)."""
        ...


class MemoryBackend:
    """In-process dict backend — the default."""

    def __init__(self):
        self._mem: Dict[Tuple[str, int, int], bytes] = {}

    def put(self, context_id: str, chunk_idx: int, level: int, blob: bytes) -> None:
        self._mem[(context_id, chunk_idx, level)] = blob

    def get(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        try:
            return self._mem[(context_id, chunk_idx, level)]
        except KeyError:
            raise _missing(context_id, chunk_idx, level, "memory backend") from None

    def contains(self, context_id: str, chunk_idx: int, level: int) -> bool:
        return (context_id, chunk_idx, level) in self._mem

    def delete(self, context_id: str, chunk_idx: int, level: int) -> bool:
        return self._mem.pop((context_id, chunk_idx, level), None) is not None


class DirectoryBackend:
    """One file per (context, chunk, level) under ``directory``."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, cid: str, ci: int, lvl: int) -> str:
        return os.path.join(self.directory, f"{cid}.c{ci:04d}.l{lvl}.kvbs")

    def put(self, context_id: str, chunk_idx: int, level: int, blob: bytes) -> None:
        with open(self._path(context_id, chunk_idx, level), "wb") as f:
            f.write(blob)

    def get(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        path = self._path(context_id, chunk_idx, level)
        try:
            with open(path, "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise _missing(
                context_id, chunk_idx, level, f"no file {path}"
            ) from None

    def contains(self, context_id: str, chunk_idx: int, level: int) -> bool:
        return os.path.exists(self._path(context_id, chunk_idx, level))

    def delete(self, context_id: str, chunk_idx: int, level: int) -> bool:
        try:
            os.remove(self._path(context_id, chunk_idx, level))
            return True
        except FileNotFoundError:
            return False


class KVStore:
    """Write/metadata frontend for encoded KV bitstreams over a backend.

    The frontend owns the codec tables, the chunk split, the pre-encoding of
    every level, and the per-context :class:`ChunkMeta` index; all blob I/O
    goes through ``self.backend`` (a :class:`StorageBackend`).
    ``directory=`` is kept as a convenience spelling of
    ``backend=DirectoryBackend(directory)``.
    """

    def __init__(
        self,
        tables: kvcodec.CodecTables,
        directory: Optional[str] = None,
        *,
        backend: Optional[StorageBackend] = None,
    ):
        # one-time upgrade: hand-built / unpickled tables may lack the
        # pre-stacked sets the batched coder calls need on the hot path
        self.tables = kvcodec.ensure_stacks(tables)
        if backend is not None and directory is not None:
            raise ValueError("pass either directory or backend, not both")
        if backend is None:
            backend = DirectoryBackend(directory) if directory else MemoryBackend()
        self.backend = backend
        self._meta: Dict[str, List[ChunkMeta]] = {}

    # -- write path (offline) ------------------------------------------------

    def store_kv(
        self,
        context_id: str,
        kv: np.ndarray,  # (L, 2, T, C)
        *,
        chunk_tokens: int = DEFAULT_CHUNK_TOKENS,
        levels: Optional[List[int]] = None,
        bytes_per_token_text: int = 4,
    ) -> List[ChunkMeta]:
        all_levels = list(range(self.tables.config.n_levels))
        levels = all_levels if levels is None else levels
        batch_all = levels == all_levels
        T = kv.shape[2]
        metas = []
        for ci, (s, e) in enumerate(split_chunks(T, chunk_tokens)):
            if batch_all:
                # batched: anchors symbolized/coded once, delta levels in one
                # stacked rANS call (byte-identical to per-level encoding)
                blobs = kvcodec.encode_all_levels(kv[:, :, s:e], self.tables, ci)
            else:
                blobs = {
                    lvl: kvcodec.encode_chunk(kv[:, :, s:e], self.tables, lvl, ci)
                    for lvl in levels
                }
            sizes = {}
            for lvl in levels:
                blob = blobs[lvl]
                self._put(context_id, ci, lvl, blob)
                sizes[lvl] = len(blob)
            metas.append(
                ChunkMeta(
                    context_id=context_id,
                    chunk_idx=ci,
                    start=s,
                    end=e,
                    sizes=sizes,
                    text_bytes=(e - s) * bytes_per_token_text,
                )
            )
        self._meta[context_id] = metas
        return metas

    def _put(self, cid: str, ci: int, lvl: int, blob: bytes) -> None:
        self.backend.put(cid, ci, lvl, blob)

    # -- read path (online) --------------------------------------------------

    def get_kv(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        """Blob for one (chunk, level); raises a descriptive ``KeyError``
        naming context/chunk/level when missing (either backend), and a
        ``bitstream.IntegrityError`` naming the same when the blob's
        checksum trailer does not match — corruption at rest is caught at
        the store boundary, before any bytes cross a link."""
        blob = self.backend.get(context_id, chunk_idx, level)
        try:
            kvcodec.verify_chunk(blob)
        except ValueError as e:  # IntegrityError is a ValueError
            raise type(e)(
                f"stored bitstream for context {context_id!r} chunk "
                f"{chunk_idx} level {level} failed integrity check: {e}"
            ) from e
        return blob

    def delete_kv(self, context_id: str, chunk_idx: int, level: int) -> bool:
        """Remove one (chunk, level) blob; True if it existed.  Metadata is
        left intact — a reader then sees the descriptive ``KeyError`` of a
        missing entry, which is exactly the fault the retry machinery
        classifies as permanent-at-level."""
        return self.backend.delete(context_id, chunk_idx, level)

    def get_run(
        self, context_id: str, chunk_levels: List[Tuple[int, int]]
    ) -> List[bytes]:
        """Fetch the bitstreams of one decode run: [(chunk_idx, level), ...]."""
        return [self.get_kv(context_id, ci, lvl) for ci, lvl in chunk_levels]

    def meta(self, context_id: str) -> List[ChunkMeta]:
        try:
            return self._meta[context_id]
        except KeyError:
            raise KeyError(
                f"no chunk metadata for context {context_id!r} "
                f"(known: {sorted(self._meta)})"
            ) from None

    def decode(self, blob: bytes) -> np.ndarray:
        return np.asarray(kvcodec.decode_chunk(blob, self.tables))

    def total_bytes(self, context_id: str, level: int) -> int:
        return sum(m.sizes[level] for m in self.meta(context_id))

    def storage_bytes(self, context_id: str) -> int:
        """Total storage across all pre-encoded levels (paper Fig. 15d)."""
        return sum(sum(m.sizes.values()) for m in self.meta(context_id))
