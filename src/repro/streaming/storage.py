"""KV bitstream store: chunk_id -> {level -> encoded bytes} (paper §6).

``store_kv`` splits a context's KV along the token axis into chunks
(default 1.5K tokens, paper §5.3), pre-encodes every chunk at every level
via the codec, and records per-(chunk, level) sizes; ``get_kv`` returns the
bitstream for a (chunk, level).  Backends: in-memory dict or a directory of
files (one per chunk-level, msgpack-framed), both with identical interfaces.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import codec as kvcodec

__all__ = ["ChunkMeta", "KVStore", "split_chunks", "DEFAULT_CHUNK_TOKENS"]

DEFAULT_CHUNK_TOKENS = 1536  # paper: ~1.5K tokens


def split_chunks(n_tokens: int, chunk_tokens: int) -> List[Tuple[int, int]]:
    """[(start, end)) chunk boundaries."""
    out = []
    s = 0
    while s < n_tokens:
        out.append((s, min(s + chunk_tokens, n_tokens)))
        s += chunk_tokens
    return out


@dataclasses.dataclass
class ChunkMeta:
    context_id: str
    chunk_idx: int
    start: int
    end: int
    sizes: Dict[int, int]  # level -> encoded bytes
    text_bytes: int  # raw text fallback size (~4 B/token)

    @property
    def n_tokens(self) -> int:
        return self.end - self.start


class KVStore:
    """Storage server for encoded KV bitstreams."""

    def __init__(self, tables: kvcodec.CodecTables, directory: Optional[str] = None):
        # one-time upgrade: hand-built / unpickled tables may lack the
        # pre-stacked sets the batched coder calls need on the hot path
        self.tables = kvcodec.ensure_stacks(tables)
        self.dir = directory
        self._mem: Dict[Tuple[str, int, int], bytes] = {}
        self._meta: Dict[str, List[ChunkMeta]] = {}
        if directory:
            os.makedirs(directory, exist_ok=True)

    # -- write path (offline) ------------------------------------------------

    def store_kv(
        self,
        context_id: str,
        kv: np.ndarray,  # (L, 2, T, C)
        *,
        chunk_tokens: int = DEFAULT_CHUNK_TOKENS,
        levels: Optional[List[int]] = None,
        bytes_per_token_text: int = 4,
    ) -> List[ChunkMeta]:
        all_levels = list(range(self.tables.config.n_levels))
        levels = all_levels if levels is None else levels
        batch_all = levels == all_levels
        T = kv.shape[2]
        metas = []
        for ci, (s, e) in enumerate(split_chunks(T, chunk_tokens)):
            if batch_all:
                # batched: anchors symbolized/coded once, delta levels in one
                # stacked rANS call (byte-identical to per-level encoding)
                blobs = kvcodec.encode_all_levels(kv[:, :, s:e], self.tables, ci)
            else:
                blobs = {
                    lvl: kvcodec.encode_chunk(kv[:, :, s:e], self.tables, lvl, ci)
                    for lvl in levels
                }
            sizes = {}
            for lvl in levels:
                blob = blobs[lvl]
                self._put(context_id, ci, lvl, blob)
                sizes[lvl] = len(blob)
            metas.append(
                ChunkMeta(
                    context_id=context_id,
                    chunk_idx=ci,
                    start=s,
                    end=e,
                    sizes=sizes,
                    text_bytes=(e - s) * bytes_per_token_text,
                )
            )
        self._meta[context_id] = metas
        return metas

    def _put(self, cid: str, ci: int, lvl: int, blob: bytes) -> None:
        if self.dir:
            with open(self._path(cid, ci, lvl), "wb") as f:
                f.write(blob)
        else:
            self._mem[(cid, ci, lvl)] = blob

    def _path(self, cid: str, ci: int, lvl: int) -> str:
        return os.path.join(self.dir, f"{cid}.c{ci:04d}.l{lvl}.kvbs")

    # -- read path (online) --------------------------------------------------

    def get_kv(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        if self.dir:
            with open(self._path(context_id, chunk_idx, level), "rb") as f:
                return f.read()
        return self._mem[(context_id, chunk_idx, level)]

    def get_run(
        self, context_id: str, chunk_levels: List[Tuple[int, int]]
    ) -> List[bytes]:
        """Fetch the bitstreams of one decode run: [(chunk_idx, level), ...]."""
        return [self.get_kv(context_id, ci, lvl) for ci, lvl in chunk_levels]

    def meta(self, context_id: str) -> List[ChunkMeta]:
        return self._meta[context_id]

    def decode(self, blob: bytes) -> np.ndarray:
        return np.asarray(kvcodec.decode_chunk(blob, self.tables))

    def total_bytes(self, context_id: str, level: int) -> int:
        return sum(m.sizes[level] for m in self.meta(context_id))

    def storage_bytes(self, context_id: str) -> int:
        """Total storage across all pre-encoded levels (paper Fig. 15d)."""
        return sum(sum(m.sizes.values()) for m in self.meta(context_id))
