"""KV bitstream store: content-addressed chunks under a tiered read path.

Layout (ISSUE 7).  Chunks are keyed by a **versioned chain hash** over the
token prefix — the vLLM prefix-caching idiom — so identical document
prefixes across contexts dedup to the same blobs:

    root  = sha256(b"cachegen-" + VERSION + b"\\0" + namespace)
    h_i   = sha256(h_{i-1} || payload_i)          (raw 32-byte digests)
    key_i = VERSION + "-" + hex(h_i)[:40]

where ``payload_i`` is the chunk's token ids as little-endian ``uint32``
bytes when the caller passes ``tokens=`` to ``store_kv`` (the canonical
spelling), else the chunk's raw KV bytes (dtype-tagged).  Because ``h_i``
covers the *entire* prefix, equal keys imply equal token prefixes at equal
positions — so the codec header's baked-in ``chunk_idx`` always matches and
dedup stays bit-correct.  ``namespace`` defaults to the codec-table config,
so stores with different codecs never alias; one store instance serves one
model (KV bytes are model-dependent — hash over tokens assumes the store's
single engine).  The ``VERSION`` prefix ("kvh1") makes any future layout
change detectable at the key level.  Per-context :class:`ChunkMeta` records
the hash reference (``chunk_hash``); per-hash refcounts track how many
contexts share each blob.

Tiers.  :class:`TieredKVStore` runs a capacity-bounded **hot tier** (a
:class:`MemoryBackend`) over a durable **cold tier** (any
:class:`StorageBackend`).  Writes are write-back: new blobs land hot when
they fit, spill cold otherwise.  Eviction is per ``(hash, level)`` LRU and
*level-aware*: victims are chosen lowest-priority-first (priority = the
realized-level pick fraction measured in ``BENCH_session.json``, via
``calibration.measured_level_priorities`` — levels Algorithm 1 never picks
leave the hot tier first), oldest within a priority.  Demotion **writes
through to cold** before the hot copy is dropped whenever any context still
references the hash — eviction never destroys the last replica.  Reads try
hot (hit), then cold (hit + promote), and raise the usual descriptive
``KeyError`` when a blob is gone from both tiers; ``tier_penalty`` prices a
run's cold entries in virtual seconds so ``SimTransport`` can report the
slower fetch to the session's throughput estimator.

The flat :class:`KVStore` (every level of every chunk of every context,
forever, context-keyed) is kept intact as the differential oracle: a
``TieredKVStore`` with never-evict capacity is bit-identical to it through
a full serving session (tests/test_store.py holds it there).

``store_kv`` splits a context's KV along the token axis into chunks
(default 1.5K tokens, paper §5.3), pre-encodes every chunk at every level
via the codec, and records per-(chunk, level) sizes; ``get_kv`` returns the
bitstream for a (chunk, level).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core import codec as kvcodec

__all__ = [
    "ChunkMeta",
    "DirectoryBackend",
    "HASH_CHAIN_VERSION",
    "KVStore",
    "MemoryBackend",
    "StorageBackend",
    "TieredKVStore",
    "chain_hashes",
    "split_chunks",
    "token_payloads",
    "DEFAULT_CHUNK_TOKENS",
]

DEFAULT_CHUNK_TOKENS = 1536  # paper: ~1.5K tokens

#: Version tag baked into the chain root *and* every key string — bump it
#: and every old key becomes unreachable-by-construction instead of
#: silently misread under a new layout.
HASH_CHAIN_VERSION = "kvh1"


def split_chunks(n_tokens: int, chunk_tokens: int) -> List[Tuple[int, int]]:
    """[(start, end)) chunk boundaries."""
    out = []
    s = 0
    while s < n_tokens:
        out.append((s, min(s + chunk_tokens, n_tokens)))
        s += chunk_tokens
    return out


def chain_hashes(payloads: Iterable[bytes], namespace: str = "") -> List[str]:
    """Chain-hash keys ``[key_1, ..., key_n]`` for a sequence of chunk
    payloads (see module docstring for the exact construction)."""
    h = hashlib.sha256(
        b"cachegen-" + HASH_CHAIN_VERSION.encode() + b"\0" + namespace.encode()
    ).digest()
    keys = []
    for p in payloads:
        h = hashlib.sha256(h + p).digest()
        keys.append(f"{HASH_CHAIN_VERSION}-{h.hex()[:40]}")
    return keys


def token_payloads(
    tokens: Sequence[int], bounds: Sequence[Tuple[int, int]]
) -> List[bytes]:
    """Canonical chain payloads: each chunk's token ids as LE uint32."""
    arr = np.asarray(tokens, dtype=np.uint32)
    return [arr[s:e].astype("<u4").tobytes() for s, e in bounds]


@dataclasses.dataclass
class ChunkMeta:
    context_id: str
    chunk_idx: int
    start: int
    end: int
    sizes: Dict[int, int]  # level -> encoded bytes
    text_bytes: int  # raw text fallback size (~4 B/token)
    chunk_hash: Optional[str] = None  # chain-hash key (tiered store)

    @property
    def n_tokens(self) -> int:
        return self.end - self.start


def _missing(cid: str, ci: int, lvl: int, detail: str = "") -> KeyError:
    extra = f" ({detail})" if detail else ""
    return KeyError(
        f"no stored bitstream for context {cid!r} chunk {ci} level {lvl}{extra}"
    )


@runtime_checkable
class StorageBackend(Protocol):
    """Byte-addressed KV-bitstream map: ``(context, chunk, level) -> blob``.

    ``get`` must raise a ``KeyError`` whose message names the missing
    context/chunk/level (not a bare tuple or an opaque file path).  The
    tiered store reuses the same triple interface for content-addressed
    blobs, keyed ``(hash, 0, level)`` — any backend works as either tier.
    """

    def put(self, context_id: str, chunk_idx: int, level: int, blob: bytes) -> None:
        ...

    def get(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        ...

    def contains(self, context_id: str, chunk_idx: int, level: int) -> bool:
        ...

    def delete(self, context_id: str, chunk_idx: int, level: int) -> bool:
        """Remove one entry; True if it existed (no error when absent)."""
        ...


class MemoryBackend:
    """In-process dict backend — the default."""

    def __init__(self):
        self._mem: Dict[Tuple[str, int, int], bytes] = {}

    def put(self, context_id: str, chunk_idx: int, level: int, blob: bytes) -> None:
        self._mem[(context_id, chunk_idx, level)] = blob

    def get(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        try:
            return self._mem[(context_id, chunk_idx, level)]
        except KeyError:
            raise _missing(context_id, chunk_idx, level, "memory backend") from None

    def contains(self, context_id: str, chunk_idx: int, level: int) -> bool:
        return (context_id, chunk_idx, level) in self._mem

    def delete(self, context_id: str, chunk_idx: int, level: int) -> bool:
        return self._mem.pop((context_id, chunk_idx, level), None) is not None


class DirectoryBackend:
    """One file per (context, chunk, level) under ``directory``.

    ``put`` is atomic: bytes land in a same-directory temp file first and
    are published with ``os.replace``, so a writer killed mid-write leaves
    the previous blob (or a clean absence) — never a truncated file that
    only surfaces later as a read-time ``IntegrityError``.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, cid: str, ci: int, lvl: int) -> str:
        return os.path.join(self.directory, f"{cid}.c{ci:04d}.l{lvl}.kvbs")

    def put(self, context_id: str, chunk_idx: int, level: int, blob: bytes) -> None:
        path = self._path(context_id, chunk_idx, level)
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        try:
            with open(tmp, "wb") as f:
                f.write(blob)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    def get(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        path = self._path(context_id, chunk_idx, level)
        try:
            with open(path, "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise _missing(
                context_id, chunk_idx, level, f"no file {path}"
            ) from None

    def contains(self, context_id: str, chunk_idx: int, level: int) -> bool:
        return os.path.exists(self._path(context_id, chunk_idx, level))

    def delete(self, context_id: str, chunk_idx: int, level: int) -> bool:
        try:
            os.remove(self._path(context_id, chunk_idx, level))
            return True
        except FileNotFoundError:
            return False


class KVStore:
    """Write/metadata frontend for encoded KV bitstreams over a backend.

    The *flat* store: context-keyed, no sharing, no eviction — kept as the
    differential oracle for :class:`TieredKVStore`.  The frontend owns the
    codec tables, the chunk split, the pre-encoding of every level, and the
    per-context :class:`ChunkMeta` index; all blob I/O goes through
    ``self.backend`` (a :class:`StorageBackend`).  ``directory=`` is kept
    as a convenience spelling of ``backend=DirectoryBackend(directory)``.
    """

    def __init__(
        self,
        tables: kvcodec.CodecTables,
        directory: Optional[str] = None,
        *,
        backend: Optional[StorageBackend] = None,
    ):
        # one-time upgrade: hand-built / unpickled tables may lack the
        # pre-stacked sets the batched coder calls need on the hot path
        self.tables = kvcodec.ensure_stacks(tables)
        if backend is not None and directory is not None:
            raise ValueError("pass either directory or backend, not both")
        if backend is None:
            backend = DirectoryBackend(directory) if directory else MemoryBackend()
        self.backend = backend
        self._meta: Dict[str, List[ChunkMeta]] = {}

    # -- write path (offline) ------------------------------------------------

    def store_kv(
        self,
        context_id: str,
        kv: np.ndarray,  # (L, 2, T, C)
        *,
        chunk_tokens: int = DEFAULT_CHUNK_TOKENS,
        levels: Optional[List[int]] = None,
        bytes_per_token_text: int = 4,
        tokens: Optional[Sequence[int]] = None,  # accepted for API parity
    ) -> List[ChunkMeta]:
        all_levels = list(range(self.tables.config.n_levels))
        levels = all_levels if levels is None else levels
        batch_all = levels == all_levels
        T = kv.shape[2]
        metas = []
        for ci, (s, e) in enumerate(split_chunks(T, chunk_tokens)):
            if batch_all:
                # batched: anchors symbolized/coded once, delta levels in one
                # stacked rANS call (byte-identical to per-level encoding)
                blobs = kvcodec.encode_all_levels(kv[:, :, s:e], self.tables, ci)
            else:
                blobs = {
                    lvl: kvcodec.encode_chunk(kv[:, :, s:e], self.tables, lvl, ci)
                    for lvl in levels
                }
            sizes = {}
            for lvl in levels:
                blob = blobs[lvl]
                self._put(context_id, ci, lvl, blob)
                sizes[lvl] = len(blob)
            metas.append(
                ChunkMeta(
                    context_id=context_id,
                    chunk_idx=ci,
                    start=s,
                    end=e,
                    sizes=sizes,
                    text_bytes=(e - s) * bytes_per_token_text,
                )
            )
        self._meta[context_id] = metas
        return metas

    def _put(self, cid: str, ci: int, lvl: int, blob: bytes) -> None:
        self.backend.put(cid, ci, lvl, blob)

    # -- read path (online) --------------------------------------------------

    def get_kv(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        """Blob for one (chunk, level); raises a descriptive ``KeyError``
        naming context/chunk/level when missing (either backend), and a
        ``bitstream.IntegrityError`` naming the same when the blob's
        checksum trailer does not match — corruption at rest is caught at
        the store boundary, before any bytes cross a link."""
        blob = self.backend.get(context_id, chunk_idx, level)
        try:
            kvcodec.verify_chunk(blob)
        except ValueError as e:  # IntegrityError is a ValueError
            raise type(e)(
                f"stored bitstream for context {context_id!r} chunk "
                f"{chunk_idx} level {level} failed integrity check: {e}"
            ) from e
        return blob

    def delete_kv(self, context_id: str, chunk_idx: int, level: int) -> bool:
        """Remove one (chunk, level) blob; True if it existed.  Metadata is
        left intact — a reader then sees the descriptive ``KeyError`` of a
        missing entry, which is exactly the fault the retry machinery
        classifies as permanent-at-level."""
        return self.backend.delete(context_id, chunk_idx, level)

    def get_run(
        self, context_id: str, chunk_levels: List[Tuple[int, int]]
    ) -> List[bytes]:
        """Fetch the bitstreams of one decode run: [(chunk_idx, level), ...]."""
        return [self.get_kv(context_id, ci, lvl) for ci, lvl in chunk_levels]

    def meta(self, context_id: str) -> List[ChunkMeta]:
        try:
            return self._meta[context_id]
        except KeyError:
            raise KeyError(
                f"no chunk metadata for context {context_id!r} "
                f"(known: {sorted(self._meta)})"
            ) from None

    def decode(self, blob: bytes) -> np.ndarray:
        return np.asarray(kvcodec.decode_chunk(blob, self.tables))

    def total_bytes(self, context_id: str, level: int) -> int:
        return sum(m.sizes[level] for m in self.meta(context_id))

    def storage_bytes(self, context_id: str) -> int:
        """Total storage across all pre-encoded levels (paper Fig. 15d)."""
        return sum(sum(m.sizes.values()) for m in self.meta(context_id))


# ---------------------------------------------------------------------------
# TieredKVStore: content-addressed blobs, hot tier over cold
# ---------------------------------------------------------------------------


class TieredKVStore(KVStore):
    """Content-addressed, prefix-sharing store with a hot tier over cold.

    Blobs live under ``(chunk_hash, 0, level)`` in two
    :class:`StorageBackend` tiers; per-context metadata is a list of hash
    references and per-hash refcounts track cross-context sharing.  See the
    module docstring for the hash-chain format and tier semantics.

    ``hot_bytes`` bounds the hot tier (0 = everything cold, ``None``/huge =
    never evict).  ``level_priorities`` maps level -> keep-priority (higher
    stays hot longer); when omitted it is seeded from the realized-level
    histograms in ``BENCH_session.json`` via
    ``calibration.measured_level_priorities`` (levels with no measurement
    get priority 0.0 and evict first).  ``cold_latency_s`` /
    ``cold_gbps`` price a cold read for :meth:`tier_penalty` — the virtual
    surcharge ``SimTransport`` folds into a fetch's modeled timing so the
    session's throughput estimator sees tier misses; wall-real transports
    (local/tcp) pay the cold tier's actual read time instead.

    ``probation`` (2Q-style read-path admission) gates promotion: a cold
    read is admitted hot only on its *second* cold touch within the last
    ``probation`` cold reads — the first touch just records a ghost entry
    (key only, no bytes).  One-shot scans then cannot flush the hot tier's
    re-read working set.  ``None`` (default) keeps the legacy
    promote-on-first-read behavior bit-identically.
    """

    def __init__(
        self,
        tables: kvcodec.CodecTables,
        *,
        hot_bytes: Optional[int] = None,
        cold: Optional[StorageBackend] = None,
        hot: Optional[StorageBackend] = None,
        level_priorities: Optional[Dict[int, float]] = None,
        cold_latency_s: float = 0.002,
        cold_gbps: float = 2.0,
        promote_on_read: bool = True,
        probation: Optional[int] = None,
        namespace: Optional[str] = None,
    ):
        if probation is not None and probation < 1:
            raise ValueError(
                f"TieredKVStore probation window must be >= 1 cold reads "
                f"(or None to disable), got {probation}"
            )
        cold = cold if cold is not None else MemoryBackend()
        super().__init__(tables, backend=cold)
        self.cold = cold  # self.backend aliases the durable tier
        self.hot = hot if hot is not None else MemoryBackend()
        self.hot_bytes = int(hot_bytes) if hot_bytes is not None else (1 << 62)
        self.cold_latency_s = float(cold_latency_s)
        self.cold_gbps = float(cold_gbps)
        self.promote_on_read = bool(promote_on_read)
        self.namespace = (
            namespace if namespace is not None else repr(self.tables.config)
        )
        if level_priorities is None:
            from repro.streaming import calibration

            level_priorities = calibration.measured_level_priorities()
        self.level_priorities = dict(level_priorities)
        # (hash, level) -> blob size; insertion order = recency (end newest)
        self._hot_lru: "OrderedDict[Tuple[str, int], int]" = OrderedDict()
        self._hot_used = 0
        self._refcount: Dict[str, int] = {}  # hash -> contexts referencing
        self._hash_levels: Dict[str, Dict[int, int]] = {}  # hash -> {lvl: size}
        self._lock = threading.RLock()
        self.n_hot_hits = 0
        self.n_cold_hits = 0
        self.n_misses = 0
        self.n_promotions = 0
        self.n_demotions = 0
        self.n_evictions = 0
        self.n_dedup_chunks = 0
        self.n_encoded_chunks = 0
        # 2Q probation ghost table: (hash, level) -> cold-read sequence of
        # the first touch; entries older than the window expire unpromoted
        self.probation = int(probation) if probation is not None else None
        self._probation: "OrderedDict[Tuple[str, int], int]" = OrderedDict()
        self._cold_read_seq = 0
        self.n_probation_adds = 0
        self.n_probation_promotes = 0
        self.n_probation_expired = 0

    # -- hashing -------------------------------------------------------------

    def chunk_hashes(
        self,
        kv: np.ndarray,
        bounds: Sequence[Tuple[int, int]],
        tokens: Optional[Sequence[int]] = None,
    ) -> List[str]:
        """Chain-hash keys for one context's chunks: over token ids when
        ``tokens`` is given (canonical), else over the raw KV bytes."""
        if tokens is not None:
            if len(tokens) != kv.shape[2]:
                raise ValueError(
                    f"tokens length {len(tokens)} != KV token axis {kv.shape[2]}"
                )
            payloads = token_payloads(tokens, bounds)
        else:
            tag = f"kvbytes:{kv.dtype.str}:".encode()
            payloads = [
                tag + np.ascontiguousarray(kv[:, :, s:e]).tobytes()
                for s, e in bounds
            ]
        return chain_hashes(payloads, namespace=self.namespace)

    def hash_for(self, context_id: str, chunk_idx: int) -> str:
        metas = self.meta(context_id)
        try:
            h = metas[chunk_idx].chunk_hash
        except IndexError:
            raise _missing(
                context_id, chunk_idx, -1,
                f"chunk index out of range (context has {len(metas)} chunks)",
            ) from None
        assert h is not None
        return h

    def try_hash(self, context_id: str, chunk_idx: int) -> Optional[str]:
        """``hash_for`` that answers None instead of raising (transports)."""
        try:
            return self.hash_for(context_id, chunk_idx)
        except KeyError:
            return None

    # -- write path ----------------------------------------------------------

    def store_kv(
        self,
        context_id: str,
        kv: np.ndarray,
        *,
        chunk_tokens: int = DEFAULT_CHUNK_TOKENS,
        levels: Optional[List[int]] = None,
        bytes_per_token_text: int = 4,
        tokens: Optional[Sequence[int]] = None,
    ) -> List[ChunkMeta]:
        all_levels = list(range(self.tables.config.n_levels))
        levels = all_levels if levels is None else levels
        batch_all = levels == all_levels
        T = kv.shape[2]
        bounds = split_chunks(T, chunk_tokens)
        hashes = self.chunk_hashes(kv, bounds, tokens)
        with self._lock:
            if context_id in self._meta:
                self._release_context(context_id)
        metas = []
        for ci, (s, e) in enumerate(bounds):
            h = hashes[ci]
            with self._lock:
                have = self._hash_levels.get(h, {})
                dedup = all(lvl in have for lvl in levels)
                if dedup:
                    sizes = {lvl: have[lvl] for lvl in levels}
                    self.n_dedup_chunks += 1
            if not dedup:
                # encoding is deterministic (PR 1: batched == per-level,
                # byte-identical), so a re-encode of a shared chunk would
                # produce the same bytes — skipping it above is pure savings
                if batch_all:
                    blobs = kvcodec.encode_all_levels(kv[:, :, s:e], self.tables, ci)
                else:
                    blobs = {
                        lvl: kvcodec.encode_chunk(kv[:, :, s:e], self.tables, lvl, ci)
                        for lvl in levels
                    }
                sizes = {}
                with self._lock:
                    slot = self._hash_levels.setdefault(h, {})
                    for lvl in levels:
                        blob = blobs[lvl]
                        sizes[lvl] = len(blob)
                        if lvl not in slot:
                            slot[lvl] = len(blob)
                            self._write_blob(h, lvl, blob)
                    self.n_encoded_chunks += 1
            with self._lock:
                self._refcount[h] = self._refcount.get(h, 0) + 1
            metas.append(
                ChunkMeta(
                    context_id=context_id,
                    chunk_idx=ci,
                    start=s,
                    end=e,
                    sizes=sizes,
                    text_bytes=(e - s) * bytes_per_token_text,
                    chunk_hash=h,
                )
            )
        self._meta[context_id] = metas
        return metas

    def _write_blob(self, h: str, lvl: int, blob: bytes) -> None:
        """Write-back admission: hot when it fits, else spill to cold."""
        if not self._admit_hot(h, lvl, blob):
            if not self.cold.contains(h, 0, lvl):
                self.cold.put(h, 0, lvl, blob)

    # -- hot-tier mechanics (call with self._lock held) ----------------------

    def _level_priority(self, lvl: int) -> float:
        return float(self.level_priorities.get(lvl, 0.0))

    def _pick_victim(self) -> Tuple[str, int]:
        """Lowest keep-priority first; oldest within a priority (the LRU
        iterates oldest -> newest, so the first minimum wins)."""
        best = None
        best_pri = None
        for key in self._hot_lru:
            pri = self._level_priority(key[1])
            if best is None or pri < best_pri:
                best, best_pri = key, pri
                if pri <= 0.0:
                    break
        assert best is not None
        return best

    def _evict_one(self) -> None:
        h, lvl = self._pick_victim()
        size = self._hot_lru.pop((h, lvl))
        self._hot_used -= size
        # a (hash, level) still in the index must stay readable — either a
        # context references it now, or its store_kv is mid-flight and will
        # reference it momentarily (the refcount lands after the writes)
        referenced = lvl in self._hash_levels.get(h, {})
        if referenced and not self.cold.contains(h, 0, lvl):
            # demotion writes through: never drop the last replica of a
            # hash some context still references
            self.cold.put(h, 0, lvl, self.hot.get(h, 0, lvl))
            self.n_demotions += 1
        self.hot.delete(h, 0, lvl)
        self.n_evictions += 1

    def _admit_hot(self, h: str, lvl: int, blob: bytes) -> bool:
        key = (h, lvl)
        size = len(blob)
        if key in self._hot_lru:
            self._hot_lru.move_to_end(key)
            return True
        if size > self.hot_bytes:
            return False
        while self._hot_used + size > self.hot_bytes and self._hot_lru:
            self._evict_one()
        if self._hot_used + size > self.hot_bytes:
            return False
        self.hot.put(h, 0, lvl, blob)
        self._hot_lru[key] = size
        self._hot_used += size
        return True

    def evict_hot(self, n: int = 1) -> int:
        """Force-evict up to ``n`` LRU victims (demoting as needed); the
        number actually evicted.  Capacity pressure does this implicitly —
        this is the explicit hammer for tests and operational drains."""
        done = 0
        with self._lock:
            while done < n and self._hot_lru:
                self._evict_one()
                done += 1
        return done

    # -- read path -----------------------------------------------------------

    def _probation_pass(self, h: str, lvl: int) -> bool:
        """2Q admission gate for one cold read (lock held by the caller).

        True when the blob may be promoted hot: probation is off, or this
        is the key's second cold touch within the last ``probation`` cold
        reads.  A first touch records a ghost entry and answers False;
        ghosts untouched for a full window expire unpromoted.
        """
        if self.probation is None:
            return True
        self._cold_read_seq += 1
        seq = self._cold_read_seq
        while self._probation:  # expire ghosts that fell out of the window
            _, first_seq = next(iter(self._probation.items()))
            if seq - first_seq <= self.probation:
                break
            self._probation.popitem(last=False)
            self.n_probation_expired += 1
        key = (h, lvl)
        if key in self._probation:
            del self._probation[key]
            self.n_probation_promotes += 1
            return True
        self._probation[key] = seq
        self.n_probation_adds += 1
        return False

    def _read_blob(self, h: str, lvl: int, cid: str, ci: int) -> bytes:
        with self._lock:
            try:
                blob = self.hot.get(h, 0, lvl)
                self.n_hot_hits += 1
                self._hot_lru.move_to_end((h, lvl), last=True)
                from_cold = False
            except KeyError:
                try:
                    blob = self.cold.get(h, 0, lvl)
                except KeyError:
                    self.n_misses += 1
                    raise _missing(
                        cid, ci, lvl, f"hash {h} absent from hot and cold tiers"
                    ) from None
                self.n_cold_hits += 1
                from_cold = True
        try:
            kvcodec.verify_chunk(blob)
        except ValueError as e:  # IntegrityError is a ValueError
            raise type(e)(
                f"stored bitstream for context {cid!r} chunk {ci} level "
                f"{lvl} (hash {h}) failed integrity check: {e}"
            ) from e
        if from_cold and self.promote_on_read:
            # verify-before-promote: a rotten cold blob must never become
            # a hot replica that re-serves the corruption
            with self._lock:
                if self._probation_pass(h, lvl) and self._admit_hot(h, lvl, blob):
                    self.n_promotions += 1
        return blob

    def get_kv(self, context_id: str, chunk_idx: int, level: int) -> bytes:
        return self._read_blob(
            self.hash_for(context_id, chunk_idx), level, context_id, chunk_idx
        )

    def get_by_hash(self, chunk_hash: str, level: int) -> bytes:
        """Content-addressed read — the TCP protocol's hash-keyed path."""
        return self._read_blob(chunk_hash, level, f"<hash {chunk_hash}>", -1)

    # -- deletion ------------------------------------------------------------

    def _release_context(self, context_id: str) -> None:
        for m in self._meta.pop(context_id, []):
            h = m.chunk_hash
            if h is None:
                continue
            left = self._refcount.get(h, 0) - 1
            if left > 0:
                self._refcount[h] = left
                continue
            self._refcount.pop(h, None)
            for lvl in list(self._hash_levels.pop(h, {})):
                self._drop_blob(h, lvl)

    def _drop_blob(self, h: str, lvl: int) -> None:
        size = self._hot_lru.pop((h, lvl), None)
        if size is not None:
            self._hot_used -= size
        self._probation.pop((h, lvl), None)
        self.hot.delete(h, 0, lvl)
        self.cold.delete(h, 0, lvl)

    def delete_context(self, context_id: str) -> bool:
        """Drop one context's references; blobs whose refcount reaches zero
        are removed from both tiers.  True if the context existed."""
        with self._lock:
            if context_id not in self._meta:
                return False
            self._release_context(context_id)
            return True

    def delete_kv(self, context_id: str, chunk_idx: int, level: int) -> bool:
        """*Physically* remove the blob backing this context's (chunk,
        level) from both tiers — regardless of sharing.  The fault hammer
        (matches the flat store's semantics: metadata stays, every reader
        of the hash then sees the descriptive missing-``KeyError``)."""
        with self._lock:
            h = self.try_hash(context_id, chunk_idx)
            if h is None:
                return False
            existed = self._hot_lru.get((h, level)) is not None or self.cold.contains(
                h, 0, level
            )
            self._drop_blob(h, level)
            self._hash_levels.get(h, {}).pop(level, None)
            return existed

    # -- tier accounting -----------------------------------------------------

    def tier_penalty(
        self, context_id: str, chunk_levels: Sequence[Tuple[int, int]]
    ) -> Tuple[float, int]:
        """(extra virtual seconds, cold-entry count) a run fetch pays for
        entries not currently hot — what ``SimTransport`` folds into the
        modeled fetch so the throughput estimator sees the slower read."""
        extra = 0.0
        n_cold = 0
        with self._lock:
            metas = self._meta.get(context_id)
            for ci, lvl in chunk_levels:
                if lvl < 0 or metas is None or not (0 <= ci < len(metas)):
                    continue
                h = metas[ci].chunk_hash
                if h is None or (h, lvl) in self._hot_lru:
                    continue
                n_cold += 1
                size = self._hash_levels.get(h, {}).get(lvl, 0)
                extra += self.cold_latency_s + size * 8.0 / (self.cold_gbps * 1e9)
        return extra, n_cold

    def unique_storage_bytes(self) -> int:
        """Bytes across unique (hash, level) blobs — what disk actually holds."""
        with self._lock:
            return sum(
                size
                for levels in self._hash_levels.values()
                for size in levels.values()
            )

    def logical_storage_bytes(self) -> int:
        """Sum of per-context storage (what a flat store would hold)."""
        with self._lock:
            return sum(
                sum(m.sizes.values()) for ms in self._meta.values() for m in ms
            )

    def refcount(self, chunk_hash: str) -> int:
        with self._lock:
            return self._refcount.get(chunk_hash, 0)

    def tier_counters(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hot_hits": self.n_hot_hits,
                "cold_hits": self.n_cold_hits,
                "misses": self.n_misses,
                "promotions": self.n_promotions,
                "demotions": self.n_demotions,
                "evictions": self.n_evictions,
                "dedup_chunks": self.n_dedup_chunks,
                "encoded_chunks": self.n_encoded_chunks,
                "hot_used_bytes": self._hot_used,
                "hot_capacity_bytes": self.hot_bytes,
                "unique_bytes": self.unique_storage_bytes(),
                "probation_adds": self.n_probation_adds,
                "probation_promotes": self.n_probation_promotes,
                "probation_expired": self.n_probation_expired,
                "probation_pending": len(self._probation),
            }
