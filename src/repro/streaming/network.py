"""Trace-driven network model for KV bitstream streaming.

The paper evaluates under piecewise-constant bandwidth traces (Fig. 7, Fig.
14: per-chunk bandwidth sampled from 0.1–10 Gbps).  ``BandwidthTrace``
integrates transfer time for a byte count starting at any instant and
supports per-fetch latency plus a heavy-tailed straggler model (used by the
hedged-fetch straggler mitigation).

Role in the transport split (ISSUE 4): this module is the *virtual-clock*
model.  ``simulate_stream`` walks it directly; the real-I/O
``streaming.transport.SimTransport`` uses the very same
:meth:`NetworkModel.fetch_outcome` arithmetic to pace genuinely asynchronous
storage reads, which is what keeps a SimTransport-backed session
differential-exact against the simulator (same trace in, same decisions
out).  ``TcpTransport`` replaces this model with a measured socket link.

Straggler draws are keyed per ``(chunk_idx, attempt)`` — not consumed from
one shared RNG stream — so hedged and concurrent simulations are
order-independent: the delay a chunk's fetch suffers does not depend on how
many other fetches (from this or other sessions) were simulated first.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "BandwidthTrace",
    "FetchOutcome",
    "NetworkModel",
    "keyed_straggler_delay",
]


@dataclasses.dataclass(frozen=True)
class BandwidthTrace:
    """Piecewise-constant bandwidth.  times[i] is the start of segment i.

    Zero-length segments (``times[i] == times[i+1]``) are permitted — they
    appear when traces are spliced or resampled — and carry no bytes; at a
    duplicated instant the *last* segment starting there is in effect.
    """

    times: np.ndarray  # (N,) seconds, non-decreasing, times[0] == 0
    gbps: np.ndarray  # (N,) bandwidth in Gbit/s for [times[i], times[i+1])

    def __post_init__(self):
        t = np.asarray(self.times, dtype=np.float64)
        g = np.asarray(self.gbps, dtype=np.float64)
        if t.ndim != 1 or t.shape != g.shape or t[0] != 0.0:
            raise ValueError("bad trace")
        if (np.diff(t) < 0).any() or (g <= 0).any():
            raise ValueError("times must be non-decreasing; bandwidth must be positive")
        object.__setattr__(self, "times", t)
        object.__setattr__(self, "gbps", g)

    @staticmethod
    def constant(gbps: float) -> "BandwidthTrace":
        return BandwidthTrace(np.array([0.0]), np.array([float(gbps)]))

    @staticmethod
    def steps(segment_s: float, gbps: Sequence[float]) -> "BandwidthTrace":
        g = np.asarray(list(gbps), dtype=np.float64)
        t = np.arange(len(g)) * float(segment_s)
        return BandwidthTrace(t, g)

    @staticmethod
    def sampled(
        rng: np.random.Generator,
        n_segments: int,
        segment_s: float,
        lo_gbps: float,
        hi_gbps: float,
        log_uniform: bool = True,
    ) -> "BandwidthTrace":
        """Paper Fig. 14 style: per-segment bandwidth ~ U[lo, hi]."""
        if log_uniform:
            g = np.exp(rng.uniform(np.log(lo_gbps), np.log(hi_gbps), n_segments))
        else:
            g = rng.uniform(lo_gbps, hi_gbps, n_segments)
        return BandwidthTrace.steps(segment_s, g)

    def bandwidth_at(self, t: float) -> float:
        i = int(np.searchsorted(self.times, t, side="right") - 1)
        return float(self.gbps[max(i, 0)])

    def transmit_time(self, nbytes: float, start_t: float) -> float:
        """Seconds to push ``nbytes`` starting at ``start_t``."""
        remaining_bits = float(nbytes) * 8.0
        t = float(start_t)
        i = int(np.searchsorted(self.times, t, side="right") - 1)
        i = max(i, 0)
        while remaining_bits > 0:
            rate = self.gbps[i] * 1e9  # bits/s
            seg_end = self.times[i + 1] if i + 1 < len(self.times) else np.inf
            dt_seg = seg_end - t
            bits_seg = rate * dt_seg
            if bits_seg >= remaining_bits:
                t += remaining_bits / rate
                remaining_bits = 0.0
            else:
                remaining_bits -= bits_seg
                t = seg_end
                i += 1
        return t - float(start_t)

    def bytes_in_window(self, duration: float, start_t: float) -> float:
        """Bytes transferable in ``[start_t, start_t + duration)``.

        Byte-integration inverse of :meth:`transmit_time`:
        ``transmit_time(bytes_in_window(d, t), t) == d`` for any ``d > 0``
        (bandwidth is strictly positive on every segment), and
        ``bytes_in_window(transmit_time(nbytes, t), t) == nbytes``.
        """
        t = float(start_t)
        end = t + float(duration)
        i = int(np.searchsorted(self.times, t, side="right") - 1)
        i = max(i, 0)
        bits = 0.0
        while t < end:
            seg_end = self.times[i + 1] if i + 1 < len(self.times) else np.inf
            stop = min(seg_end, end)
            bits += self.gbps[i] * 1e9 * (stop - t)
            t = stop
            i += 1
        return bits / 8.0

    def measured_throughput_gbps(self, nbytes: float, start_t: float) -> float:
        """What a sender would measure for this transfer (paper's estimator)."""
        dur = self.transmit_time(nbytes, start_t)
        if dur <= 0:
            return float(self.gbps[-1])
        return float(nbytes) * 8.0 / dur / 1e9


def keyed_straggler_delay(
    seed: int,
    chunk_idx: int,
    attempt: int,
    *,
    p: float,
    scale_s: float,
    alpha: float,
) -> float:
    """Pareto-tailed straggler stall, keyed per ``(seed, chunk_idx, attempt)``.

    Deterministic in the key and independent of any draw order — the shared
    primitive behind :meth:`NetworkModel.straggler_delay` and the TCP store
    server's stall injection, so a simulated link and a real socket link
    straggle identically for the same seed.
    """
    if p <= 0:
        return 0.0
    rng = np.random.default_rng(
        (int(seed) & 0xFFFFFFFF, int(chunk_idx) & 0xFFFFFFFF, int(attempt) & 0xFF)
    )
    if rng.uniform() >= p:
        return 0.0
    return float(scale_s * (rng.pareto(alpha) + 1.0))


@dataclasses.dataclass(frozen=True)
class FetchOutcome:
    """Resolved timing of one (possibly hedged) fetch on a clock.

    Produced by :meth:`NetworkModel.fetch_outcome` (virtual clock) and by
    the transports in ``streaming.transport`` (realized I/O, virtual or wall
    timing depending on the transport).  ``hedged`` means a duplicate fetch
    was issued *and won*; ``hedge_issued`` counts the duplicate regardless of
    who won; ``duplicate_bytes`` is what the losing attempt transferred
    before being cancelled (0 when no hedge was issued).
    """

    start_t: float
    end_t: float
    throughput_gbps: float
    hedged: bool = False
    hedge_issued: bool = False
    duplicate_bytes: float = 0.0


@dataclasses.dataclass
class NetworkModel:
    """Trace + fixed per-fetch latency + optional straggler tail.

    Straggler model: with prob ``straggler_p`` a fetch stalls for an extra
    Pareto-tailed delay, keyed per ``(chunk_idx, attempt)`` so concurrent /
    hedged simulations are order-independent.  The mitigation (a hedged
    second fetch after ``hedge_after_s``) lives in :meth:`fetch_outcome`,
    shared by the virtual-clock simulator (``streaming/pipeline.py``) and
    the async ``SimTransport`` (``streaming/transport.py``).
    """

    trace: BandwidthTrace
    rtt_s: float = 0.0
    straggler_p: float = 0.0
    straggler_scale_s: float = 1.0
    straggler_alpha: float = 1.5
    seed: int = 0

    def straggler_delay(self, chunk_idx: int, attempt: int = 0) -> float:
        return keyed_straggler_delay(
            self.seed,
            chunk_idx,
            attempt,
            p=self.straggler_p,
            scale_s=self.straggler_scale_s,
            alpha=self.straggler_alpha,
        )

    def fetch_time(
        self,
        nbytes: float,
        start_t: float,
        *,
        chunk_idx: int = 0,
        attempt: int = 0,
        straggle: bool = True,
    ) -> float:
        base = self.rtt_s + self.trace.transmit_time(nbytes, start_t + self.rtt_s)
        extra = self.straggler_delay(chunk_idx, attempt) if straggle else 0.0
        return base + extra

    def fetch_outcome(
        self,
        nbytes: float,
        start_t: float,
        *,
        chunk_idx: int = 0,
        hedge_after_s: Optional[float] = None,
        straggle: bool = True,
    ) -> FetchOutcome:
        """One fetch with optional hedging, resolved on the virtual clock.

        The single source of the hedging arithmetic: a duplicate fetch is
        issued ``hedge_after_s`` after the primary (attempt 1, no straggler
        tail — a fresh replica), the earlier completion wins, and the loser
        is cancelled at the winner's completion instant.  ``duplicate_bytes``
        integrates the trace over the loser's active transfer window
        (straggler stalls are modeled as up-front server stall, during which
        no bytes flow), capped at ``nbytes``.
        """
        base = self.fetch_time(
            nbytes, start_t, chunk_idx=chunk_idx, attempt=0, straggle=straggle
        )
        end_t = start_t + base
        hedged = False
        hedge_issued = False
        duplicate_bytes = 0.0
        if hedge_after_s is not None and base > hedge_after_s:
            hedge_issued = True
            hedged_fetch = hedge_after_s + self.fetch_time(
                nbytes,
                start_t + hedge_after_s,
                chunk_idx=chunk_idx,
                attempt=1,
                straggle=False,
            )
            if hedged_fetch < base:
                # hedge wins; primary is cancelled at the hedge's completion.
                hedged = True
                end_t = start_t + hedged_fetch
                stall = base - self.rtt_s - self.trace.transmit_time(
                    nbytes, start_t + self.rtt_s
                )
                flow_start = start_t + self.rtt_s + stall
                window = end_t - flow_start
                if window > 0:
                    duplicate_bytes = min(
                        float(nbytes),
                        self.trace.bytes_in_window(window, flow_start),
                    )
            else:
                # primary wins; the hedge transferred bytes until cancelled.
                flow_start = start_t + hedge_after_s + self.rtt_s
                window = end_t - flow_start
                if window > 0:
                    duplicate_bytes = min(
                        float(nbytes),
                        self.trace.bytes_in_window(window, flow_start),
                    )
        return FetchOutcome(
            start_t=start_t,
            end_t=end_t,
            throughput_gbps=self.trace.measured_throughput_gbps(
                max(nbytes, 1.0), start_t
            ),
            hedged=hedged,
            hedge_issued=hedge_issued,
            duplicate_bytes=duplicate_bytes,
        )
