"""Trace-driven network simulator for KV bitstream streaming.

The paper evaluates under piecewise-constant bandwidth traces (Fig. 7, Fig.
14: per-chunk bandwidth sampled from 0.1–10 Gbps).  ``BandwidthTrace``
integrates transfer time for a byte count starting at any instant and
supports per-fetch latency plus a heavy-tailed straggler model (used by the
hedged-fetch straggler mitigation tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = ["BandwidthTrace", "NetworkModel"]


@dataclasses.dataclass(frozen=True)
class BandwidthTrace:
    """Piecewise-constant bandwidth.  times[i] is the start of segment i.

    Zero-length segments (``times[i] == times[i+1]``) are permitted — they
    appear when traces are spliced or resampled — and carry no bytes; at a
    duplicated instant the *last* segment starting there is in effect.
    """

    times: np.ndarray  # (N,) seconds, non-decreasing, times[0] == 0
    gbps: np.ndarray  # (N,) bandwidth in Gbit/s for [times[i], times[i+1])

    def __post_init__(self):
        t = np.asarray(self.times, dtype=np.float64)
        g = np.asarray(self.gbps, dtype=np.float64)
        if t.ndim != 1 or t.shape != g.shape or t[0] != 0.0:
            raise ValueError("bad trace")
        if (np.diff(t) < 0).any() or (g <= 0).any():
            raise ValueError("times must be non-decreasing; bandwidth must be positive")
        object.__setattr__(self, "times", t)
        object.__setattr__(self, "gbps", g)

    @staticmethod
    def constant(gbps: float) -> "BandwidthTrace":
        return BandwidthTrace(np.array([0.0]), np.array([float(gbps)]))

    @staticmethod
    def steps(segment_s: float, gbps: Sequence[float]) -> "BandwidthTrace":
        g = np.asarray(list(gbps), dtype=np.float64)
        t = np.arange(len(g)) * float(segment_s)
        return BandwidthTrace(t, g)

    @staticmethod
    def sampled(
        rng: np.random.Generator,
        n_segments: int,
        segment_s: float,
        lo_gbps: float,
        hi_gbps: float,
        log_uniform: bool = True,
    ) -> "BandwidthTrace":
        """Paper Fig. 14 style: per-segment bandwidth ~ U[lo, hi]."""
        if log_uniform:
            g = np.exp(rng.uniform(np.log(lo_gbps), np.log(hi_gbps), n_segments))
        else:
            g = rng.uniform(lo_gbps, hi_gbps, n_segments)
        return BandwidthTrace.steps(segment_s, g)

    def bandwidth_at(self, t: float) -> float:
        i = int(np.searchsorted(self.times, t, side="right") - 1)
        return float(self.gbps[max(i, 0)])

    def transmit_time(self, nbytes: float, start_t: float) -> float:
        """Seconds to push ``nbytes`` starting at ``start_t``."""
        remaining_bits = float(nbytes) * 8.0
        t = float(start_t)
        i = int(np.searchsorted(self.times, t, side="right") - 1)
        i = max(i, 0)
        while remaining_bits > 0:
            rate = self.gbps[i] * 1e9  # bits/s
            seg_end = self.times[i + 1] if i + 1 < len(self.times) else np.inf
            dt_seg = seg_end - t
            bits_seg = rate * dt_seg
            if bits_seg >= remaining_bits:
                t += remaining_bits / rate
                remaining_bits = 0.0
            else:
                remaining_bits -= bits_seg
                t = seg_end
                i += 1
        return t - float(start_t)

    def bytes_in_window(self, duration: float, start_t: float) -> float:
        """Bytes transferable in ``[start_t, start_t + duration)``.

        Byte-integration inverse of :meth:`transmit_time`:
        ``transmit_time(bytes_in_window(d, t), t) == d`` for any ``d > 0``
        (bandwidth is strictly positive on every segment), and
        ``bytes_in_window(transmit_time(nbytes, t), t) == nbytes``.
        """
        t = float(start_t)
        end = t + float(duration)
        i = int(np.searchsorted(self.times, t, side="right") - 1)
        i = max(i, 0)
        bits = 0.0
        while t < end:
            seg_end = self.times[i + 1] if i + 1 < len(self.times) else np.inf
            stop = min(seg_end, end)
            bits += self.gbps[i] * 1e9 * (stop - t)
            t = stop
            i += 1
        return bits / 8.0

    def measured_throughput_gbps(self, nbytes: float, start_t: float) -> float:
        """What a sender would measure for this transfer (paper's estimator)."""
        dur = self.transmit_time(nbytes, start_t)
        if dur <= 0:
            return float(self.gbps[-1])
        return float(nbytes) * 8.0 / dur / 1e9


@dataclasses.dataclass
class NetworkModel:
    """Trace + fixed per-fetch latency + optional straggler tail.

    Straggler model: with prob ``straggler_p`` a fetch stalls for an extra
    Pareto-tailed delay — the mitigation (hedged second fetch after
    ``hedge_after_s``) lives in streaming/pipeline.py.
    """

    trace: BandwidthTrace
    rtt_s: float = 0.0
    straggler_p: float = 0.0
    straggler_scale_s: float = 1.0
    straggler_alpha: float = 1.5
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def straggler_delay(self) -> float:
        if self.straggler_p <= 0:
            return 0.0
        if self._rng.uniform() >= self.straggler_p:
            return 0.0
        return float(self.straggler_scale_s * (self._rng.pareto(self.straggler_alpha) + 1.0))

    def fetch_time(self, nbytes: float, start_t: float, straggle: bool = True) -> float:
        base = self.rtt_s + self.trace.transmit_time(nbytes, start_t + self.rtt_s)
        extra = self.straggler_delay() if straggle else 0.0
        return base + extra
