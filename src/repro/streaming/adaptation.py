"""CacheGen streaming adaptation (paper §5.3 + Algorithm 1, §C.1).

Per chunk, choose the *streaming configuration* — text-recompute or one of
the encoding levels — that has the least compression loss among those whose
projected completion time (assuming the throughput measured on the previous
chunk persists and the same configuration is applied to all remaining
chunks) still meets the TTFT SLO.

Quality ordering (least loss first): TEXT (no loss, but costs GPU prefill
compute) > level 0 (lossless-after-8bit) > level 1 > ... > level n (coarsest).
If nothing fits the SLO, the smallest representation is chosen (best effort).

Failure fallback (ISSUE 6): the serving layer generalizes §C.1's bandwidth
fallback into a *failure* fallback by re-deciding a chunk with the
configurations that already failed (and everything finer) ``exclude``-d.
When every candidate is excluded there is nothing left to try —
:class:`NoFeasibleConfigError` — and the session reports a clean failure.
"""
from __future__ import annotations

import dataclasses
from typing import Collection, Dict, List, Optional, Sequence

__all__ = [
    "StreamConfig",
    "TEXT",
    "NoFeasibleConfigError",
    "choose_config",
    "salvage_credit",
    "AdaptationPolicy",
    "make_policy",
]

TEXT = -1  # sentinel streaming configuration: send text + recompute


class NoFeasibleConfigError(RuntimeError):
    """Every streaming configuration (all levels and TEXT) is excluded."""


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Resolved choice for one chunk."""

    config: int  # TEXT or encoding level
    projected_s: float  # projected completion for all remaining chunks


def _projected_delay(
    remaining_bytes: float,
    throughput_gbps: float,
    recompute_s: float = 0.0,
) -> float:
    return recompute_s + remaining_bytes * 8.0 / (throughput_gbps * 1e9)


def choose_config(
    *,
    remaining_sizes: Dict[int, float],  # level -> total bytes of remaining chunks
    remaining_text_bytes: float,
    remaining_recompute_s: float,  # GPU time to recompute all remaining chunks
    throughput_gbps: float,
    time_left_s: float,
    levels_quality_order: Sequence[int],
    allow_text: bool = True,
    exclude: Collection[int] = (),
) -> StreamConfig:
    """Algorithm 1 step: pick the best-quality feasible configuration.

    ``exclude`` removes configurations (levels or TEXT) that already failed
    past their retry budget for this chunk — the failure-fallback ladder.
    """
    candidates: List[StreamConfig] = []
    if allow_text and TEXT not in exclude:
        proj = _projected_delay(
            remaining_text_bytes, throughput_gbps, remaining_recompute_s
        )
        candidates.append(StreamConfig(TEXT, proj))
    for lvl in levels_quality_order:
        if lvl in exclude:
            continue
        proj = _projected_delay(remaining_sizes[lvl], throughput_gbps)
        candidates.append(StreamConfig(lvl, proj))
    if not candidates:
        raise NoFeasibleConfigError(
            f"all streaming configurations excluded "
            f"(levels {list(levels_quality_order)}, allow_text={allow_text}, "
            f"exclude={sorted(exclude)})"
        )
    for c in candidates:  # quality order: first feasible wins
        if c.projected_s <= time_left_s:
            return c
    return min(candidates, key=lambda c: c.projected_s)  # best effort


def salvage_credit(
    sizes: Dict[int, float],
    salvage_level: int,
    verified_end: int,
    head_end: int,
    anchor_end: int,
    *,
    lossless_level: int = 0,
) -> Dict[int, float]:
    """Per-level byte credit of a verified partial chunk (ISSUE 8).

    A failed/cancelled fetch leaves a checksum-verified byte prefix behind
    (``bitstream.SegmentIndex.verified_prefix``).  When the chunk is
    re-decided, that prefix is worth different amounts at different levels:

    - at ``salvage_level`` itself, every verified byte resumes for free
      (byte-range refetch of only the suffix);
    - at any *other lossy* level, the level-invariant anchor segment — the
      bytes in ``[head_end, anchor_end)`` — composes bit-exactly with that
      level's delta suffix, provided the prefix covers the whole anchor;
    - the lossless level's anchor is encoded with different tables, so a
      lossy prefix is worth nothing there (and vice versa); TEXT recompute
      cannot reuse bitstream bytes at all (rANS lanes span the full token
      axis), so it gets no entry.

    ``choose_config`` subtracts these credits from the current chunk's
    contribution to ``remaining_sizes`` so Algorithm 1 prices only the
    bytes still to be moved.
    """
    anchor_bytes = float(max(int(anchor_end) - int(head_end), 0))
    covers_anchor = int(verified_end) >= int(anchor_end) and anchor_bytes > 0
    credit: Dict[int, float] = {}
    for lvl, size in sizes.items():
        if lvl == salvage_level:
            credit[lvl] = min(float(verified_end), float(size))
        elif (
            covers_anchor
            and salvage_level != lossless_level
            and lvl != lossless_level
        ):
            credit[lvl] = min(anchor_bytes, float(size))
        else:
            credit[lvl] = 0.0
    return credit


@dataclasses.dataclass
class AdaptationPolicy:
    """Stateful per-stream adaptation: carries the throughput estimate.

    ``default_level`` is used for the first chunk when no prior bandwidth
    knowledge exists (paper: "starts with a default medium encoding level").
    """

    levels_quality_order: Sequence[int]
    slo_s: float
    default_level: int
    prior_throughput_gbps: Optional[float] = None
    allow_text: bool = True

    def __post_init__(self):
        self._throughput = self.prior_throughput_gbps

    def next_config(
        self,
        *,
        elapsed_s: float,
        remaining_sizes: Dict[int, float],
        remaining_text_bytes: float,
        remaining_recompute_s: float,
        exclude: Collection[int] = (),
    ) -> StreamConfig:
        if self._throughput is None:
            # no bandwidth estimate yet: default level, else the finest
            # non-excluded level, else TEXT — quality order still applies
            if not exclude:
                return StreamConfig(self.default_level, float("nan"))
            if self.default_level not in exclude:
                return StreamConfig(self.default_level, float("nan"))
            for lvl in self.levels_quality_order:
                if lvl not in exclude:
                    return StreamConfig(lvl, float("nan"))
            if self.allow_text and TEXT not in exclude:
                return StreamConfig(TEXT, float("nan"))
            raise NoFeasibleConfigError(
                f"all streaming configurations excluded "
                f"(levels {list(self.levels_quality_order)}, "
                f"allow_text={self.allow_text}, exclude={sorted(exclude)})"
            )
        return choose_config(
            remaining_sizes=remaining_sizes,
            remaining_text_bytes=remaining_text_bytes,
            remaining_recompute_s=remaining_recompute_s,
            throughput_gbps=self._throughput,
            time_left_s=self.slo_s - elapsed_s,
            levels_quality_order=self.levels_quality_order,
            allow_text=self.allow_text,
            exclude=exclude,
        )

    def observe_throughput(self, gbps: float) -> None:
        self._throughput = gbps

    @property
    def throughput_gbps(self) -> Optional[float]:
        """Current live estimate (None until the first observation)."""
        return self._throughput


def make_policy(
    n_levels: int,
    *,
    slo_s: float,
    default_level: Optional[int] = None,
    prior_throughput_gbps: Optional[float] = None,
    allow_text: bool = True,
    adapt: bool = True,
    fixed_level: Optional[int] = None,
) -> AdaptationPolicy:
    """Canonical policy construction shared by the offline simulator entry
    point (``CacheGenStreamer.stream``) and the live ``ServeSession``.

    ``fixed_level`` (or ``adapt=False``) pins a single representation with no
    text fallback — the "no adaptation" baseline; otherwise all levels are
    candidates in quality order (0 = least loss).
    """
    if fixed_level is not None or not adapt:
        lvl = fixed_level if fixed_level is not None else (
            default_level if default_level is not None else 1
        )
        if not 0 <= lvl < n_levels:
            raise ValueError(
                f"pinned level {lvl} out of range for {n_levels} levels"
            )
        return AdaptationPolicy(
            levels_quality_order=[lvl],
            slo_s=slo_s,
            default_level=lvl,
            prior_throughput_gbps=prior_throughput_gbps,
            allow_text=False,
        )
    return AdaptationPolicy(
        levels_quality_order=list(range(n_levels)),
        slo_s=slo_s,
        default_level=default_level
        if default_level is not None
        else min(1, n_levels - 1),
        prior_throughput_gbps=prior_throughput_gbps,
        allow_text=allow_text,
    )
