"""Async fetch transports for KV bitstreams: the read path as real I/O.

The fetch layer split (ISSUE 4):

  * :class:`~repro.streaming.storage.StorageBackend` — where blobs live
    (memory, directory);
  * :class:`Transport` — how blobs travel: ``fetch_run(context_id,
    [(chunk, level), ...]) -> FetchHandle``.  A handle is a cancellable,
    in-flight fetch whose :meth:`~FetchHandle.result` carries the realized
    bytes *and* timing (:class:`FetchResult`); :func:`as_completed` yields
    handles in completion order;
  * ``NetworkModel`` (streaming/network.py) — the virtual-clock link model,
    used by the offline simulator and by :class:`SimTransport`'s pacing.

Three transports:

  * :class:`LocalTransport` — direct storage read, no link.  Timing is
    host wall time; the offline ``materialize`` default.
  * :class:`SimTransport` — *real* asynchronous reads (one worker thread
    per attempt, bytes read from the backing store and paced in cancellable
    slices against the ``BandwidthTrace``), with completion timing taken
    from ``NetworkModel.fetch_outcome`` — the identical arithmetic the
    virtual-clock simulator runs.  A SimTransport-backed session therefore
    makes exactly the simulator's per-chunk decisions (the differential
    suite in tests/test_transport.py holds it to that) while its fetches,
    hedges and cancellations are genuinely concurrent I/O.
  * :class:`TcpTransport` — a real socket link to a
    :class:`TcpStoreServer` fronting a ``KVStore`` (length-prefixed frames,
    optional server-side pacing + keyed straggler stalls).  Timing is
    measured off the wire, so the session's throughput estimator sees an
    actual link.

Hedging is transport-level I/O, not clock arithmetic: pass
``hedge_after_s`` to :meth:`Transport.fetch_run` and the transport issues a
duplicate attempt after that delay, uses the winner's bytes, *cancels* the
loser (sim: cancellation event stops its paced read; tcp: the loser's
socket is closed mid-stream), and reports the loser's transferred bytes as
``duplicate_bytes``.

Failure model (ISSUE 6).  A fetch can fail five ways, and each maps to one
:func:`classify_failure` kind the session's retry machinery acts on:

  * ``"missing"`` (``KeyError``) — the store has no such ``(context, chunk,
    level)``.  Permanent at that level: retrying the same key cannot
    succeed, so the session skips straight to the degrade ladder.
  * ``"integrity"`` (``bitstream.IntegrityError`` / plan-mismatch
    ``ValueError``) — bytes arrived but are corrupt or are the wrong blob.
    Retryable: the next attempt re-reads the store / re-crosses the link.
  * ``"timeout"`` (``TimeoutError``) — the attempt out-waited the policy's
    budget (wall for realtime transports, virtual for sim).  Retryable; the
    in-flight handle is cancelled first.
  * ``"io"`` (:class:`FetchError`, ``ConnectionError``, ``OSError``) — the
    link died: dropped fetch, severed TCP stream, refused reconnect.
    Retryable; on tcp each attempt opens a fresh connection, so retrying
    *is* reconnect-with-backoff.
  * ``"fatal"`` (anything else) — a programming error; never masked, always
    re-raised.

Retryable kinds are retried up to :class:`RetryPolicy` bounds with
exponential backoff; detection latency + backoff are charged to the
session's ``StreamClock`` so Algorithm-1 re-planning sees the lost time.
Once the per-level budget is exhausted the chunk is re-decided with that
level (and everything finer) excluded — coarser levels, ultimately TEXT
recompute — generalizing the paper's §C.1 bandwidth fallback into a
failure fallback.

Byte-range resume (ISSUE 8).  ``fetch_run(..., byte_range=(offset,
length_or_None), resumable=True)`` fetches a slice of a single chunk's blob
and/or asks for the blob's :class:`~repro.core.bitstream.SegmentIndex` as
fetch metadata (``FetchResult.seg_index`` — unpriced: indexes travel in the
response header, not the payload).  A failed or cancelled attempt no longer
discards its realized bytes: :meth:`FetchHandle.cancel` (and
:meth:`FetchHandle.salvage_at`) return a :class:`Salvage` — the raw
realized payload prefix, its absolute blob offset, and the index — which
``SegmentIndex.verified_prefix`` resolves into complete CRC-verified
segments plus a resume offset.  Transports advertise the capability with a
``supports_range`` class attribute; callers must not pass the new kwargs to
transports without it.

Versioned range-request frame (tcp).  Request: one msgpack frame
``{cid, chunks, straggle, attempt[, hashes][, range: [offset, length|0]]
[, want_idx: true]}``; ``length 0`` means to-end.  Response header:
``{ok, sizes[, total, idx]}`` — ``total`` (the full blob length) and
``idx`` (the segment index, wire form) are present only when the request
carried ``range``/``want_idx``.  Version tolerance is by omission on both
sides: an old server ignores the extra request keys and streams the whole
blob (the client detects the missing ``total`` and treats the response as
a whole-blob fetch from offset 0); an old client never sends them and gets
byte-identical frames to the pre-range protocol.

Resume state machine (driven by ``serving/session.py``)::

    attempt fails / is cancelled / mid-chunk collapse detected
      -> Salvage(data, offset, index) via err.salvage or handle.cancel(at_t)
      -> index.verified_prefix(data, offset) -> verified resume offset
      -> re-decide the remainder (choose_config, salvage-credit-adjusted):
           same level     -> RESUME   byte_range=(verified_end, None)
           coarser level  -> DEGRADE-COMPOSE  keep the level-invariant
                             anchor segments already paid for, fetch only
                             the delta suffix at the coarser level, and
                             synthesize the coarser head — composes
                             bit-exactly (whole-blob CRC still verifies)
           TEXT           -> RECOMPUTE  drop the bytes.  rANS lanes span
                             the whole token axis (a byte prefix covers
                             *lanes*, not leading tokens), so TEXT
                             recompute is whole-chunk; per-token-run delta
                             segmentation is the ROADMAP follow-on.
"""
from __future__ import annotations

import dataclasses
import logging
import socket
import struct
import threading
import time
from typing import List, Optional, Protocol, Sequence, Tuple, runtime_checkable

from repro.core.bitstream import IntegrityError, SegmentIndex, segment_index
from repro.streaming.network import NetworkModel, keyed_straggler_delay
from repro.streaming.storage import KVStore

__all__ = [
    "FetchError",
    "FetchHandle",
    "FetchResult",
    "LocalTransport",
    "RetryPolicy",
    "Salvage",
    "SimTransport",
    "TcpStoreServer",
    "TcpTransport",
    "Transport",
    "as_completed",
    "classify_failure",
]

logger = logging.getLogger(__name__)

ChunkLevels = Sequence[Tuple[int, int]]  # [(chunk_idx, level), ...]


class FetchError(RuntimeError):
    """A fetch failed or was cancelled before completing.

    Carries the context id and ``(chunk, level)`` list when the issuing
    transport knows them, so failures under concurrency are attributable;
    ``fail_t`` (when set) is the transport-clock instant the failure was
    detected — what the session charges to its ``StreamClock``.
    """

    def __init__(
        self,
        message: str,
        *,
        context_id: Optional[str] = None,
        chunk_levels: Optional[ChunkLevels] = None,
        fail_t: Optional[float] = None,
        salvage: Optional["Salvage"] = None,
    ):
        detail = ""
        if context_id is not None or chunk_levels is not None:
            parts = []
            if context_id is not None:
                parts.append(f"context {context_id!r}")
            if chunk_levels is not None:
                parts.append(f"(chunk, level)={[tuple(c) for c in chunk_levels]}")
            detail = f" [{', '.join(parts)}]"
        super().__init__(message + detail)
        self.context_id = context_id
        self.chunk_levels = list(chunk_levels) if chunk_levels is not None else None
        self.fail_t = fail_t
        self.salvage = salvage  # realized prefix delivered before the failure


@dataclasses.dataclass
class Salvage:
    """The realized remainder of a failed, cancelled, or abandoned fetch.

    ``data`` is the raw realized payload prefix — *unverified*; the caller
    resolves it into complete segments plus a resume offset via
    ``index.verified_prefix(data, offset)``.  ``offset`` is the absolute
    blob offset where ``data`` begins (0 for a whole-blob attempt, the
    requested range offset for a resume attempt); ``total`` is the full
    blob length when known (0 otherwise).  ``nbytes_wire`` is what this
    attempt actually cost on the wire — the reconciliation ledger's input
    (``salvaged + refetched == realized wire bytes``).
    """

    data: bytes
    offset: int = 0
    total: int = 0
    index: Optional[SegmentIndex] = None
    nbytes_wire: float = 0.0


def classify_failure(err: BaseException) -> str:
    """Map a fetch exception to a retry-machinery kind (see module docstring).

    Order matters: ``IntegrityError`` is a ``ValueError``, and ``FetchError``
    is a ``RuntimeError`` — most-specific first.
    """
    if isinstance(err, KeyError):
        return "missing"
    if isinstance(err, IntegrityError):
        return "integrity"
    if isinstance(err, TimeoutError):
        return "timeout"
    if isinstance(err, (FetchError, ConnectionError, OSError)):
        return "io"
    if isinstance(err, ValueError):
        return "integrity"  # plan/header mismatch: wrong blob delivered
    return "fatal"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded-retry budget for one chunk fetch at one level.

    ``max_attempts`` counts total tries (1 = no retry); ``backoff(k)`` is the
    pause charged before re-attempt ``k`` (exponential).  ``timeout_s``
    bounds a *virtual-clock* attempt (sim transport: a stall that resolves
    past it is treated as a timeout failure); ``wall_timeout_s`` bounds a
    *wall-clock* attempt on realtime transports (tcp/local/paced sim).
    ``degrade=False`` disables the coarser-level/TEXT fallback — the session
    fails cleanly once retries are exhausted.
    """

    max_attempts: int = 3
    backoff_s: float = 0.02
    backoff_mult: float = 2.0
    timeout_s: Optional[float] = None
    wall_timeout_s: Optional[float] = None
    degrade: bool = True

    def backoff(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based: first retry = 1)."""
        return self.backoff_s * self.backoff_mult ** max(attempt - 1, 0)


@dataclasses.dataclass
class FetchResult:
    """Realized outcome of one (possibly hedged) run fetch.

    ``blobs`` are in request order.  ``end_t``/``throughput_gbps`` are on
    the transport's clock — the session's virtual clock for
    :class:`SimTransport` (trace arithmetic), wall-derived for
    :class:`TcpTransport`/:class:`LocalTransport` — and are exactly the
    fields ``StreamClock.account`` consumes.  ``duplicate_bytes`` is what
    the cancelled losing attempt transferred; ``loser_bytes_read`` is the
    realized byte counter of that attempt's reader (equals
    ``duplicate_bytes`` on tcp, where accounting *is* the counter).
    """

    blobs: List[bytes]
    nbytes: int
    start_t: float
    end_t: float
    throughput_gbps: float
    hedged: bool = False
    hedge_issued: bool = False
    duplicate_bytes: float = 0.0
    wall_s: float = 0.0
    winner: str = "primary"  # "primary" | "hedge"
    loser_cancelled: bool = False
    loser_bytes_read: int = 0
    completion_order: Tuple[int, ...] = ()  # chunk_idx in arrival order
    cold_entries: int = 0  # entries served from the cold tier (tiered store)
    seg_index: Optional[SegmentIndex] = None  # when resumable was requested
    range_offset: int = 0  # absolute blob offset blobs[0] begins at
    range_total: int = 0  # full blob length for a range fetch (0 = whole)


@runtime_checkable
class Transport(Protocol):
    """Pluggable fetch path: issue a run fetch, get a cancellable handle.

    Implementations that understand ``byte_range``/``resumable`` set a
    ``supports_range = True`` class attribute; callers gate on it so
    pre-range transports (and test stubs) keep working unchanged.
    """

    def fetch_run(
        self,
        context_id: str,
        chunk_levels: ChunkLevels,
        *,
        start_t: float = 0.0,
        hedge_after_s: Optional[float] = None,
    ) -> "FetchHandle":
        ...

    def close(self) -> None:
        ...


class FetchHandle:
    """One in-flight run fetch: wait on it, or cancel it.

    ``result()`` blocks until the winning attempt completes and returns the
    :class:`FetchResult`; ``cancel()`` aborts every attempt (a subsequent
    ``result()`` raises :class:`FetchError`).  ``add_done_callback`` powers
    :func:`as_completed`.
    """

    def __init__(
        self,
        context_id: Optional[str] = None,
        chunk_levels: Optional[ChunkLevels] = None,
    ):
        self._done = threading.Event()
        self._result: Optional[FetchResult] = None
        self._error: Optional[BaseException] = None
        self._callbacks: List = []
        self._lock = threading.Lock()
        self.context_id = context_id
        self.chunk_levels = list(chunk_levels) if chunk_levels is not None else None

    # -- completion plumbing (transport side) ------------------------------

    def _finish(self, result: Optional[FetchResult], error=None) -> None:
        with self._lock:
            if self._done.is_set():
                return
            self._result = result
            self._error = error
            self._done.set()
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    # -- consumer side -----------------------------------------------------

    def done(self) -> bool:
        return self._done.is_set()

    def add_done_callback(self, cb) -> None:
        with self._lock:
            if not self._done.is_set():
                self._callbacks.append(cb)
                return
        cb(self)

    def result(self, timeout: Optional[float] = None) -> FetchResult:
        if not self._done.wait(timeout):
            raise TimeoutError("fetch still in flight")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def salvage_at(self, at_t: Optional[float] = None) -> Optional["Salvage"]:
        """Realized payload prefix of a single-chunk fetch at transport time
        ``at_t`` (None = everything realized so far / by completion).

        Base transports cannot salvage — returns None; range-capable
        transports override.  Valid whether the fetch is in flight, failed,
        or already complete (a *completed* fetch salvages its full payload,
        which is what lets a preempted session keep a finished-but-unused
        fetch across suspend/resume).
        """
        return None

    def cancel(self, at_t: Optional[float] = None) -> Optional["Salvage"]:
        """Abort all attempts; a pending ``result()`` raises FetchError.

        Returns the realized, resumable prefix (see :meth:`salvage_at`)
        instead of discarding it — ``at_t`` bounds the salvage on the
        transport's clock for virtual-time cancellation.
        """
        salvage = self.salvage_at(at_t)
        self._abort()
        self._finish(None, FetchError(
            "fetch cancelled by caller",
            context_id=self.context_id,
            chunk_levels=self.chunk_levels,
            salvage=salvage,
        ))
        return salvage

    def _abort(self) -> None:  # transport-specific teardown
        pass


def as_completed(handles: Sequence[FetchHandle], timeout: Optional[float] = None):
    """Yield handles in the order their fetches complete.

    ``timeout`` bounds the *total* wait across all handles; on expiry a
    ``TimeoutError`` is raised (matching :meth:`FetchHandle.result`).
    """
    import queue

    deadline = None if timeout is None else time.monotonic() + timeout
    q: "queue.Queue[FetchHandle]" = queue.Queue()
    for h in handles:
        h.add_done_callback(q.put)
    for _ in range(len(handles)):
        try:
            if deadline is None:
                yield q.get()
            else:
                yield q.get(timeout=max(deadline - time.monotonic(), 0.0))
        except queue.Empty:
            raise TimeoutError(
                "fetches still in flight past as_completed timeout"
            ) from None


def _clamp_range(
    byte_range: Tuple[int, Optional[int]], blob_len: int
) -> Tuple[int, int]:
    """Resolve a ``(offset, length_or_None)`` request against a blob length.

    ``length`` of None (or <= 0) means to-end; offsets are clamped so a
    stale request (e.g. a resume offset past a shrunken blob) degrades to
    an empty slice rather than an exception.
    """
    off, ln = byte_range
    off = max(0, min(int(off), blob_len))
    if ln is None or int(ln) <= 0:
        return off, blob_len
    return off, min(off + int(ln), blob_len)


def _probe_cold(store, context_id: str, chunk_levels: ChunkLevels) -> int:
    """How many of a run's entries would be served cold right now (0 for a
    flat store — only the tiered store exposes ``tier_penalty``)."""
    penalty = getattr(store, "tier_penalty", None)
    if not callable(penalty):
        return 0
    try:
        return penalty(context_id, chunk_levels)[1]
    except Exception:
        return 0


# ---------------------------------------------------------------------------
# LocalTransport: direct store read
# ---------------------------------------------------------------------------


class LocalTransport:
    """Direct storage reads — no link between the store and the consumer.

    Fetches still run on a worker thread (handles are uniformly async and
    cancellable), but there is nothing to pace: ``end_t`` advances by the
    realized host read time.
    """

    realtime = False  # resolving a handle costs ~no wall time
    supports_range = True

    def __init__(self, store: KVStore):
        self.store = store

    def fetch_run(
        self,
        context_id: str,
        chunk_levels: ChunkLevels,
        *,
        start_t: float = 0.0,
        hedge_after_s: Optional[float] = None,  # no link -> nothing to hedge
        byte_range: Optional[Tuple[int, Optional[int]]] = None,
        resumable: bool = False,
    ) -> FetchHandle:
        chunk_levels = list(chunk_levels)
        if byte_range is not None and len(chunk_levels) != 1:
            raise ValueError("byte-range fetch is single-chunk only")
        handle = FetchHandle(context_id, chunk_levels)

        def work():
            # tier probe before the reads promote everything hot; wall
            # timing below then includes the cold tier's actual read cost
            cold_entries = _probe_cold(self.store, context_id, chunk_levels)
            t0 = time.perf_counter()
            try:
                blobs = [
                    self.store.get_kv(context_id, ci, lvl)
                    for ci, lvl in chunk_levels
                ]
            except BaseException as e:  # surfaced at result()
                handle._finish(None, e)
                return
            seg_idx = None
            range_offset = range_total = 0
            if len(blobs) == 1 and (resumable or byte_range is not None):
                full = blobs[0]
                if resumable:
                    seg_idx = segment_index(full)
                if byte_range is not None:
                    off, end = _clamp_range(byte_range, len(full))
                    blobs = [full[off:end]]
                    range_offset, range_total = off, len(full)
            wall = time.perf_counter() - t0
            nbytes = sum(len(b) for b in blobs)
            handle._finish(FetchResult(
                blobs=blobs,
                nbytes=nbytes,
                start_t=start_t,
                end_t=start_t + wall,
                throughput_gbps=nbytes * 8.0 / max(wall, 1e-9) / 1e9,
                wall_s=wall,
                completion_order=tuple(ci for ci, _ in chunk_levels),
                cold_entries=cold_entries,
                seg_index=seg_idx,
                range_offset=range_offset,
                range_total=range_total,
            ))

        threading.Thread(target=work, daemon=True).start()
        return handle

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# SimTransport: paced async reads against a BandwidthTrace
# ---------------------------------------------------------------------------


class _Attempt:
    """One attempt's paced read: real bytes off the store, real slices,
    really cancellable.  ``time_scale`` maps virtual seconds to host sleep
    (0 = read at host speed, timing stays purely virtual)."""

    def __init__(self, nbytes: int, duration_s: float, time_scale: float):
        self.nbytes = nbytes
        self.duration_s = max(float(duration_s), 0.0)
        self.time_scale = time_scale
        self.bytes_read = 0
        self.error: Optional[BaseException] = None
        self.cancelled = threading.Event()
        self.finished = threading.Event()

    def run(self, read_blobs) -> None:
        try:
            blobs = read_blobs()
        except BaseException as e:
            self.error = e
            self.finished.set()
            return
        # pace the payload in cancellable slices proportional to the
        # attempt's share of its (virtual) transfer window
        n_slices = 16 if self.time_scale > 0 else 1
        sleep_per = self.duration_s * self.time_scale / n_slices
        total = sum(len(b) for b in blobs)
        for s in range(n_slices):
            if self.cancelled.is_set():
                self.finished.set()
                return
            if sleep_per > 0:
                time.sleep(sleep_per)
            self.bytes_read = min(total, (total * (s + 1)) // n_slices)
        self.bytes_read = total
        self.blobs = blobs
        self.finished.set()


class _SimHandle(FetchHandle):
    def __init__(self, attempts: List[_Attempt], context_id=None, chunk_levels=None):
        super().__init__(context_id, chunk_levels)
        self._attempts = attempts
        self._salvage_fn = None  # set by the transport when salvageable

    def salvage_at(self, at_t: Optional[float] = None) -> Optional[Salvage]:
        if self._salvage_fn is None:
            return None
        return self._salvage_fn(at_t)

    def _abort(self) -> None:
        for a in self._attempts:
            a.cancelled.set()


class SimTransport:
    """Trace-paced asynchronous reads over a :class:`KVStore`.

    Completion timing comes from ``NetworkModel.fetch_outcome`` — the exact
    arithmetic the virtual-clock simulator uses, straggler draws keyed per
    (chunk_idx, attempt) — so sessions fetching through this transport make
    the simulator's decisions on the same trace, while the bytes genuinely
    move on worker threads: the primary attempt reads and paces, a hedge
    attempt (when ``hedge_after_s`` fires) races it, and the virtual loser's
    read is cancelled mid-pace.  ``time_scale`` scales virtual seconds into
    real host sleep (default 0: no sleeping, timing stays virtual — the
    scenario-matrix default; benchmarks set it > 0 for wall-real pacing).
    """

    def __init__(
        self,
        store: KVStore,
        network: NetworkModel,
        *,
        time_scale: float = 0.0,
    ):
        self.store = store
        self.network = network
        self.time_scale = float(time_scale)
        # paced reads take real wall time; unpaced handles resolve ~instantly
        self.realtime = self.time_scale > 0

    supports_range = True

    def fetch_run(
        self,
        context_id: str,
        chunk_levels: ChunkLevels,
        *,
        start_t: float = 0.0,
        hedge_after_s: Optional[float] = None,
        byte_range: Optional[Tuple[int, Optional[int]]] = None,
        resumable: bool = False,
    ) -> FetchHandle:
        chunk_levels = list(chunk_levels)
        if byte_range is not None and len(chunk_levels) != 1:
            raise ValueError("byte-range fetch is single-chunk only")
        if byte_range is not None:
            hedge_after_s = None  # a resumed suffix is never hedged
        salvageable = resumable and len(chunk_levels) == 1
        read_full = lambda: [  # noqa: E731
            self.store.get_kv(context_id, ci, lvl) for ci, lvl in chunk_levels
        ]
        # one cell per concern, filled when the worker's read realizes the
        # blob: the segment index (metadata, unpriced) and the range span
        idx_cell: List[Optional[SegmentIndex]] = [None]
        span_cell: List[Tuple[int, int]] = [(0, 0)]  # (range_offset, total)

        def read():
            blobs = read_full()
            if len(blobs) == 1 and (resumable or byte_range is not None):
                full = blobs[0]
                if resumable:
                    idx_cell[0] = segment_index(full)
                if byte_range is not None:
                    off, end = _clamp_range(byte_range, len(full))
                    span_cell[0] = (off, len(full))
                    blobs = [full[off:end]]
            return blobs

        # sizes are needed up front to price the transfer; metadata is the
        # frontend's job, the blob bytes still travel through the attempts
        try:
            try:
                metas = self.store.meta(context_id)
                full_nbytes = sum(
                    metas[ci].sizes[lvl] for ci, lvl in chunk_levels
                )
            except (KeyError, IndexError):
                full_nbytes = sum(len(b) for b in read_full())
        except KeyError as e:
            # 404 after one round trip on the virtual clock
            e.fail_t = start_t + float(getattr(self.network, "rtt_s", 0.0))
            failed = FetchHandle(context_id, chunk_levels)
            failed._finish(None, e)
            return failed
        if byte_range is not None:
            # the link only carries the requested slice
            off, end = _clamp_range(byte_range, int(full_nbytes))
            nbytes = end - off
        else:
            nbytes = full_nbytes
        key_chunk = chunk_levels[0][0] if chunk_levels else 0

        # tiered store: entries not currently hot pay the cold tier's
        # modeled read surcharge — folded into the fetch's virtual timing
        # *before* the reads below promote them, so the session's
        # throughput estimator sees the slower fetch and re-plans around
        # tier misses (a flat store has no tier_penalty: surcharge 0)
        tier_penalty = getattr(self.store, "tier_penalty", None)
        tier_extra_s, cold_entries = (
            tier_penalty(context_id, chunk_levels)
            if callable(tier_penalty)
            else (0.0, 0)
        )

        # virtual truth, computed once at issue: who wins, and when
        outcome = self.network.fetch_outcome(
            float(nbytes), start_t, chunk_idx=key_chunk,
            hedge_after_s=hedge_after_s,
        )
        if tier_extra_s > 0:
            end_t = outcome.end_t + tier_extra_s
            dur = max(end_t - start_t, 1e-9)
            outcome = dataclasses.replace(
                outcome,
                end_t=end_t,
                throughput_gbps=float(nbytes) * 8.0 / dur / 1e9,
            )
        primary_dur = self.network.fetch_time(
            float(nbytes), start_t, chunk_idx=key_chunk, attempt=0
        ) + tier_extra_s
        hedge_issued = outcome.hedge_issued
        attempts = [_Attempt(nbytes, primary_dur, self.time_scale)]
        if hedge_issued:
            hedge_dur = self.network.fetch_time(
                float(nbytes), start_t + (hedge_after_s or 0.0),
                chunk_idx=key_chunk, attempt=1, straggle=False,
            )
            attempts.append(_Attempt(nbytes, hedge_dur, self.time_scale))
        handle = _SimHandle(attempts, context_id, chunk_levels)
        winner_i = 1 if outcome.hedged else 0

        if salvageable or byte_range is not None:
            # bytes start flowing one RTT (plus any up-front stall and cold
            # surcharge) after issue; what has crossed the link by virtual
            # time t is the trace's byte integral over [flow_start, t) —
            # the same arithmetic fetch_outcome charges for a hedge loser
            flow_start = (
                start_t
                + float(getattr(self.network, "rtt_s", 0.0))
                + self.network.straggler_delay(key_chunk, attempt=0)
                + tier_extra_s
            )

            def salvage_fn(at_t: Optional[float]) -> Optional[Salvage]:
                a = attempts[0]
                if not a.finished.is_set():
                    a.finished.wait(timeout=5.0)
                if a.error is not None or not hasattr(a, "blobs"):
                    return None  # the read itself failed: nothing realized
                payload = b"".join(a.blobs)
                if at_t is None:
                    realized = len(payload)
                else:
                    realized = 0 if at_t <= flow_start else min(
                        len(payload),
                        int(self.network.trace.bytes_in_window(
                            at_t - flow_start, flow_start
                        )),
                    )
                if realized <= 0:
                    return None
                off, total = span_cell[0]
                return Salvage(
                    data=payload[:realized],
                    offset=off,
                    total=total or (len(payload) if byte_range is None else 0),
                    index=idx_cell[0],
                    nbytes_wire=float(realized),
                )

            handle._salvage_fn = salvage_fn

        def coordinate():
            threads = []
            for i, a in enumerate(attempts):
                th = threading.Thread(target=a.run, args=(read,), daemon=True)
                threads.append(th)
                if i == 0:
                    th.start()
            if hedge_issued:
                # the duplicate is issued hedge_after_s after the primary
                # (scaled into host time when pacing is on)
                if self.time_scale > 0 and hedge_after_s:
                    attempts[0].finished.wait(hedge_after_s * self.time_scale)
                threads[1].start()
            winner = attempts[winner_i]
            winner.finished.wait()
            # cancel the loser(s) at the winner's completion instant
            for i, a in enumerate(attempts):
                if i != winner_i:
                    a.cancelled.set()
            if winner.error is not None:
                # bytes travelled (or the read failed) on the virtual window;
                # the failure is detected at the transfer's modeled end
                if getattr(winner.error, "fail_t", None) is None:
                    try:
                        winner.error.fail_t = outcome.end_t
                    except AttributeError:
                        pass  # exception type with __slots__
                handle._finish(None, winner.error)
                return
            if winner.cancelled.is_set() or not hasattr(winner, "blobs"):
                handle._finish(None, FetchError(
                    "fetch was cancelled",
                    context_id=context_id,
                    chunk_levels=chunk_levels,
                    fail_t=outcome.end_t,
                ))
                return
            loser = attempts[1 - winner_i] if hedge_issued else None
            handle._finish(FetchResult(
                blobs=winner.blobs,
                nbytes=nbytes,
                start_t=start_t,
                end_t=outcome.end_t,
                throughput_gbps=outcome.throughput_gbps,
                hedged=outcome.hedged,
                hedge_issued=hedge_issued,
                duplicate_bytes=outcome.duplicate_bytes,
                wall_s=0.0,
                winner="hedge" if outcome.hedged else "primary",
                loser_cancelled=loser.cancelled.is_set() if loser else False,
                loser_bytes_read=loser.bytes_read if loser else 0,
                completion_order=tuple(ci for ci, _ in chunk_levels),
                cold_entries=cold_entries,
                seg_index=idx_cell[0],
                range_offset=span_cell[0][0],
                range_total=span_cell[0][1],
            ))

        threading.Thread(target=coordinate, daemon=True).start()
        return handle

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# TcpTransport: a real socket link
# ---------------------------------------------------------------------------

_LEN = struct.Struct(">I")


def _recv_exact(sock: socket.socket, n: int, counter=None) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        part = sock.recv(min(65536, n - len(buf)))
        if not part:
            raise ConnectionError("peer closed mid-frame")
        buf += part
        if counter is not None:
            counter[0] += len(part)
    return bytes(buf)


def _send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_frame(sock: socket.socket, counter=None) -> bytes:
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size, counter))
    return _recv_exact(sock, n, counter)


def _recv_frame_into(sock: socket.socket, counter, buf: bytearray) -> bytes:
    """Receive one frame, appending payload bytes to ``buf`` *as they
    arrive* — a stream severed mid-frame leaves its realized prefix in
    ``buf`` for salvage instead of losing it inside the exception."""
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size, counter))
    start = len(buf)
    while len(buf) - start < n:
        part = sock.recv(min(65536, n - (len(buf) - start)))
        if not part:
            raise ConnectionError("peer closed mid-frame")
        buf += part
        if counter is not None:
            counter[0] += len(part)
    return bytes(buf[start:start + n])


class TcpStoreServer:
    """Length-prefixed socket server fronting a :class:`KVStore`.

    Request: one msgpack frame ``{cid, chunks: [[ci, lvl], ...], straggle,
    attempt}``, optionally carrying ``hashes: [key | nil, ...]`` aligned
    with ``chunks`` — when the fronted store is content-addressed
    (``TieredKVStore``), a non-nil hash key is served directly via
    ``get_by_hash`` (two tenants sharing a document prefix hit the same
    blob without the server consulting either tenant's catalog); nil
    entries and flat stores fall back to the ``(cid, chunk, level)`` path.
    Optional ``range: [offset, length|0]`` and ``want_idx: true`` request
    keys (see the module docstring) slice the single blob and attach its
    segment index + full length to the response header — old clients never
    send them, old servers ignore them.  Connections are persistent: the
    server loops serving requests until the client closes at a frame
    boundary (clean goodbye, not a dropped connection).
    Response: one msgpack header frame ``{ok, sizes[, total, idx] | error}``
    followed by each blob as a raw frame.  ``tier_stats()`` snapshots the
    fronted store's per-tier hit/miss/demotion counters (empty for a flat
    store) — the multi-tenant deployment's observability surface.  ``pace_gbps`` throttles the blob
    stream into timed slices (an actual paced link, not a sleep-at-the-end
    model); ``straggler_p`` injects a keyed Pareto stall per
    ``(chunk_idx, attempt)`` before the payload — the same
    ``keyed_straggler_delay`` the virtual-clock model draws from, so a
    hedged client (attempt 1, ``straggle=False``) escapes exactly the
    stalls the simulator's hedge escapes.

    Connection-failure accounting: every accepted connection increments
    ``n_connections``; a connection that dies mid-exchange (client gone,
    socket error) increments ``n_dropped_connections``; a request frame that
    does not parse increments ``n_malformed``.  The most recent reasons are
    kept in ``last_errors`` (bounded) and logged at debug level — a flaky
    peer is observable on the server object, not silently swallowed.

    ``fault_plan`` (``streaming/faults.FaultPlan``) injects server-side
    chaos per request: a "drop" severs the stream mid-frame (header + half
    the first blob, then close), a "stall" sleeps past the client's timeout,
    a "corrupt" flips payload bytes before sending, a "truncate" delivers a
    valid payload prefix then severs (the salvageable partial delivery the
    resume path exists for).  ``n_injected_faults`` counts them.
    """

    def __init__(
        self,
        store: KVStore,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        pace_gbps: Optional[float] = None,
        straggler_p: float = 0.0,
        straggler_scale_s: float = 0.1,
        straggler_alpha: float = 1.5,
        seed: int = 0,
        fault_plan=None,
    ):
        self.store = store
        self.pace_gbps = pace_gbps
        self.straggler_p = straggler_p
        self.straggler_scale_s = straggler_scale_s
        self.straggler_alpha = straggler_alpha
        self.seed = seed
        self.fault_plan = fault_plan
        self.n_connections = 0
        self.n_dropped_connections = 0
        self.n_malformed = 0
        self.n_injected_faults = 0
        self.last_errors: List[str] = []  # bounded, most recent last
        self._attempt_counts: dict = {}  # (cid, chunk, level) -> tries seen
        self._stats_lock = threading.Lock()
        self._live_conns: set = set()  # persistent conns to sever on close()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self._closing = threading.Event()
        self._thread = threading.Thread(target=self._accept_loop, daemon=True)
        self._thread.start()

    # -- server internals --------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener closed
            threading.Thread(
                target=self._serve_conn, args=(conn,), daemon=True
            ).start()

    def _note_error(self, reason: str) -> None:
        with self._stats_lock:
            self.last_errors.append(reason)
            del self.last_errors[:-16]
        logger.debug("tcp store server: %s", reason)

    def _draw_fault(self, cid, chunks):
        """One injected fault decision per request (first chunk keys it)."""
        if self.fault_plan is None or not chunks:
            return None, 0
        ci, lvl = chunks[0]
        with self._stats_lock:
            attempt = self._attempt_counts.get((cid, ci, lvl), 0)
            self._attempt_counts[(cid, ci, lvl)] = attempt + 1
        return self.fault_plan.draw(cid, ci, lvl, attempt), attempt

    def _serve_conn(self, conn: socket.socket) -> None:
        import msgpack

        with self._stats_lock:
            self.n_connections += 1
            self._live_conns.add(conn)
        try:
            with conn:
                # persistent connection: serve requests until the client
                # closes cleanly at a frame boundary (connection reuse —
                # a retrying session does not re-pay connection setup)
                while self._serve_one(conn, msgpack):
                    pass
        except (ConnectionError, OSError, ValueError) as e:
            if self._closing.is_set():
                return  # shutdown severed us, not the peer
            # client gone (a cancelled hedge loser, a dropped peer) — the
            # request is over, but the event is counted and attributable
            with self._stats_lock:
                self.n_dropped_connections += 1
            self._note_error(f"connection dropped mid-exchange: {e!r}")
            return
        finally:
            with self._stats_lock:
                self._live_conns.discard(conn)

    def _serve_one(self, conn: socket.socket, msgpack) -> bool:
        """Serve one request; False ends the connection (cleanly or after
        an injected sever fault)."""
        # clean EOF at a frame boundary is the reuse protocol's goodbye,
        # not a dropped connection
        first = conn.recv(1)
        if not first:
            return False
        try:
            n = _LEN.unpack(first + _recv_exact(conn, _LEN.size - 1))[0]
            req = msgpack.unpackb(_recv_exact(conn, n), raw=False)
            cid = req["cid"]
            chunks = [(int(c), int(lv)) for c, lv in req["chunks"]]
            hashes = req.get("hashes")
            if hashes is not None and len(hashes) != len(chunks):
                raise ValueError(
                    f"hashes length {len(hashes)} != chunks "
                    f"length {len(chunks)}"
                )
            rng = req.get("range")
            want_idx = bool(req.get("want_idx"))
            if rng is not None and len(chunks) != 1:
                raise ValueError("range request must name exactly one chunk")
        except ConnectionError:
            raise  # peer vanished mid-request frame
        except Exception as e:
            with self._stats_lock:
                self.n_malformed += 1
            self._note_error(f"malformed request frame: {e!r}")
            return False
        get_by_hash = getattr(self.store, "get_by_hash", None)
        if hashes is None or not callable(get_by_hash):
            hashes = [None] * len(chunks)
        try:
            blobs = [
                get_by_hash(h, lvl)
                if h is not None
                else self.store.get_kv(cid, ci, lvl)
                for h, (ci, lvl) in zip(hashes, chunks)
            ]
        except KeyError as e:
            _send_frame(conn, msgpack.packb(
                {"ok": False, "error": str(e.args[0])}
            ))
            return True
        # range/index view of the (single) blob — computed before fault
        # injection so a corrupt fault damages the *delivered* bytes while
        # the index still describes the canonical blob (the client's
        # verified_prefix then catches the corruption segment-by-segment)
        header: dict = {"ok": True}
        if rng is not None or want_idx:
            header["total"] = len(blobs[0]) if len(blobs) == 1 else 0
            if want_idx and len(blobs) == 1:
                header["idx"] = segment_index(blobs[0]).to_wire()
            if rng is not None:
                off, end = _clamp_range(
                    (int(rng[0]), int(rng[1]) if len(rng) > 1 else None),
                    len(blobs[0]),
                )
                blobs = [blobs[0][off:end]]
        fault, attempt = self._draw_fault(cid, chunks)
        if fault is not None:
            with self._stats_lock:
                self.n_injected_faults += 1
            self._note_error(
                f"injected {fault.kind} fault for {cid!r} chunks {chunks}"
            )
            if fault.kind == "stall":
                time.sleep(fault.delay_s)
            elif fault.kind == "corrupt":
                blobs = [
                    self.fault_plan.corrupt_bytes(b, cid, ci, lvl, attempt)
                    for b, (ci, lvl) in zip(blobs, chunks)
                ]
        header["sizes"] = [len(b) for b in blobs]
        _send_frame(conn, msgpack.packb(header))
        if fault is not None and fault.kind == "drop":
            # sever mid-frame: length prefix + half the payload, then the
            # connection closes — the client sees ConnectionError
            half = blobs[0][: max(len(blobs[0]) // 2, 1)]
            conn.sendall(_LEN.pack(len(blobs[0])) + half)
            return False
        if fault is not None and fault.kind == "truncate":
            # deliver a *valid prefix* then sever: the adversarial input
            # the resume path must salvage (drop's bytes are mid-frame
            # garbage to the framing layer; truncate's parse as segments)
            frac = self.fault_plan.truncate_fraction(
                cid, chunks[0][0], chunks[0][1], attempt
            )
            k = max(1, int(len(blobs[0]) * frac))
            conn.sendall(_LEN.pack(len(blobs[0])) + blobs[0][:k])
            return False
        if req.get("straggle", True) and self.straggler_p > 0:
            key_chunk = chunks[0][0] if chunks else 0
            stall = keyed_straggler_delay(
                self.seed, key_chunk, int(req.get("attempt", 0)),
                p=self.straggler_p, scale_s=self.straggler_scale_s,
                alpha=self.straggler_alpha,
            )
            if stall > 0:
                time.sleep(stall)
        for blob in blobs:
            self._send_paced(conn, blob)
        return True

    def _send_paced(self, conn: socket.socket, blob: bytes) -> None:
        conn.sendall(_LEN.pack(len(blob)))
        if not self.pace_gbps:
            conn.sendall(blob)
            return
        # timed slices: ~5 ms of link time each, so cancellation (client
        # closing its socket) lands mid-stream, not between blobs
        bytes_per_s = self.pace_gbps * 1e9 / 8.0
        slice_bytes = max(1, int(bytes_per_s * 0.005))
        sent = 0
        t0 = time.perf_counter()
        while sent < len(blob):
            part = blob[sent : sent + slice_bytes]
            conn.sendall(part)
            sent += len(part)
            target = sent / bytes_per_s
            lag = target - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)

    def tier_stats(self) -> dict:
        """Per-tier hit/miss/demotion counters of the fronted store
        (``{}`` when the store is flat — no tiers, nothing to report)."""
        counters = getattr(self.store, "tier_counters", None)
        return dict(counters()) if callable(counters) else {}

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        self._closing.set()
        try:
            self._sock.close()
        except OSError:
            pass
        # persistent connections would otherwise outlive the server — a
        # pooled client socket must go stale when its server goes away
        with self._stats_lock:
            live = list(self._live_conns)
        for conn in live:
            try:
                conn.close()
            except OSError:
                pass

    def __enter__(self) -> "TcpStoreServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class _TcpAttempt:
    def __init__(self):
        self.sock: Optional[socket.socket] = None
        self.counter = [0]  # bytes received (mutable cell for _recv_exact)
        self.blobs: Optional[List[bytes]] = None
        self.error: Optional[BaseException] = None
        self.finished = threading.Event()
        self.cancelled = False
        self.pooled = False  # sock was checked out of the reuse pool
        # salvage state: payload bytes of a single-chunk fetch accumulate
        # here as frames drain, so a severed stream leaves its realized
        # prefix behind instead of vanishing with the exception
        self.blob_buf = bytearray()
        self.seg_index: Optional[SegmentIndex] = None
        self.range_offset = 0
        self.range_total = 0

    @property
    def bytes_read(self) -> int:
        return self.counter[0]

    def cancel(self) -> None:
        self.cancelled = True
        if self.sock is not None:
            try:
                self.sock.close()  # real cancellation: the stream dies now
            except OSError:
                pass


class _TcpHandle(FetchHandle):
    def __init__(self, attempts: List[_TcpAttempt], context_id=None, chunk_levels=None):
        super().__init__(context_id, chunk_levels)
        self._attempts = attempts

    def salvage_at(self, at_t: Optional[float] = None) -> Optional[Salvage]:
        # wall-clock transport: "now" is the only observable instant, so
        # at_t is advisory — the realized prefix is whatever has actually
        # drained off the socket into the primary attempt's buffer
        a = self._attempts[0]
        if not a.blob_buf:
            return None
        return Salvage(
            data=bytes(a.blob_buf),
            offset=a.range_offset,
            total=a.range_total,
            index=a.seg_index,
            nbytes_wire=float(len(a.blob_buf)),
        )

    def _abort(self) -> None:
        for a in self._attempts:
            a.cancel()


class TcpTransport:
    """Client for :class:`TcpStoreServer` with a connection-reuse pool.

    Each attempt runs on its own socket, but sockets whose exchange ends
    cleanly (frame-aligned) return to a pool and serve the next attempt —
    a retrying session no longer re-pays TCP setup per retry.  A pooled
    socket that went stale while idle is replaced by a fresh dial and the
    request replayed once (``n_reconnects``); sockets severed mid-stream
    (faults, cancellation, hedging losers) are closed, never pooled.
    ``tier_stats()`` reports the dial/reuse/reconnect counters.

    Timing is measured on the wire — ``end_t = start_t + wall`` and the
    observed throughput is realized bytes over realized seconds, so a
    session running over this transport estimates bandwidth from an actual
    link.  Hedging is an actual race: a second connection is opened
    ``hedge_after_s`` (real seconds) after the first if it hasn't finished,
    the first completion wins, and the loser's socket is closed mid-stream
    (``duplicate_bytes`` = the loser's realized byte counter).

    ``hash_lookup`` (optional, ``(context_id, chunk_idx) -> key | None``) is
    the client-side manifest for a content-addressed server: when it yields
    keys, the request frame carries them as ``hashes`` and the server reads
    by ``(hash, level)`` instead of the per-context catalog.  A lookup that
    answers None (or raises) for a chunk falls back to the context-keyed
    path for that entry — old servers ignore the extra field entirely.
    """

    realtime = True  # handles resolve on actual link time
    supports_range = True

    def __init__(
        self,
        host: str,
        port: int,
        *,
        connect_timeout_s: float = 5.0,
        io_timeout_s: float = 30.0,
        hash_lookup=None,
    ):
        self.host = host
        self.port = port
        self.connect_timeout_s = connect_timeout_s
        self.io_timeout_s = io_timeout_s
        self.hash_lookup = hash_lookup
        # connection reuse: sockets whose exchange completed cleanly are
        # pooled for the next attempt instead of re-paying TCP setup
        self._pool: List[socket.socket] = []
        self._pool_lock = threading.Lock()
        self.n_connects = 0  # fresh sockets dialed
        self.n_reconnects = 0  # stale pooled socket -> fresh dial + replay
        self.n_pool_reuses = 0  # attempts served on a pooled socket

    # -- connection pool ---------------------------------------------------

    def _checkout(self) -> Tuple[socket.socket, bool]:
        """A socket to run one request on: pooled if available, else a
        fresh dial.  Returns ``(sock, was_pooled)``."""
        with self._pool_lock:
            if self._pool:
                self.n_pool_reuses += 1
                return self._pool.pop(), True
            self.n_connects += 1
        sock = socket.create_connection(
            (self.host, self.port), timeout=self.connect_timeout_s
        )
        sock.settimeout(self.io_timeout_s)
        return sock, False

    def _checkin(self, sock: socket.socket) -> None:
        with self._pool_lock:
            self._pool.append(sock)

    def tier_stats(self) -> dict:
        """Client-side connection counters (mirrors the server's
        observability surface): fresh dials, pooled reuses, and reconnects
        forced by a stale pooled socket."""
        with self._pool_lock:
            return {
                "n_connects": self.n_connects,
                "n_reconnects": self.n_reconnects,
                "n_pool_reuses": self.n_pool_reuses,
            }

    def _hashes_for(
        self, context_id: str, chunk_levels: List[Tuple[int, int]]
    ) -> Optional[List[Optional[str]]]:
        if self.hash_lookup is None:
            return None
        hashes: List[Optional[str]] = []
        for ci, _lvl in chunk_levels:
            try:
                hashes.append(self.hash_lookup(context_id, ci))
            except Exception:
                hashes.append(None)
        return hashes if any(h is not None for h in hashes) else None

    @staticmethod
    def for_server(server: TcpStoreServer, **kw) -> "TcpTransport":
        return TcpTransport(server.address[0], server.address[1], **kw)

    def _run_attempt(
        self,
        attempt: _TcpAttempt,
        context_id: str,
        chunk_levels: List[Tuple[int, int]],
        attempt_idx: int,
        notify: Optional[threading.Event] = None,
        byte_range: Optional[Tuple[int, Optional[int]]] = None,
        resumable: bool = False,
    ) -> None:
        import msgpack

        clean = False
        try:
            try:
                self._exchange(
                    attempt, context_id, chunk_levels, attempt_idx,
                    msgpack, byte_range, resumable,
                )
            except (ConnectionError, OSError):
                # a pooled socket may have gone stale while idle (server
                # restarted, keepalive lapsed): if the failure hit before
                # any response bytes arrived, dial fresh and replay once
                if not (attempt.pooled and attempt.counter[0] == 0
                        and not attempt.cancelled):
                    raise
                with self._pool_lock:
                    self.n_reconnects += 1
                try:
                    attempt.sock.close()
                except OSError:
                    pass
                attempt.sock = None
                attempt.pooled = False
                self._exchange(
                    attempt, context_id, chunk_levels, attempt_idx,
                    msgpack, byte_range, resumable,
                )
            clean = True
        except BaseException as e:
            attempt.error = e
        finally:
            if attempt.sock is not None:
                if clean and not attempt.cancelled:
                    self._checkin(attempt.sock)  # reusable: frame-aligned
                else:
                    try:
                        attempt.sock.close()
                    except OSError:
                        pass
            attempt.finished.set()
            if notify is not None:
                notify.set()

    def _exchange(
        self,
        attempt: _TcpAttempt,
        context_id: str,
        chunk_levels: List[Tuple[int, int]],
        attempt_idx: int,
        msgpack,
        byte_range: Optional[Tuple[int, Optional[int]]],
        resumable: bool,
    ) -> None:
        sock, pooled = self._checkout()
        attempt.sock = sock
        attempt.pooled = pooled
        if attempt.cancelled:
            # cancel() landed while we were connecting (sock was None,
            # nothing to close then) — abort before requesting anything,
            # or the "cancelled" loser would stream the whole payload
            raise FetchError("attempt cancelled before request")
        req = {
            "cid": context_id,
            "chunks": [list(c) for c in chunk_levels],
            "straggle": attempt_idx == 0,
            "attempt": attempt_idx,
        }
        hashes = self._hashes_for(context_id, chunk_levels)
        if hashes is not None:
            req["hashes"] = hashes
        single = len(chunk_levels) == 1
        if byte_range is not None and single:
            off, ln = byte_range
            req["range"] = [int(off), int(ln) if ln else 0]
        if (resumable or byte_range is not None) and single:
            req["want_idx"] = True
        _send_frame(sock, msgpack.packb(req))
        header = msgpack.unpackb(_recv_frame(sock, attempt.counter), raw=False)
        if not header.get("ok"):
            raise KeyError(header.get("error", "storage error"))
        if "idx" in header:
            attempt.seg_index = SegmentIndex.from_wire(header["idx"])
        if "total" in header:
            attempt.range_total = int(header["total"])
            if byte_range is not None:
                attempt.range_offset = int(byte_range[0])
        # a pre-range server ignored the request keys and is streaming the
        # whole blob: "total" absent -> the payload starts at offset 0
        if single:
            blobs = [
                _recv_frame_into(sock, attempt.counter, attempt.blob_buf)
                for _ in header["sizes"]
            ]
        else:
            blobs = [
                _recv_frame(sock, attempt.counter) for _ in header["sizes"]
            ]
        attempt.blobs = blobs

    def fetch_run(
        self,
        context_id: str,
        chunk_levels: ChunkLevels,
        *,
        start_t: float = 0.0,
        hedge_after_s: Optional[float] = None,
        byte_range: Optional[Tuple[int, Optional[int]]] = None,
        resumable: bool = False,
    ) -> FetchHandle:
        chunk_levels = list(chunk_levels)
        if byte_range is not None and len(chunk_levels) != 1:
            raise ValueError("byte-range fetch is single-chunk only")
        if byte_range is not None:
            hedge_after_s = None  # a resumed suffix is never hedged
        primary = _TcpAttempt()
        attempts = [primary]
        handle = _TcpHandle(attempts, context_id, chunk_levels)

        def coordinate():
            t0 = time.perf_counter()
            any_finished = threading.Event()
            threading.Thread(
                target=self._run_attempt,
                args=(primary, context_id, chunk_levels, 0, any_finished,
                      byte_range, resumable),
                daemon=True,
            ).start()
            hedge: Optional[_TcpAttempt] = None
            if hedge_after_s is not None:
                if not primary.finished.wait(hedge_after_s):
                    if handle.done():  # cancelled while primary connected
                        primary.cancel()
                        return
                    hedge = _TcpAttempt()
                    attempts.append(hedge)
                    threading.Thread(
                        target=self._run_attempt,
                        args=(hedge, context_id, chunk_levels, 1, any_finished,
                              byte_range, resumable),
                        daemon=True,
                    ).start()
                    if handle.done():  # cancel() raced the hedge spawn
                        hedge.cancel()
            # race: first attempt to finish with blobs wins
            contenders = [a for a in attempts]
            winner: Optional[_TcpAttempt] = None
            while winner is None:
                winner = next(
                    (a for a in contenders
                     if a.finished.is_set() and a.blobs is not None),
                    None,
                )
                if winner is not None:
                    break
                if all(a.finished.is_set() for a in contenders):  # all failed
                    err = next(
                        (a.error for a in contenders if a.error is not None),
                        FetchError(
                            "all fetch attempts failed",
                            context_id=context_id,
                            chunk_levels=chunk_levels,
                        ),
                    )
                    handle._finish(None, err)
                    return
                any_finished.wait()
                any_finished.clear()
            wall = time.perf_counter() - t0
            loser = next((a for a in attempts if a is not winner), None)
            if loser is not None and not loser.finished.is_set():
                loser.cancel()
            nbytes = sum(len(b) for b in winner.blobs)
            # single snapshot of the loser's live counter: its recv loop may
            # still be draining buffered data as the socket dies
            loser_read = loser.bytes_read if loser is not None else 0
            handle._finish(FetchResult(
                blobs=winner.blobs,
                nbytes=nbytes,
                start_t=start_t,
                end_t=start_t + wall,
                throughput_gbps=nbytes * 8.0 / max(wall, 1e-9) / 1e9,
                hedged=winner is not primary,
                hedge_issued=hedge is not None,
                duplicate_bytes=float(loser_read),
                wall_s=wall,
                winner="primary" if winner is primary else "hedge",
                loser_cancelled=loser.cancelled if loser is not None else False,
                loser_bytes_read=loser_read,
                completion_order=tuple(ci for ci, _ in chunk_levels),
                seg_index=winner.seg_index,
                range_offset=winner.range_offset,
                range_total=winner.range_total,
            ))

        threading.Thread(target=coordinate, daemon=True).start()
        return handle

    def close(self) -> None:
        with self._pool_lock:
            pool, self._pool = self._pool, []
        for sock in pool:
            try:
                sock.close()
            except OSError:
                pass
