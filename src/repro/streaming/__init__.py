from repro.streaming.adaptation import TEXT, AdaptationPolicy, make_policy  # noqa: F401
from repro.streaming.calibration import measured_decode_bytes_per_s  # noqa: F401
from repro.streaming.network import BandwidthTrace, NetworkModel  # noqa: F401
from repro.streaming.pipeline import StreamResult, simulate_stream  # noqa: F401
from repro.streaming.storage import KVStore  # noqa: F401
from repro.streaming.streamer import (  # noqa: F401
    CacheGenStreamer,
    PlanSegment,
    RunSegmenter,
    segment_plan,
)
