from repro.streaming.adaptation import (  # noqa: F401
    TEXT,
    AdaptationPolicy,
    NoFeasibleConfigError,
    make_policy,
)
from repro.streaming.calibration import (  # noqa: F401
    measured_decode_bytes_per_s,
    measured_level_priorities,
)
from repro.streaming.faults import (  # noqa: F401
    Fault,
    FaultPlan,
    FaultyBackend,
    FaultyTransport,
    with_faulty_backend,
)
from repro.streaming.network import (  # noqa: F401
    BandwidthTrace,
    FetchOutcome,
    NetworkModel,
)
from repro.streaming.pipeline import StreamResult, simulate_stream  # noqa: F401
from repro.streaming.storage import (  # noqa: F401
    HASH_CHAIN_VERSION,
    DirectoryBackend,
    KVStore,
    MemoryBackend,
    StorageBackend,
    TieredKVStore,
    chain_hashes,
    token_payloads,
)
from repro.streaming.streamer import (  # noqa: F401
    CacheGenStreamer,
    PlanSegment,
    RunSegmenter,
    segment_plan,
)
from repro.streaming.transport import (  # noqa: F401
    FetchError,
    FetchHandle,
    FetchResult,
    LocalTransport,
    RetryPolicy,
    SimTransport,
    TcpStoreServer,
    TcpTransport,
    Transport,
    as_completed,
    classify_failure,
)
