"""zamba2-2.7b [hybrid] — 54L d2560 32H (kv=32) ff10240 vocab32000 ssm_state=64.

Mamba2 backbone with a weight-shared attention+MLP block applied every 6
layers.  [arXiv:2411.15242; hf-verified]

CacheGen applies to the shared-block KV caches (one per application);
Mamba2 layers carry no KV (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=80,  # d_inner / ssm_headdim = 5120 / 64
    ssm_headdim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=256,
    shared_block_every=6,
    norm="rmsnorm",
    mlp="gelu",
    supports_long_context=True,
)
