"""Registry of the 10 assigned architectures (``--arch <id>``).

Exact configs from the assignment sheet; source tags in each module docstring.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_ARCH_MODULES = [
    "qwen1_5_110b",
    "smollm_360m",
    "olmo_1b",
    "command_r_35b",
    "mamba2_370m",
    "qwen2_moe_a2_7b",
    "granite_moe_3b_a800m",
    "paligemma_3b",
    "seamless_m4t_large_v2",
    "zamba2_2_7b",
]

_BY_NAME: Dict[str, ArchConfig] = {}


def _load() -> None:
    if _BY_NAME:
        return
    for mod_name in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        cfg: ArchConfig = mod.CONFIG
        _BY_NAME[cfg.name] = cfg


def get(name: str) -> ArchConfig:
    _load()
    if name.endswith("-tiny"):
        return get(name[: -len("-tiny")]).tiny()
    if name not in _BY_NAME:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_BY_NAME)}")
    return _BY_NAME[name]


def names() -> List[str]:
    _load()
    return sorted(_BY_NAME)
