"""mamba2-370m [ssm] — 48L d1024, attention-free, ssm_state=128, vocab 50280.

SSD (state-space duality).  [arXiv:2405.21060; unverified]

CacheGen applicability: attention-free -> no KV cache; the paper's technique
does not apply (DESIGN.md §Arch-applicability).  Long-context shapes run.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=32,  # d_inner / ssm_headdim = 2048 / 64
    ssm_headdim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=256,
    norm="rmsnorm",
    supports_long_context=True,
    has_kv_cache=False,
)
