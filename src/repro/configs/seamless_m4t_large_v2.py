"""seamless-m4t-large-v2 [audio] — enc-dec, 24L d1024 16H (kv=16) ff8192
vocab256206.  [arXiv:2308.11596; hf-verified]

The speech frontend (conformer feature extractor) is a STUB: ``input_specs()``
provides precomputed frame embeddings.  "24L" names the per-stack depth of
the v2 text/unit model: 24 encoder + 24 decoder layers (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,  # total; enc/dec split below
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab_size=256206,
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    mlp_bias=True,
    frontend_dim=1024,
)
