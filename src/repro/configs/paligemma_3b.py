"""paligemma-3b [vlm] — 18L d2048 8H (MQA kv=1) ff16384 vocab257216.

SigLIP vision frontend is a STUB: ``input_specs()`` provides precomputed
patch embeddings; the gemma-style text backbone runs prefix-LM attention
(bidirectional over the image+prefix region).  [arXiv:2407.07726; hf-verified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab_size=257216,
    norm="rmsnorm",
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    n_prefix_tokens=256,  # 224px / patch 14 -> 256 patches
    frontend_dim=1152,  # SigLIP-So400m width
)
