"""smollm-360m [dense] — 32L d960 15H (GQA kv=5) ff2560 vocab49152, llama arch.

[hf:HuggingFaceTB/SmolLM-135M family; hf-verified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_head=64,
    d_ff=2560,
    vocab_size=49152,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=True,
)
