"""Architecture config schema covering all assigned families.

One frozen dataclass describes every selectable architecture
(``--arch <id>``); family-specific fields are zero/empty when unused.
``tiny()`` derives the reduced smoke-test variant of any config.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # block options
    qkv_bias: bool = False
    mlp_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    parallel_block: bool = False  # command-r style attn ∥ FFN
    rope_theta: float = 10_000.0
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_topk: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "global"  # global (one sort) | grouped (per-dp-shard)
    moe_groups: int = 32  # dispatch groups for "grouped" (>= dp shards)
    # replicate the d_model dim of expert weights (ff stays TP-sharded):
    # avoids GSPMD partial-sum all-reduces of (groups, E, cap, .) activations
    # when d is FSDP-sharded (§Perf iteration 3)
    moe_replicate_d: bool = False

    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # hybrid (zamba2): shared attention block applied every k SSM layers
    shared_block_every: int = 0

    # encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0

    # multimodal frontend stub
    n_prefix_tokens: int = 0  # image/frame prefix length for prefix-LM
    frontend_dim: int = 0  # precomputed embedding dim from the stub

    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # full | dots (dots_with_no_batch_dims_saveable)
    attention_impl: str = "xla"  # xla | pallas | pallas_interpret
    attn_chunk: int = 1024  # q-chunking for the xla prefill path
    shard_repeated_kv: bool = False  # constrain GQA-repeated K/V over heads
    # Store K pre-RoPE in the KV cache (rotated at attention-read).  RoPE's
    # position-dependent rotation destroys the token-locality the CacheGen
    # codec exploits (paper Insight 1); pre-RoPE caching restores it
    # (KIVI/KVQuant report the same effect).  Beyond-paper knob.
    prerope_kv_cache: bool = False
    scan_unroll: bool = False  # python-unroll layer loops (cost-analysis mode)

    # which shapes apply (per assignment skip rules)
    supports_long_context: bool = False  # sub-quadratic -> run long_500k
    has_kv_cache: bool = True  # False for pure SSM (codec inapplicable)

    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"):
            raise ValueError(f"unknown family {self.family}")
        if self.family not in ("ssm",) and self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def kv_channels(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab axis shards
        evenly on the production meshes (MaxText/Megatron-style padding)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    def tiny(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        scale = dict(
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 7),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2))
            if self.n_kv_heads < self.n_heads
            else 4,
            d_head=32,
            d_ff=256,
            vocab_size=512,
            rope_theta=self.rope_theta,
            remat=False,
        )
        if self.family in ("moe",):
            scale.update(n_experts=8, n_shared_experts=min(self.n_shared_experts, 2),
                         moe_topk=min(self.moe_topk, 2), d_ff=64)
        if self.family in ("ssm", "hybrid"):
            # d_inner = ssm_expand * d_model = 256 -> 8 heads x 32
            scale.update(ssm_state=16, ssm_heads=8, ssm_headdim=32, ssm_chunk=16)
        if self.family == "hybrid":
            scale.update(shared_block_every=3)
        if self.family == "encdec":
            scale.update(enc_layers=2, dec_layers=2)
        if self.family == "vlm":
            scale.update(n_prefix_tokens=8, frontend_dim=64)
        if self.frontend_dim:
            scale.setdefault("frontend_dim", 64)
        return dataclasses.replace(self, name=self.name + "-tiny", **scale)
