"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) expert-ff512 vocab49155.

MoE: 40 experts top-8 (fine-grained).
[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf-verified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    n_shared_experts=0,
    moe_topk=8,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=True,
)
