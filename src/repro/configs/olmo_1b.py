"""olmo-1b [dense] — 16L d2048 16H (kv=16) ff8192 vocab50304, non-parametric LN.

[arXiv:2402.00838; hf-verified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparam_ln",
    mlp="swiglu",
    tie_embeddings=True,
)
