"""qwen2-moe-a2.7b [moe] — 24L d2048 16H (kv=16) expert-ff1408 vocab151936.

MoE: 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf-verified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab_size=151936,
    n_experts=60,
    n_shared_experts=4,
    moe_topk=4,
    norm="rmsnorm",
    mlp="swiglu",
)
