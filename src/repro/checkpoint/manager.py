"""Fault-tolerant checkpointing: atomic, versioned, resharding-aware.

Write protocol (survives kill -9 at any instant):
  1. serialize the pytree into ``step_<N>.tmp-<nonce>/`` (one .npy per leaf,
     path-keyed; metadata.json holds the treedef + step);
  2. fsync files, then atomically ``rename`` the directory to ``step_<N>``;
  3. update ``LATEST`` via write-temp + rename.
Restore never sees a partial checkpoint: only renamed directories count.

Resharding/elasticity: leaves are stored as *global* arrays; ``restore``
device_puts them against whatever shardings the current mesh wants, so a run
can resume on a different topology (tested in tests/test_checkpoint.py).
Retention keeps the newest K checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import uuid
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "/"

# numpy can't round-trip ml_dtypes (bfloat16, fp8) through np.save reliably;
# store them as raw byte views and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16", "float8_e4m3fn", "float8_e5m2"}


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(np.uint8), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree: Any) -> str:
        flat = _flatten(tree)
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for key, arr in flat.items():
            fname = key.replace(_SEP, "__") + ".npy"
            path = os.path.join(tmp, fname)
            storable, dtype_name = _to_storable(arr)
            with open(path, "wb") as f:
                np.save(f, storable)
                f.flush()
                os.fsync(f.fileno())
            manifest[key] = {
                "file": fname,
                "dtype": dtype_name,
                "shape": list(arr.shape),
            }
        meta = {"step": step, "leaves": manifest}
        with open(os.path.join(tmp, "metadata.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)  # atomic publish
        self._update_latest(final)
        self._gc()
        return final

    def _update_latest(self, final: str) -> None:
        latest = os.path.join(self.dir, "LATEST")
        tmp = latest + f".tmp-{uuid.uuid4().hex[:8]}"
        with open(tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, latest)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp-" not in name:
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            if os.path.isdir(os.path.join(self.dir, name)):
                return int(name[5:])
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, step: int, target_like: Any, shardings: Any = None
    ) -> Any:
        """Restore into the structure of ``target_like``.

        ``shardings``: optional matching pytree of jax.sharding.Sharding —
        leaves are device_put against them (cross-topology resume).
        """
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "metadata.json")) as f:
            meta = json.load(f)
        flat_target, tdef = jax.tree_util.tree_flatten_with_path(target_like)
        shard_leaves = None
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
            )
        leaves = []
        for i, (kpath, like) in enumerate(flat_target):
            key = _SEP.join(_path_str(p) for p in kpath)
            info = meta["leaves"][key]
            arr = _from_storable(np.load(os.path.join(path, info["file"])), info["dtype"])
            arr = arr.reshape(info["shape"])
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {like.shape}")
            if shard_leaves is not None:
                leaves.append(jax.device_put(arr.astype(like.dtype), shard_leaves[i]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target_like), leaves
        )
