from repro.data.synthetic import MarkovLM, TopicRetrievalTask, sample_lengths  # noqa: F401
