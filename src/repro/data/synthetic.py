"""Synthetic data substrate.

Two generators:

* :class:`MarkovLM` — a sparse order-1 Markov "language" with Zipfian branch
  probabilities.  Low entropy -> a tiny transformer learns real structure in
  a few hundred steps, which matters because the KV-codec claims (token-wise
  locality, channel-grouped entropy) are properties of *trained* models'
  caches.

* :class:`TopicRetrievalTask` — the LongChat-style probe ("What was the
  first topic we discussed?"): a long context containing topic segments,
  each introduced by a distinctive marker n-gram; the query asks for the
  first topic and accuracy = exact retrieval of the topic id token.  Context
  lengths are drawn to match the paper's Table 2 distributions (median /
  std / P95 per dataset preset).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["MarkovLM", "TopicRetrievalTask", "TABLE2_PRESETS", "sample_lengths"]

# Paper Table 2 context-length stats (median, std, p95) in tokens.
TABLE2_PRESETS: Dict[str, Tuple[float, float, float]] = {
    "longchat": (9400, 164, 9600),
    "triviaqa": (9300, 4497, 15000),
    "narrativeqa": (14000, 1916, 15000),
    "wikitext": (5900, 4548, 14800),
}


def sample_lengths(
    rng: np.random.Generator, preset: str, n: int, scale: float = 1.0
) -> np.ndarray:
    """Draw context lengths matching a Table 2 preset (optionally scaled
    down for CPU-sized experiments, preserving shape)."""
    med, std, p95 = TABLE2_PRESETS[preset]
    raw = rng.normal(med, std, size=n)
    raw = np.clip(raw, med - 2 * std, p95 * 1.02)
    return np.maximum((raw * scale).astype(np.int64), 16)


@dataclasses.dataclass
class MarkovLM:
    vocab_size: int
    branching: int = 8
    zipf_a: float = 1.3
    stickiness: float = 0.0  # P(repeat previous token) — local coherence,
    # mirroring natural text's burstiness (matters for KV token locality)
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, B = self.vocab_size, self.branching
        self.successors = rng.integers(0, V, size=(V, B))
        p = (1.0 / np.arange(1, B + 1) ** self.zipf_a)
        self.probs = p / p.sum()

    def sample(self, rng: np.random.Generator, n_tokens: int, start: Optional[int] = None) -> np.ndarray:
        out = np.empty(n_tokens, dtype=np.int32)
        tok = int(rng.integers(0, self.vocab_size)) if start is None else start
        branch = rng.choice(self.branching, size=n_tokens, p=self.probs)
        stay = (
            rng.uniform(size=n_tokens) < self.stickiness
            if self.stickiness > 0
            else np.zeros(n_tokens, bool)
        )
        for i in range(n_tokens):
            if not stay[i]:
                tok = int(self.successors[tok, branch[i]])
            out[i] = tok
        return out

    def batches(
        self, rng: np.random.Generator, batch: int, seq: int
    ) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            toks = np.stack([self.sample(rng, seq + 1) for _ in range(batch)])
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class TopicRetrievalTask:
    """LongChat-style first-topic retrieval over a synthetic language."""

    lm: MarkovLM
    n_topics: int = 8
    topic_span: int = 3  # marker + topic-id + marker2
    query_len: int = 4

    def __post_init__(self):
        V = self.lm.vocab_size
        # reserve the top of the vocab for markers / topic ids / query tokens
        self.marker = V - 1
        self.query_start = V - 2
        self.topic_ids = np.arange(V - 2 - self.n_topics, V - 2)

    def make_context(
        self, rng: np.random.Generator, n_tokens: int
    ) -> Tuple[np.ndarray, int]:
        """Returns (context tokens (n_tokens,), first_topic_id)."""
        n_seg = self.n_topics
        seg_len = max((n_tokens - self.query_len) // n_seg, self.topic_span + 4)
        topics = rng.permutation(self.topic_ids)[:n_seg]
        parts: List[np.ndarray] = []
        for t in topics:
            filler = self.lm.sample(rng, seg_len - self.topic_span)
            parts.append(np.array([self.marker, t, self.marker], dtype=np.int32))
            parts.append(filler)
        ctx = np.concatenate(parts)
        need = n_tokens - self.query_len
        if ctx.shape[0] < need:  # segment rounding shortfall -> pad with filler
            ctx = np.concatenate([ctx, self.lm.sample(rng, need - ctx.shape[0])])
        ctx = ctx[:need]
        query = np.full(self.query_len, self.query_start, dtype=np.int32)
        return np.concatenate([ctx, query]).astype(np.int32), int(topics[0])

    def training_batches(
        self, rng: np.random.Generator, batch: int, seq: int
    ) -> Iterator[Dict[str, np.ndarray]]:
        """Mixed LM + retrieval batches: the answer token follows the query."""
        while True:
            toks = np.empty((batch, seq + 1), np.int32)
            for b in range(batch):
                ctx, topic = self.make_context(rng, seq)
                toks[b, :-1] = ctx
                toks[b, -1] = topic
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def answer_of(self, tokens: np.ndarray) -> int:
        """Ground truth for a generated context (first topic id)."""
        idx = np.argmax(tokens == self.marker)
        return int(tokens[idx + 1])
