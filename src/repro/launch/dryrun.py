import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for the
single-pod (16 data x 16 model = 256 chips) and multi-pod (2 pods x 16 x 16
= 512 chips) meshes, each cell's step function must lower and compile under
GSPMD; we record memory_analysis (fits?), cost_analysis (FLOPs/bytes for
§Roofline) and the collective traffic parsed from the optimized HLO.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --jobs 4 --mesh both --out results/dryrun
Each --all child runs in its own process (fresh XLA, isolated failures).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402


def _reduced_pair(cfg):
    """Two reduced-depth *unrolled* configs + (k1, k2, K) in layer units.

    XLA cost_analysis counts while-loop bodies once, so per-layer costs come
    from unrolled depth-1 / depth-2 compiles and linear extrapolation
    F(K) = F(k1) + (F(k2) - F(k1)) * (K - k1) / (k2 - k1), which is exact for
    homogeneous stacks (all of ours are, per segment/stack).
    """
    import dataclasses

    if cfg.family == "hybrid":
        e = cfg.shared_block_every
        c1 = dataclasses.replace(cfg, n_layers=e, scan_unroll=True)
        c2 = dataclasses.replace(cfg, n_layers=2 * e, scan_unroll=True)
        return c1, c2, (1, 2, cfg.n_layers // e)
    if cfg.family == "encdec":
        c1 = dataclasses.replace(
            cfg, n_layers=2, enc_layers=1, dec_layers=1, scan_unroll=True
        )
        c2 = dataclasses.replace(
            cfg, n_layers=4, enc_layers=2, dec_layers=2, scan_unroll=True
        )
        return c1, c2, (1, 2, cfg.enc_layers)
    import dataclasses as dc

    c1 = dc.replace(cfg, n_layers=1, scan_unroll=True)
    c2 = dc.replace(cfg, n_layers=2, scan_unroll=True)
    return c1, c2, (1, 2, cfg.n_layers)


def _cell_metrics(cfg, shape, mesh, overrides, n_chips, donate=False):
    """Lower+compile one config; return (flops, transcendentals, bytes, coll)."""
    import jax

    from repro.launch import hlo_stats
    from repro.launch.specs import make_cell
    from repro.models import sharding as shlib

    cell = make_cell(cfg, shape, mesh, overrides)
    with mesh, shlib.use_rules(mesh, cell.rules):
        compiled = (
            jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate if donate else (),
            )
            .lower(*cell.inputs)
            .compile()
        )
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    st = hlo_stats.collective_stats(compiled.as_text(), n_chips)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "wire_bytes": dict(st.wire_bytes),
        "counts": dict(st.counts),
    }


def _extrapolate(m1, m2, k1, k2, K):
    def lin(a, b):
        return a + (b - a) * (K - k1) / (k2 - k1)

    out = {
        "flops": lin(m1["flops"], m2["flops"]),
        "transcendentals": lin(m1["transcendentals"], m2["transcendentals"]),
        "bytes": lin(m1["bytes"], m2["bytes"]),
        "wire_bytes": {
            k: lin(m1["wire_bytes"][k], m2["wire_bytes"][k]) for k in m1["wire_bytes"]
        },
        "counts": {
            k: lin(m1["counts"][k], m2["counts"][k]) for k in m1["counts"]
        },
    }
    out["total_wire_bytes"] = float(sum(out["wire_bytes"].values()))
    return out


def run_cell(
    arch: str,
    shape: str,
    mesh_kind: str,
    rules_json: str = "",
    save_hlo: str = "",
    cfg_json: str = "",
    donate: bool = False,
) -> dict:
    import dataclasses

    import jax

    from repro.configs import registry
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import cell_applicable, make_cell
    from repro.models import sharding as shlib

    cfg = registry.get(arch)
    if cfg_json:
        cfg = dataclasses.replace(cfg, **json.loads(cfg_json))
    ok, why = cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    overrides = json.loads(rules_json) if rules_json else {}
    overrides = {k: (tuple(v) if isinstance(v, list) else v) for k, v in overrides.items()}
    try:
        cell = make_cell(cfg, shape, mesh, overrides)
        t0 = time.time()
        with mesh, shlib.use_rules(mesh, cell.rules):
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate if donate else (),
            )
            lowered = jitted.lower(*cell.inputs)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)

        # ---- memory analysis (proves it fits) ----
        try:
            ma = compiled.memory_analysis()
            mem = {}
            for attr in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            ):
                if hasattr(ma, attr):
                    mem[attr] = int(getattr(ma, attr))
            if not mem:
                mem["repr"] = str(ma)
            rec["memory"] = mem
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}

        # ---- cost analysis (FLOPs / bytes for the roofline) ----
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["cost"] = {
                k: float(v)
                for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals", "optimal_seconds")
                    or k.startswith("bytes accessed")
                )
            }
        except Exception as e:  # pragma: no cover
            rec["cost"] = {"error": str(e)}

        # ---- collectives from optimized HLO ----
        hlo = compiled.as_text()
        st = hlo_stats.collective_stats(hlo, n_chips)
        rec["collectives"] = {
            "counts": st.counts,
            "result_bytes": st.result_bytes,
            "wire_bytes": st.wire_bytes,
            "total_wire_bytes": st.total_wire_bytes,
        }
        rec["hlo_lines"] = hlo.count("\n")
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)

        # ---- depth-extrapolated metrics (scan bodies counted once by XLA;
        #      see _reduced_pair) ----
        try:
            c1, c2, (k1, k2, K) = _reduced_pair(cfg)
            m1 = _cell_metrics(c1, shape, mesh, overrides, n_chips, donate)
            m2 = _cell_metrics(c2, shape, mesh, overrides, n_chips, donate)
            rec["extrapolated"] = _extrapolate(m1, m2, k1, k2, K)
            rec["extrapolated"]["points"] = {"k1": k1, "k2": k2, "K": K, "m1": m1, "m2": m2}
        except Exception as e:
            rec["extrapolated"] = {"error": f"{type(e).__name__}: {e}"}
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--rules", default="", help="JSON logical-rule overrides")
    ap.add_argument("--cfg", default="", help="JSON ArchConfig field overrides")
    ap.add_argument("--donate", action="store_true", help="donate state/cache buffers")
    ap.add_argument("--tag", default="", help="suffix for the output file name")
    ap.add_argument("--save-hlo", default="", help="dump optimized HLO to file")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if not args.all:
        assert args.arch and args.shape, "--arch and --shape required"
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for mk in meshes:
            rec = run_cell(
                args.arch, args.shape, mk, args.rules, args.save_hlo, args.cfg,
                donate=args.donate,
            )
            tag = f".{args.tag}" if args.tag else ""
            fname = f"{args.arch}.{args.shape}.{mk}{tag}.json"
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(rec, f, indent=1)
            brief = {
                k: rec.get(k)
                for k in ("arch", "shape", "mesh", "status", "error", "compile_s")
            }
            print(json.dumps(brief))
        return

    # --all: spawn one subprocess per cell
    from repro.launch.specs import all_cells

    jobs = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch, shape, (ok, why) in all_cells():
        for mk in meshes:
            jobs.append((arch, shape, mk, ok, why))

    running = []
    results = []

    def _drain(block: bool):
        while running and (block or any(p.poll() is not None for p, *_ in running)):
            for item in list(running):
                p, arch, shape, mk = item
                if p.poll() is not None:
                    running.remove(item)
                    results.append((arch, shape, mk, p.returncode))
                    print(f"[dryrun] done {arch} {shape} {mk} rc={p.returncode}")
            if running and block:
                time.sleep(2.0)
            elif not block:
                break

    for arch, shape, mk, ok, why in jobs:
        if not ok:
            rec = {"arch": arch, "shape": shape, "mesh": mk, "status": "skipped", "reason": why}
            fname = f"{arch}.{shape}.{mk}.json"
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[dryrun] skip {arch} {shape} {mk}: {why[:60]}")
            continue
        while len(running) >= args.jobs:
            _drain(block=True)
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mk, "--out", args.out,
        ]
        if args.rules:
            cmd += ["--rules", args.rules]
        if args.cfg:
            cmd += ["--cfg", args.cfg]
        if args.donate:
            cmd += ["--donate"]
        if args.tag:
            cmd += ["--tag", args.tag]
        p = subprocess.Popen(cmd, env=os.environ.copy())
        running.append((p, arch, shape, mk))
    _drain(block=True)
    n_fail = sum(1 for *_, rc in results if rc != 0)
    print(f"[dryrun] all done: {len(results)} ran, {n_fail} subprocess failures")


if __name__ == "__main__":
    main()
