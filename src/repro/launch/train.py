"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the selected architecture's *reduced* config on local devices (this
container: 1 CPU core) or lowers the full config against the production mesh
with ``--dryrun``.  The full-scale path is exercised by launch/dryrun.py;
this driver is the runnable end-to-end loop (checkpointed, preemption-safe).
"""
from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.configs import registry
    from repro.data import MarkovLM
    from repro.models import build
    from repro.training import AdamWConfig, Trainer

    cfg = registry.get(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    model = build(cfg)
    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=0)

    def batch_fn(step):
        rng = np.random.default_rng(31_337 + step)
        toks = np.stack([lm.sample(rng, args.seq + 1) for _ in range(args.batch)])
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "encdec":
            batch["src_embeds"] = rng.normal(
                size=(args.batch, args.seq, cfg.frontend_dim)
            ).astype(np.float32)
        if cfg.family == "vlm":
            batch["patch_embeds"] = rng.normal(
                size=(args.batch, cfg.n_prefix_tokens, cfg.frontend_dim)
            ).astype(np.float32)
        return batch

    ck = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    tr = Trainer(
        model=model,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1)),
        batch_fn=batch_fn,
        ckpt=ck,
        ckpt_every=max(args.steps // 4, 1),
        grad_compression=args.grad_compression,
        log_every=10,
    )
    state = tr.init_or_restore(0)
    state, hist = tr.run(state, args.steps)
    print(f"[launch.train] {cfg.name}: loss {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f}")


if __name__ == "__main__":
    main()
