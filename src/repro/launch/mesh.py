"""Production mesh construction (assignment-mandated shapes).

Defined as functions so importing this module never touches jax device
state (device count is locked on first jax init — the dry-run sets
XLA_FLAGS before importing anything).

Besides the assignment meshes, :func:`make_serving_mesh` builds the
single-axis ``("data",)`` mesh the sharded serving engine
(``serving.mesh_engine.ShardedEngine``) partitions its batch-of-requests
cache over: one shard of cache rows per device.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_serving_mesh", "make_test_mesh"]


def _device_inventory() -> str:
    """Human-readable current device census for error messages."""
    devices = jax.devices()
    kinds: dict = {}
    for d in devices:
        kinds[d.platform] = kinds.get(d.platform, 0) + 1
    census = ", ".join(f"{n}x {k}" for k, n in sorted(kinds.items()))
    return f"{len(devices)} visible ({census})"


def _require_devices(n: int, shape: Tuple[int, ...], axes: Sequence[str]):
    """First ``n`` devices, or a RuntimeError naming the exact remediation.

    The remediation string is the actual flag to export — device count is
    locked on first jax init, so it must land in the environment before any
    jax import (the dry-run and the CI multi-device job both do this).
    """
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {tuple(shape)} with axes "
            f"{tuple(axes)}, have {_device_inventory()}. Remediation: export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"the first jax import (an already-initialized backend cannot "
            f"grow its device count)"
        )
    return devices[:n]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = _require_devices(n, shape, axes)
    try:
        return jax.make_mesh(shape, axes, devices=devices)
    except TypeError:  # older signature without devices kwarg
        if len(jax.devices()) == n:
            return jax.make_mesh(shape, axes)
        arr = np.asarray(devices).reshape(shape)
        return Mesh(arr, axes)


def make_test_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Small mesh for multi-device tests (8 host devices)."""
    n = data * model
    devices = _require_devices(n, (data, model), ("data", "model"))
    arr = np.asarray(devices).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def make_serving_mesh(data: int) -> Mesh:
    """One-axis ``("data",)`` mesh of ``data`` devices for row-sharded
    serving: the ShardedEngine splits its batch-of-requests cache's row axis
    across this axis (one shard of rows, one Transport, one contention
    domain per device)."""
    if data < 1:
        raise ValueError(f"make_serving_mesh needs data >= 1, got {data}")
    devices = _require_devices(data, (data,), ("data",))
    return Mesh(np.asarray(devices), ("data",))
