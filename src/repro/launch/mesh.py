"""Production mesh construction (assignment-mandated shapes).

Defined as functions so importing this module never touches jax device
state (device count is locked on first jax init — the dry-run sets
XLA_FLAGS before importing anything).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import)"
        )
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older signature without devices kwarg
        if len(devices) == n:
            return jax.make_mesh(shape, axes)
        arr = np.asarray(devices[:n]).reshape(shape)
        return Mesh(arr, axes)


def make_test_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Small mesh for multi-device tests (8 host devices)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    arr = np.asarray(devices[:n]).reshape(data, model)
    return Mesh(arr, ("data", "model"))
