"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up the engine for a (reduced) architecture, stores a context pool
through the CacheGen streamer, then serves a request loop — each request is
a live closed-loop :class:`~repro.serving.session.ServeSession`: per chunk
it measures realized throughput from the trace-driven fetch, picks the next
streaming configuration (Algorithm 1), decodes fetched bitstreams through
the fused batched path and recomputes TEXT chunks for real, then generates.
``--check-sim`` cross-checks every session's per-chunk decisions against the
offline simulator on the same trace (the differential invariant that
tests/test_session.py enforces).

``--concurrency N`` (N > 1) serves the requests in waves of N concurrent
context loads on the one shared engine via
:class:`~repro.serving.scheduler.ConcurrentScheduler` — each request keeps
its own trace/policy/clock, while decodes, cache insertions and TEXT
recomputes are batched across requests, and per-session compute charges are
stretched by the measured contention model.

``--arrivals`` switches from closed waves to *open-loop* serving (ISSUE 5):
requests arrive over virtual time (``poisson:RATE`` draws seeded
exponential inter-arrivals at RATE requests/s; ``trace:FILE`` reads one
ascending arrival time per line) and are admitted by the
:class:`~repro.serving.scheduler.ContinuousScheduler` the moment one of
``--rows`` cache rows frees — TTFT then includes queueing delay from
arrival.  ``--preempt`` additionally lets a waiting arrival evict a live
session whose in-flight fetch is known to land past its SLO deadline (plus
``--preempt-margin``): the straggler's fetch handle is cancelled, its
realized rows are suspended into a snapshot, and it resumes on the next
free row.

``--store tiered`` (ISSUE 7) swaps the flat context-keyed store for the
content-addressed :class:`~repro.streaming.storage.TieredKVStore`: chunks
are chain-hashed over the token prefix (shared document prefixes dedup
across contexts), a ``--hot-bytes``-bounded hot tier sits over the cold
tier (``--store-dir`` for an on-disk cold backend), eviction is level-aware
LRU with demotion write-through, and cold-tier hits report their slower
fetch timing to the session's throughput estimator.  Per-tier counters are
printed at exit; over ``--transport tcp`` the protocol carries the hash
keys and the server reads content-addressed.

``--transport`` picks the fetch path (ISSUE 4): ``sim`` (default) paces
real asynchronous store reads against the request's bandwidth trace —
simulator-differential, so ``--check-sim`` still holds; ``local`` reads the
store directly (wall-time link); ``tcp`` brings up an in-process
:class:`~repro.streaming.transport.TcpStoreServer` and fetches every
bitstream over an actual paced socket — the session's throughput estimator
then measures a real link, so ``--check-sim`` is meaningless there.
``--hedge-after S`` issues a duplicate fetch for any chunk still in flight
after S seconds; the loser is cancelled and its bytes are reported as
duplicate overhead.

``--fault-*`` injects seeded chaos into the fetch path (ISSUE 6):
``--fault-drop/-stall/-corrupt/-truncate`` perturb in-flight fetches (via
:class:`~repro.streaming.faults.FaultyTransport` on sim/local, server-side
on tcp; truncate delivers a valid prefix then severs), ``--fault-missing``
deletes store entries behind the readers' backs
(:func:`~repro.streaming.faults.with_faulty_backend`).  ``--retry N``
arms the session's :class:`~repro.streaming.transport.RetryPolicy`
(bounded attempts, backoff charged to the virtual clock, degrade to
coarser levels / TEXT unless ``--no-degrade``); without it, injected
faults reproduce the legacy crash-through behavior.

Byte-range resume (ISSUE 8): with ``--retry`` armed, failed/cancelled
fetches keep their checksum-verified byte prefix and the next attempt
refetches only the missing suffix (same level) or only the coarser delta
suffix on degrade (the level-invariant anchor composes bit-exactly);
``--no-resume`` restores PR 6 whole-blob retries for comparison.
``--replan-factor F`` additionally cancels an in-flight chunk on the sim
transport once its realized duration exceeds F× the live-estimate
prediction (§C.1 mid-chunk re-planning).  Per-request output then carries
``salvaged``/``resumes``/``replans`` next to the PR 6 fault counters, and
the aggregate lines reconcile salvaged + refetched == wire bytes.

``--generate N`` (ISSUE 9, open-loop only) keeps each request on its
engine row after the context load completes and decodes N output tokens
*inside* the scheduler's event loop: every virtual step stacks all ready
generating rows into one batched ``Engine.decode_step_rows`` dispatch, so
generation contends with in-flight context loads exactly as Algorithm 1
sees it (``ContentionModel.gen_factor``).  ``--gen-slo S`` attaches a
per-output-token latency SLO, ``--sample-seed`` switches greedy argmax to
seeded sampling, ``--gen-step-ms`` sets the uncontended virtual step cost.
Per-request output gains ``gen=``/``tpot_mean=``; the aggregate line adds
mean/p95 TPOT and total generated tokens/s.  ``--generate 0`` (default)
is load-only and bit-identical to the PR 8 open-loop path.
"""
from __future__ import annotations

import argparse

import numpy as np


def _parse_arrivals(spec: str, n: int, seed: int):
    """``poisson:RATE`` (seeded exponential inter-arrivals) or
    ``trace:FILE`` (one ascending arrival time per line) -> n arrival
    instants on the virtual clock."""
    kind, _, val = spec.partition(":")
    if kind == "poisson":
        try:
            rate = float(val)
        except ValueError:
            raise SystemExit(f"--arrivals poisson:RATE needs a number, got {val!r}")
        if not rate > 0:  # also rejects nan
            raise SystemExit(f"--arrivals poisson rate must be > 0, got {rate}")
        rng = np.random.default_rng(seed)
        return np.cumsum(rng.exponential(1.0 / rate, size=n)).tolist()
    if kind == "trace":
        with open(val) as f:
            ts = [float(line) for line in f if line.strip()]
        if len(ts) < n:
            raise SystemExit(
                f"--arrivals trace:{val} has {len(ts)} arrivals, need {n}"
            )
        ts = ts[:n]
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise SystemExit(f"--arrivals trace:{val} times must be ascending")
        return ts
    raise SystemExit("--arrivals must be poisson:RATE or trace:FILE")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--ctx-len", type=int, default=300)
    ap.add_argument("--slo-ms", type=float, default=250)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--fixed-level", type=int, default=None,
                    help="pin one encoding level (no adaptation baseline)")
    ap.add_argument("--max-run-tokens", type=int, default=None,
                    help="double-buffer granularity for fetch/decode overlap")
    ap.add_argument("--check-sim", action="store_true",
                    help="cross-check session decisions against the simulator")
    ap.add_argument("--concurrency", type=int, default=1,
                    help="serve requests in waves of N concurrent context "
                         "loads batched on the shared engine")
    ap.add_argument("--arrivals", default=None, metavar="SPEC",
                    help="open-loop serving instead of closed waves: "
                         "'poisson:RATE' draws seeded exponential "
                         "inter-arrivals at RATE requests/s on the virtual "
                         "clock; 'trace:FILE' reads one ascending arrival "
                         "time (seconds) per line.  Requests are admitted "
                         "to the --rows row pool as rows free up, so TTFT "
                         "includes queueing delay from arrival")
    ap.add_argument("--rows", type=int, default=None,
                    help="--arrivals: row-pool capacity (concurrent context "
                         "loads resident on the engine; default: "
                         "--concurrency)")
    ap.add_argument("--preempt", action="store_true",
                    help="--arrivals: let a waiting arrival preempt a live "
                         "session whose in-flight fetch is known to land "
                         "past its SLO deadline — the fetch is cancelled "
                         "and the session's realized rows suspend into a "
                         "snapshot until a row frees again")
    ap.add_argument("--preempt-margin", type=float, default=0.0, metavar="S",
                    help="extra SLO overshoot (seconds) a pending fetch "
                         "must incur before its session is preemptible")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    help="seed for poisson:RATE arrival draws")
    ap.add_argument("--generate", type=int, default=0, metavar="N",
                    help="--arrivals: decode N output tokens per request on "
                         "the shared engine after its context load lands — "
                         "continuous batching: ready generating rows stack "
                         "into one decode_step_rows dispatch per virtual "
                         "step and contend with in-flight loads (0 = "
                         "load-only, bit-identical to the PR 8 path)")
    ap.add_argument("--gen-slo", type=float, default=None, metavar="S",
                    help="--generate: per-output-token latency SLO in "
                         "seconds (TPOT); EDF admission orders waiters by "
                         "start + SLO deadline")
    ap.add_argument("--sample-seed", type=int, default=None,
                    help="--generate: seeded softmax sampling instead of "
                         "greedy argmax (greedy stays bit-identical to the "
                         "generate_with_kv oracle)")
    ap.add_argument("--gen-step-ms", type=float, default=2.0,
                    help="--generate: uncontended virtual cost of one "
                         "stacked decode step (milliseconds)")
    ap.add_argument("--store", choices=("flat", "tiered"), default="flat",
                    help="storage layout: flat = context-keyed, keeps "
                         "everything forever; tiered = content-addressed "
                         "(chain-hashed token prefixes dedup across "
                         "contexts) with a capacity-bounded hot tier over "
                         "cold, level-aware LRU eviction, and cold-read "
                         "penalties fed to the throughput estimator")
    ap.add_argument("--hot-bytes", type=int, default=None, metavar="N",
                    help="--store tiered: hot-tier capacity in bytes "
                         "(default: never evict; 0 = everything cold)")
    ap.add_argument("--store-dir", default=None, metavar="DIR",
                    help="--store tiered: directory for the cold tier "
                         "(default: in-memory cold backend)")
    ap.add_argument("--transport", choices=("sim", "local", "tcp"),
                    default="sim",
                    help="fetch path: sim = trace-paced async reads "
                         "(simulator-differential), local = direct store "
                         "reads, tcp = real socket link to an in-process "
                         "store server")
    ap.add_argument("--hedge-after", type=float, default=None, metavar="S",
                    help="issue a duplicate (hedged) fetch for any chunk "
                         "still in flight after S seconds; the loser is "
                         "cancelled")
    ap.add_argument("--tcp-pace-gbps", type=float, default=0.2,
                    help="--transport tcp: server-side link pacing")
    ap.add_argument("--fault-drop", type=float, default=0.0, metavar="P",
                    help="probability a fetch attempt is dropped (link dies)")
    ap.add_argument("--fault-stall", type=float, default=0.0, metavar="P",
                    help="probability a fetch attempt stalls (Pareto tail)")
    ap.add_argument("--fault-corrupt", type=float, default=0.0, metavar="P",
                    help="probability a fetched payload is bit-flipped")
    ap.add_argument("--fault-truncate", type=float, default=0.0, metavar="P",
                    help="probability a fetch delivers a valid byte prefix "
                         "then severs (resumable with --retry)")
    ap.add_argument("--fault-missing", type=float, default=0.0, metavar="P",
                    help="probability a (chunk, level) entry is missing "
                         "from the store")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the deterministic fault plan")
    ap.add_argument("--fault-stall-scale", type=float, default=0.2,
                    metavar="S", help="injected stall scale (seconds)")
    ap.add_argument("--retry", type=int, default=0, metavar="N",
                    help="fault tolerance: total fetch attempts per chunk "
                         "level (0 = legacy crash-through on any failure)")
    ap.add_argument("--retry-backoff", type=float, default=0.02, metavar="S",
                    help="--retry: initial exponential backoff (seconds)")
    ap.add_argument("--retry-timeout", type=float, default=None, metavar="S",
                    help="--retry: per-attempt timeout (virtual seconds on "
                         "sim, wall seconds on local/tcp)")
    ap.add_argument("--no-degrade", action="store_true",
                    help="--retry: fail the session once retries are "
                         "exhausted instead of falling back to coarser "
                         "levels / TEXT recompute")
    ap.add_argument("--no-resume", action="store_true",
                    help="--retry: discard verified byte prefixes and "
                         "refetch whole blobs on retry (PR 6 baseline)")
    ap.add_argument("--replan-factor", type=float, default=None, metavar="F",
                    help="sim transport: cancel an in-flight chunk whose "
                         "realized duration exceeds F x the live-estimate "
                         "prediction, salvage the verified prefix, and "
                         "re-decide the remainder (mid-chunk re-planning)")
    args = ap.parse_args()
    if args.concurrency < 1:
        raise SystemExit("--concurrency must be >= 1")
    if args.generate < 0:
        raise SystemExit("--generate must be >= 0")
    if args.generate and args.arrivals is None:
        raise SystemExit(
            "--generate requires --arrivals (continuous batching lives in "
            "the open-loop scheduler); closed waves still generate post-hoc "
            "via --gen"
        )

    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.core import codec as kvcodec
    from repro.data import MarkovLM
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv
    from repro.serving.session import ServeSession
    from repro.streaming import (
        BandwidthTrace,
        CacheGenStreamer,
        KVStore,
        NetworkModel,
    )
    from repro.streaming.adaptation import TEXT

    cfg = registry.get(args.arch).tiny()
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit(
            f"--arch {args.arch}: serve driver supports attention families "
            "(KV-cache streaming); see DESIGN.md §Arch-applicability"
        )
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, cache_capacity=args.ctx_len + 32 + args.generate)
    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    tokens = lm.sample(rng, args.ctx_len)[None]
    if cfg.family == "vlm":
        batch = {
            "tokens": jnp.asarray(tokens),
            "patch_embeds": jnp.asarray(
                rng.normal(size=(1, cfg.n_prefix_tokens, cfg.frontend_dim)),
                jnp.float32,
            ),
        }
    else:
        batch = {"tokens": jnp.asarray(tokens)}
    logits, caches = engine.calculate_kv(batch)
    n_cached = args.ctx_len + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    kv = caches_to_codec_kv(caches, 0, n_cached)
    tables = kvcodec.profile([kv], kvcodec.CodecConfig(precision=11))
    if args.store == "tiered":
        from repro.streaming import DirectoryBackend, TieredKVStore

        store = TieredKVStore(
            tables,
            hot_bytes=args.hot_bytes,
            cold=DirectoryBackend(args.store_dir) if args.store_dir else None,
        )
    else:
        store = KVStore(tables)
    streamer = CacheGenStreamer(store, cfg)
    store.store_kv(
        "ctx", kv, chunk_tokens=max(args.ctx_len // 4, 50),
        # canonical token-chain hashing when the KV rows are 1:1 with
        # text tokens; a vlm's prefix rows aren't, so hash KV bytes there
        tokens=tokens[0].tolist() if tokens.shape[1] == n_cached else None,
    )
    print(f"[serve] context stored: {store.storage_bytes('ctx')/1e3:.1f} KB all levels")

    # fetch path: sim (default, per-request trace pacing), local, or a real
    # in-process socket server with paced sends
    from repro.streaming import (
        FaultPlan,
        FaultyTransport,
        LocalTransport,
        RetryPolicy,
        SimTransport,
        TcpStoreServer,
        TcpTransport,
        with_faulty_backend,
    )

    fault_plan = None
    if (args.fault_drop or args.fault_stall or args.fault_corrupt
            or args.fault_truncate or args.fault_missing):
        fault_plan = FaultPlan(
            seed=args.fault_seed,
            drop_p=args.fault_drop,
            stall_p=args.fault_stall,
            corrupt_p=args.fault_corrupt,
            truncate_p=args.fault_truncate,
            missing_p=args.fault_missing,
            stall_scale_s=args.fault_stall_scale,
        )
        print(f"[serve] fault plan armed: {fault_plan}")
    # storage faults live behind the readers; in-flight faults wrap the
    # transport (sim/local) or run server-side (tcp)
    serve_store = (
        with_faulty_backend(store, fault_plan)
        if fault_plan is not None and args.fault_missing > 0
        else store
    )
    inflight_faults = fault_plan is not None and bool(
        args.fault_drop or args.fault_stall or args.fault_corrupt
        or args.fault_truncate
    )

    tcp_server = None
    transport = None  # sim: a SimTransport is built per request below
    if args.transport == "local":
        transport = LocalTransport(serve_store)
        if inflight_faults:
            transport = FaultyTransport(transport, fault_plan)
    elif args.transport == "tcp":
        tcp_server = TcpStoreServer(
            serve_store, pace_gbps=args.tcp_pace_gbps,
            fault_plan=fault_plan if inflight_faults else None,
        )
        transport = TcpTransport.for_server(
            tcp_server,
            # content-addressed protocol: the client sends hash keys when
            # the store has them, and the server reads by (hash, level)
            hash_lookup=getattr(serve_store, "try_hash", None),
        )
        print(f"[serve] tcp store server on {tcp_server.address} "
              f"paced at {args.tcp_pace_gbps} Gbps")

    def mk_transport(net):
        """Per-request fetch path with the fault plan applied."""
        if transport is not None:
            return transport
        if serve_store is store and not inflight_faults:
            return None  # default: SessionTask builds a clean SimTransport
        t = SimTransport(serve_store, net)
        return FaultyTransport(t, fault_plan) if inflight_faults else t

    retry_policy = None
    if args.retry >= 1:
        retry_policy = RetryPolicy(
            max_attempts=args.retry,
            backoff_s=args.retry_backoff,
            timeout_s=None if args.transport != "sim" else args.retry_timeout,
            wall_timeout_s=args.retry_timeout if args.transport != "sim" else None,
            degrade=not args.no_degrade,
        )
        print(f"[serve] retry policy armed: {retry_policy}")

    recompute_s = lambda t, p: 0.02 * t / 64  # noqa: E731
    session = ServeSession(
        streamer,
        engine,
        slo_s=args.slo_ms / 1e3,
        recompute_s=recompute_s,
        decode_bytes_per_s=300e6,
        allow_text=(cfg.family != "vlm"),
        fixed_level=args.fixed_level,
        max_run_tokens=args.max_run_tokens,
        hedge_after_s=args.hedge_after,
        transport=transport,
        retry_policy=retry_policy,
        resume_fetch=not args.no_resume,
        replan_factor=args.replan_factor,
    )

    def close_server():
        counters = getattr(serve_store, "tier_counters", None)
        if callable(counters):
            c = counters()
            print(
                f"[serve] tiered store: hot_hits={c['hot_hits']} "
                f"cold_hits={c['cold_hits']} misses={c['misses']} "
                f"demotions={c['demotions']} evictions={c['evictions']} "
                f"dedup_chunks={c['dedup_chunks']} "
                f"hot={c['hot_used_bytes']/1e3:.1f}/"
                f"{min(c['hot_capacity_bytes'], 1 << 40)/1e3:.1f} KB "
                f"unique={c['unique_bytes']/1e3:.1f} KB"
            )
        if tcp_server is None:
            return
        tcp_server.close()
        if fault_plan is not None:
            print(
                f"[serve] tcp server: conns={tcp_server.n_connections} "
                f"dropped={tcp_server.n_dropped_connections} "
                f"malformed={tcp_server.n_malformed} "
                f"injected={tcp_server.n_injected_faults}"
            )
        stats = getattr(transport, "tier_stats", None)
        if callable(stats):
            s = stats()
            print(
                f"[serve] tcp client: connects={s.get('n_connects', 0)} "
                f"reconnects={s.get('n_reconnects', 0)} "
                f"pool_reuses={s.get('n_pool_reuses', 0)}"
            )

    names = {TEXT: "TEXT"}

    def describe(r, res, extra=""):
        fault = ""
        if retry_policy is not None or fault_plan is not None:
            fault = (
                f" retries={res.n_retries} degrades={res.n_degrades} "
                f"faults={res.fault_counts}"
            )
            if retry_policy is not None:
                fault += (
                    f" salvaged={res.salvaged_bytes/1e3:.1f}KB "
                    f"resumes={res.n_resumes} "
                    f"replans={res.n_mid_chunk_replans}"
                )
        if res.failed:
            print(
                f"[req {r}] FAILED ({res.failure}) "
                f"configs={[names.get(c, f'L{c}') for c in res.configs]}"
                + fault + extra
            )
            return
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        gen = engine.generate_with_kv(res.caches, first, args.gen)
        hedge = (
            f" hedged={res.n_hedged} dup={res.duplicate_bytes/1e3:.1f}KB"
            if args.hedge_after is not None else ""
        )
        print(
            f"[req {r}] configs={[names.get(c, f'L{c}') for c in res.configs]} "
            f"ttft={res.ttft_s*1e3:.1f} ms ok={not res.slo_violated} "
            f"runs={res.n_runs} wall_decode={res.wall_decode_s*1e3:.1f} ms "
            f"tokens={gen[0].tolist()}" + hedge + fault + extra
        )

    def check_sim(res, trace, prior):
        if not args.check_sim:
            return ""
        plan = streamer.stream(
            "ctx", NetworkModel(trace, rtt_s=0.002), slo_s=args.slo_ms / 1e3,
            decode_bytes_per_s=300e6, recompute_s=recompute_s,
            prior_throughput_gbps=prior, allow_text=(cfg.family != "vlm"),
            fixed_level=args.fixed_level, hedge_after_s=args.hedge_after,
        )
        return f" sim_match={res.configs == plan.result.configs}"

    if args.arrivals is not None:
        from repro.serving.generation import GenerationSpec
        from repro.serving.scheduler import (
            ContinuousScheduler,
            PreemptionPolicy,
            SessionRequest,
        )

        arrivals = _parse_arrivals(args.arrivals, args.requests, args.arrival_seed)
        traces = [
            BandwidthTrace.sampled(rng, 6, 0.05, 0.05, 2.0)
            for _ in range(args.requests)
        ]
        gen_spec = None
        if args.generate:
            # first decode input = the context prefill's TTFT token
            first_tok = int(jnp.argmax(logits[0, -1]))
            gen_spec = GenerationSpec(
                n_tokens=args.generate,
                first_token=first_tok,
                gen_slo_s=args.gen_slo,
                sample_seed=args.sample_seed,
            )
        scheduler = ContinuousScheduler(
            engine,
            rows=args.rows if args.rows is not None else args.concurrency,
            preemption=(
                PreemptionPolicy(margin_s=args.preempt_margin)
                if args.preempt else None
            ),
            gen_step_s=args.gen_step_ms / 1e3,
        )
        nets = [NetworkModel(tr, rtt_s=0.002) for tr in traces]
        out = scheduler.run([
            SessionRequest(
                session, "ctx", tokens, net,
                prior_throughput_gbps=float(tr.gbps[0]), start_t=arr,
                transport=mk_transport(net),
                generation=gen_spec,
            )
            for tr, net, arr in zip(traces, nets, arrivals)
        ])
        for r, (res, tl) in enumerate(zip(out.sessions, out.timeline)):
            extra = (
                f" arrival={tl.arrival_t*1e3:.0f}ms wait={tl.queue_wait_s*1e3:.0f}ms"
                + (f" preempted={tl.n_preemptions}x" if tl.n_preemptions else "")
            )
            if tl.n_tokens_out:
                extra += (
                    f" gen={tl.n_tokens_out}tok"
                    f" tpot_mean={tl.mean_tpot_s*1e3:.2f}ms"
                )
            describe(r, res, extra)
        ttfts = sorted(s.ttft_s for s in out.sessions)
        p = lambda q: ttfts[min(int(q * len(ttfts)), len(ttfts) - 1)]  # noqa: E731
        resume = ""
        if retry_policy is not None:
            resume = (
                f" salvaged={sum(s.salvaged_bytes for s in out.sessions)/1e3:.1f}KB"
                f" fetch_resumes={sum(s.n_resumes for s in out.sessions)}"
                f" replans={sum(s.n_mid_chunk_replans for s in out.sessions)}"
            )
        print(
            f"[open-loop rows={out.n_rows}] ttft p50={p(0.5)*1e3:.1f} ms "
            f"p95={p(0.95)*1e3:.1f} ms preemptions={out.n_preemptions} "
            f"resumes={out.n_resumes} rounds={out.n_rounds} "
            f"decode_batches={out.n_decode_batches} "
            f"peak_rows={max(n for _, n in out.occupancy)} "
            f"failed={out.n_failed}" + resume
        )
        if out.n_gen_tokens:
            tpots = sorted(
                d for tl in out.timeline for d in tl.tpot_s
            )
            pq = lambda q: tpots[min(int(q * len(tpots)), len(tpots) - 1)]  # noqa: E731
            agg = (
                out.n_gen_tokens / out.wall_gen_s if out.wall_gen_s > 0
                else float("nan")
            )
            peak_gen = max((n for _, n in out.gen_occupancy), default=0)
            print(
                f"[generation tokens={out.n_gen_tokens}] "
                f"tpot mean={sum(tpots)/len(tpots)*1e3:.2f} ms "
                f"p95={pq(0.95)*1e3:.2f} ms "
                f"agg {agg:.1f} tok/s steps={out.n_gen_steps} "
                f"peak_gen_rows={peak_gen}"
            )
        close_server()
        return

    if args.concurrency == 1:
        for r in range(args.requests):
            trace = BandwidthTrace.sampled(rng, 6, 0.05, 0.05, 2.0)
            prior = float(trace.gbps[0])
            net = NetworkModel(trace, rtt_s=0.002)
            res = session.run(
                "ctx",
                tokens,
                net,
                prior_throughput_gbps=prior,
                transport=mk_transport(net),
            )
            describe(r, res, check_sim(res, trace, prior))
        close_server()
        return

    from repro.serving.scheduler import ConcurrentScheduler, SessionRequest

    if args.check_sim:
        # the offline simulator has no contention model, so comparing its
        # decisions is only meaningful with contention charging disabled
        # (factor 1 at any N); without --check-sim, waves use the measured
        # contention model and decisions legitimately diverge from the
        # uncontended simulator under load
        from repro.streaming.pipeline import ContentionModel

        scheduler = ConcurrentScheduler(
            engine, contention=ContentionModel({1: 1.0, 2: 1.0})
        )
    else:
        scheduler = ConcurrentScheduler(engine)
    served = 0
    while served < args.requests:
        wave = min(args.concurrency, args.requests - served)
        traces = [BandwidthTrace.sampled(rng, 6, 0.05, 0.05, 2.0) for _ in range(wave)]
        nets = [NetworkModel(tr, rtt_s=0.002) for tr in traces]
        out = scheduler.run([
            SessionRequest(
                session, "ctx", tokens, net,
                prior_throughput_gbps=float(tr.gbps[0]),
                transport=mk_transport(net),
            )
            for tr, net in zip(traces, nets)
        ])
        for i, res in enumerate(out.sessions):
            describe(served + i, res, check_sim(res, traces[i], float(traces[i].gbps[0])))
        resume = ""
        if retry_policy is not None:
            resume = (
                f" salvaged={sum(s.salvaged_bytes for s in out.sessions)/1e3:.1f}KB"
                f" fetch_resumes={sum(s.n_resumes for s in out.sessions)}"
                f" replans={sum(s.n_mid_chunk_replans for s in out.sessions)}"
            )
        print(
            f"[wave of {wave}] decode_batches={out.n_decode_batches} "
            f"text_batches={out.n_text_batches} runs={out.n_runs} "
            f"wall_total={out.wall_total_s*1e3:.1f} ms failed={out.n_failed}"
            + resume
        )
        served += wave
    close_server()


if __name__ == "__main__":
    main()
