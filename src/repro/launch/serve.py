"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up the engine for a (reduced) architecture, stores a context pool
through the CacheGen streamer, then serves a request loop — each request is
a live closed-loop :class:`~repro.serving.session.ServeSession`: per chunk
it measures realized throughput from the trace-driven fetch, picks the next
streaming configuration (Algorithm 1), decodes fetched bitstreams through
the fused batched path and recomputes TEXT chunks for real, then generates.
``--check-sim`` cross-checks every session's per-chunk decisions against the
offline simulator on the same trace (the differential invariant that
tests/test_session.py enforces).

``--concurrency N`` (N > 1) serves the requests in waves of N concurrent
context loads on the one shared engine via
:class:`~repro.serving.scheduler.ConcurrentScheduler` — each request keeps
its own trace/policy/clock, while decodes, cache insertions and TEXT
recomputes are batched across requests, and per-session compute charges are
stretched by the measured contention model.

``--arrivals`` switches from closed waves to *open-loop* serving (ISSUE 5):
requests arrive over virtual time (``poisson:RATE`` draws seeded
exponential inter-arrivals at RATE requests/s; ``trace:FILE`` reads one
ascending arrival time per line) and are admitted by the
:class:`~repro.serving.scheduler.ContinuousScheduler` the moment one of
``--rows`` cache rows frees — TTFT then includes queueing delay from
arrival.  ``--preempt`` additionally lets a waiting arrival evict a live
session whose in-flight fetch is known to land past its SLO deadline (plus
``--preempt-margin``): the straggler's fetch handle is cancelled, its
realized rows are suspended into a snapshot, and it resumes on the next
free row.

``--transport`` picks the fetch path (ISSUE 4): ``sim`` (default) paces
real asynchronous store reads against the request's bandwidth trace —
simulator-differential, so ``--check-sim`` still holds; ``local`` reads the
store directly (wall-time link); ``tcp`` brings up an in-process
:class:`~repro.streaming.transport.TcpStoreServer` and fetches every
bitstream over an actual paced socket — the session's throughput estimator
then measures a real link, so ``--check-sim`` is meaningless there.
``--hedge-after S`` issues a duplicate fetch for any chunk still in flight
after S seconds; the loser is cancelled and its bytes are reported as
duplicate overhead.
"""
from __future__ import annotations

import argparse

import numpy as np


def _parse_arrivals(spec: str, n: int, seed: int):
    """``poisson:RATE`` (seeded exponential inter-arrivals) or
    ``trace:FILE`` (one ascending arrival time per line) -> n arrival
    instants on the virtual clock."""
    kind, _, val = spec.partition(":")
    if kind == "poisson":
        try:
            rate = float(val)
        except ValueError:
            raise SystemExit(f"--arrivals poisson:RATE needs a number, got {val!r}")
        if not rate > 0:  # also rejects nan
            raise SystemExit(f"--arrivals poisson rate must be > 0, got {rate}")
        rng = np.random.default_rng(seed)
        return np.cumsum(rng.exponential(1.0 / rate, size=n)).tolist()
    if kind == "trace":
        with open(val) as f:
            ts = [float(line) for line in f if line.strip()]
        if len(ts) < n:
            raise SystemExit(
                f"--arrivals trace:{val} has {len(ts)} arrivals, need {n}"
            )
        ts = ts[:n]
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise SystemExit(f"--arrivals trace:{val} times must be ascending")
        return ts
    raise SystemExit("--arrivals must be poisson:RATE or trace:FILE")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--ctx-len", type=int, default=300)
    ap.add_argument("--slo-ms", type=float, default=250)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--fixed-level", type=int, default=None,
                    help="pin one encoding level (no adaptation baseline)")
    ap.add_argument("--max-run-tokens", type=int, default=None,
                    help="double-buffer granularity for fetch/decode overlap")
    ap.add_argument("--check-sim", action="store_true",
                    help="cross-check session decisions against the simulator")
    ap.add_argument("--concurrency", type=int, default=1,
                    help="serve requests in waves of N concurrent context "
                         "loads batched on the shared engine")
    ap.add_argument("--arrivals", default=None, metavar="SPEC",
                    help="open-loop serving instead of closed waves: "
                         "'poisson:RATE' draws seeded exponential "
                         "inter-arrivals at RATE requests/s on the virtual "
                         "clock; 'trace:FILE' reads one ascending arrival "
                         "time (seconds) per line.  Requests are admitted "
                         "to the --rows row pool as rows free up, so TTFT "
                         "includes queueing delay from arrival")
    ap.add_argument("--rows", type=int, default=None,
                    help="--arrivals: row-pool capacity (concurrent context "
                         "loads resident on the engine; default: "
                         "--concurrency)")
    ap.add_argument("--preempt", action="store_true",
                    help="--arrivals: let a waiting arrival preempt a live "
                         "session whose in-flight fetch is known to land "
                         "past its SLO deadline — the fetch is cancelled "
                         "and the session's realized rows suspend into a "
                         "snapshot until a row frees again")
    ap.add_argument("--preempt-margin", type=float, default=0.0, metavar="S",
                    help="extra SLO overshoot (seconds) a pending fetch "
                         "must incur before its session is preemptible")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    help="seed for poisson:RATE arrival draws")
    ap.add_argument("--transport", choices=("sim", "local", "tcp"),
                    default="sim",
                    help="fetch path: sim = trace-paced async reads "
                         "(simulator-differential), local = direct store "
                         "reads, tcp = real socket link to an in-process "
                         "store server")
    ap.add_argument("--hedge-after", type=float, default=None, metavar="S",
                    help="issue a duplicate (hedged) fetch for any chunk "
                         "still in flight after S seconds; the loser is "
                         "cancelled")
    ap.add_argument("--tcp-pace-gbps", type=float, default=0.2,
                    help="--transport tcp: server-side link pacing")
    args = ap.parse_args()
    if args.concurrency < 1:
        raise SystemExit("--concurrency must be >= 1")

    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.core import codec as kvcodec
    from repro.data import MarkovLM
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv
    from repro.serving.session import ServeSession
    from repro.streaming import (
        BandwidthTrace,
        CacheGenStreamer,
        KVStore,
        NetworkModel,
    )
    from repro.streaming.adaptation import TEXT

    cfg = registry.get(args.arch).tiny()
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit(
            f"--arch {args.arch}: serve driver supports attention families "
            "(KV-cache streaming); see DESIGN.md §Arch-applicability"
        )
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, cache_capacity=args.ctx_len + 32)
    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    tokens = lm.sample(rng, args.ctx_len)[None]
    if cfg.family == "vlm":
        batch = {
            "tokens": jnp.asarray(tokens),
            "patch_embeds": jnp.asarray(
                rng.normal(size=(1, cfg.n_prefix_tokens, cfg.frontend_dim)),
                jnp.float32,
            ),
        }
    else:
        batch = {"tokens": jnp.asarray(tokens)}
    logits, caches = engine.calculate_kv(batch)
    n_cached = args.ctx_len + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    kv = caches_to_codec_kv(caches, 0, n_cached)
    tables = kvcodec.profile([kv], kvcodec.CodecConfig(precision=11))
    store = KVStore(tables)
    streamer = CacheGenStreamer(store, cfg)
    store.store_kv("ctx", kv, chunk_tokens=max(args.ctx_len // 4, 50))
    print(f"[serve] context stored: {store.storage_bytes('ctx')/1e3:.1f} KB all levels")

    # fetch path: sim (default, per-request trace pacing), local, or a real
    # in-process socket server with paced sends
    from repro.streaming import LocalTransport, TcpStoreServer, TcpTransport

    tcp_server = None
    transport = None  # sim: SessionTask builds SimTransport per request
    if args.transport == "local":
        transport = LocalTransport(store)
    elif args.transport == "tcp":
        tcp_server = TcpStoreServer(store, pace_gbps=args.tcp_pace_gbps)
        transport = TcpTransport.for_server(tcp_server)
        print(f"[serve] tcp store server on {tcp_server.address} "
              f"paced at {args.tcp_pace_gbps} Gbps")

    recompute_s = lambda t, p: 0.02 * t / 64  # noqa: E731
    session = ServeSession(
        streamer,
        engine,
        slo_s=args.slo_ms / 1e3,
        recompute_s=recompute_s,
        decode_bytes_per_s=300e6,
        allow_text=(cfg.family != "vlm"),
        fixed_level=args.fixed_level,
        max_run_tokens=args.max_run_tokens,
        hedge_after_s=args.hedge_after,
        transport=transport,
    )

    names = {TEXT: "TEXT"}

    def describe(r, res, extra=""):
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        gen = engine.generate_with_kv(res.caches, first, args.gen)
        hedge = (
            f" hedged={res.n_hedged} dup={res.duplicate_bytes/1e3:.1f}KB"
            if args.hedge_after is not None else ""
        )
        print(
            f"[req {r}] configs={[names.get(c, f'L{c}') for c in res.configs]} "
            f"ttft={res.ttft_s*1e3:.1f} ms ok={not res.slo_violated} "
            f"runs={res.n_runs} wall_decode={res.wall_decode_s*1e3:.1f} ms "
            f"tokens={gen[0].tolist()}" + hedge + extra
        )

    def check_sim(res, trace, prior):
        if not args.check_sim:
            return ""
        plan = streamer.stream(
            "ctx", NetworkModel(trace, rtt_s=0.002), slo_s=args.slo_ms / 1e3,
            decode_bytes_per_s=300e6, recompute_s=recompute_s,
            prior_throughput_gbps=prior, allow_text=(cfg.family != "vlm"),
            fixed_level=args.fixed_level, hedge_after_s=args.hedge_after,
        )
        return f" sim_match={res.configs == plan.result.configs}"

    if args.arrivals is not None:
        from repro.serving.scheduler import (
            ContinuousScheduler,
            PreemptionPolicy,
            SessionRequest,
        )

        arrivals = _parse_arrivals(args.arrivals, args.requests, args.arrival_seed)
        traces = [
            BandwidthTrace.sampled(rng, 6, 0.05, 0.05, 2.0)
            for _ in range(args.requests)
        ]
        scheduler = ContinuousScheduler(
            engine,
            rows=args.rows if args.rows is not None else args.concurrency,
            preemption=(
                PreemptionPolicy(margin_s=args.preempt_margin)
                if args.preempt else None
            ),
        )
        out = scheduler.run([
            SessionRequest(
                session, "ctx", tokens, NetworkModel(tr, rtt_s=0.002),
                prior_throughput_gbps=float(tr.gbps[0]), start_t=arr,
                transport=transport,
            )
            for tr, arr in zip(traces, arrivals)
        ])
        for r, (res, tl) in enumerate(zip(out.sessions, out.timeline)):
            extra = (
                f" arrival={tl.arrival_t*1e3:.0f}ms wait={tl.queue_wait_s*1e3:.0f}ms"
                + (f" preempted={tl.n_preemptions}x" if tl.n_preemptions else "")
            )
            describe(r, res, extra)
        ttfts = sorted(s.ttft_s for s in out.sessions)
        p = lambda q: ttfts[min(int(q * len(ttfts)), len(ttfts) - 1)]  # noqa: E731
        print(
            f"[open-loop rows={out.n_rows}] ttft p50={p(0.5)*1e3:.1f} ms "
            f"p95={p(0.95)*1e3:.1f} ms preemptions={out.n_preemptions} "
            f"resumes={out.n_resumes} rounds={out.n_rounds} "
            f"decode_batches={out.n_decode_batches} "
            f"peak_rows={max(n for _, n in out.occupancy)}"
        )
        if tcp_server is not None:
            tcp_server.close()
        return

    if args.concurrency == 1:
        for r in range(args.requests):
            trace = BandwidthTrace.sampled(rng, 6, 0.05, 0.05, 2.0)
            prior = float(trace.gbps[0])
            res = session.run(
                "ctx",
                tokens,
                NetworkModel(trace, rtt_s=0.002),
                prior_throughput_gbps=prior,
            )
            describe(r, res, check_sim(res, trace, prior))
        if tcp_server is not None:
            tcp_server.close()
        return

    from repro.serving.scheduler import ConcurrentScheduler, SessionRequest

    if args.check_sim:
        # the offline simulator has no contention model, so comparing its
        # decisions is only meaningful with contention charging disabled
        # (factor 1 at any N); without --check-sim, waves use the measured
        # contention model and decisions legitimately diverge from the
        # uncontended simulator under load
        from repro.streaming.pipeline import ContentionModel

        scheduler = ConcurrentScheduler(
            engine, contention=ContentionModel({1: 1.0, 2: 1.0})
        )
    else:
        scheduler = ConcurrentScheduler(engine)
    served = 0
    while served < args.requests:
        wave = min(args.concurrency, args.requests - served)
        traces = [BandwidthTrace.sampled(rng, 6, 0.05, 0.05, 2.0) for _ in range(wave)]
        out = scheduler.run([
            SessionRequest(
                session, "ctx", tokens, NetworkModel(tr, rtt_s=0.002),
                prior_throughput_gbps=float(tr.gbps[0]),
                transport=transport,
            )
            for tr in traces
        ])
        for i, res in enumerate(out.sessions):
            describe(served + i, res, check_sim(res, traces[i], float(traces[i].gbps[0])))
        print(
            f"[wave of {wave}] decode_batches={out.n_decode_batches} "
            f"text_batches={out.n_text_batches} runs={out.n_runs} "
            f"wall_total={out.wall_total_s*1e3:.1f} ms"
        )
        served += wave
    if tcp_server is not None:
        tcp_server.close()


if __name__ == "__main__":
    main()
