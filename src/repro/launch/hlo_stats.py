"""Parse collective traffic out of optimized (post-SPMD) HLO text.

``cost_analysis()`` does not expose collective bytes, so the roofline's
collective term comes from summing the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction in ``compiled.as_text()`` (per-device program -> per-device
bytes).

Wire-byte model (ring algorithms, group size n):
  all-gather          result_bytes * (n-1)/n        (result = gathered)
  all-reduce          2 * result_bytes * (n-1)/n    (reduce-scatter + all-gather)
  reduce-scatter      result_bytes * (n-1)          (operand = result * n)
  all-to-all          result_bytes * (n-1)/n
  collective-permute  result_bytes
Group size is parsed from replica_groups; defaults to the mesh size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

__all__ = ["CollectiveStats", "collective_stats"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %ar = bf16[8,128]{1,0} all-reduce(...)  or tuple results
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|[\w\[\]{},\/#: ]+?)\s+"
    r"(" + "|".join(k.replace("-", r"\-") for k in _COLL_KINDS) + r")"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# replica_groups={{0,1},{2,3}} or replica_groups=[8,32]<=[256]...
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        first = m.group(1)
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]  # per-device result bytes per kind
    wire_bytes: Dict[str, float]  # modeled per-device wire bytes per kind

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    @property
    def total_result_bytes(self) -> int:
        return int(sum(self.result_bytes.values()))


def collective_stats(hlo_text: str, mesh_size: int) -> CollectiveStats:
    counts = {k: 0 for k in _COLL_KINDS}
    rbytes = {k: 0 for k in _COLL_KINDS}
    wbytes = {k: 0.0 for k in _COLL_KINDS}
    seen_started: set = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # avoid double counting async pairs (count the -start)
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        n = _group_size(line, mesh_size)
        counts[kind] += 1
        rbytes[kind] += b
        if kind == "all-reduce":
            w = 2.0 * b * (n - 1) / max(n, 1)
        elif kind == "all-gather":
            w = b * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            w = float(b) * (n - 1)
        elif kind == "all-to-all":
            w = b * (n - 1) / max(n, 1)
        else:  # collective-permute
            w = float(b)
        wbytes[kind] += w
    del seen_started
    return CollectiveStats(counts=counts, result_bytes=rbytes, wire_bytes=wbytes)
