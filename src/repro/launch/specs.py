"""Per-(arch x shape) dry-run cell definitions: input ShapeDtypeStructs,
sharding rules, and the step function to lower.

The four assigned input shapes (LM-family: seq_len x global_batch):
  train_4k     seq 4,096   batch 256   -> train_step
  prefill_32k  seq 32,768  batch 32    -> prefill
  decode_32k   seq 32,768  batch 128   -> serve_step (1 token + KV cache)
  long_500k    seq 524,288 batch 1     -> serve_step; sub-quadratic archs only

Family mapping (DESIGN.md §4): enc-dec splits seq into src/tgt halves; VLM
reserves ``n_prefix_tokens`` of the sequence for the (stubbed) image patch
embeddings; SSM/hybrid decode cells carry recurrent states instead of /
alongside KV caches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import encdec as encdec_lib
from repro.models import lm as lm_lib
from repro.models import sharding
from repro.models.common import Leaf
from repro.models.lm import Caches
from repro.training import optimizer as opt_lib
from repro.training.trainer import TrainState, make_train_step
from repro.models.model import build as build_model

__all__ = ["SHAPES", "Cell", "make_cell", "cell_applicable", "all_cells"]

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

_SDS = jax.ShapeDtypeStruct


def cell_applicable(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "full quadratic attention at 524K tokens — skipped per assignment "
            "(sub-quadratic archs only); see DESIGN.md §4"
        )
    return True, ""


@dataclasses.dataclass
class Cell:
    cfg: ArchConfig
    shape_name: str
    kind: str
    fn: Callable  # function to jit
    inputs: Tuple[Any, ...]  # ShapeDtypeStruct pytrees (positional)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    rules: Dict[str, Any]  # logical rule overrides used
    donate: Tuple[int, ...] = ()  # argnums donated (in-place state/caches)


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------


def _batch_specs(cfg: ArchConfig, seq: int, batch: int, train: bool) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if cfg.family == "encdec":
        s = seq // 2
        out["src_embeds"] = _SDS((batch, s, cfg.frontend_dim), jnp.bfloat16)
        out["tokens"] = _SDS((batch, s), jnp.int32)
        if train:
            out["labels"] = _SDS((batch, s), jnp.int32)
        return out
    if cfg.family == "vlm":
        t = seq - cfg.n_prefix_tokens
        out["patch_embeds"] = _SDS(
            (batch, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.bfloat16
        )
        out["tokens"] = _SDS((batch, t), jnp.int32)
        if train:
            out["labels"] = _SDS((batch, t), jnp.int32)
        return out
    out["tokens"] = _SDS((batch, seq), jnp.int32)
    if train:
        out["labels"] = _SDS((batch, seq), jnp.int32)
    return out


def _batch_shardings(batch_specs: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in batch_specs.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = sharding.named_sharding(logical)
    return out


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------


def _lm_cache_specs(cfg: ArchConfig, batch: int, seq: int):
    """(ShapeDtypeStruct caches, logical caches) for decode cells."""
    sds: Dict[str, Any] = dict(
        kv_k=None, kv_v=None, length=None, mamba_conv=None, mamba_ssm=None,
        shared_k=None, shared_v=None,
    )
    log: Dict[str, Any] = dict(sds)
    kv_logical = ("layers", "batch", "kv_seq_decode", "kv_heads", "head_dim")
    if cfg.family in ("dense", "moe", "vlm"):
        shp = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.d_head)
        sds["kv_k"] = _SDS(shp, jnp.bfloat16)
        sds["kv_v"] = _SDS(shp, jnp.bfloat16)
        log["kv_k"] = kv_logical
        log["kv_v"] = kv_logical
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.d_inner
        conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
        sds["mamba_conv"] = _SDS(
            (cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), jnp.bfloat16
        )
        sds["mamba_ssm"] = _SDS(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
            jnp.float32,
        )
        log["mamba_conv"] = ("layers", "batch", None, "ssm_inner")
        log["mamba_ssm"] = ("layers", "batch", "ssm_heads", None, "state")
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // cfg.shared_block_every
        shp = (n_apps, batch, seq, cfg.n_kv_heads, cfg.d_head)
        sds["shared_k"] = _SDS(shp, jnp.bfloat16)
        sds["shared_v"] = _SDS(shp, jnp.bfloat16)
        log["shared_k"] = kv_logical
        log["shared_v"] = kv_logical
    sds["length"] = _SDS((batch,), jnp.int32)
    log["length"] = ("batch",)
    caches = Caches(**sds)
    shardings = Caches(
        **{
            k: (sharding.named_sharding(v) if v is not None else None)
            for k, v in log.items()
        }
    )
    return caches, shardings


def _encdec_cache_specs(cfg: ArchConfig, batch: int, seq: int):
    s_dec = seq // 2
    s_src = seq // 2
    shp_self = (cfg.dec_layers, batch, s_dec, cfg.n_kv_heads, cfg.d_head)
    shp_cross = (cfg.dec_layers, batch, s_src, cfg.n_kv_heads, cfg.d_head)
    kv_logical = ("layers", "batch", "kv_seq_decode", "kv_heads", "head_dim")
    sds = encdec_lib.EncDecCaches(
        self_k=_SDS(shp_self, jnp.bfloat16),
        self_v=_SDS(shp_self, jnp.bfloat16),
        cross_k=_SDS(shp_cross, jnp.bfloat16),
        cross_v=_SDS(shp_cross, jnp.bfloat16),
        src_len=_SDS((batch,), jnp.int32),
        length=_SDS((batch,), jnp.int32),
    )
    ns = sharding.named_sharding
    shardings = encdec_lib.EncDecCaches(
        self_k=ns(kv_logical),
        self_v=ns(kv_logical),
        cross_k=ns(kv_logical),
        cross_v=ns(kv_logical),
        src_len=ns(("batch",)),
        length=ns(("batch",)),
    )
    return sds, shardings


# ---------------------------------------------------------------------------
# param/opt specs
# ---------------------------------------------------------------------------


def _param_sds_and_shardings(cfg: ArchConfig):
    mod = encdec_lib if cfg.family == "encdec" else lm_lib
    plan = mod.param_plan(cfg)
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    is_leaf = lambda x: isinstance(x, Leaf)
    sds = jax.tree_util.tree_map(
        lambda l: _SDS(l.shape, dtype), plan, is_leaf=is_leaf
    )
    shardings = jax.tree_util.tree_map(
        lambda l: sharding.named_sharding(l.logical), plan, is_leaf=is_leaf
    )
    return sds, shardings


def _train_state_specs(cfg: ArchConfig):
    p_sds, p_sh = _param_sds_and_shardings(cfg)
    f32 = lambda t: jax.tree_util.tree_map(
        lambda s: _SDS(s.shape, jnp.float32), t
    )
    state_sds = TrainState(
        params=p_sds,
        opt=opt_lib.OptState(mu=f32(p_sds), nu=f32(p_sds), step=_SDS((), jnp.int32)),
        ef_error=None,
        step=_SDS((), jnp.int32),
    )
    rep = sharding.named_sharding(())
    state_sh = TrainState(
        params=p_sh,
        opt=opt_lib.OptState(mu=p_sh, nu=p_sh, step=rep),
        ef_error=None,
        step=rep,
    )
    return state_sds, state_sh


# ---------------------------------------------------------------------------
# rule overrides per cell
# ---------------------------------------------------------------------------


def rules_for(cfg: ArchConfig, shape_name: str) -> Dict[str, Any]:
    rules: Dict[str, Any] = {}
    if shape_name == "long_500k":
        # batch=1: nothing to shard on dp -> shard sequence/state instead
        rules["batch"] = None
        rules["expert_cap"] = None
        rules["kv_seq_decode"] = ("data", "model")
        rules["state"] = "data"
    if cfg.family == "encdec" and shape_name in ("decode_32k",):
        rules["kv_seq_decode"] = "model"
    return rules


# ---------------------------------------------------------------------------
# cell builder
# ---------------------------------------------------------------------------


def make_cell(
    cfg: ArchConfig,
    shape_name: str,
    mesh,
    rule_overrides: Optional[Dict[str, Any]] = None,
) -> Cell:
    info = SHAPES[shape_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    rules = rules_for(cfg, shape_name)
    rules.update(rule_overrides or {})
    model = build_model(cfg)

    with sharding.use_rules(mesh, rules):
        if kind == "train":
            state_sds, state_sh = _train_state_specs(cfg)
            b_sds = _batch_specs(cfg, seq, batch, train=True)
            b_sh = _batch_shardings(b_sds)
            step = make_train_step(model, opt_lib.AdamWConfig())
            rep = sharding.named_sharding(())
            metrics_sh = {
                k: rep for k in ("ce", "aux", "loss", "grad_norm", "lr")
            }
            return Cell(
                cfg=cfg,
                shape_name=shape_name,
                kind=kind,
                fn=step,
                inputs=(state_sds, b_sds),
                in_shardings=(state_sh, b_sh),
                out_shardings=(state_sh, metrics_sh),
                rules=rules,
                donate=(0,),  # train state updated in place
            )

        if kind == "prefill":
            p_sds, p_sh = _param_sds_and_shardings(cfg)
            b_sds = _batch_specs(cfg, seq, batch, train=False)
            b_sh = _batch_shardings(b_sds)
            if cfg.family == "encdec":
                cache_sds, cache_sh = _encdec_cache_specs(cfg, batch, seq)
            else:
                cache_sds, cache_sh = _lm_cache_specs(cfg, batch, seq)
            logits_sh = sharding.named_sharding(("batch", None, "act_vocab"))

            def prefill_fn(params, b):
                return model.prefill(params, b)

            return Cell(
                cfg=cfg,
                shape_name=shape_name,
                kind=kind,
                fn=prefill_fn,
                inputs=(p_sds, b_sds),
                in_shardings=(p_sh, b_sh),
                out_shardings=(logits_sh, cache_sh),
                rules=rules,
            )

        # decode
        p_sds, p_sh = _param_sds_and_shardings(cfg)
        tok_sds = _SDS((batch, 1), jnp.int32)
        tok_sh = sharding.named_sharding(("batch", None))
        if cfg.family == "encdec":
            cache_sds, cache_sh = _encdec_cache_specs(cfg, batch, seq)
        else:
            cache_sds, cache_sh = _lm_cache_specs(cfg, batch, seq)
        logits_sh = sharding.named_sharding(("batch", None, "act_vocab"))

        def decode_fn(params, tokens, caches):
            return model.decode_step(params, tokens, caches)

        return Cell(
            cfg=cfg,
            shape_name=shape_name,
            kind=kind,
            fn=decode_fn,
            inputs=(p_sds, tok_sds, cache_sds),
            in_shardings=(p_sh, tok_sh, cache_sh),
            out_shardings=(logits_sh, cache_sh),
            rules=rules,
            donate=(2,),  # KV caches / recurrent states updated in place
        )


def all_cells():
    from repro.configs import registry

    for name in registry.names():
        cfg = registry.get(name)
        for shape_name in SHAPES:
            yield name, shape_name, cell_applicable(cfg, shape_name)
