"""Logical-axis sharding: one place that maps model axes onto mesh axes.

Model code names axes logically (``"batch"``, ``"heads"``, ``"mlp"`` ...);
the launcher installs a rule set for the current mesh via
:func:`use_rules`.  Outside any rule context every constraint is a no-op, so
single-device tests run the same code path.

Rule sets are plain dicts and are the main lever of the §Perf hillclimb —
changing a rule re-lowers the whole model under a different distribution
without touching model code.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "use_rules",
    "constrain",
    "logical_to_spec",
    "named_sharding",
    "current_mesh",
]

MeshAxes = Union[None, str, Tuple[str, ...]]

# Production rules for the (pod, data, model) / (data, model) meshes.
# Parameter axes ("embed", "heads", "mlp", ...) and activation axes
# ("act_*") are distinct so FSDP-style weight sharding over the data axis
# never leaks onto activations.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv": None,
    "act_mlp": "model",
    "act_vocab": "model",
    "expert_cap": ("pod", "data"),  # MoE dispatch buffer token-capacity dim
    # caches
    "kv_seq": None,  # prefill cache seq axis
    "kv_seq_decode": "model",  # decode cache sharded along sequence (SP)
    "kv_heads": None,
    # batch-of-requests serving cache: one row per live session, rows
    # split across the data axis (serving.mesh_engine.ShardedEngine derives
    # its shard_map specs from this rule via logical_to_spec)
    "cache_rows": ("pod", "data"),
    "head_dim": None,
    "state": None,
    # parameters
    "embed": "data",  # FSDP: weights gathered per layer
    "heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": None,  # expert counts (40/60) don't divide 16; TP via "mlp"
    "layers": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
    "conv": None,
    "frontend": None,
}

_STATE: dict = {"mesh": None, "rules": None}


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None):
    """Install (mesh, rules) for model tracing; restores previous on exit."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    # Drop references to mesh axes the mesh doesn't have (e.g. "pod" on the
    # single-pod mesh).
    have = set(mesh.axis_names)

    def _filt(v: MeshAxes) -> MeshAxes:
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in have else None
        kept = tuple(a for a in v if a in have)
        return kept if kept else None

    rules = {k: _filt(v) for k, v in rules.items()}
    prev = dict(_STATE)
    _STATE.update(mesh=mesh, rules=rules)
    try:
        yield
    finally:
        _STATE.update(prev)


def current_mesh() -> Optional[Mesh]:
    return _STATE["mesh"]


def logical_to_spec(logical: Sequence[Optional[str]]) -> P:
    rules = _STATE["rules"] or {}
    parts = []
    for name in logical:
        if name is None:
            parts.append(None)
        else:
            parts.append(rules.get(name))
    return P(*parts)


def named_sharding(logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    mesh = _STATE["mesh"]
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without rules.

    Axes whose mesh-shard product does not divide the dimension are dropped
    (replicated) — e.g. 15 attention heads on a 16-way model axis.  The
    sharding fallbacks taken this way are a §Perf hillclimb topic, not an
    error.
    """
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(
            f"constrain got {len(logical)} axes for rank-{x.ndim} array"
        )
    spec = list(logical_to_spec(logical))
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    for i, part in enumerate(spec):
        if part is None:
            continue
        names = (part,) if isinstance(part, str) else tuple(part)
        total = 1
        for n in names:
            total *= axis_size[n]
        if x.shape[i] % total:
            spec[i] = None
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
