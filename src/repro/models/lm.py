"""Unified decoder LM covering dense / moe / ssm / hybrid / vlm families.

Scan-over-layers with optional remat (keeps HLO small at 80 layers and
controls activation memory); hybrid (zamba2) runs segment loops: scan over
``shared_block_every`` Mamba2 layers, then the weight-shared attention block.

Public entry points (used by trainer, serving engine, and the dry-run):
  * param_plan / init_params
  * loss_fn(params, batch)                      — train step target
  * prefill(params, batch)                      — returns logits + caches
  * decode_step(params, tokens, caches)         — one-token serve step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import sharding
from repro.models.attention import attn_decode, attn_plan, attn_prefill
from repro.models.common import (
    Leaf,
    apply_norm,
    init_from_plan,
    maybe_scan,
    mlp_apply,
    mlp_plan,
    norm_plan,
    softmax_cross_entropy,
    specs_from_plan,
)
from repro.models.mamba2 import (
    Mamba2State,
    mamba2_decode,
    mamba2_plan,
    mamba2_prefill,
)
from repro.models.moe import moe_apply, moe_plan

__all__ = ["Caches", "param_plan", "init_params", "loss_fn", "prefill", "decode_step"]


class Caches(NamedTuple):
    """Serving caches; unused fields are None per family."""

    kv_k: Optional[jnp.ndarray]  # (L, B, S, Hkv, Dh)
    kv_v: Optional[jnp.ndarray]
    length: Optional[jnp.ndarray]  # (B,)
    mamba_conv: Optional[jnp.ndarray]  # (L, B, K-1, C)
    mamba_ssm: Optional[jnp.ndarray]  # (L, B, H, P, N)
    shared_k: Optional[jnp.ndarray]  # (n_apps, B, S, Hkv, Dh)  [zamba2]
    shared_v: Optional[jnp.ndarray]


def _empty_caches(**kw) -> Caches:
    base = dict(
        kv_k=None, kv_v=None, length=None, mamba_conv=None, mamba_ssm=None,
        shared_k=None, shared_v=None,
    )
    base.update(kw)
    return Caches(**base)


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------


def _stack_plan(plan: Dict[str, Any], n: int) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda leaf: Leaf((n,) + leaf.shape, ("layers",) + leaf.logical, leaf.init, leaf.scale),
        plan,
        is_leaf=lambda x: isinstance(x, Leaf),
    )


def _dense_layer_plan(cfg: ArchConfig) -> Dict[str, Any]:
    p: Dict[str, Any] = {"ln1": norm_plan(cfg.norm, cfg.d_model), "attn": attn_plan(cfg)}
    if not cfg.parallel_block:
        p["ln2"] = norm_plan(cfg.norm, cfg.d_model)
    if cfg.family == "moe":
        p["moe"] = moe_plan(cfg)
    else:
        p["mlp"] = mlp_plan(cfg.mlp, cfg.d_model, cfg.d_ff, cfg.mlp_bias)
    return p


def _shared_block_plan(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": norm_plan("rmsnorm", cfg.d_model),
        "attn": attn_plan(cfg),
        "ln2": norm_plan("rmsnorm", cfg.d_model),
        "mlp": mlp_plan(cfg.mlp, cfg.d_model, cfg.d_ff, cfg.mlp_bias),
    }


def param_plan(cfg: ArchConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.padded_vocab_size
    plan: Dict[str, Any] = {
        "embed": Leaf((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": norm_plan(cfg.norm, d),
    }
    if not cfg.tie_embeddings:
        plan["head"] = Leaf((d, V), ("embed", "vocab"))
    if cfg.family in ("dense", "moe", "vlm"):
        plan["layers"] = _stack_plan(_dense_layer_plan(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        lp = {"ln1": norm_plan(cfg.norm, d), "mamba": mamba2_plan(cfg)}
        plan["layers"] = _stack_plan(lp, cfg.n_layers)
    elif cfg.family == "hybrid":
        lp = {"ln1": norm_plan(cfg.norm, d), "mamba": mamba2_plan(cfg)}
        plan["layers"] = _stack_plan(lp, cfg.n_layers)
        plan["shared_block"] = _shared_block_plan(cfg)
    else:
        raise ValueError(f"lm.py does not build family {cfg.family}")
    if cfg.family == "vlm":
        plan["frontend_proj"] = Leaf((cfg.frontend_dim, d), ("frontend", "embed"))
    return plan


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    return init_from_plan(param_plan(cfg), key, dtype)


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return specs_from_plan(param_plan(cfg))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _dense_block_prefill(cfg, p, x, positions, prefix_len):
    h = apply_norm(cfg.norm, p["ln1"], x)
    attn_out, kv = attn_prefill(cfg, p["attn"], h, positions, prefix_len=prefix_len)
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        mlp_out = mlp_apply(cfg.mlp, p["mlp"], h)
        x = x + attn_out + mlp_out
    else:
        x = x + attn_out
        h2 = apply_norm(cfg.norm, p["ln2"], x)
        if cfg.family == "moe":
            mo, aux = moe_apply(cfg, p["moe"], h2)
            x = x + mo
        else:
            x = x + mlp_apply(cfg.mlp, p["mlp"], h2)
    return x, kv, aux


def _dense_block_decode(cfg, p, x, kc, vc, cache_len):
    h = apply_norm(cfg.norm, p["ln1"], x)
    attn_out, (kc, vc) = attn_decode(cfg, p["attn"], h, (kc, vc), cache_len)
    if cfg.parallel_block:
        x = x + attn_out + mlp_apply(cfg.mlp, p["mlp"], h)
    else:
        x = x + attn_out
        h2 = apply_norm(cfg.norm, p["ln2"], x)
        if cfg.family == "moe":
            mo, _ = moe_apply(cfg, p["moe"], h2)
            x = x + mo
        else:
            x = x + mlp_apply(cfg.mlp, p["mlp"], h2)
    return x, kc, vc


def _shared_block_prefill(cfg, p, x, positions):
    h = apply_norm("rmsnorm", p["ln1"], x)
    attn_out, kv = attn_prefill(cfg, p["attn"], h, positions)
    x = x + attn_out
    h2 = apply_norm("rmsnorm", p["ln2"], x)
    x = x + mlp_apply(cfg.mlp, p["mlp"], h2)
    return x, kv


def _shared_block_decode(cfg, p, x, kc, vc, cache_len):
    h = apply_norm("rmsnorm", p["ln1"], x)
    attn_out, (kc, vc) = attn_decode(cfg, p["attn"], h, (kc, vc), cache_len)
    x = x + attn_out
    h2 = apply_norm("rmsnorm", p["ln2"], x)
    x = x + mlp_apply(cfg.mlp, p["mlp"], h2)
    return x, kc, vc


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return sharding.constrain(x, "batch", "seq", "act_embed")


def _logits(cfg, params, x):
    x = apply_norm(cfg.norm, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ w
    if logits.ndim == 3:
        logits = sharding.constrain(logits, "batch", "seq", "act_vocab")
    return logits


def _assemble_input(cfg, params, batch):
    """Token (+ optional multimodal prefix) embedding.

    Returns (x, positions, prefix_len or None, n_prefix).
    """
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = _embed_tokens(cfg, params, tokens)
    n_prefix = 0
    prefix_len = None
    if cfg.family == "vlm":
        patches = batch["patch_embeds"]  # (B, n_img, frontend_dim)
        px = patches @ params["frontend_proj"]
        x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
        n_prefix = patches.shape[1]
        prefix_len = jnp.full((B,), n_prefix, jnp.int32)
    T = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    return x, positions, prefix_len, n_prefix


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------




def _remat(cfg, fn):
    """jax.checkpoint with the config-selected policy (perf hillclimb knob)."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)

def _run_layers_prefill(cfg, params, x, positions, prefix_len, initial: Optional[Caches] = None):
    """Returns (x, caches-without-length, aux)."""
    dtype = x.dtype

    if cfg.family in ("dense", "moe", "vlm"):

        def body(carry, p_l):
            h, aux = carry
            h, kv, aux_l = _dense_block_prefill(cfg, p_l, h, positions, prefix_len)
            return (h, aux + aux_l), kv

        body_fn = _remat(cfg, body)
        (x, aux), (ks, vs) = maybe_scan(
            body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"], cfg.scan_unroll
        )
        return x, _empty_caches(kv_k=ks, kv_v=vs), aux

    if cfg.family == "ssm":

        def body(carry, inp):
            h = carry
            p_l = inp[0]
            init_l = None
            if initial is not None:
                init_l = Mamba2State(conv=inp[1], ssm=inp[2])
            hn = apply_norm(cfg.norm, p_l["ln1"], h)
            out, st = mamba2_prefill(cfg, p_l["mamba"], hn, init_l)
            return h + out, (st.conv, st.ssm)

        body_fn = _remat(cfg, body)
        xs = (params["layers"],)
        if initial is not None:
            xs = (params["layers"], initial.mamba_conv, initial.mamba_ssm)
        x, (convs, ssms) = maybe_scan(body_fn, x, xs, cfg.scan_unroll)
        return x, _empty_caches(mamba_conv=convs, mamba_ssm=ssms), jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        every = cfg.shared_block_every
        L = cfg.n_layers
        n_segs, rem = divmod(L, every)
        sb = params["shared_block"]

        def mamba_body(carry, p_l):
            h = carry
            hn = apply_norm(cfg.norm, p_l["ln1"], h)
            out, st = mamba2_prefill(cfg, p_l["mamba"], hn, None)
            return h + out, (st.conv, st.ssm)

        mamba_fn = _remat(cfg, mamba_body)

        convs, ssms, sks, svs = [], [], [], []
        layer_tree = params["layers"]
        for s in range(n_segs):
            seg = jax.tree_util.tree_map(
                lambda a: jax.lax.slice_in_dim(a, s * every, (s + 1) * every, axis=0),
                layer_tree,
            )
            x, (cv, sm) = maybe_scan(mamba_fn, x, seg, cfg.scan_unroll)
            convs.append(cv)
            ssms.append(sm)
            x, kv = _shared_block_prefill(cfg, sb, x, positions)
            sks.append(kv[0])
            svs.append(kv[1])
        if rem:
            seg = jax.tree_util.tree_map(
                lambda a: jax.lax.slice_in_dim(a, n_segs * every, L, axis=0), layer_tree
            )
            x, (cv, sm) = maybe_scan(mamba_fn, x, seg, cfg.scan_unroll)
            convs.append(cv)
            ssms.append(sm)
        caches = _empty_caches(
            mamba_conv=jnp.concatenate(convs, axis=0),
            mamba_ssm=jnp.concatenate(ssms, axis=0),
            shared_k=jnp.stack(sks, axis=0),
            shared_v=jnp.stack(svs, axis=0),
        )
        return x, caches, jnp.zeros((), jnp.float32)

    raise ValueError(cfg.family)


def loss_fn(cfg: ArchConfig, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    x, positions, prefix_len, n_prefix = _assemble_input(cfg, params, batch)
    x, _, aux = _run_layers_prefill(cfg, params, x, positions, prefix_len)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = _logits(cfg, params, x)
    loss = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


def prefill(cfg: ArchConfig, params, batch, *, pad_to: Optional[int] = None):
    """Prefill the context; returns (last-token logits, Caches).

    ``pad_to``: allocate KV caches with this sequence capacity (>= T) so the
    serving engine can decode further tokens in place.
    """
    x, positions, prefix_len, n_prefix = _assemble_input(cfg, params, batch)
    B, T = x.shape[0], x.shape[1]
    x, caches, _ = _run_layers_prefill(cfg, params, x, positions, prefix_len)
    logits = _logits(cfg, params, x[:, -1:])
    length = jnp.full((B,), T, jnp.int32)
    cap = pad_to or T
    if caches.kv_k is not None:
        pad = cap - T
        if pad:
            pw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
            caches = caches._replace(
                kv_k=jnp.pad(caches.kv_k, pw), kv_v=jnp.pad(caches.kv_v, pw)
            )
        caches = caches._replace(
            kv_k=sharding.constrain(
                caches.kv_k, "layers", "batch", "kv_seq_decode", "kv_heads", "head_dim"
            ),
            kv_v=sharding.constrain(
                caches.kv_v, "layers", "batch", "kv_seq_decode", "kv_heads", "head_dim"
            ),
        )
    if caches.shared_k is not None:
        pad = cap - T
        if pad:
            pw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
            caches = caches._replace(
                shared_k=jnp.pad(caches.shared_k, pw),
                shared_v=jnp.pad(caches.shared_v, pw),
            )
    return logits, caches._replace(length=length)


def _extend_mha(q, kc, vc, cache_len, n_new):
    """Attention of a new chunk's queries vs (cache + itself already written).

    q: (B, Tc, Hq, D); kc/vc: (B, S_cap, Hkv, D) with the chunk already
    written at [cache_len, cache_len + Tc).  Causal within the chunk,
    full attention to the cache prefix.
    """
    B, Tc, Hq, D = q.shape
    Hkv = kc.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    kh = jnp.repeat(kc, rep, axis=2)
    vh = jnp.repeat(vc, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kh).astype(jnp.float32) * scale
    S = kc.shape[1]
    k_pos = jnp.arange(S)[None, None, :]
    q_limit = cache_len[:, None, None] + jnp.arange(Tc)[None, :, None] + 1
    mask = k_pos < q_limit  # (B, Tc, S)
    s = jnp.where(mask[:, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(vh.dtype), vh)


def masked_window_update(cache, new, start, width):
    """Commit ``new[:width]`` into ``cache[start : start + width]``.

    ``cache`` is (S, ...), ``new`` is (T, ...) with the same trailing dims,
    token axis leading; ``start``/``width`` are traced scalars.  The single
    shared implementation of the *shifted* read-merge-write window used by
    every masked per-row cache write (``prefill_extend`` with widths here;
    ``serving.kv_layout.insert_codec_runs`` vmaps it over layers too):
    ``dynamic_slice`` clamps the window start when ``start + T`` overhangs
    ``S``, so the merge is expressed in window coordinates — token ``j`` of
    ``new`` lives at window position ``j + shift``, and everything outside
    ``[shift, shift + width)`` keeps the current contents verbatim (a
    ``width == 0`` row is preserved exactly, even when its stale ``start``
    abuts capacity).  Requires only that the *committed* tokens fit:
    ``start + width <= S``.
    """
    T = new.shape[0]
    S = cache.shape[0]
    start_c = jnp.clip(start, 0, S - T)
    shift = start - start_c
    p = jnp.arange(T, dtype=jnp.int32)
    new_s = jnp.take(new, jnp.clip(p - shift, 0, T - 1), axis=0)
    keep = ((p >= shift) & (p < shift + width))[
        (...,) + (None,) * (cache.ndim - 1)
    ]
    cur = jax.lax.dynamic_slice(
        cache, (start_c,) + (0,) * (cache.ndim - 1), (T,) + cache.shape[1:]
    )
    merged = jnp.where(keep, new_s.astype(cache.dtype), cur)
    return jax.lax.dynamic_update_slice(
        cache, merged, (start_c,) + (0,) * (cache.ndim - 1)
    )


def prefill_extend(cfg: ArchConfig, params, tokens, caches: Caches,
                   widths=None):
    """Compute KV for a text chunk *given* earlier chunks' KV (paper fn. 6:
    the LLM recomputes a text-format chunk based on the previous chunks'
    received-and-decoded KV).  Supported for attention families; SSM uses
    ``prefill`` with an initial state instead.

    tokens: (B, Tc).  Returns (last logits, updated caches).

    ``widths`` (optional, (B,) int32 in [0, Tc]) masks the per-row cache
    write: row ``b`` commits only its first ``widths[b]`` tokens and its
    length advances by ``widths[b]``.  This is how the concurrent scheduler
    coalesces different requests' TEXT recomputes into one padded batched
    call — rows whose request has no TEXT chunk this round ride along with
    width 0 and their cache/length are untouched (their logits are garbage
    and must be ignored).  ``widths=None`` keeps the original full-width
    write path unchanged.
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"prefill_extend not supported for family {cfg.family}")
    from repro.models.attention import _project_qkv

    B, Tc = tokens.shape
    cache_len = caches.length
    x = _embed_tokens(cfg, params, tokens)
    positions = cache_len[:, None] + jnp.arange(Tc, dtype=jnp.int32)[None]

    if widths is not None:
        widths = widths.astype(jnp.int32)

    def _write(cache, new, i):
        if widths is None:
            return jax.vmap(
                lambda c, n, j: jax.lax.dynamic_update_slice_in_dim(
                    c, n, j, axis=0
                )
            )(cache, new, i)
        return jax.vmap(masked_window_update)(cache, new, i, widths)

    def body(h, xs):
        p_l, kc, vc = xs
        hn = apply_norm(cfg.norm, p_l["ln1"], h)
        q, k, v, k_pre = _project_qkv(cfg, p_l["attn"], hn, positions)
        k_wr = k_pre if cfg.prerope_kv_cache else k
        kc = _write(kc, k_wr.astype(kc.dtype), cache_len)
        vc = _write(vc, v.astype(vc.dtype), cache_len)
        if cfg.prerope_kv_cache:
            from repro.models.common import rope as _rope

            S = kc.shape[1]
            pos_grid = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S)
            )
            kc_read = _rope(kc, pos_grid, cfg.rope_theta)
        else:
            kc_read = kc
        o = _extend_mha(q, kc_read, vc, cache_len, Tc)
        attn_out = o.reshape(B, Tc, cfg.n_heads * cfg.d_head) @ p_l["attn"]["wo"]
        if cfg.parallel_block:
            h = h + attn_out + mlp_apply(cfg.mlp, p_l["mlp"], hn)
        else:
            h = h + attn_out
            h2 = apply_norm(cfg.norm, p_l["ln2"], h)
            if cfg.family == "moe":
                mo, _ = moe_apply(cfg, p_l["moe"], h2)
                h = h + mo
            else:
                h = h + mlp_apply(cfg.mlp, p_l["mlp"], h2)
        return h, (kc, vc)

    x, (kc, vc) = maybe_scan(
        body, x, (params["layers"], caches.kv_k, caches.kv_v), cfg.scan_unroll
    )
    logits = _logits(cfg, params, x[:, -1:])
    adv = Tc if widths is None else widths
    return logits, caches._replace(kv_k=kc, kv_v=vc, length=cache_len + adv)


def decode_step(cfg: ArchConfig, params, tokens, caches: Caches):
    """One-token step.  tokens (B, 1) -> (logits (B, 1, V), updated caches)."""
    B = tokens.shape[0]
    x = _embed_tokens(cfg, params, tokens)
    cache_len = caches.length

    if cfg.family in ("dense", "moe", "vlm"):

        def body(h, xs):
            p_l, kc, vc = xs
            h, kc, vc = _dense_block_decode(cfg, p_l, h, kc, vc, cache_len)
            return h, (kc, vc)

        x, (kc, vc) = maybe_scan(
        body, x, (params["layers"], caches.kv_k, caches.kv_v), cfg.scan_unroll
    )
        caches = caches._replace(kv_k=kc, kv_v=vc, length=cache_len + 1)

    elif cfg.family == "ssm":

        def body(h, xs):
            p_l, conv, ssm = xs
            hn = apply_norm(cfg.norm, p_l["ln1"], h)
            out, st = mamba2_decode(cfg, p_l["mamba"], hn, Mamba2State(conv, ssm))
            return h + out, (st.conv, st.ssm)

        x, (convs, ssms) = maybe_scan(
            body, x, (params["layers"], caches.mamba_conv, caches.mamba_ssm),
            cfg.scan_unroll,
        )
        caches = caches._replace(
            mamba_conv=convs, mamba_ssm=ssms, length=cache_len + 1
        )

    elif cfg.family == "hybrid":
        every = cfg.shared_block_every
        L = cfg.n_layers
        n_segs, rem = divmod(L, every)
        sb = params["shared_block"]

        def body(h, xs):
            p_l, conv, ssm = xs
            hn = apply_norm(cfg.norm, p_l["ln1"], h)
            out, st = mamba2_decode(cfg, p_l["mamba"], hn, Mamba2State(conv, ssm))
            return h + out, (st.conv, st.ssm)

        convs, ssms, sks, svs = [], [], [], []
        for s in range(n_segs):
            seg = jax.tree_util.tree_map(
                lambda a: jax.lax.slice_in_dim(a, s * every, (s + 1) * every, axis=0),
                params["layers"],
            )
            seg_conv = jax.lax.slice_in_dim(
                caches.mamba_conv, s * every, (s + 1) * every, axis=0
            )
            seg_ssm = jax.lax.slice_in_dim(
                caches.mamba_ssm, s * every, (s + 1) * every, axis=0
            )
            x, (cv, sm) = maybe_scan(body, x, (seg, seg_conv, seg_ssm), cfg.scan_unroll)
            convs.append(cv)
            ssms.append(sm)
            kc = caches.shared_k[s]
            vc = caches.shared_v[s]
            x, kc, vc = _shared_block_decode(cfg, sb, x, kc, vc, cache_len)
            sks.append(kc)
            svs.append(vc)
        if rem:
            seg = jax.tree_util.tree_map(
                lambda a: jax.lax.slice_in_dim(a, n_segs * every, L, axis=0),
                params["layers"],
            )
            seg_conv = jax.lax.slice_in_dim(caches.mamba_conv, n_segs * every, L, axis=0)
            seg_ssm = jax.lax.slice_in_dim(caches.mamba_ssm, n_segs * every, L, axis=0)
            x, (cv, sm) = maybe_scan(body, x, (seg, seg_conv, seg_ssm), cfg.scan_unroll)
            convs.append(cv)
            ssms.append(sm)
        caches = caches._replace(
            mamba_conv=jnp.concatenate(convs, 0),
            mamba_ssm=jnp.concatenate(ssms, 0),
            shared_k=jnp.stack(sks, 0),
            shared_v=jnp.stack(svs, 0),
            length=cache_len + 1,
        )
    else:
        raise ValueError(cfg.family)

    logits = _logits(cfg, params, x)
    return logits, caches
