"""Attention layer: plans + prefill/decode apply, with sequence-parallel decode.

Three execution paths:
  * prefill (Tq == Tk): chunked-q attention — ``xla`` (lax.map over q chunks,
    memory-bounded, clean HLO for the dry-run/roofline) or the Pallas flash
    kernel on TPU;
  * decode (Tq == 1 vs cache): plain einsum, or — when the installed sharding
    rules put the cache's sequence axis on a mesh axis ("kv_seq_decode") —
    an explicit shard_map flash-decode combine: per-shard partial
    (max, sumexp, acc) + 2-scalar psum (the DistAttention pattern,
    paper-related work [80]);
  * GQA throughout (n_kv_heads <= n_heads).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models import sharding
from repro.models.common import Leaf, rope

__all__ = ["attn_plan", "attn_prefill", "attn_decode", "chunked_mha"]


def attn_plan(cfg: ArchConfig) -> Dict[str, Leaf]:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": Leaf((d, hq * dh), ("embed", "heads")),
        "wk": Leaf((d, hkv * dh), ("embed", "kv_heads")),
        "wv": Leaf((d, hkv * dh), ("embed", "kv_heads")),
        "wo": Leaf((hq * dh, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = Leaf((hq * dh,), ("heads",), "zeros")
        p["bk"] = Leaf((hkv * dh,), ("kv_heads",), "zeros")
        p["bv"] = Leaf((hkv * dh,), ("kv_heads",), "zeros")
    return p


def _project_qkv(cfg: ArchConfig, p, x, positions):
    """Returns (q_roped, k_roped, v, k_pre_rope)."""
    B, T, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    q = rope(q, positions, cfg.rope_theta)
    k_pre = k
    k = rope(k, positions, cfg.rope_theta)
    q = sharding.constrain(q, "batch", "seq", "act_heads", "head_dim")
    k = sharding.constrain(k, "batch", "seq", "act_kv", "head_dim")
    v = sharding.constrain(v, "batch", "seq", "act_kv", "head_dim")
    return q, k, v, k_pre


def chunked_mha(
    q: jnp.ndarray,  # (B, Tq, Hq, D)
    k: jnp.ndarray,  # (B, Tk, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool,
    prefix_len: Optional[jnp.ndarray],
    chunk: int,
    shard_repeated_kv: bool = False,
) -> jnp.ndarray:
    """Memory-bounded attention: full Tk per q-chunk, f32 softmax."""
    B, Tq, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    kh = jnp.repeat(k, rep, axis=2)
    vh = jnp.repeat(v, rep, axis=2)
    if shard_repeated_kv:
        # shard the GQA-expanded K/V over the head axis so the repeat never
        # materializes replicated (baseline memory hotspot, §Perf)
        kh = sharding.constrain(kh, "batch", "seq", "act_heads", "head_dim")
        vh = sharding.constrain(vh, "batch", "seq", "act_heads", "head_dim")
    Tk = k.shape[1]
    chunk = min(chunk, Tq)
    n_chunks = -(-Tq // chunk)
    pad = n_chunks * chunk - Tq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qc = qp.reshape(B, n_chunks, chunk, Hq, D)

    k_pos = jnp.arange(Tk)

    def one_chunk(ci):
        qi = qc[:, ci]  # (B, chunk, Hq, D)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, kh).astype(jnp.float32) * scale
        if causal:
            q_pos = ci * chunk + jnp.arange(chunk) + (Tk - Tq)
            mask = k_pos[None, :] <= q_pos[:, None]  # (chunk, Tk)
            if prefix_len is not None:
                mask = mask[None] | (k_pos[None, None, :] < prefix_len[:, None, None])
                mask = mask[:, None]  # (B,1,chunk,Tk)
            else:
                mask = mask[None, None]
            s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w.astype(vh.dtype), vh)

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))  # (nc, B, chunk, Hq, D)
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * chunk, Hq, D)
    return out[:, :Tq]


def attn_prefill(
    cfg: ArchConfig,
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (B, T, d)
    positions: jnp.ndarray,  # (B, T)
    *,
    causal: bool = True,
    prefix_len: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Returns (attn_out (B,T,d), (k, v) each (B,T,Hkv,Dh)) — the KV cache.

    With ``cfg.prerope_kv_cache`` the cached K is pre-RoPE (decode rotates
    it at read time); attention math always uses roped K.
    """
    q, k, v, k_pre = _project_qkv(cfg, p, x, positions)
    if cfg.attention_impl == "xla":
        o = chunked_mha(
            q, k, v, causal=causal, prefix_len=prefix_len, chunk=cfg.attn_chunk,
            shard_repeated_kv=cfg.shard_repeated_kv,
        )
    else:
        o = kops.mha(
            jnp.moveaxis(q, 2, 1),
            jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1),
            prefix_len,
            causal=causal,
            impl=cfg.attention_impl,
        )
        o = jnp.moveaxis(o, 1, 2)
    B, T, _, _ = q.shape
    out = o.reshape(B, T, cfg.n_heads * cfg.d_head) @ p["wo"]
    k_cache = k_pre if cfg.prerope_kv_cache else k
    return out, (k_cache, v)


def cross_attn_prefill(
    cfg: ArchConfig,
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # decoder states (B, T, d)
    memory_kv: Tuple[jnp.ndarray, jnp.ndarray],  # (B, S, Hkv, Dh) x2
) -> jnp.ndarray:
    B, T, _ = x.shape
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, T, cfg.n_heads, cfg.d_head)
    k, v = memory_kv
    o = chunked_mha(
        q, k, v, causal=False, prefix_len=None, chunk=cfg.attn_chunk,
        shard_repeated_kv=cfg.shard_repeated_kv,
    )
    return o.reshape(B, T, cfg.n_heads * cfg.d_head) @ p["wo"]


def memory_kv(cfg: ArchConfig, p, mem: jnp.ndarray):
    """Project encoder memory once into cross-attention K/V."""
    B, S, _ = mem.shape
    k = mem @ p["wk"]
    v = mem @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (
        k.reshape(B, S, cfg.n_kv_heads, cfg.d_head),
        v.reshape(B, S, cfg.n_kv_heads, cfg.d_head),
    )


def _decode_mha_plain(q, kc, vc, kv_len):
    # q (B,Hq,D); kc/vc (B,S,Hkv,D)
    B, Hq, D = q.shape
    Hkv = kc.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hkv, rep, D)
    s = jnp.einsum("bkrd,bskd->bkrs", qg, kc).astype(jnp.float32) * scale
    S = kc.shape[1]
    mask = jnp.arange(S)[None, None, None, :] < kv_len[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrs,bskd->bkrd", w.astype(vc.dtype), vc)
    return o.reshape(B, Hq, D)


def _decode_mha_sp(q, kc, vc, kv_len, mesh, seq_axis: str):
    """Sequence-parallel decode: cache S-axis sharded over ``seq_axis``."""
    batch_axes = sharding.logical_to_spec(("batch",))[0]

    def local(q, kc, vc, kv_len):
        # shapes here are per-shard; S_loc = S / n_shards
        idx = jax.lax.axis_index(seq_axis)
        B, Hq, D = q.shape
        S_loc = kc.shape[1]
        Hkv = kc.shape[2]
        rep = Hq // Hkv
        scale = 1.0 / np.sqrt(D)
        qg = q.reshape(B, Hkv, rep, D)
        s = jnp.einsum("bkrd,bskd->bkrs", qg, kc).astype(jnp.float32) * scale
        pos = idx * S_loc + jnp.arange(S_loc)
        mask = pos[None, None, None, :] < kv_len[:, None, None, None]
        s = jnp.where(mask, s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bkrs,bskd->bkrd", p.astype(vc.dtype), vc).astype(
            jnp.float32
        )
        m_glob = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * corr, seq_axis)
        acc_glob = jax.lax.psum(acc * corr[..., 0][..., None], seq_axis)
        o = acc_glob / jnp.maximum(l_glob[..., 0][..., None], 1e-30)
        return o.reshape(B, Hq, D).astype(q.dtype)

    from jax.experimental.shard_map import shard_map

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(batch_axes, seq_axis, None, None),
            P(batch_axes, seq_axis, None, None),
            P(batch_axes),
        ),
        out_specs=P(batch_axes, None, None),
        check_rep=False,
    )(q, kc, vc, kv_len)


def attn_decode(
    cfg: ArchConfig,
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (B, 1, d)
    cache: Tuple[jnp.ndarray, jnp.ndarray],  # (B, S, Hkv, Dh) x2
    cache_len: jnp.ndarray,  # (B,) tokens already in cache
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One-token decode; returns (out (B,1,d), updated cache)."""
    B = x.shape[0]
    positions = cache_len[:, None]  # (B,1)
    q, k, v, k_pre = _project_qkv(cfg, p, x, positions)
    kc, vc = cache
    # write new token at cache_len (uniform position assumed for the batch;
    # ragged per-request positions are handled by the serving engine batching
    # same-length groups)
    upd = jax.vmap(
        lambda c, new, i: jax.lax.dynamic_update_slice_in_dim(c, new, i, axis=0)
    )
    k_wr = k_pre if cfg.prerope_kv_cache else k
    kc = upd(kc, k_wr[:, 0:1].astype(kc.dtype), cache_len)
    vc = upd(vc, v[:, 0:1].astype(vc.dtype), cache_len)
    kv_len = cache_len + 1
    if cfg.prerope_kv_cache:
        # rotate the whole cache at read time (position grid 0..S)
        S = kc.shape[1]
        pos_grid = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        kc_read = rope(kc, pos_grid, cfg.rope_theta)
    else:
        kc_read = kc

    mesh = sharding.current_mesh()
    seq_axis = sharding.logical_to_spec(("kv_seq_decode",))[0] if mesh else None
    if (
        cfg.attention_impl in ("pallas", "pallas_interpret")
        and mesh is None
    ):
        o = kops.decode_attention(
            jnp.moveaxis(q[:, 0:1], 2, 1)[:, :, 0],
            jnp.moveaxis(kc_read, 2, 1),
            jnp.moveaxis(vc, 2, 1),
            kv_len,
            impl=cfg.attention_impl,
        )
    elif mesh is not None and seq_axis is not None:
        o = _decode_mha_sp(q[:, 0], kc_read, vc, kv_len, mesh, seq_axis)
    else:
        o = _decode_mha_plain(q[:, 0], kc_read, vc, kv_len)
    out = o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ p["wo"]
    return out, (kc, vc)
