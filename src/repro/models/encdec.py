"""Encoder-decoder backbone (seamless-m4t-large-v2 text/unit model).

The speech frontend is stubbed per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, frontend_dim).  Decoder layers are
self-attn (causal) + cross-attn (encoder memory) + FFN, post-norm-free
pre-LN like the rest of the repo.

Serving: ``prefill`` runs the encoder once, projects per-layer cross KV, and
prefills the decoder prompt; ``decode_step`` appends one token (cross KV is
static).  CacheGen streams both the decoder self-KV of a reusable prompt and
the per-layer cross-KV of reusable source audio (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import sharding
from repro.models.attention import (
    attn_decode,
    attn_plan,
    attn_prefill,
    cross_attn_prefill,
    memory_kv,
)
from repro.models.common import (
    Leaf,
    apply_norm,
    init_from_plan,
    maybe_scan,
    mlp_apply,
    mlp_plan,
    norm_plan,
    softmax_cross_entropy,
    specs_from_plan,
)
from repro.models.lm import _remat, _stack_plan  # shared helpers

__all__ = ["EncDecCaches", "param_plan", "init_params", "loss_fn", "prefill", "decode_step"]


class EncDecCaches(NamedTuple):
    self_k: jnp.ndarray  # (Ld, B, S_dec, Hkv, Dh)
    self_v: jnp.ndarray
    cross_k: jnp.ndarray  # (Ld, B, S_src, Hkv, Dh)
    cross_v: jnp.ndarray
    src_len: jnp.ndarray  # (B,)
    length: jnp.ndarray  # (B,) decoder tokens so far


def _enc_layer_plan(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": norm_plan(cfg.norm, cfg.d_model),
        "attn": attn_plan(cfg),
        "ln2": norm_plan(cfg.norm, cfg.d_model),
        "mlp": mlp_plan(cfg.mlp, cfg.d_model, cfg.d_ff, cfg.mlp_bias),
    }


def _dec_layer_plan(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": norm_plan(cfg.norm, cfg.d_model),
        "self_attn": attn_plan(cfg),
        "ln_x": norm_plan(cfg.norm, cfg.d_model),
        "cross_attn": attn_plan(cfg),
        "ln2": norm_plan(cfg.norm, cfg.d_model),
        "mlp": mlp_plan(cfg.mlp, cfg.d_model, cfg.d_ff, cfg.mlp_bias),
    }


def param_plan(cfg: ArchConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.padded_vocab_size
    return {
        "embed": Leaf((V, d), ("vocab", "embed"), scale=0.02),
        "frontend_proj": Leaf((cfg.frontend_dim, d), ("frontend", "embed")),
        "enc_layers": _stack_plan(_enc_layer_plan(cfg), cfg.enc_layers),
        "enc_norm": norm_plan(cfg.norm, d),
        "dec_layers": _stack_plan(_dec_layer_plan(cfg), cfg.dec_layers),
        "final_norm": norm_plan(cfg.norm, d),
        "head": Leaf((d, V), ("embed", "vocab")),
    }


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    return init_from_plan(param_plan(cfg), key, dtype)


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return specs_from_plan(param_plan(cfg))


def encode(cfg: ArchConfig, params, src_embeds: jnp.ndarray) -> jnp.ndarray:
    """src_embeds (B, S, frontend_dim) -> encoder memory (B, S, d)."""
    proj = params["frontend_proj"]
    x = (src_embeds.astype(proj.dtype) @ proj).astype(proj.dtype)
    x = sharding.constrain(x, "batch", "seq", "act_embed")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(h, p_l):
        hn = apply_norm(cfg.norm, p_l["ln1"], h)
        attn_out, _ = attn_prefill(cfg, p_l["attn"], hn, positions, causal=False)
        h = h + attn_out
        hn2 = apply_norm(cfg.norm, p_l["ln2"], h)
        return h + mlp_apply(cfg.mlp, p_l["mlp"], hn2), None

    body_fn = _remat(cfg, body)
    x, _ = maybe_scan(body_fn, x, params["enc_layers"], cfg.scan_unroll)
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _decoder_prefill(cfg, params, memory, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = sharding.constrain(x, "batch", "seq", "act_embed")
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(h, p_l):
        hn = apply_norm(cfg.norm, p_l["ln1"], h)
        attn_out, self_kv = attn_prefill(cfg, p_l["self_attn"], hn, positions)
        h = h + attn_out
        hx = apply_norm(cfg.norm, p_l["ln_x"], h)
        mem_kv = memory_kv(cfg, p_l["cross_attn"], memory)
        h = h + cross_attn_prefill(cfg, p_l["cross_attn"], hx, mem_kv)
        hn2 = apply_norm(cfg.norm, p_l["ln2"], h)
        h = h + mlp_apply(cfg.mlp, p_l["mlp"], hn2)
        return h, (self_kv, mem_kv)

    body_fn = _remat(cfg, body)
    x, ((sk, sv), (ck, cv)) = maybe_scan(body_fn, x, params["dec_layers"], cfg.scan_unroll)
    return x, (sk, sv), (ck, cv)


def loss_fn(cfg: ArchConfig, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    memory = encode(cfg, params, batch["src_embeds"])
    x, _, _ = _decoder_prefill(cfg, params, memory, batch["tokens"])
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = x @ params["head"]
    logits = sharding.constrain(logits, "batch", "seq", "act_vocab")
    loss = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}


def prefill(cfg: ArchConfig, params, batch, *, pad_to: Optional[int] = None):
    memory = encode(cfg, params, batch["src_embeds"])
    tokens = batch["tokens"]
    B, T = tokens.shape
    x, (sk, sv), (ck, cv) = _decoder_prefill(cfg, params, memory, tokens)
    x = apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
    logits = x @ params["head"]
    cap = pad_to or T
    pad = cap - T
    if pad:
        pw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        sk, sv = jnp.pad(sk, pw), jnp.pad(sv, pw)
    S_src = memory.shape[1]
    caches = EncDecCaches(
        self_k=sk,
        self_v=sv,
        cross_k=ck,
        cross_v=cv,
        src_len=jnp.full((B,), S_src, jnp.int32),
        length=jnp.full((B,), T, jnp.int32),
    )
    return logits, caches


def decode_step(cfg: ArchConfig, params, tokens, caches: EncDecCaches):
    from repro.models.attention import _decode_mha_plain  # reuse

    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    cache_len = caches.length

    def body(h, xs):
        p_l, sk, sv, ck, cv = xs
        hn = apply_norm(cfg.norm, p_l["ln1"], h)
        attn_out, (sk, sv) = attn_decode(cfg, p_l["self_attn"], hn, (sk, sv), cache_len)
        h = h + attn_out
        hx = apply_norm(cfg.norm, p_l["ln_x"], h)
        q = (hx[:, 0] @ p_l["cross_attn"]["wq"]).reshape(
            B, cfg.n_heads, cfg.d_head
        )
        if cfg.qkv_bias:
            q = q + p_l["cross_attn"]["bq"].reshape(cfg.n_heads, cfg.d_head)
        o = _decode_mha_plain(q, ck, cv, caches.src_len)
        h = h + (o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ p_l["cross_attn"]["wo"])
        hn2 = apply_norm(cfg.norm, p_l["ln2"], h)
        h = h + mlp_apply(cfg.mlp, p_l["mlp"], hn2)
        return h, (sk, sv)

    x, (sk, sv) = maybe_scan(
        body,
        x,
        (params["dec_layers"], caches.self_k, caches.self_v, caches.cross_k, caches.cross_v),
        cfg.scan_unroll,
    )
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = x @ params["head"]
    return logits, caches._replace(self_k=sk, self_v=sv, length=cache_len + 1)
