"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-shardable).

Top-k routing -> stable-sort slots by expert -> position-in-expert via
searchsorted -> scatter into a dense (E, capacity, d) buffer (overflow
dropped, GShard-style) -> block-diagonal expert matmuls (MXU friendly,
experts sharded over the "experts" logical axis = EP) -> weighted combine.

Static shapes throughout (capacity factor), so the same code lowers for the
dry run and runs the smoke tests.  Shared experts (qwen2-moe) are a plain
dense MLP over all tokens added to the routed output.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import sharding
from repro.models.common import Leaf

__all__ = ["moe_plan", "moe_apply"]


def moe_plan(cfg: ArchConfig) -> Dict[str, Leaf]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    d_axis = None if cfg.moe_replicate_d else "embed"
    p = {
        "router": Leaf((d, E), ("embed", None), scale=0.02),
        "w_gate": Leaf((E, d, ff), ("experts", d_axis, "mlp")),
        "w_up": Leaf((E, d, ff), ("experts", d_axis, "mlp")),
        "w_down": Leaf((E, ff, d), ("experts", "mlp", d_axis)),
    }
    if cfg.n_shared_experts:
        sff = ff * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": Leaf((d, sff), ("embed", "mlp")),
            "w_up": Leaf((d, sff), ("embed", "mlp")),
            "w_down": Leaf((sff, d), ("mlp", "embed")),
        }
    return p


def moe_apply(
    cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out, aux_loss).  Dispatch per cfg.moe_dispatch."""
    if cfg.moe_dispatch == "grouped":
        return _moe_apply_grouped(cfg, p, x)
    return _moe_apply_global(cfg, p, x)


def _moe_apply_global(
    cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.moe_topk
    N = B * T
    xf = x.reshape(N, d)

    logits = (xf @ p["router"]).astype(jnp.float32)  # (N, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)  # (N, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing aux loss.
    density = jnp.mean(
        jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0
    )
    aux = E * jnp.sum(density * jnp.mean(gates, axis=0))

    # round capacity to a multiple of 128 so the (E, capacity, d) dispatch
    # buffer's capacity axis shards evenly over the dp axes
    capacity = int(max(1, round(N * k / E * cfg.capacity_factor)))
    capacity = -(-capacity // 128) * 128

    flat_e = topi.reshape(-1).astype(jnp.int32)  # (N*k,)
    flat_w = topv.reshape(-1)
    flat_t = (
        jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[:, None], (N, k)).reshape(-1)
    )
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    sorted_w = flat_w[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(N * k, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    keep = pos < capacity
    slot = sorted_e * capacity + jnp.minimum(pos, capacity - 1)
    slot = jnp.where(keep, slot, E * capacity)  # OOB -> dropped

    xs = xf[sorted_t]  # (N*k, d) gather in expert order
    buf = jnp.zeros((E * capacity, d), xf.dtype)
    buf = buf.at[slot].set(xs, mode="drop")
    buf = buf.reshape(E, capacity, d)
    buf = sharding.constrain(buf, None, "expert_cap", "act_embed")

    # Block-diagonal expert SwiGLU.
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g) * u
    h = sharding.constrain(h, None, "expert_cap", "act_mlp")
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    y = sharding.constrain(y, None, "expert_cap", "act_embed")
    y = y.reshape(E * capacity, d)

    y_slot = jnp.where(
        keep[:, None], y.at[slot].get(mode="fill", fill_value=0), 0
    )
    out = jnp.zeros((N, d), y.dtype)
    out = out.at[sorted_t].add(y_slot * sorted_w[:, None].astype(y.dtype))

    if cfg.n_shared_experts:
        sp = p["shared"]
        gate = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        out = out + gate @ sp["w_down"]

    return out.reshape(B, T, d), aux


def _moe_apply_grouped(
    cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style grouped dispatch: tokens split into ``moe_groups``
    groups (>= dp shards), each sorted/scattered *locally* with per-group
    capacity.  All dispatch intermediates carry a leading group axis sharded
    over dp, so nothing is replicated across data shards — the fix for the
    global-sort memory blowup visible in the baseline roofline (§Perf)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.moe_topk
    N = B * T
    G = min(cfg.moe_groups, N)
    while N % G:
        G //= 2
    n_loc = N // G
    xg = x.reshape(G, n_loc, d)
    xg = sharding.constrain(xg, "expert_cap", None, "act_embed")

    logits = (xg @ p["router"]).astype(jnp.float32)  # (G, n, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)  # (G, n, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    density = jnp.mean(
        jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    aux = E * jnp.sum(density * jnp.mean(gates, axis=(0, 1)))

    capacity = int(max(8, -(-int(n_loc * k / E * cfg.capacity_factor) // 8) * 8))

    flat_e = topi.reshape(G, n_loc * k).astype(jnp.int32)
    flat_w = topv.reshape(G, n_loc * k)
    flat_t = jnp.broadcast_to(
        jnp.arange(n_loc, dtype=jnp.int32)[:, None], (n_loc, k)
    ).reshape(1, n_loc * k)
    flat_t = jnp.broadcast_to(flat_t, (G, n_loc * k))

    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_t = jnp.take_along_axis(flat_t, order, axis=-1)
    sorted_w = jnp.take_along_axis(flat_w, order, axis=-1)
    seg_start = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(sorted_e)
    pos = jnp.arange(n_loc * k, dtype=jnp.int32)[None] - seg_start.astype(jnp.int32)
    keep = pos < capacity
    slot = sorted_e * capacity + jnp.minimum(pos, capacity - 1)
    slot = jnp.where(keep, slot, E * capacity)

    xs = jnp.take_along_axis(
        xg, sorted_t[..., None].astype(jnp.int32), axis=1
    )  # (G, n*k, d)
    buf = jnp.zeros((G, E * capacity, d), xg.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].set(v, mode="drop"))(buf, slot, xs)
    buf = buf.reshape(G, E, capacity, d)
    buf = sharding.constrain(buf, "expert_cap", None, None, "act_embed")

    mesh = sharding.current_mesh()
    mlp_axis = sharding.logical_to_spec(("act_mlp",))[0] if mesh else None
    if mesh is not None and mlp_axis is not None:
        # TP-local expert FFN + combine: keep the ff-partial sums local
        # through the (linear) combine and psum only the final token
        # outputs — turns the 8 GB (E, G, cap, d) all-reduces into
        # (G, n_loc, d) ones (§Perf granite iteration 4).
        out = _grouped_ffn_combine_sm(
            p, buf, slot, sorted_t, sorted_w, keep, mesh, mlp_axis, n_loc
        )
    else:
        g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
        u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
        h = jax.nn.silu(g) * u
        h = sharding.constrain(h, "expert_cap", None, None, "act_mlp")
        y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
        y = sharding.constrain(y, "expert_cap", None, None, "act_embed")
        y = y.reshape(G, E * capacity, d)
        y_slot = jax.vmap(lambda a, s: a.at[s].get(mode="fill", fill_value=0))(y, slot)
        y_slot = jnp.where(keep[..., None], y_slot, 0)
        out = jnp.zeros((G, n_loc, d), y.dtype)
        out = jax.vmap(lambda o, t, v: o.at[t].add(v))(
            out, sorted_t, y_slot * sorted_w[..., None].astype(y.dtype)
        )

    out = out.reshape(B, T, d)
    if cfg.n_shared_experts:
        sp = p["shared"]
        xf = x.reshape(N, d)
        gate = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        out = out + (gate @ sp["w_down"]).reshape(B, T, d)
    return out, aux


def _grouped_ffn_combine_sm(
    p, buf, slot, sorted_t, sorted_w, keep, mesh, mlp_axis, n_loc
):
    """shard_map expert FFN: ff sharded over ``mlp_axis``, groups over dp;
    partial down-proj outputs are combined locally, then psum'd once."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    G, E, capacity, d = buf.shape
    dp = sharding.logical_to_spec(("expert_cap",))[0]

    def local(buf_l, wg_l, wu_l, wd_l, slot_l, st_l, sw_l, keep_l):
        g = jnp.einsum("gecd,edf->gecf", buf_l, wg_l)
        u = jnp.einsum("gecd,edf->gecf", buf_l, wu_l)
        h = jax.nn.silu(g) * u
        y = jnp.einsum("gecf,efd->gecd", h, wd_l)  # partial over mlp shards
        y = y.reshape(buf_l.shape[0], E * capacity, d)
        y_slot = jax.vmap(lambda a, s: a.at[s].get(mode="fill", fill_value=0))(
            y, slot_l
        )
        y_slot = jnp.where(keep_l[..., None], y_slot, 0)
        out = jnp.zeros((buf_l.shape[0], n_loc, d), y.dtype)
        out = jax.vmap(lambda o, t, v: o.at[t].add(v))(
            out, st_l, y_slot * sw_l[..., None].astype(y.dtype)
        )
        return jax.lax.psum(out, mlp_axis)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(dp, None, None, None),
            P(None, None, mlp_axis),
            P(None, None, mlp_axis),
            P(None, mlp_axis, None),
            P(dp, None),
            P(dp, None),
            P(dp, None),
            P(dp, None),
        ),
        out_specs=P(dp, None, None),
        check_rep=False,
    )(buf, p["w_gate"], p["w_up"], p["w_down"], slot, sorted_t, sorted_w, keep)
