"""Mamba-2 (SSD, state-space duality) block: chunked train/prefill scan +
O(1)-state decode step.  [arXiv:2405.21060]

The chunked algorithm computes, per chunk of Q tokens:
  intra-chunk:  Y_intra[i] = sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) dt_j x_j
  chunk state:  S_c        = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
  inter-chunk:  h_c = exp(cum_end) h_{c-1} + S_c   (lax.scan over chunks)
                Y_inter[i] = exp(cum_i) C_i . h_{c-1}
All decays are <= 1 (A < 0, dt > 0) so every exp() is stable in f32.

Decode carries (conv_state, ssm_state); the ssm update is the exact
recurrence (kernels/ref.py:ssd_ref is the oracle for both paths).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import sharding
from repro.models.common import Leaf, rmsnorm

__all__ = ["mamba2_plan", "mamba2_prefill", "mamba2_decode", "Mamba2State", "ssd_chunked"]


class Mamba2State(NamedTuple):
    conv: jnp.ndarray  # (B, conv_w - 1, d_conv_channels)
    ssm: jnp.ndarray  # (B, H, P, N) f32


def _dims(cfg: ArchConfig):
    d_in = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_headdim
    G = cfg.ssm_groups
    N = cfg.ssm_state
    assert H * P == d_in, f"ssm_heads*ssm_headdim {H}x{P} != d_inner {d_in}"
    conv_ch = d_in + 2 * G * N
    return d_in, H, P, G, N, conv_ch


def mamba2_plan(cfg: ArchConfig) -> Dict[str, Leaf]:
    d = cfg.d_model
    d_in, H, P, G, N, conv_ch = _dims(cfg)
    return {
        "in_proj": Leaf((d, 2 * d_in + 2 * G * N + H), ("embed", "ssm_inner")),
        "conv_w": Leaf((cfg.ssm_conv, conv_ch), ("conv", "ssm_inner"), scale=0.5),
        "conv_b": Leaf((conv_ch,), ("ssm_inner",), "zeros"),
        "a_log": Leaf((H,), ("ssm_heads",), "zeros"),  # A = -exp(a_log)
        "dt_bias": Leaf((H,), ("ssm_heads",), "zeros"),
        "d_skip": Leaf((H,), ("ssm_heads",), "ones"),
        "norm_gamma": Leaf((d_in,), ("ssm_inner",), "ones"),
        "out_proj": Leaf((d_in, d), ("ssm_inner", "embed")),
    }


def ssd_chunked(
    x: jnp.ndarray,  # (B, T, H, P)
    dt: jnp.ndarray,  # (B, T, H) positive
    A: jnp.ndarray,  # (H,) negative
    Bm: jnp.ndarray,  # (B, T, G, N)
    Cm: jnp.ndarray,  # (B, T, G, N)
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    Bb, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, T)
    T_orig = T
    if T % Q:
        # pad with dt=0 tokens: decay=exp(0)=1 and dt*x=0, so padding is
        # exactly state-neutral; outputs are truncated below.
        pad = Q * (-(-T // Q)) - T
        pw = ((0, 0), (0, pad), (0, 0), (0, 0))
        x = jnp.pad(x, pw)
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, pw)
        Cm = jnp.pad(Cm, pw)
        T = T + pad
    nc = T // Q

    xf = x.astype(jnp.float32).reshape(Bb, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, Q, H)
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32).reshape(Bb, nc, Q, H, N)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32).reshape(Bb, nc, Q, H, N)

    a = dtf * A[None, None, None, :]  # (B,nc,Q,H) negative log-decays
    cum = jnp.cumsum(a, axis=2)  # inclusive
    cum_end = cum[:, :, -1, :]  # (B,nc,H)

    # intra-chunk (i >= j): scores = (C_i.B_j) * exp(cum_i - cum_j) * dt_j
    cb = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)  # (B,nc,H,Q_i,Q_j)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])[None, None, :, :, None]
    decay = jnp.where(mask, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    scores = cb * jnp.moveaxis(decay, -1, 2)  # (B,nc,H,Q_i,Q_j)
    sdt = scores * dtf.transpose(0, 1, 3, 2)[:, :, :, None, :]  # x dt_j
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", sdt, xf)

    # chunk states: S_c = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    w = jnp.exp(cum_end[:, :, None, :] - cum) * dtf  # (B,nc,Q,H)
    S = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w, Bh, xf)

    # inter-chunk recurrence over nc
    cdecay = jnp.exp(cum_end)  # (B,nc,H)

    def step(h, inp):
        dec, s_c = inp  # (B,H), (B,H,P,N)
        h_prev = h
        h = h * dec[:, :, None, None] + s_c
        return h, h_prev

    h0 = (
        jnp.zeros((Bb, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    hT, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(cdecay, 1, 0), jnp.moveaxis(S, 1, 0))
    )
    h_prev = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,P,N) state before each chunk

    y_inter = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Ch, h_prev, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(Bb, T, H, P)[:, :T_orig]
    return y.astype(x.dtype), hT


def _split_proj(cfg: ArchConfig, z_x_bc_dt: jnp.ndarray):
    d_in, H, P, G, N, conv_ch = _dims(cfg)
    z, xbc, dt = jnp.split(z_x_bc_dt, [d_in, d_in + conv_ch], axis=-1)
    return z, xbc, dt  # dt: (..., H)


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv1d, window K.  xbc: (B,T,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    T = xbc.shape[1]
    for i in range(K):
        out = out + pad[:, i : i + T].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def mamba2_prefill(
    cfg: ArchConfig,
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (B, T, d)
    initial: Optional[Mamba2State] = None,
) -> Tuple[jnp.ndarray, Mamba2State]:
    B, T, d = x.shape
    d_in, H, P, G, N, conv_ch = _dims(cfg)
    zxd = x @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxd)
    conv_in = xbc
    if initial is not None:
        conv_ctx = jnp.concatenate([initial.conv.astype(xbc.dtype), xbc], axis=1)
        conv_out = _causal_conv(conv_ctx, p["conv_w"], p["conv_b"])[:, cfg.ssm_conv - 1 :]
    else:
        conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bc, Cc = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B, T, H, P)
    Bc = Bc.reshape(B, T, G, N)
    Cc = Cc.reshape(B, T, G, N)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, hT = ssd_chunked(xs, dtv, A, Bc, Cc, cfg.ssm_chunk,
                        None if initial is None else initial.ssm)
    y = y + xs.astype(jnp.float32).astype(y.dtype) * p["d_skip"].astype(y.dtype)[
        None, None, :, None
    ]
    y = y.reshape(B, T, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_gamma"])
    out = y @ p["out_proj"]
    new_conv = (
        jnp.concatenate([initial.conv.astype(conv_in.dtype), conv_in], axis=1)
        if initial is not None
        else conv_in
    )[:, -(cfg.ssm_conv - 1) :]
    return out, Mamba2State(conv=new_conv, ssm=hT)


def mamba2_decode(
    cfg: ArchConfig,
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (B, 1, d)
    state: Mamba2State,
) -> Tuple[jnp.ndarray, Mamba2State]:
    B = x.shape[0]
    d_in, H, P, G, N, conv_ch = _dims(cfg)
    zxd = x[:, 0] @ p["in_proj"]  # (B, ...)
    z, xbc, dt = _split_proj(cfg, zxd)
    # conv over (state ++ new token)
    window = jnp.concatenate(
        [state.conv.astype(xbc.dtype), xbc[:, None, :]], axis=1
    )  # (B, K, C)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    xs, Bc, Cc = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B, H, P)
    Bc = jnp.repeat(Bc.reshape(B, G, N), H // G, axis=1)
    Cc = jnp.repeat(Cc.reshape(B, G, N), H // G, axis=1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dtv * A[None, :])  # (B,H)
    h = state.ssm * dec[:, :, None, None] + (dtv[:, :, None] * xs.astype(jnp.float32))[
        ..., None
    ] * Bc.astype(jnp.float32)[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h, Cc.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_gamma"])
    out = (y @ p["out_proj"])[:, None, :]
    new_conv = window[:, 1:, :]
    return out, Mamba2State(conv=new_conv, ssm=h)
