"""Shared model building blocks: norms, RoPE, MLPs, param plans.

Parameters are plain pytrees (nested dicts of arrays).  A *plan* is the
single source of truth for each parameter's shape, logical sharding axes and
init scale; :func:`init_from_plan` materializes values and
:func:`specs_from_plan` derives the matching sharding-spec tree, so the two
can never drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Leaf",
    "init_from_plan",
    "specs_from_plan",
    "abstract_from_plan",
    "rmsnorm",
    "layernorm",
    "apply_norm",
    "norm_plan",
    "rope",
    "mlp_plan",
    "mlp_apply",
    "softmax_cross_entropy",
    "maybe_scan",
]


def maybe_scan(body, carry, xs, unroll: bool = False):
    """lax.scan, or a python-unrolled equivalent when ``unroll``.

    The unrolled form exists because XLA's cost_analysis counts while-loop
    bodies once; the dry-run lowers reduced-depth unrolled variants to get
    exact per-layer FLOPs/bytes/collectives (launch/dryrun.py).
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if all(jax.tree_util.tree_leaves(y) == [] for y in ys):
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a, 0), *ys)
    return carry, stacked


@dataclasses.dataclass(frozen=True)
class Leaf:
    """One parameter's plan: shape, logical axes, init."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: Optional[float] = None  # stddev override

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"plan leaf rank mismatch: {self.shape} vs {self.logical}")


def _init_leaf(leaf: Leaf, key, dtype) -> jnp.ndarray:
    if leaf.init == "zeros":
        return jnp.zeros(leaf.shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(leaf.shape, dtype)
    fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
    std = leaf.scale if leaf.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, leaf.shape, jnp.float32) * std).astype(dtype)


def init_from_plan(plan: Dict[str, Any], key, dtype=jnp.float32) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten(
        plan, is_leaf=lambda x: isinstance(x, Leaf)
    )
    keys = jax.random.split(key, len(flat))
    vals = [_init_leaf(leaf, k, dtype) for leaf, k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def specs_from_plan(plan: Dict[str, Any]) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda leaf: leaf.logical, plan, is_leaf=lambda x: isinstance(x, Leaf)
    )


def abstract_from_plan(plan: Dict[str, Any], dtype=jnp.float32) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda leaf: jax.ShapeDtypeStruct(leaf.shape, dtype),
        plan,
        is_leaf=lambda x: isinstance(x, Leaf),
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, gamma: Optional[jnp.ndarray], eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm(
    x: jnp.ndarray,
    gamma: Optional[jnp.ndarray],
    beta: Optional[jnp.ndarray],
    eps: float = 1e-5,
):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)


def norm_plan(kind: str, d: int) -> Dict[str, Leaf]:
    if kind == "rmsnorm":
        return {"gamma": Leaf((d,), ("embed",), "ones")}
    if kind == "layernorm":
        return {"gamma": Leaf((d,), ("embed",), "ones"), "beta": Leaf((d,), ("embed",), "zeros")}
    if kind == "nonparam_ln":  # OLMo: LN without affine params
        return {}
    raise ValueError(f"unknown norm {kind}")


def apply_norm(kind: str, p: Dict[str, jnp.ndarray], x: jnp.ndarray):
    if kind == "rmsnorm":
        return rmsnorm(x, p["gamma"])
    if kind == "layernorm":
        return layernorm(x, p["gamma"], p["beta"])
    if kind == "nonparam_ln":
        return layernorm(x, None, None)
    raise ValueError(f"unknown norm {kind}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (..., T, H, D); positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_plan(kind: str, d: int, ff: int, bias: bool, prefix_axes=()) -> Dict[str, Leaf]:
    pa = tuple(prefix_axes)
    pshape = tuple(1 for _ in pa)  # caller overrides leading dims via stack

    def leaf(shape, logical, init="normal"):
        return Leaf(shape, logical, init)

    p: Dict[str, Leaf] = {}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = leaf((d, ff), ("embed", "mlp"))
        p["w_up"] = leaf((d, ff), ("embed", "mlp"))
        p["w_down"] = leaf((ff, d), ("mlp", "embed"))
        if bias:
            p["b_gate"] = leaf((ff,), ("mlp",), "zeros")
            p["b_up"] = leaf((ff,), ("mlp",), "zeros")
            p["b_down"] = leaf((d,), ("embed",), "zeros")
    elif kind == "gelu":
        p["w_up"] = leaf((d, ff), ("embed", "mlp"))
        p["w_down"] = leaf((ff, d), ("mlp", "embed"))
        if bias:
            p["b_up"] = leaf((ff,), ("mlp",), "zeros")
            p["b_down"] = leaf((d,), ("embed",), "zeros")
    else:
        raise ValueError(f"unknown mlp {kind}")
    del pshape, pa
    return p


def mlp_apply(kind: str, p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    from repro.models.sharding import constrain

    def maybe_bias(y, name):
        return y + p[name] if name in p else y

    if kind in ("swiglu", "geglu"):
        g = maybe_bias(x @ p["w_gate"], "b_gate")
        u = maybe_bias(x @ p["w_up"], "b_up")
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
        if h.ndim == 3:
            h = constrain(h, "batch", "seq", "act_mlp")
        return maybe_bias(h @ p["w_down"], "b_down")
    if kind == "gelu":
        h = jax.nn.gelu(maybe_bias(x @ p["w_up"], "b_up"))
        if h.ndim == 3:
            h = constrain(h, "batch", "seq", "act_mlp")
        return maybe_bias(h @ p["w_down"], "b_down")
    raise ValueError(f"unknown mlp {kind}")


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Mean CE over masked positions.  logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
