"""Family dispatch facade: one object per architecture with a uniform API."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, lm

__all__ = ["Model", "build"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    param_plan: Callable[[], Any]
    init_params: Callable[[Any], Any]
    param_specs: Callable[[], Any]
    loss_fn: Callable[..., Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]
    prefill: Callable[..., Tuple[jnp.ndarray, Any]]
    decode_step: Callable[..., Tuple[jnp.ndarray, Any]]


def build(cfg: ArchConfig) -> Model:
    if cfg.family == "encdec":
        mod = encdec
    else:
        mod = lm
    return Model(
        cfg=cfg,
        param_plan=lambda: mod.param_plan(cfg),
        init_params=lambda key: mod.init_params(cfg, key),
        param_specs=lambda: mod.param_specs(cfg),
        loss_fn=lambda params, batch: mod.loss_fn(cfg, params, batch),
        prefill=lambda params, batch, **kw: mod.prefill(cfg, params, batch, **kw),
        decode_step=lambda params, tokens, caches: mod.decode_step(
            cfg, params, tokens, caches
        ),
    )
