"""Token-group ("group of pictures") structure for KV delta coding.

CacheGen §5.2: the context is split into groups of ``group_size`` contiguous
tokens.  The first token of each group is the *anchor*; every other token in
the group is represented by its *delta tensor* against the anchor.  Groups
never span chunk boundaries, which is what makes chunks independently
decodable (§5.3).

All functions here are shape-polymorphic over leading axes: KV tensors are
laid out ``(..., T, C)`` with ``T`` the token axis and ``C`` the flattened
channel axis (kv_heads * head_dim).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "GroupLayout",
    "make_layout",
    "split_anchors_deltas",
    "merge_anchors_deltas",
    "anchor_of_token",
]


@dataclasses.dataclass(frozen=True)
class GroupLayout:
    """Static description of the anchor/delta structure of one chunk."""

    n_tokens: int
    group_size: int

    @property
    def n_groups(self) -> int:
        return -(-self.n_tokens // self.group_size)

    @property
    def n_anchors(self) -> int:
        return self.n_groups

    @property
    def n_deltas(self) -> int:
        return self.n_tokens - self.n_anchors

    @property
    def anchor_positions(self) -> np.ndarray:
        return np.arange(self.n_groups) * self.group_size

    @property
    def delta_positions(self) -> np.ndarray:
        pos = np.arange(self.n_tokens)
        return pos[pos % self.group_size != 0]

    @property
    def delta_group_index(self) -> np.ndarray:
        """For each delta token, the index of its group (= its anchor)."""
        return self.delta_positions // self.group_size

    @property
    def token_group_index(self) -> np.ndarray:
        return np.arange(self.n_tokens) // self.group_size


def make_layout(n_tokens: int, group_size: int) -> GroupLayout:
    if n_tokens <= 0:
        raise ValueError(f"n_tokens must be positive, got {n_tokens}")
    if group_size <= 0:
        raise ValueError(f"group_size must be positive, got {group_size}")
    return GroupLayout(n_tokens=n_tokens, group_size=group_size)


def anchor_of_token(layout: GroupLayout) -> np.ndarray:
    """Token index of the anchor governing each token position."""
    return (np.arange(layout.n_tokens) // layout.group_size) * layout.group_size


def split_anchors_deltas(
    kv: jnp.ndarray, layout: GroupLayout
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split ``(..., T, C)`` into anchors ``(..., G, C)`` and deltas.

    Deltas are ``x_t - x_anchor(t)`` for every non-anchor token, in token
    order: shape ``(..., T - G, C)``.
    """
    a_pos = jnp.asarray(layout.anchor_positions)
    d_pos = jnp.asarray(layout.delta_positions)
    g_idx = jnp.asarray(layout.delta_group_index)
    anchors = jnp.take(kv, a_pos, axis=-2)
    others = jnp.take(kv, d_pos, axis=-2)
    deltas = others - jnp.take(anchors, g_idx, axis=-2)
    return anchors, deltas


def merge_anchors_deltas(
    anchors: jnp.ndarray, deltas: jnp.ndarray, layout: GroupLayout
) -> jnp.ndarray:
    """Inverse of :func:`split_anchors_deltas` (up to quantization error)."""
    g_idx = jnp.asarray(layout.delta_group_index)
    others = deltas + jnp.take(anchors, g_idx, axis=-2)
    out_shape = anchors.shape[:-2] + (layout.n_tokens,) + anchors.shape[-1:]
    out = jnp.zeros(out_shape, dtype=anchors.dtype)
    out = out.at[..., jnp.asarray(layout.anchor_positions), :].set(anchors)
    out = out.at[..., jnp.asarray(layout.delta_positions), :].set(others)
    return out
