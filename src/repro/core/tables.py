"""Offline probability-table profiling for the KV codec (paper Insight 3).

CacheGen profiles a separate symbol distribution for every (layer, K/V,
channel) combination of delta tensors — and another set for anchor tensors —
once per model, and reuses them for every context served by that model.
This module builds those tables from calibration KV caches and converts them
to rANS-ready quantized frequency tables.

Channel bucketing: per-channel tables are exact for small models; for very
wide models the tables can be hashed into ``channel_buckets`` buckets with
negligible compression loss (measured in benchmarks/ablation.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.rans import CoderTables

__all__ = [
    "normalize_freqs",
    "build_coder_tables",
    "histogram_symbols",
    "entropy_bits_per_symbol",
    "lane_table_index",
]


def normalize_freqs(counts: np.ndarray, precision: int) -> np.ndarray:
    """Quantize per-table histograms to frequencies summing to 2**precision.

    counts: (n_tables, A) nonneg ints/floats.  Every output frequency is >= 1
    (Laplace smoothing) so any symbol stays codable, and <= 2**precision - 1
    so the rANS renormalization bound holds.
    """
    counts = np.asarray(counts, dtype=np.float64) + 1.0  # Laplace
    n_tables, A = counts.shape
    M = 1 << precision
    if A < 2:
        raise ValueError("alphabet must have >= 2 symbols")
    if A > M:
        raise ValueError(f"alphabet {A} larger than 2**precision {M}")
    target = counts / counts.sum(axis=1, keepdims=True) * M
    f = np.maximum(np.floor(target), 1.0).astype(np.int64)
    # largest-remainder style fixup to make each row sum exactly to M
    deficit = M - f.sum(axis=1)
    rem = target - np.floor(target)
    for i in range(n_tables):
        d = int(deficit[i])
        if d > 0:
            order = np.argsort(-rem[i])
            j = 0
            while d > 0:
                f[i, order[j % A]] += 1
                j += 1
                d -= 1
        elif d < 0:
            order = np.argsort(-f[i])
            j = 0
            while d < 0:
                idx = order[j % A]
                if f[i, idx] > 1:
                    f[i, idx] -= 1
                    d += 1
                j += 1
    assert (f.sum(axis=1) == M).all()
    assert (f >= 1).all() and (f < M).all()
    return f.astype(np.uint32)


def build_coder_tables(freqs: np.ndarray, precision: int) -> CoderTables:
    """freqs (n_tables, A) summing to 2**precision -> rANS tables."""
    freqs = np.asarray(freqs, dtype=np.uint32)
    n_tables, A = freqs.shape
    M = 1 << precision
    cums = np.zeros((n_tables, A + 1), dtype=np.uint32)
    np.cumsum(freqs, axis=1, out=cums[:, 1:])
    assert (cums[:, -1] == M).all()
    slot2sym = np.zeros((n_tables, M), dtype=np.uint16)
    sym_ids = np.arange(A, dtype=np.uint16)
    for i in range(n_tables):
        slot2sym[i] = np.repeat(sym_ids, freqs[i])
    import jax.numpy as jnp

    return CoderTables(
        freqs=jnp.asarray(freqs),
        cums=jnp.asarray(cums),
        slot2sym=jnp.asarray(slot2sym),
        precision=precision,
    )


def histogram_symbols(
    symbols: np.ndarray, table_idx: np.ndarray, n_tables: int, alphabet: int
) -> np.ndarray:
    """Accumulate per-table symbol counts.

    symbols: (n_lanes, n_sym) ints; table_idx: (n_lanes,).
    Returns (n_tables, alphabet) int64.
    """
    symbols = np.asarray(symbols, dtype=np.int64)
    table_idx = np.asarray(table_idx, dtype=np.int64)
    flat = (table_idx[:, None] * alphabet + symbols).ravel()
    counts = np.bincount(flat, minlength=n_tables * alphabet)
    return counts.reshape(n_tables, alphabet)


def entropy_bits_per_symbol(counts: np.ndarray) -> float:
    """Empirical entropy (bits/symbol) of pooled per-table distributions.

    Each table contributes its own entropy weighted by its symbol mass —
    i.e. the achievable bits/symbol of an ideal coder using per-table
    static distributions (the quantity plotted in paper Fig. 5).
    """
    counts = np.asarray(counts, dtype=np.float64)
    totals = counts.sum(axis=-1, keepdims=True)
    mass = totals.squeeze(-1) / max(counts.sum(), 1.0)
    p = counts / np.maximum(totals, 1.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        h = -np.where(p > 0, p * np.log2(p), 0.0).sum(axis=-1)
    return float((h * mass).sum())


def lane_table_index(
    n_layers: int,
    n_channels: int,
    channel_buckets: Optional[int] = None,
) -> np.ndarray:
    """Map lane (layer, kv, channel) -> table index.

    Lanes are ordered ``lane = (l * 2 + kv) * C + c``.  With bucketing, the
    channel id is folded modulo ``channel_buckets``.
    """
    L, C = n_layers, n_channels
    lanes = np.arange(L * 2 * C)
    c = lanes % C
    lkv = lanes // C
    if channel_buckets is None or channel_buckets >= C:
        return (lkv * C + c).astype(np.int32)
    b = c % channel_buckets
    return (lkv * channel_buckets + b).astype(np.int32)


def n_tables_for(
    n_layers: int, n_channels: int, channel_buckets: Optional[int] = None
) -> int:
    eff = n_channels if (channel_buckets is None or channel_buckets >= n_channels) else channel_buckets
    return n_layers * 2 * eff
