"""Quantization for the CacheGen KV codec.

Implements the paper's §5.2 quantization stage:

* **Anchors** (first token of each group) are kept at high precision:
  8-bit *vectorwise* quantization (per-anchor-token absmax over the channel
  vector), following LLM.int8-style vectorwise scaling.
* **Deltas** are quantized with *layer-group bin widths*: the transformer
  layers are split into three equal groups and the bin width grows from the
  earliest group to the last (paper §C.2 defaults 0.5 / 1.0 / 1.5), reflecting
  Insight 2 (early layers are more loss-sensitive).  The streaming *encoding
  level* scales all three bins by ``level_mult``.
* **Level 0 ("lossless-after-8bit")** reproduces the paper's lossless result:
  the KV is 8-bit quantized with a shared per-(layer, kv, group) scale and the
  *integer* symbol deltas are entropy coded — reconstruction is bit-exact with
  respect to the 8-bit quantization.

KV tensors are ``(L, 2, T, C)`` float32: layers × {K,V} × tokens × channels.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import gop

__all__ = [
    "ANCHOR_ALPHABET",
    "lossless_delta_alphabet",
    "delta_alphabet",
    "layer_group_ids",
    "effective_bins",
    "quantize_anchors",
    "dequantize_anchors",
    "quantize_deltas",
    "dequantize_deltas",
    "lossless_quantize",
    "lossless_reconstruct",
]

ANCHOR_ALPHABET = 256  # 8-bit anchors / 8-bit lossless base symbols


def delta_alphabet(qmax: int) -> int:
    return 2 * qmax + 1


def lossless_delta_alphabet() -> int:
    # int8 symbols are in [-127, 127]; integer deltas span [-254, 254].
    return 2 * 254 + 1


def layer_group_ids(n_layers: int, n_groups: int = 3) -> np.ndarray:
    """Paper §5.2: split layers into three equal-distance groups."""
    edges = np.linspace(0, n_layers, n_groups + 1)
    ids = np.searchsorted(edges[1:-1], np.arange(n_layers), side="right")
    return ids.astype(np.int32)


def effective_bins(
    n_layers: int,
    layer_group_bins: Tuple[float, float, float],
    level_mult: float,
    delta_scale: np.ndarray | None = None,
) -> np.ndarray:
    """Per-(layer, kv) effective bin width, shape (L, 2) float32.

    ``delta_scale`` is an optional per-(layer, kv) calibration (std of deltas
    measured offline) making the paper's absolute bin widths model-agnostic;
    ``None`` means raw value space (paper default).
    """
    gids = layer_group_ids(n_layers)
    base = np.asarray(layer_group_bins, dtype=np.float32)[gids]  # (L,)
    bins = np.broadcast_to(base[:, None], (n_layers, 2)).astype(np.float32)
    bins = bins * np.float32(level_mult)
    if delta_scale is not None:
        bins = bins * np.asarray(delta_scale, dtype=np.float32)
    return np.ascontiguousarray(bins)


# ---------------------------------------------------------------------------
# Lossy path: 8-bit vectorwise anchors + binned deltas
# ---------------------------------------------------------------------------


def quantize_anchors(anchors: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorwise 8-bit quantization of anchor tokens.

    anchors: (L, 2, G, C) f32 -> symbols (L, 2, G, C) uint16 in [0, 256),
    scales (L, 2, G) f32.
    """
    absmax = jnp.max(jnp.abs(anchors), axis=-1)  # (L, 2, G)
    scale = jnp.maximum(absmax / 127.0, 1e-7)
    # Round to the wire precision (f16) *before* quantizing so that the
    # decoder, which only sees f16 scales, reconstructs exactly.
    scale = scale.astype(jnp.float16).astype(jnp.float32)
    q = jnp.clip(jnp.round(anchors / scale[..., None]), -127, 127)
    symbols = (q + 128).astype(jnp.uint16)  # [1, 255]
    return symbols, scale


def dequantize_anchors(symbols: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    q = symbols.astype(jnp.float32) - 128.0
    return q * scales[..., None]


def quantize_deltas(
    deltas: jnp.ndarray, bins_lkv: jnp.ndarray, qmax: int
) -> jnp.ndarray:
    """Binned symmetric quantization of delta tensors.

    deltas: (L, 2, D, C) f32; bins_lkv: (L, 2) f32 bin widths.
    Returns symbols (L, 2, D, C) uint16 in [0, 2*qmax].
    """
    b = bins_lkv[..., None, None]
    q = jnp.clip(jnp.round(deltas / b), -qmax, qmax)
    return (q + qmax).astype(jnp.uint16)


def dequantize_deltas(
    symbols: jnp.ndarray, bins_lkv: jnp.ndarray, qmax: int
) -> jnp.ndarray:
    b = bins_lkv[..., None, None]
    return (symbols.astype(jnp.float32) - qmax) * b


# ---------------------------------------------------------------------------
# Level 0: lossless after 8-bit quantization
# ---------------------------------------------------------------------------


def lossless_quantize(
    kv: jnp.ndarray, layout: gop.GroupLayout
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """8-bit quantize with per-(layer, kv, group) shared scale, then take
    integer deltas within each group.

    Returns (anchor_symbols (L,2,G,C) uint16 in [0,255),
             delta_symbols (L,2,T-G,C) uint16 in [0, 509),
             scales (L,2,G) f32).
    Reconstruction via :func:`lossless_reconstruct` is bit-exact w.r.t. the
    8-bit quantization.
    """
    L, two, T, C = kv.shape
    g_of_t = jnp.asarray(layout.token_group_index)  # (T,)
    # per-group absmax over tokens-in-group x channels
    n_groups = layout.n_groups
    absmax_tok = jnp.max(jnp.abs(kv), axis=-1)  # (L,2,T)
    seg = jnp.zeros((L, two, n_groups), kv.dtype)
    seg = seg.at[..., g_of_t].max(absmax_tok)
    scale = jnp.maximum(seg / 127.0, 1e-7)  # (L,2,G)
    scale = scale.astype(jnp.float16).astype(jnp.float32)  # wire precision
    scale_t = jnp.take(scale, g_of_t, axis=-1)  # (L,2,T)
    q = jnp.clip(jnp.round(kv / scale_t[..., None]), -127, 127).astype(jnp.int32)
    a_pos = jnp.asarray(layout.anchor_positions)
    d_pos = jnp.asarray(layout.delta_positions)
    g_idx = jnp.asarray(layout.delta_group_index)
    q_anchor = jnp.take(q, a_pos, axis=-2)  # (L,2,G,C)
    q_delta = jnp.take(q, d_pos, axis=-2) - jnp.take(q_anchor, g_idx, axis=-2)
    anchor_symbols = (q_anchor + 128).astype(jnp.uint16)  # [1,255]
    delta_symbols = (q_delta + 254).astype(jnp.uint16)  # [0,508]
    return anchor_symbols, delta_symbols, scale


def lossless_reconstruct(
    anchor_symbols: jnp.ndarray,
    delta_symbols: jnp.ndarray,
    scales: jnp.ndarray,
    layout: gop.GroupLayout,
) -> jnp.ndarray:
    """Exact inverse of :func:`lossless_quantize` back to dequantized floats."""
    q_anchor = anchor_symbols.astype(jnp.int32) - 128
    g_idx = jnp.asarray(layout.delta_group_index)
    q_delta = delta_symbols.astype(jnp.int32) - 254
    q_other = q_delta + jnp.take(q_anchor, g_idx, axis=-2)
    L, two, G, C = q_anchor.shape
    q = jnp.zeros((L, two, layout.n_tokens, C), jnp.int32)
    q = q.at[..., jnp.asarray(layout.anchor_positions), :].set(q_anchor)
    q = q.at[..., jnp.asarray(layout.delta_positions), :].set(q_other)
    g_of_t = jnp.asarray(layout.token_group_index)
    scale_t = jnp.take(scales, g_of_t, axis=-1)  # (L,2,T)
    return q.astype(jnp.float32) * scale_t[..., None]
