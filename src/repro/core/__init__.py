"""CacheGen core: the paper's KV-cache codec (encode -> stream -> decode)."""

from repro.core.codec import (  # noqa: F401
    CodecConfig,
    CodecTables,
    decode_chunk,
    encode_all_levels,
    encode_chunk,
    profile,
)
from repro.core.gop import GroupLayout, make_layout  # noqa: F401
from repro.core.rans import CoderTables  # noqa: F401
