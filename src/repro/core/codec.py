"""CacheGen KV-cache codec: chunk-level encode/decode at multiple levels.

Pipeline (paper §5.2):

    KV (L, 2, T, C) f32
      └─ split into token groups of ``group_size``; anchor = first token
         ├─ anchors: 8-bit vectorwise quantization            (quant.py)
         ├─ deltas: layer-group binned quantization           (quant.py)
         └─ symbols → lane-parallel rANS with per-(layer,K/V,channel)
            static distributions                              (rans.py)
      → bitstream (bitstream.py)

Encoding levels:
  * level 0: "lossless-after-8bit" — entropy coding of 8-bit quantized KV
    (paper's lossless configuration, 1.67–1.81× claim);
  * level 1..n: lossy, bins scaled by ``level_mults[level-1]``
    (level 1 finest; higher level = smaller stream, coarser KV).

Tables must be profiled offline per model on calibration KV caches
(:func:`profile`), matching the paper's offline per-model profiling.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import bitstream, gop, quant, rans, tables

__all__ = [
    "CodecConfig",
    "CodecTables",
    "profile",
    "encode_chunk",
    "decode_chunk",
    "encode_all_levels",
    "kv_nbytes_fp16",
    "kv_nbytes_int8",
]


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    group_size: int = 10
    layer_group_bins: Tuple[float, float, float] = (0.5, 1.0, 1.5)
    level_mults: Tuple[float, ...] = (0.5, 1.0, 2.0, 4.0)
    delta_qmax: int = 127
    precision: int = 12
    channel_buckets: Optional[int] = None
    use_delta_scale: bool = True

    @property
    def n_levels(self) -> int:
        return 1 + len(self.level_mults)

    @property
    def delta_alphabet(self) -> int:
        return quant.delta_alphabet(self.delta_qmax)


class CodecTables(NamedTuple):
    """Per-model static coder tables (profiled offline)."""

    anchor: rans.CoderTables  # lossy anchors, alphabet 256
    deltas: Dict[int, rans.CoderTables]  # per lossy level, alphabet 2*qmax+1
    ll_anchor: rans.CoderTables  # lossless anchors, alphabet 256
    ll_delta: rans.CoderTables  # lossless integer deltas, alphabet 509
    table_idx: np.ndarray  # lane -> table
    delta_scale: Optional[np.ndarray]  # (L, 2) or None
    config: CodecConfig
    n_layers: int
    n_channels: int


def _lanes(x: jnp.ndarray) -> jnp.ndarray:
    """(L, 2, T', C) -> (L*2*C, T') lane-major symbol matrix."""
    L, two, Tp, C = x.shape
    return jnp.transpose(x, (0, 1, 3, 2)).reshape(L * two * C, Tp)


def _unlanes(x: jnp.ndarray, L: int, C: int) -> jnp.ndarray:
    n_lanes, Tp = x.shape
    return jnp.transpose(x.reshape(L, 2, C, Tp), (0, 1, 3, 2))


def _bins_for_level(
    cfg: CodecConfig, L: int, level: int, delta_scale: Optional[np.ndarray]
) -> np.ndarray:
    mult = cfg.level_mults[level - 1]
    ds = delta_scale if cfg.use_delta_scale else None
    return quant.effective_bins(L, cfg.layer_group_bins, mult, ds)


def _symbolize(
    kv: jnp.ndarray,
    cfg: CodecConfig,
    level: int,
    delta_scale: Optional[np.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, gop.GroupLayout]:
    """KV -> (anchor_symbols_lanes, delta_symbols_lanes, scales, layout)."""
    L, two, T, C = kv.shape
    layout = gop.make_layout(T, cfg.group_size)
    if level == 0:
        a_sym, d_sym, scales = quant.lossless_quantize(kv, layout)
    else:
        anchors, deltas = gop.split_anchors_deltas(kv, layout)
        a_sym, scales = quant.quantize_anchors(anchors)
        bins = jnp.asarray(_bins_for_level(cfg, L, level, delta_scale))
        d_sym = quant.quantize_deltas(deltas, bins, cfg.delta_qmax)
    return _lanes(a_sym), _lanes(d_sym), scales, layout


def profile(
    kv_samples: Sequence[np.ndarray],
    cfg: CodecConfig = CodecConfig(),
) -> CodecTables:
    """Offline table profiling from calibration KV caches (paper §5.2).

    kv_samples: list of (L, 2, T, C) arrays from representative contexts.
    """
    if not kv_samples:
        raise ValueError("need at least one calibration KV cache")
    L, two, _, C = kv_samples[0].shape
    n_t = tables.n_tables_for(L, C, cfg.channel_buckets)
    t_idx = tables.lane_table_index(L, C, cfg.channel_buckets)

    delta_scale = None
    if cfg.use_delta_scale:
        acc = np.zeros((L, 2), np.float64)
        n = 0
        for kv in kv_samples:
            layout = gop.make_layout(kv.shape[2], cfg.group_size)
            _, deltas = gop.split_anchors_deltas(jnp.asarray(kv, jnp.float32), layout)
            acc += np.asarray(jnp.mean(deltas.astype(jnp.float32) ** 2, axis=(2, 3)))
            n += 1
        delta_scale = np.sqrt(acc / n).astype(np.float32)
        delta_scale = np.maximum(delta_scale, 1e-6)

    a_counts = np.zeros((n_t, quant.ANCHOR_ALPHABET), np.int64)
    lla_counts = np.zeros((n_t, quant.ANCHOR_ALPHABET), np.int64)
    lld_counts = np.zeros((n_t, quant.lossless_delta_alphabet()), np.int64)
    d_counts = {
        lvl: np.zeros((n_t, cfg.delta_alphabet), np.int64)
        for lvl in range(1, cfg.n_levels)
    }
    for kv in kv_samples:
        kvj = jnp.asarray(kv, jnp.float32)
        a, d, _, _ = _symbolize(kvj, cfg, 0, delta_scale)
        lla_counts += tables.histogram_symbols(np.asarray(a), t_idx, n_t, quant.ANCHOR_ALPHABET)
        lld_counts += tables.histogram_symbols(
            np.asarray(d), t_idx, n_t, quant.lossless_delta_alphabet()
        )
        for lvl in range(1, cfg.n_levels):
            a, d, _, _ = _symbolize(kvj, cfg, lvl, delta_scale)
            if lvl == 1:
                a_counts += tables.histogram_symbols(
                    np.asarray(a), t_idx, n_t, quant.ANCHOR_ALPHABET
                )
            d_counts[lvl] += tables.histogram_symbols(
                np.asarray(d), t_idx, n_t, cfg.delta_alphabet
            )

    def _mk(counts):
        return tables.build_coder_tables(
            tables.normalize_freqs(counts, cfg.precision), cfg.precision
        )

    return CodecTables(
        anchor=_mk(a_counts),
        deltas={lvl: _mk(d_counts[lvl]) for lvl in d_counts},
        ll_anchor=_mk(lla_counts),
        ll_delta=_mk(lld_counts),
        table_idx=t_idx,
        delta_scale=delta_scale,
        config=cfg,
        n_layers=L,
        n_channels=C,
    )


def encode_chunk(
    kv: np.ndarray | jnp.ndarray, ct: CodecTables, level: int
) -> bytes:
    """Encode one chunk's KV (L, 2, T, C) at ``level`` into a bitstream."""
    cfg = ct.config
    kv = jnp.asarray(kv, jnp.float32)
    L, two, T, C = kv.shape
    if L != ct.n_layers or C != ct.n_channels:
        raise ValueError(
            f"KV shape {kv.shape} does not match profiled tables "
            f"(L={ct.n_layers}, C={ct.n_channels})"
        )
    a_sym, d_sym, scales, layout = _symbolize(kv, cfg, level, ct.delta_scale)
    a_tab = ct.ll_anchor if level == 0 else ct.anchor
    d_tab = ct.ll_delta if level == 0 else ct.deltas[level]
    t_idx = jnp.asarray(ct.table_idx)
    aw, an, ax = rans.encode(a_sym, t_idx, a_tab)
    dw, dn, dx = rans.encode(d_sym, t_idx, d_tab)
    arrays = {}
    arrays.update(bitstream.pack_stream(np.asarray(aw), np.asarray(an), np.asarray(ax), "a"))
    arrays.update(bitstream.pack_stream(np.asarray(dw), np.asarray(dn), np.asarray(dx), "d"))
    arrays["scales"] = np.asarray(scales, np.float16)
    header = {
        "v": 1,
        "level": int(level),
        "n_tokens": int(T),
        "n_layers": int(L),
        "n_channels": int(C),
        "group_size": int(cfg.group_size),
    }
    return bitstream.pack(header, arrays)


def decode_chunk(blob: bytes, ct: CodecTables) -> jnp.ndarray:
    """Decode a chunk bitstream back to KV (L, 2, T, C) float32."""
    cfg = ct.config
    header, arrays = bitstream.unpack(blob)
    level = int(header["level"])
    T = int(header["n_tokens"])
    L = int(header["n_layers"])
    C = int(header["n_channels"])
    layout = gop.make_layout(T, int(header["group_size"]))
    t_idx = jnp.asarray(ct.table_idx)
    a_tab = ct.ll_anchor if level == 0 else ct.anchor
    d_tab = ct.ll_delta if level == 0 else ct.deltas[level]
    aw, an, ax = bitstream.unpack_stream(arrays, "a")
    dw, dn, dx = bitstream.unpack_stream(arrays, "d")
    a_sym = rans.decode(
        jnp.asarray(aw), jnp.asarray(an), jnp.asarray(ax), t_idx, a_tab, layout.n_anchors
    )
    d_sym = rans.decode(
        jnp.asarray(dw), jnp.asarray(dn), jnp.asarray(dx), t_idx, d_tab, layout.n_deltas
    )
    a_sym = _unlanes(a_sym, L, C)
    d_sym = _unlanes(d_sym, L, C)
    scales = jnp.asarray(arrays["scales"].astype(np.float32))
    if level == 0:
        return quant.lossless_reconstruct(a_sym, d_sym, scales, layout)
    anchors = quant.dequantize_anchors(a_sym, scales)
    bins = jnp.asarray(_bins_for_level(cfg, L, level, ct.delta_scale))
    deltas = quant.dequantize_deltas(d_sym, bins, cfg.delta_qmax)
    return gop.merge_anchors_deltas(anchors, deltas, layout)


def encode_all_levels(
    kv: np.ndarray | jnp.ndarray, ct: CodecTables
) -> Dict[int, bytes]:
    """Offline pre-encoding of every streaming level (paper §5.3)."""
    return {lvl: encode_chunk(kv, ct, lvl) for lvl in range(ct.config.n_levels)}


def kv_nbytes_fp16(L: int, T: int, C: int) -> int:
    """Baseline 'raw fp16 tensors' wire size for a chunk."""
    return L * 2 * T * C * 2


def kv_nbytes_int8(L: int, T: int, C: int, group_size: int = 10) -> int:
    """Baseline '8-bit uniform quantization' wire size (symbols + scales)."""
    n_groups = -(-T // group_size)
    return L * 2 * T * C + L * 2 * n_groups * 2
