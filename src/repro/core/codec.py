"""CacheGen KV-cache codec: chunk-level encode/decode at multiple levels.

Pipeline (paper §5.2):

    KV (L, 2, T, C) f32
      └─ split into token groups of ``group_size``; anchor = first token
         ├─ anchors: 8-bit vectorwise quantization            (quant.py)
         ├─ deltas: layer-group binned quantization           (quant.py)
         └─ symbols → lane-parallel rANS with per-(layer,K/V,channel)
            static distributions                              (rans.py)
      → bitstream (bitstream.py)

Encoding levels:
  * level 0: "lossless-after-8bit" — entropy coding of 8-bit quantized KV
    (paper's lossless configuration, 1.67–1.81× claim);
  * level 1..n: lossy, bins scaled by ``level_mults[level-1]``
    (level 1 finest; higher level = smaller stream, coarser KV).

Tables must be profiled offline per model on calibration KV caches
(:func:`profile`), matching the paper's offline per-model profiling.

Fused-path / oracle split (PR 1): the serving hot path is
:func:`decode_chunks` — a *batched* decode that parses every fetched chunk's
bitstream once on the host, stacks all lanes into exactly two rANS scans
(anchors for all chunks; deltas for all chunks — mixed lossy levels *and*
the lossless family share the delta scan via alphabet-padded
:func:`rans.stack_tables` table stacking), then reconstructs
every chunk's tokens in a single jitted assemble step that drives the fused
Pallas kernels in ``kernels/kvquant.py`` (dequant + anchor-broadcast-add +
dtype cast in one HBM pass, emitting whole token groups).  No intermediate
f32 ``(L, 2, T, C)`` tensor, no per-chunk host round-trips, no per-chunk
device dispatch.  :func:`decode_chunk` (singular) is the retained unfused
reference path — the correctness oracle the fused path is tested against
(bit-exact at level 0, tolerance-exact at lossy levels).

Mirror-image encode batching: :func:`encode_all_levels` symbolizes and
entropy-codes the (level-invariant) anchors once, and runs all lossy levels'
delta rANS encodes as one stacked call; its per-level bitstreams are
byte-identical to per-level :func:`encode_chunk`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitstream, gop, quant, rans, tables

__all__ = [
    "CodecConfig",
    "CodecTables",
    "profile",
    "encode_chunk",
    "peek_chunk_header",
    "verify_chunk",
    "decode_chunk",
    "decode_chunks",
    "decode_chunk_runs",
    "encode_all_levels",
    "ensure_stacks",
    "kv_nbytes_fp16",
    "kv_nbytes_int8",
]


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    group_size: int = 10
    layer_group_bins: Tuple[float, float, float] = (0.5, 1.0, 1.5)
    level_mults: Tuple[float, ...] = (0.5, 1.0, 2.0, 4.0)
    delta_qmax: int = 127
    precision: int = 12
    channel_buckets: Optional[int] = None
    use_delta_scale: bool = True

    @property
    def n_levels(self) -> int:
        return 1 + len(self.level_mults)

    @property
    def delta_alphabet(self) -> int:
        return quant.delta_alphabet(self.delta_qmax)


class CodecTables(NamedTuple):
    """Per-model static coder tables (profiled offline)."""

    anchor: rans.CoderTables  # lossy anchors, alphabet 256
    deltas: Dict[int, rans.CoderTables]  # per lossy level, alphabet 2*qmax+1
    ll_anchor: rans.CoderTables  # lossless anchors, alphabet 256
    ll_delta: rans.CoderTables  # lossless integer deltas, alphabet 509
    table_idx: np.ndarray  # lane -> table
    delta_scale: Optional[np.ndarray]  # (L, 2) or None
    config: CodecConfig
    n_layers: int
    n_channels: int
    # Pre-stacked table sets for the batched coder calls (built by
    # :func:`profile`; lazily derived when tables are constructed by hand).
    anchor_stack: Optional[rans.CoderTables] = None  # [anchor; ll_anchor]
    lossy_delta_stack: Optional[rans.CoderTables] = None  # deltas lvl 1..n
    # decode-only: all delta sets (lossy levels + lossless) alphabet-padded
    # into one stack so mixed-level runs need a single delta scan
    delta_decode_stack: Optional[rans.CoderTables] = None


def _anchor_stack(ct: CodecTables) -> rans.CoderTables:
    if ct.anchor_stack is not None:
        return ct.anchor_stack
    return rans.stack_tables([ct.anchor, ct.ll_anchor])


def _lossy_delta_stack(ct: CodecTables) -> rans.CoderTables:
    if ct.lossy_delta_stack is not None:
        return ct.lossy_delta_stack
    return rans.stack_tables([ct.deltas[l] for l in sorted(ct.deltas)])


def _delta_decode_stack(ct: CodecTables) -> rans.CoderTables:
    if ct.delta_decode_stack is not None:
        return ct.delta_decode_stack
    lossy = [ct.deltas[l] for l in sorted(ct.deltas)]
    return rans.stack_tables(lossy + [ct.ll_delta], pad_alphabet=True)


def _delta_table_base(ct: CodecTables, level: int) -> int:
    """Table offset of ``level``'s delta set inside the decode stack."""
    n_td = ct.ll_delta.n_tables
    return len(ct.deltas) * n_td if level == 0 else (level - 1) * n_td


def ensure_stacks(ct: CodecTables) -> CodecTables:
    """Fill in any missing pre-stacked table sets (one-time upgrade).

    Tables built by :func:`profile` already carry them; tables constructed
    by hand or unpickled from pre-stack assets default the fields to None,
    which would otherwise rebuild + re-upload the stacks on every batched
    coder call.  Long-lived holders (e.g. ``KVStore``) call this once.
    """
    return ct._replace(
        anchor_stack=_anchor_stack(ct),
        lossy_delta_stack=_lossy_delta_stack(ct) if ct.deltas else None,
        delta_decode_stack=_delta_decode_stack(ct),
    )


def _lanes(x: jnp.ndarray) -> jnp.ndarray:
    """(L, 2, T', C) -> (L*2*C, T') lane-major symbol matrix."""
    L, two, Tp, C = x.shape
    return jnp.transpose(x, (0, 1, 3, 2)).reshape(L * two * C, Tp)


def _unlanes(x: jnp.ndarray, L: int, C: int) -> jnp.ndarray:
    n_lanes, Tp = x.shape
    return jnp.transpose(x.reshape(L, 2, C, Tp), (0, 1, 3, 2))


def _bins_for_level(
    cfg: CodecConfig, L: int, level: int, delta_scale: Optional[np.ndarray]
) -> np.ndarray:
    mult = cfg.level_mults[level - 1]
    ds = delta_scale if cfg.use_delta_scale else None
    return quant.effective_bins(L, cfg.layer_group_bins, mult, ds)


def _symbolize(
    kv: jnp.ndarray,
    cfg: CodecConfig,
    level: int,
    delta_scale: Optional[np.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, gop.GroupLayout]:
    """KV -> (anchor_symbols_lanes, delta_symbols_lanes, scales, layout)."""
    L, two, T, C = kv.shape
    layout = gop.make_layout(T, cfg.group_size)
    if level == 0:
        a_sym, d_sym, scales = quant.lossless_quantize(kv, layout)
    else:
        anchors, deltas = gop.split_anchors_deltas(kv, layout)
        a_sym, scales = quant.quantize_anchors(anchors)
        bins = jnp.asarray(_bins_for_level(cfg, L, level, delta_scale))
        d_sym = quant.quantize_deltas(deltas, bins, cfg.delta_qmax)
    return _lanes(a_sym), _lanes(d_sym), scales, layout


def profile(
    kv_samples: Sequence[np.ndarray],
    cfg: CodecConfig = CodecConfig(),
) -> CodecTables:
    """Offline table profiling from calibration KV caches (paper §5.2).

    kv_samples: list of (L, 2, T, C) arrays from representative contexts.
    """
    if not kv_samples:
        raise ValueError("need at least one calibration KV cache")
    L, two, _, C = kv_samples[0].shape
    n_t = tables.n_tables_for(L, C, cfg.channel_buckets)
    t_idx = tables.lane_table_index(L, C, cfg.channel_buckets)

    delta_scale = None
    if cfg.use_delta_scale:
        acc = np.zeros((L, 2), np.float64)
        n = 0
        for kv in kv_samples:
            layout = gop.make_layout(kv.shape[2], cfg.group_size)
            _, deltas = gop.split_anchors_deltas(jnp.asarray(kv, jnp.float32), layout)
            acc += np.asarray(jnp.mean(deltas.astype(jnp.float32) ** 2, axis=(2, 3)))
            n += 1
        delta_scale = np.sqrt(acc / n).astype(np.float32)
        delta_scale = np.maximum(delta_scale, 1e-6)

    a_counts = np.zeros((n_t, quant.ANCHOR_ALPHABET), np.int64)
    lla_counts = np.zeros((n_t, quant.ANCHOR_ALPHABET), np.int64)
    lld_counts = np.zeros((n_t, quant.lossless_delta_alphabet()), np.int64)
    d_counts = {
        lvl: np.zeros((n_t, cfg.delta_alphabet), np.int64)
        for lvl in range(1, cfg.n_levels)
    }
    for kv in kv_samples:
        kvj = jnp.asarray(kv, jnp.float32)
        a, d, _, _ = _symbolize(kvj, cfg, 0, delta_scale)
        lla_counts += tables.histogram_symbols(np.asarray(a), t_idx, n_t, quant.ANCHOR_ALPHABET)
        lld_counts += tables.histogram_symbols(
            np.asarray(d), t_idx, n_t, quant.lossless_delta_alphabet()
        )
        for lvl in range(1, cfg.n_levels):
            a, d, _, _ = _symbolize(kvj, cfg, lvl, delta_scale)
            if lvl == 1:
                a_counts += tables.histogram_symbols(
                    np.asarray(a), t_idx, n_t, quant.ANCHOR_ALPHABET
                )
            d_counts[lvl] += tables.histogram_symbols(
                np.asarray(d), t_idx, n_t, cfg.delta_alphabet
            )

    def _mk(counts):
        return tables.build_coder_tables(
            tables.normalize_freqs(counts, cfg.precision), cfg.precision
        )

    ct = CodecTables(
        anchor=_mk(a_counts),
        deltas={lvl: _mk(d_counts[lvl]) for lvl in d_counts},
        ll_anchor=_mk(lla_counts),
        ll_delta=_mk(lld_counts),
        table_idx=t_idx,
        delta_scale=delta_scale,
        config=cfg,
        n_layers=L,
        n_channels=C,
    )
    return ensure_stacks(ct)


def _chunk_header(
    cfg: CodecConfig, level: int, T: int, L: int, C: int,
    chunk_idx: Optional[int] = None,
) -> dict:
    """Single source of truth for the chunk bitstream header (wire v1).

    ``chunk_idx`` is the chunk's position in its context (written by the
    KVStore so serving-layer validation can detect a storage server
    returning the *wrong chunk*, not just the wrong level); omitted when
    unknown, keeping standalone encodes byte-identical.
    """
    h = {
        "v": 1,
        "level": int(level),
        "n_tokens": int(T),
        "n_layers": int(L),
        "n_channels": int(C),
        "group_size": int(cfg.group_size),
    }
    if chunk_idx is not None:
        h["chunk_idx"] = int(chunk_idx)
    return h


def peek_chunk_header(blob: bytes) -> dict:
    """Parse only a chunk bitstream's header — O(header), the rANS payload
    is never materialized (``bitstream.peek_header``).

    Serving-layer validation hook: the live ``ServeSession`` checks every
    fetched blob against its plan entry (chosen level, token count, and —
    for store-written blobs, which carry ``chunk_idx`` — chunk identity)
    before spending decode time on it; a storage server returning the wrong
    bitstream must fail loudly, not corrupt the cache silently.
    """
    return bitstream.peek_header(blob)


def verify_chunk(blob: bytes) -> bool:
    """Checksum-gate a chunk bitstream before decode (``bitstream.verify_checksum``).

    Returns ``True`` if the blob carries a valid integrity trailer, ``False``
    for legacy/foreign blobs without one; raises ``bitstream.IntegrityError``
    on corruption.  The serving layer runs this at store read and again on
    every fetched blob so corrupt bytes surface as a retryable failure
    instead of a rANS crash or silent garbage KV.
    """
    return bitstream.verify_checksum(blob)


def encode_chunk(
    kv: np.ndarray | jnp.ndarray, ct: CodecTables, level: int,
    chunk_idx: Optional[int] = None,
) -> bytes:
    """Encode one chunk's KV (L, 2, T, C) at ``level`` into a bitstream."""
    cfg = ct.config
    kv = jnp.asarray(kv, jnp.float32)
    L, two, T, C = kv.shape
    if L != ct.n_layers or C != ct.n_channels:
        raise ValueError(
            f"KV shape {kv.shape} does not match profiled tables "
            f"(L={ct.n_layers}, C={ct.n_channels})"
        )
    a_sym, d_sym, scales, layout = _symbolize(kv, cfg, level, ct.delta_scale)
    a_tab = ct.ll_anchor if level == 0 else ct.anchor
    d_tab = ct.ll_delta if level == 0 else ct.deltas[level]
    t_idx = jnp.asarray(ct.table_idx)
    aw, an, ax = rans.encode(a_sym, t_idx, a_tab)
    dw, dn, dx = rans.encode(d_sym, t_idx, d_tab)
    # level-invariant entries (a.*, scales) lead so they form a contiguous
    # anchor segment in the resumable layout (bitstream.segment_index)
    arrays = {}
    arrays.update(bitstream.pack_stream(np.asarray(aw), np.asarray(an), np.asarray(ax), "a"))
    arrays["scales"] = np.asarray(scales, np.float16)
    arrays.update(bitstream.pack_stream(np.asarray(dw), np.asarray(dn), np.asarray(dx), "d"))
    return bitstream.pack(_chunk_header(cfg, level, T, L, C, chunk_idx), arrays)


def decode_chunk(blob: bytes, ct: CodecTables) -> jnp.ndarray:
    """Decode a chunk bitstream back to KV (L, 2, T, C) float32."""
    cfg = ct.config
    header, arrays = bitstream.unpack(blob)
    level = int(header["level"])
    T = int(header["n_tokens"])
    L = int(header["n_layers"])
    C = int(header["n_channels"])
    layout = gop.make_layout(T, int(header["group_size"]))
    t_idx = jnp.asarray(ct.table_idx)
    a_tab = ct.ll_anchor if level == 0 else ct.anchor
    d_tab = ct.ll_delta if level == 0 else ct.deltas[level]
    aw, an, ax = bitstream.unpack_stream(arrays, "a")
    dw, dn, dx = bitstream.unpack_stream(arrays, "d")
    a_sym = rans.decode(
        jnp.asarray(aw), jnp.asarray(an), jnp.asarray(ax), t_idx, a_tab, layout.n_anchors
    )
    d_sym = rans.decode(
        jnp.asarray(dw), jnp.asarray(dn), jnp.asarray(dx), t_idx, d_tab, layout.n_deltas
    )
    a_sym = _unlanes(a_sym, L, C)
    d_sym = _unlanes(d_sym, L, C)
    scales = jnp.asarray(arrays["scales"].astype(np.float32))
    if level == 0:
        return quant.lossless_reconstruct(a_sym, d_sym, scales, layout)
    anchors = quant.dequantize_anchors(a_sym, scales)
    bins = jnp.asarray(_bins_for_level(cfg, L, level, ct.delta_scale))
    deltas = quant.dequantize_deltas(d_sym, bins, cfg.delta_qmax)
    return gop.merge_anchors_deltas(anchors, deltas, layout)


# ---------------------------------------------------------------------------
# Batched fused decode (serving hot path)
# ---------------------------------------------------------------------------


_CAP_BUCKET = 64  # round padded word caps up: content-dependent stream
# lengths would otherwise retrace the jitted rANS scan per novel cap


def _stack_streams(
    parsed: List[Tuple[dict, Dict[str, np.ndarray]]],
    idxs: Sequence[int],
    prefix: str,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack several chunks' packed rANS streams into one padded lane block."""
    streams = [bitstream.unpack_stream(parsed[i][1], prefix) for i in idxs]
    n_lanes = streams[0][0].shape[0]
    cap = max(w.shape[1] for w, _, _ in streams)
    cap = -(-cap // _CAP_BUCKET) * _CAP_BUCKET  # decoder never reads the pad
    words = np.zeros((len(idxs) * n_lanes, cap), np.uint16)
    n_words = np.empty((len(idxs) * n_lanes,), np.int32)
    state = np.empty((len(idxs) * n_lanes,), np.uint32)
    for j, (w, n, x) in enumerate(streams):
        sl = slice(j * n_lanes, (j + 1) * n_lanes)
        words[sl, : w.shape[1]] = w
        n_words[sl] = n
        state[sl] = x
    return words, n_words, state


@functools.partial(
    jax.jit,
    static_argnames=("shape_meta", "out_dtype", "use_pallas", "interpret", "block_groups"),
)
def _assemble_chunks(
    a_sym: jnp.ndarray,  # (N * n_lanes, Gmax) anchor symbols, all chunks
    d_sym: jnp.ndarray,  # (N * n_lanes, Dmax) delta symbols, all chunks
    scales: jnp.ndarray,  # (N, L, 2, Gmax) f32 anchor/group scales
    bins: jnp.ndarray,  # (Nl, L, 2) f32 effective bin widths per lossy chunk
    *,
    shape_meta,  # (L, C, g, qmax, ((T, G, D, is_lossless), ...)) — static
    out_dtype,
    use_pallas: bool,
    interpret: bool,
    block_groups: int,
) -> jnp.ndarray:
    """Reconstruct all chunks' tokens in one traced program: symbol regroup +
    fused dequant kernels + token-major concat.  Returns (L, 2, sum T, C).

    Only geometry and the lossy/lossless partition are static — the lossy
    *level* enters purely as data (``bins``; table offsets were applied in
    the rANS stage), so adaptive per-chunk level choices don't multiply jit
    signatures: one compile per run geometry, not per level pattern.
    """
    from repro.kernels import ref as kref
    from repro.kernels.kvquant import (
        kv_dequant_tokens_pallas,
        kv_lossless_tokens_pallas,
    )

    L, C, g, qmax, chunk_meta = shape_meta
    N = len(chunk_meta)
    Gmax = max(m[1] for m in chunk_meta)
    gm1 = g - 1
    lossy_idx = [i for i, m in enumerate(chunk_meta) if not m[3]]
    ll_idx = [i for i, m in enumerate(chunk_meta) if m[3]]

    # anchors for all chunks: lane-major symbols -> (N, L, 2, Gmax, C)
    a = a_sym.reshape(N, L, 2, C, Gmax).transpose(0, 1, 2, 4, 3)
    d_all = d_sym.reshape(N, L, 2, C, -1)

    def regroup(subset: Sequence[int]) -> jnp.ndarray:
        """Lane-major delta symbols -> (n_sub, L, 2, Gmax, g-1, C).

        The uint16 symbol transpose here replaces the seed path's f32
        ``_unlanes`` transpose at half the bytes; padding appends only
        positions >= the chunk's T (deltas are contiguous in token order).
        """
        outs = []
        for i in subset:
            T, G, D, _ = chunk_meta[i]
            di = d_all[i, ..., :D]
            di = jnp.pad(di, ((0, 0), (0, 0), (0, 0), (0, G * gm1 - D)))
            di = di.reshape(L, 2, C, G, gm1)
            di = jnp.pad(di, ((0, 0), (0, 0), (0, 0), (0, Gmax - G), (0, 0)))
            outs.append(di)
        return jnp.stack(outs).transpose(0, 1, 2, 4, 5, 3)

    tok_by_chunk: Dict[int, jnp.ndarray] = {}

    if lossy_idx:
        sel = jnp.asarray(lossy_idx)
        anchors_f = (a[sel].astype(jnp.float32) - 128.0) * scales[sel][..., None]
        if gm1 == 0:
            tok = anchors_f[:, :, :, :, None, :].astype(out_dtype)
        else:
            d_g = regroup(lossy_idx)  # (Nl, L, 2, Gmax, g-1, C)
            Nl = len(lossy_idx)
            args = (
                d_g.reshape(Nl * L * 2, Gmax, gm1, C),
                anchors_f.reshape(Nl * L * 2, Gmax, C),
                bins.reshape(Nl * L * 2),
            )
            if use_pallas:
                tok = kv_dequant_tokens_pallas(
                    *args,
                    qmax=qmax,
                    out_dtype=out_dtype,
                    interpret=interpret,
                    block_groups=block_groups,
                )
            else:
                tok = kref.kv_dequant_tokens_ref(*args, qmax=qmax, out_dtype=out_dtype)
            tok = tok.reshape(Nl, L, 2, Gmax, g, C)
        for j, i in enumerate(lossy_idx):
            tok_by_chunk[i] = tok[j]

    if ll_idx:
        sel = jnp.asarray(ll_idx)
        a_ll = a[sel]  # uint16 symbols
        s_ll = scales[sel]  # (N0, L, 2, Gmax)
        N0 = len(ll_idx)
        if gm1 == 0:
            tok = (
                (a_ll.astype(jnp.float32) - 128.0) * s_ll[..., None]
            )[:, :, :, :, None, :].astype(out_dtype)
        else:
            d_g = regroup(ll_idx)
            args = (
                d_g.reshape(N0 * L * 2, Gmax, gm1, C),
                a_ll.reshape(N0 * L * 2, Gmax, C),
                s_ll.reshape(N0 * L * 2, Gmax),
            )
            if use_pallas:
                tok = kv_lossless_tokens_pallas(
                    *args,
                    out_dtype=out_dtype,
                    interpret=interpret,
                    block_groups=block_groups,
                )
            else:
                tok = kref.kv_lossless_tokens_ref(*args, out_dtype=out_dtype)
            tok = tok.reshape(N0, L, 2, Gmax, g, C)
        for j, i in enumerate(ll_idx):
            tok_by_chunk[i] = tok[j]

    pieces = []
    for i, (T, G, _, _) in enumerate(chunk_meta):
        tok = tok_by_chunk[i]  # (L, 2, Gmax, g', C)
        gp = tok.shape[3]
        pieces.append(tok[:, :, :G].reshape(L, 2, G * gp, C)[:, :, :T])
    out = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=2)
    return out.astype(out_dtype)


def decode_chunks(
    blobs: Sequence[bytes],
    ct: CodecTables,
    *,
    out_dtype=jnp.float32,
    use_pallas: Optional[bool] = None,
    block_groups: int = 8,
) -> jnp.ndarray:
    """Batched fused decode of several chunk bitstreams (serving hot path).

    Parses every blob once on the host, then runs exactly two lane-stacked
    rANS scans — anchors for all chunks, deltas for all chunks (per-level
    and lossless tables merged via alphabet-padded
    :func:`rans.stack_tables`) — and a single
    jitted assemble step that applies the fused dequant kernels and emits
    token-major KV for all chunks concatenated along the token axis:
    ``(L, 2, sum(T_i), C)`` in ``out_dtype``.  The result stays on device —
    no per-chunk host transfers.

    ``use_pallas=None`` selects the Pallas kernels on accelerator backends
    and the XLA-fused jnp twins on CPU (where Pallas runs in interpret mode
    and is kept as a test oracle, not a fast path).

    Equivalent to concatenating per-chunk :func:`decode_chunk` results:
    bit-exact at level 0 (in f32), tolerance-exact at lossy levels.
    """
    if not blobs:
        raise ValueError("decode_chunks needs at least one blob")
    cfg = ct.config
    parsed = [bitstream.unpack(b) for b in blobs]
    h0 = parsed[0][0]
    L, C, g = int(h0["n_layers"]), int(h0["n_channels"]), int(h0["group_size"])
    for h, _ in parsed:
        if (int(h["n_layers"]), int(h["n_channels"]), int(h["group_size"])) != (L, C, g):
            raise ValueError("decode_chunks requires chunks with a common geometry")
    if L != ct.n_layers or C != ct.n_channels:
        raise ValueError(
            f"chunk geometry (L={L}, C={C}) does not match profiled tables "
            f"(L={ct.n_layers}, C={ct.n_channels})"
        )
    if use_pallas is None:
        use_pallas = jax.default_backend() != "cpu"
    interpret = jax.default_backend() == "cpu"

    metas = []
    for h, _ in parsed:
        lvl, T = int(h["level"]), int(h["n_tokens"])
        layout = gop.make_layout(T, g)
        metas.append((lvl, T, layout.n_anchors, layout.n_deltas))
    N = len(metas)
    n_lanes = L * 2 * C
    Gmax = max(m[2] for m in metas)
    t_idx_np = np.asarray(ct.table_idx)
    n_ta = ct.anchor.n_tables

    # --- anchors: one scan over all chunks (lossy + lossless tables stacked)
    aw, an, ax = _stack_streams(parsed, range(N), "a")
    t_idx_a = np.concatenate(
        [t_idx_np + (n_ta if m[0] == 0 else 0) for m in metas]
    )
    a_sym = rans.decode(aw, an, ax, t_idx_a, _anchor_stack(ct), Gmax)

    # --- deltas: ONE scan for all chunks — lossy levels and the lossless
    # family (different alphabet) share it via alphabet-padded table stacking
    d_max = max(m[3] for m in metas)
    if d_max > 0:
        dw, dn, dx = _stack_streams(parsed, range(N), "d")
        t_idx_d = np.concatenate(
            [t_idx_np + _delta_table_base(ct, m[0]) for m in metas]
        )
        d_sym = rans.decode(dw, dn, dx, t_idx_d, _delta_decode_stack(ct), d_max)
    else:
        d_sym = jnp.zeros((N * n_lanes, 0), jnp.uint16)

    # --- per-chunk side data, padded + stacked once on the host
    lossy_idx = [i for i, m in enumerate(metas) if m[0] != 0]
    scales = np.zeros((N, L, 2, Gmax), np.float32)
    for i, (_, arrays) in enumerate(parsed):
        s = arrays["scales"].astype(np.float32)
        scales[i, :, :, : s.shape[2]] = s
    bins = np.zeros((len(lossy_idx), L, 2), np.float32)
    for j, i in enumerate(lossy_idx):
        bins[j] = _bins_for_level(cfg, L, metas[i][0], ct.delta_scale)

    # static meta carries geometry + the binary lossy/lossless partition
    # only; the chosen lossy level reaches the trace as data (bins)
    shape_meta = (
        L, C, g, cfg.delta_qmax,
        tuple((T, G, D, lvl == 0) for (lvl, T, G, D) in metas),
    )
    return _assemble_chunks(
        a_sym,
        d_sym,
        jnp.asarray(scales),
        jnp.asarray(bins),
        shape_meta=shape_meta,
        out_dtype=np.dtype(out_dtype),
        use_pallas=bool(use_pallas),
        interpret=interpret,
        block_groups=block_groups,
    )


def decode_chunk_runs(
    runs: Sequence[Sequence[bytes]],
    ct: CodecTables,
    *,
    out_dtype=jnp.float32,
    use_pallas: Optional[bool] = None,
    block_groups: int = 8,
    run_tokens: Optional[Sequence[int]] = None,
) -> Tuple[jnp.ndarray, List[Tuple[int, int]]]:
    """Cross-request run assembly: several requests' chunk runs, one decode.

    ``runs`` is one entry per request — that request's consecutive bitstream
    chunks (what a single :func:`decode_chunks` call would take).  All runs
    are flattened into *one* pair of lane-stacked rANS scans and one jitted
    assemble (``decode_chunks``), so N concurrent requests cost the same
    number of device dispatches as one.  The jit signature is shaped by the
    flattened run geometry (chunk token counts + lossy/lossless split)
    exactly as for a single-request call — request identity (which run a
    chunk came from) never enters the trace; it only determines how the
    caller slices the output.

    Returns ``(kv, spans)``: ``kv`` is the token-major concat
    ``(L, 2, sum_all_T, C)`` of every chunk of every run in order, and
    ``spans[r] = (token_offset, n_tokens)`` locates request ``r``'s run
    inside it.  Slicing ``kv[:, :, off : off + n]`` is bit-identical to the
    request's own ``decode_chunks`` output (the assemble is elementwise per
    chunk; stacking mates cannot perturb it).

    ``run_tokens`` (optional) supplies each run's known token count so the
    span computation skips re-parsing headers the caller already validated
    (the scheduler checks every fetched blob against its plan at fetch
    time); when given it is cross-checked against the decoded total.
    """
    if not runs or any(not r for r in runs):
        raise ValueError("decode_chunk_runs needs non-empty runs")
    flat: List[bytes] = [b for run in runs for b in run]
    kv = decode_chunks(
        flat, ct, out_dtype=out_dtype, use_pallas=use_pallas,
        block_groups=block_groups,
    )
    if run_tokens is None:
        run_tokens = [
            sum(int(peek_chunk_header(b)["n_tokens"]) for b in run)
            for run in runs
        ]
    elif len(run_tokens) != len(runs):
        raise ValueError(
            f"run_tokens covers {len(run_tokens)} runs, got {len(runs)}"
        )
    if sum(run_tokens) != kv.shape[2]:
        raise ValueError(
            f"runs decode to {kv.shape[2]} tokens but run_tokens sums to "
            f"{sum(run_tokens)}; bitstream/plan divergence"
        )
    spans: List[Tuple[int, int]] = []
    off = 0
    for n in run_tokens:
        spans.append((off, int(n)))
        off += int(n)
    return kv, spans


def encode_all_levels(
    kv: np.ndarray | jnp.ndarray, ct: CodecTables,
    chunk_idx: Optional[int] = None,
) -> Dict[int, bytes]:
    """Offline pre-encoding of every streaming level (paper §5.3).

    Batched: the lossy levels share their anchor stream (anchors are
    level-invariant), so anchors are symbolized and entropy-coded exactly
    once, and all lossy levels' delta streams are encoded in one stacked
    rANS call over ``n_lossy_levels * n_lanes`` lanes.  Output bitstreams
    are byte-identical to per-level :func:`encode_chunk`.
    """
    cfg = ct.config
    kv = jnp.asarray(kv, jnp.float32)
    L, two, T, C = kv.shape
    if L != ct.n_layers or C != ct.n_channels:
        raise ValueError(
            f"KV shape {kv.shape} does not match profiled tables "
            f"(L={ct.n_layers}, C={ct.n_channels})"
        )
    out: Dict[int, bytes] = {0: encode_chunk(kv, ct, 0, chunk_idx)}
    lossy = list(range(1, cfg.n_levels))
    if not lossy:
        return out

    layout = gop.make_layout(T, cfg.group_size)
    t_idx = jnp.asarray(ct.table_idx)

    # anchors: level-invariant — symbolize and entropy-code once
    anchors, deltas = gop.split_anchors_deltas(kv, layout)
    a_sym, scales = quant.quantize_anchors(anchors)
    aw, an, ax = rans.encode(_lanes(a_sym), t_idx, ct.anchor)
    a_arrays = bitstream.pack_stream(np.asarray(aw), np.asarray(an), np.asarray(ax), "a")
    scales16 = np.asarray(scales, np.float16)

    # deltas: quantize all levels in one vectorized op, entropy-code in one
    # stacked rANS call (per-lane streams are independent of the stacking)
    bins_all = np.stack(
        [_bins_for_level(cfg, L, lvl, ct.delta_scale) for lvl in lossy]
    )  # (n_lossy, L, 2)
    d_sym_all = quant.quantize_deltas(
        deltas[None], jnp.asarray(bins_all), cfg.delta_qmax
    )  # (n_lossy, L, 2, D, C)
    n_lanes = L * two * C
    d_stack = jnp.transpose(d_sym_all, (0, 1, 2, 4, 3)).reshape(
        len(lossy) * n_lanes, layout.n_deltas
    )
    n_td = ct.deltas[lossy[0]].n_tables
    t_idx_np = np.asarray(ct.table_idx)
    t_stack = np.concatenate([t_idx_np + (lvl - 1) * n_td for lvl in lossy])
    dw, dn, dx = rans.encode(d_stack, jnp.asarray(t_stack), _lossy_delta_stack(ct))
    dw, dn, dx = np.asarray(dw), np.asarray(dn), np.asarray(dx)

    for j, lvl in enumerate(lossy):
        sl = slice(j * n_lanes, (j + 1) * n_lanes)
        arrays = {}
        arrays.update(a_arrays)
        arrays["scales"] = scales16
        arrays.update(bitstream.pack_stream(dw[sl], dn[sl], dx[sl], "d"))
        out[lvl] = bitstream.pack(_chunk_header(cfg, lvl, T, L, C, chunk_idx), arrays)
    return out


def kv_nbytes_fp16(L: int, T: int, C: int) -> int:
    """Baseline 'raw fp16 tensors' wire size for a chunk."""
    return L * 2 * T * C * 2


def kv_nbytes_int8(L: int, T: int, C: int, group_size: int = 10) -> int:
    """Baseline '8-bit uniform quantization' wire size (symbols + scales)."""
    n_groups = -(-T // group_size)
    return L * 2 * T * C + L * 2 * n_groups * 2
