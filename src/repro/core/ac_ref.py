"""Exact integer arithmetic coder (Witten–Neal–Cleary), pure Python oracle.

Used only in tests/benchmarks as the ground-truth entropy coder that the
paper's design names (§5.2 "arithmetic coding").  The production coder is the
lane-parallel rANS in :mod:`repro.core.rans`; tests assert that rANS lands
within ~1% of this oracle's compressed size and that both are lossless.

Static model: a frequency table ``freqs`` (all >= 1) summing to ``total``.
32-bit registers, carry handling via pending-bit counting.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

__all__ = ["ac_encode", "ac_decode", "ac_encoded_bits"]

_CODE_BITS = 32
_TOP = (1 << _CODE_BITS) - 1
_HALF = 1 << (_CODE_BITS - 1)
_QUARTER = 1 << (_CODE_BITS - 2)
_THREE_Q = _HALF + _QUARTER


class _BitWriter:
    def __init__(self) -> None:
        self.bits: List[int] = []
        self.pending = 0

    def write(self, bit: int) -> None:
        self.bits.append(bit)
        while self.pending:
            self.bits.append(1 - bit)
            self.pending -= 1

    def to_bytes(self) -> bytes:
        bits = self.bits[:]
        while len(bits) % 8:
            bits.append(0)
        out = bytearray()
        for i in range(0, len(bits), 8):
            byte = 0
            for b in bits[i : i + 8]:
                byte = (byte << 1) | b
            out.append(byte)
        return bytes(out)


class _BitReader:
    def __init__(self, data: bytes) -> None:
        self.data = data
        self.pos = 0

    def read(self) -> int:
        byte_i, bit_i = divmod(self.pos, 8)
        self.pos += 1
        if byte_i >= len(self.data):
            return 0
        return (self.data[byte_i] >> (7 - bit_i)) & 1


def _cums(freqs: Sequence[int]) -> np.ndarray:
    c = np.zeros(len(freqs) + 1, dtype=np.uint64)
    c[1:] = np.cumsum(np.asarray(freqs, dtype=np.uint64))
    return c


def ac_encode(symbols: Sequence[int], freqs: Sequence[int]) -> bytes:
    cums = _cums(freqs)
    total = int(cums[-1])
    low, high = 0, _TOP
    w = _BitWriter()
    for s in symbols:
        s = int(s)
        span = high - low + 1
        high = low + span * int(cums[s + 1]) // total - 1
        low = low + span * int(cums[s]) // total
        while True:
            if high < _HALF:
                w.write(0)
            elif low >= _HALF:
                w.write(1)
                low -= _HALF
                high -= _HALF
            elif low >= _QUARTER and high < _THREE_Q:
                w.pending += 1
                low -= _QUARTER
                high -= _QUARTER
            else:
                break
            low = low * 2
            high = high * 2 + 1
    # flush
    w.pending += 1
    if low < _QUARTER:
        w.write(0)
    else:
        w.write(1)
    return w.to_bytes()


def ac_decode(data: bytes, n_sym: int, freqs: Sequence[int]) -> List[int]:
    cums = _cums(freqs)
    total = int(cums[-1])
    r = _BitReader(data)
    value = 0
    for _ in range(_CODE_BITS):
        value = (value << 1) | r.read()
    low, high = 0, _TOP
    out: List[int] = []
    cums_list = [int(x) for x in cums]
    for _ in range(n_sym):
        span = high - low + 1
        scaled = ((value - low + 1) * total - 1) // span
        # binary search for symbol with cums[s] <= scaled < cums[s+1]
        lo, hi = 0, len(freqs) - 1
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if cums_list[mid] <= scaled:
                lo = mid
            else:
                hi = mid - 1
        s = lo
        out.append(s)
        high = low + span * cums_list[s + 1] // total - 1
        low = low + span * cums_list[s] // total
        while True:
            if high < _HALF:
                pass
            elif low >= _HALF:
                value -= _HALF
                low -= _HALF
                high -= _HALF
            elif low >= _QUARTER and high < _THREE_Q:
                value -= _QUARTER
                low -= _QUARTER
                high -= _QUARTER
            else:
                break
            low = low * 2
            high = high * 2 + 1
            value = (value << 1) | r.read()
    return out


def ac_encoded_bits(symbols: Sequence[int], freqs: Sequence[int]) -> int:
    return len(ac_encode(symbols, freqs)) * 8
