"""Wire format for encoded KV chunks.

A chunk payload is a msgpack map: a small header plus named binary arrays.
rANS streams are stored *packed* — only the valid words of every lane are
concatenated — because the padded per-lane buffers used during encoding are
not the wire representation.  ``unpack_stream`` re-pads for the vectorized
decoder.

Integrity (ISSUE 6): every :func:`pack`-ed blob carries an 8-byte trailer —
a 4-byte magic plus the CRC32 of the msgpack payload — so a blob corrupted
in storage or in transit is *detected* (:class:`IntegrityError`, a
``ValueError`` the serving layer treats as a retryable fetch failure)
instead of crashing the rANS decoder or silently materializing garbage KV.
:func:`verify_checksum` is the O(blob) gate run at store read and again
before decode; :func:`unpack` verifies by default.  Blobs without the
trailer (foreign producers, pre-checksum writers) still parse — there is
simply nothing to verify — and any msgpack-level parse failure is reported
as an :class:`IntegrityError` too, since it is indistinguishable from
corruption that happened to hit the framing bytes.

Resumable segment layout (ISSUE 8).  A packed chunk additionally parses as
a sequence of self-delimiting *segments* — byte ranges of the canonical
blob, each with its own length + CRC32 sub-trailer carried out-of-band in a
:class:`SegmentIndex` (so the blob bytes themselves are unchanged and every
legacy whole-blob trailer still verifies):

  * ``head`` — the msgpack framing plus the chunk header (level-specific,
    but byte-synthesizable from the header fields alone via
    :func:`synthesize_head`);
  * ``anchor`` — the contiguous run of level-invariant arrays (``a.*`` and
    ``scales``; the lossy levels share these bytes exactly, which is what
    lets a fine-level anchor prefix compose with a coarser delta suffix);
  * ``delta`` runs — fixed-size slices of the remaining bytes (delta
    streams + the whole-blob trailer).

Any byte prefix of the blob then resolves — via
:meth:`SegmentIndex.verified_prefix` — into a set of complete, CRC-verified
segments plus a resume offset; a truncation mid-segment yields a shorter
verified prefix, and a corrupted complete segment raises
:class:`IntegrityError` (never silently short bytes).  The index is
computed by :func:`segment_index` on whoever holds the full blob (the
storage server / transport) and travels as fetch metadata.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Dict, Tuple

import msgpack
import numpy as np

__all__ = [
    "DELTA_RUN_BYTES",
    "IntegrityError",
    "Segment",
    "SegmentIndex",
    "has_checksum",
    "pack",
    "peek_header",
    "pack_stream",
    "segment_index",
    "synthesize_head",
    "unpack",
    "unpack_stream",
    "verify_checksum",
]

# trailer: 4-byte magic + CRC32 (big-endian) of the msgpack payload bytes
_CRC_MAGIC = b"KVC1"
_CRC_TAIL = struct.Struct(">I")
_TRAILER_LEN = len(_CRC_MAGIC) + _CRC_TAIL.size


class IntegrityError(ValueError):
    """A packed chunk failed its checksum or could not be parsed — the
    bytes were corrupted in storage or in transit (retryable, unlike a
    plan/header mismatch which points at the wrong blob being returned)."""


def _arr_to_wire(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"d": a.dtype.str, "s": list(a.shape), "b": a.tobytes()}


def _arr_from_wire(w: dict) -> np.ndarray:
    return np.frombuffer(w[b"b"], dtype=np.dtype(w[b"d"].decode())).reshape(w[b"s"])


def pack(header: dict, arrays: Dict[str, np.ndarray]) -> bytes:
    payload = {
        "h": header,
        "a": {name: _arr_to_wire(np.asarray(a)) for name, a in arrays.items()},
    }
    body = msgpack.packb(payload, use_bin_type=True)
    return body + _CRC_MAGIC + _CRC_TAIL.pack(zlib.crc32(body) & 0xFFFFFFFF)


def has_checksum(blob: bytes) -> bool:
    """True if ``blob`` ends with this module's integrity trailer."""
    return len(blob) >= _TRAILER_LEN and blob[-_TRAILER_LEN:-_CRC_TAIL.size] == _CRC_MAGIC


def verify_checksum(blob: bytes) -> bool:
    """Check the integrity trailer without parsing the payload.

    Returns ``True`` when a trailer is present and the CRC matches, ``False``
    when no trailer is present (legacy / foreign blob: nothing to verify).
    Raises :class:`IntegrityError` on a mismatch.
    """
    if not has_checksum(blob):
        return False
    (expected,) = _CRC_TAIL.unpack(blob[-_CRC_TAIL.size:])
    actual = zlib.crc32(blob[:-_TRAILER_LEN]) & 0xFFFFFFFF
    if actual != expected:
        raise IntegrityError(
            f"chunk checksum mismatch: crc32 {actual:#010x} != stored "
            f"{expected:#010x} over {len(blob) - _TRAILER_LEN} payload bytes"
        )
    return True


def unpack(blob: bytes, *, verify: bool = True) -> Tuple[dict, Dict[str, np.ndarray]]:
    if verify:
        verify_checksum(blob)
    body = blob[:-_TRAILER_LEN] if has_checksum(blob) else blob
    try:
        payload = msgpack.unpackb(body, raw=True, strict_map_key=False)
        if not isinstance(payload, dict):
            raise ValueError(f"top-level wire object is {type(payload).__name__}, not a map")
        header = {
            k.decode() if isinstance(k, bytes) else k: v
            for k, v in payload[b"h"].items()
        }
        header = {
            k: (v.decode() if isinstance(v, bytes) else v) for k, v in header.items()
        }
        arrays = {
            (k.decode() if isinstance(k, bytes) else k): _arr_from_wire(v)
            for k, v in payload[b"a"].items()
        }
    except IntegrityError:
        raise
    except Exception as e:
        # a trailer-less blob whose framing bytes were hit by corruption
        # fails here rather than at verify_checksum — same diagnosis
        raise IntegrityError(
            f"chunk payload is corrupt, truncated, or from a foreign producer: {e}"
        ) from e
    return header, arrays


def peek_header(blob: bytes) -> dict:
    """Read only the header map of a packed chunk, without materializing the
    array payload.

    :func:`pack` writes ``{"h": ..., "a": ...}`` in insertion order, so a
    streaming unpacker can stop right after the header object — O(header)
    parse instead of O(blob) (serving-layer validation runs this per fetched
    chunk).  Falls back to a full :func:`unpack` if the first key is not
    ``"h"`` (foreign producer).
    """
    unp = msgpack.Unpacker(raw=True, strict_map_key=False)
    unp.feed(blob)
    try:
        unp.read_map_header()
        key = unp.unpack()
        if key in (b"h", "h"):
            header = unp.unpack()
            return {
                (k.decode() if isinstance(k, bytes) else k): (
                    v.decode() if isinstance(v, bytes) else v
                )
                for k, v in header.items()
            }
    except (msgpack.UnpackException, ValueError):
        # non-map top level raises a plain ValueError, not an UnpackException
        pass
    return unpack(blob)[0]


def pack_stream(
    words: np.ndarray, n_words: np.ndarray, state: np.ndarray, prefix: str
) -> Dict[str, np.ndarray]:
    """Compact a padded rANS buffer into wire arrays under ``prefix``."""
    words = np.asarray(words)
    n_words = np.asarray(n_words, dtype=np.int32)
    n_lanes, cap = words.shape
    mask = np.arange(cap)[None, :] < n_words[:, None]
    payload = words[mask]  # concatenated valid words, lane-major
    return {
        f"{prefix}.payload": payload.astype(np.uint16),
        f"{prefix}.n_words": n_words,
        f"{prefix}.state": np.asarray(state, dtype=np.uint32),
    }


def unpack_stream(
    arrays: Dict[str, np.ndarray], prefix: str
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Inverse of :func:`pack_stream`: returns (padded_words, n_words, state)."""
    payload = arrays[f"{prefix}.payload"]
    n_words = arrays[f"{prefix}.n_words"].astype(np.int32)
    state = arrays[f"{prefix}.state"].astype(np.uint32)
    n_lanes = n_words.shape[0]
    cap = max(int(n_words.max()) if n_lanes else 0, 1)
    words = np.zeros((n_lanes, cap), dtype=np.uint16)
    mask = np.arange(cap)[None, :] < n_words[:, None]
    words[mask] = payload
    return words, n_words, state


def stream_wire_bytes(arrays: Dict[str, np.ndarray], prefix: str) -> int:
    return (
        arrays[f"{prefix}.payload"].nbytes
        + arrays[f"{prefix}.n_words"].nbytes
        + arrays[f"{prefix}.state"].nbytes
    )


# ---------------------------------------------------------------------------
# Resumable segment layout (ISSUE 8)
# ---------------------------------------------------------------------------

# target size of one delta run segment: the resume/salvage granularity for
# the delta region.  Must agree between whoever computes an index and
# whoever requests an offset derived from it — clients always interpret the
# *received* index (absolute offsets), so a mismatch degrades resume
# granularity, never correctness.
DELTA_RUN_BYTES = 8192

# names whose wire bytes are identical across the lossy levels (anchors are
# symbolized and entropy-coded once per chunk; scales are shared with them)
_INVARIANT_PREFIXES = ("a.",)
_INVARIANT_NAMES = (b"scales",)


def _is_invariant(name: bytes) -> bool:
    return name in _INVARIANT_NAMES or any(
        name.startswith(p.encode()) for p in _INVARIANT_PREFIXES
    )


@dataclasses.dataclass(frozen=True)
class Segment:
    """One self-delimiting byte range of a packed chunk."""

    kind: str  # "head" | "anchor" | "delta"
    start: int
    end: int
    crc: int

    @property
    def length(self) -> int:
        return self.end - self.start


@dataclasses.dataclass(frozen=True)
class SegmentIndex:
    """Derived segment view of one canonical packed chunk.

    ``segments`` tile ``[0, total)`` in order: head, anchor (possibly
    zero-length for a foreign layout), then one or more delta runs — the
    last delta run includes the whole-blob integrity trailer.  ``n_arrays``
    is the array-map entry count (what :func:`synthesize_head` needs to
    rebuild a level's head bytes without the level's blob).
    """

    segments: Tuple[Segment, ...]
    total: int
    n_arrays: int

    @property
    def head(self) -> Segment:
        return self.segments[0]

    @property
    def anchor(self) -> Segment:
        return self.segments[1]

    @property
    def anchor_end(self) -> int:
        return self.segments[1].end

    def verified_prefix(self, data: bytes, offset: int = 0) -> int:
        """Largest segment boundary ``<= offset + len(data)`` such that every
        complete segment inside ``[offset, boundary)`` passes its CRC.

        ``data`` are blob bytes starting at absolute ``offset`` (0 for a
        whole-blob prefix, a resume offset for a suffix fetch).  A segment
        that is fully present but fails its CRC raises
        :class:`IntegrityError`; a segment cut short by the end of ``data``
        simply bounds the verified range — truncation is a resume point,
        corruption is an error.
        """
        end = offset + len(data)
        verified = offset
        for seg in self.segments:
            if seg.start < offset:
                continue  # not covered by this fetch
            if seg.start > verified:
                break  # gap: segments beyond the contiguous range
            if seg.end > end:
                break  # cut mid-segment: everything before it stands
            actual = zlib.crc32(data[seg.start - offset : seg.end - offset]) & 0xFFFFFFFF
            if actual != seg.crc:
                raise IntegrityError(
                    f"segment [{seg.start}, {seg.end}) ({seg.kind}) failed its "
                    f"sub-trailer: crc32 {actual:#010x} != indexed {seg.crc:#010x}"
                )
            verified = seg.end
        return verified

    # -- wire form (travels as fetch metadata, not inside the blob) --------

    _KINDS = ("head", "anchor", "delta")

    def to_wire(self) -> dict:
        return {
            "v": 1,
            "total": self.total,
            "na": self.n_arrays,
            "segs": [
                [self._KINDS.index(s.kind), s.start, s.end, s.crc]
                for s in self.segments
            ],
        }

    @staticmethod
    def from_wire(w: dict) -> "SegmentIndex":
        try:
            segs = tuple(
                Segment(SegmentIndex._KINDS[int(k)], int(a), int(b), int(c))
                for k, a, b, c in w["segs"]
            )
            return SegmentIndex(
                segments=segs, total=int(w["total"]), n_arrays=int(w["na"])
            )
        except (KeyError, TypeError, ValueError, IndexError) as e:
            raise IntegrityError(f"malformed segment index: {e}") from e


def _entry_spans(blob: bytes):
    """Byte spans of the array-map entries of a canonical packed blob.

    Returns ``(entries, head_end, body_end)`` where ``entries`` is a list of
    ``(name, start, end)`` — the span of each ``name: wire-dict`` entry —
    and ``head_end`` is where the first entry begins (end of the msgpack
    framing + header).  Raises :class:`IntegrityError` for anything that is
    not this module's ``{"h": ..., "a": {...}}`` layout.
    """
    body = blob[:-_TRAILER_LEN] if has_checksum(blob) else blob
    unp = msgpack.Unpacker(raw=True, strict_map_key=False)
    unp.feed(body)
    try:
        if unp.read_map_header() != 2:
            raise ValueError("top-level map is not {h, a}")
        if unp.unpack() not in (b"h", "h"):
            raise ValueError("first key is not 'h'")
        unp.skip()  # header value
        if unp.unpack() not in (b"a", "a"):
            raise ValueError("second key is not 'a'")
        n_arrays = unp.read_map_header()
        entries = []
        for _ in range(n_arrays):
            start = unp.tell()
            name = unp.unpack()
            unp.skip()  # the array wire dict
            entries.append(
                (name if isinstance(name, bytes) else str(name).encode(),
                 start, unp.tell())
            )
        body_end = unp.tell()
    except IntegrityError:
        raise
    except Exception as e:
        raise IntegrityError(
            f"blob does not parse as a segmentable packed chunk: {e}"
        ) from e
    head_end = entries[0][1] if entries else body_end
    return entries, head_end, body_end


def segment_index(
    blob: bytes, *, delta_run_bytes: int = DELTA_RUN_BYTES
) -> SegmentIndex:
    """Compute the segment view of one canonical packed chunk.

    The anchor segment covers the *leading contiguous run* of
    level-invariant entries (``a.*`` / ``scales``); everything after it —
    the delta streams plus the whole-blob trailer — is sliced into
    near-equal delta runs of about ``delta_run_bytes`` each.  Pure function
    of the blob bytes: every holder of the blob derives the same index.
    """
    try:
        entries, head_end, _body_end = _entry_spans(blob)
        anchor_end = head_end
        for name, _start, end in entries:
            if _is_invariant(name):
                anchor_end = end
            else:
                break
        n_arrays = len(entries)
    except IntegrityError:
        # foreign layout: no compose, but delta-run slicing still gives
        # byte-range resume with per-run verification
        head_end = anchor_end = 0
        n_arrays = 0
    total = len(blob)

    def crc(a: int, b: int) -> int:
        return zlib.crc32(blob[a:b]) & 0xFFFFFFFF

    segs = [
        Segment("head", 0, head_end, crc(0, head_end)),
        Segment("anchor", head_end, anchor_end, crc(head_end, anchor_end)),
    ]
    region = total - anchor_end
    n_runs = max(1, -(-region // max(int(delta_run_bytes), 1)))
    for k in range(n_runs):
        a = anchor_end + (region * k) // n_runs
        b = anchor_end + (region * (k + 1)) // n_runs
        segs.append(Segment("delta", a, b, crc(a, b)))
    return SegmentIndex(segments=tuple(segs), total=total, n_arrays=n_arrays)


def _mp_map_header(n: int) -> bytes:
    if n < 16:
        return bytes([0x80 | n])
    if n < 1 << 16:
        return b"\xde" + struct.pack(">H", n)
    return b"\xdf" + struct.pack(">I", n)


def synthesize_head(header: dict, n_arrays: int) -> bytes:
    """Rebuild a packed chunk's head segment from its header fields alone.

    Byte-identical to ``blob[:head_end]`` of :func:`pack` output for the
    same header (msgpack encoding is deterministic given key order) — the
    degrade-compose path uses this to stand in the *coarser* level's head
    in front of a salvaged fine-level anchor segment without ever fetching
    the coarse head bytes.
    """
    return (
        _mp_map_header(2)
        + msgpack.packb("h", use_bin_type=True)
        + msgpack.packb(header, use_bin_type=True)
        + msgpack.packb("a", use_bin_type=True)
        + _mp_map_header(int(n_arrays))
    )
