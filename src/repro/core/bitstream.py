"""Wire format for encoded KV chunks.

A chunk payload is a msgpack map: a small header plus named binary arrays.
rANS streams are stored *packed* — only the valid words of every lane are
concatenated — because the padded per-lane buffers used during encoding are
not the wire representation.  ``unpack_stream`` re-pads for the vectorized
decoder.
"""
from __future__ import annotations

from typing import Dict, Tuple

import msgpack
import numpy as np

__all__ = ["pack", "unpack", "peek_header", "pack_stream", "unpack_stream"]


def _arr_to_wire(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"d": a.dtype.str, "s": list(a.shape), "b": a.tobytes()}


def _arr_from_wire(w: dict) -> np.ndarray:
    return np.frombuffer(w[b"b"], dtype=np.dtype(w[b"d"].decode())).reshape(w[b"s"])


def pack(header: dict, arrays: Dict[str, np.ndarray]) -> bytes:
    payload = {
        "h": header,
        "a": {name: _arr_to_wire(np.asarray(a)) for name, a in arrays.items()},
    }
    return msgpack.packb(payload, use_bin_type=True)


def unpack(blob: bytes) -> Tuple[dict, Dict[str, np.ndarray]]:
    payload = msgpack.unpackb(blob, raw=True, strict_map_key=False)
    header = {
        k.decode() if isinstance(k, bytes) else k: v for k, v in payload[b"h"].items()
    }
    header = {
        k: (v.decode() if isinstance(v, bytes) else v) for k, v in header.items()
    }
    arrays = {
        (k.decode() if isinstance(k, bytes) else k): _arr_from_wire(v)
        for k, v in payload[b"a"].items()
    }
    return header, arrays


def peek_header(blob: bytes) -> dict:
    """Read only the header map of a packed chunk, without materializing the
    array payload.

    :func:`pack` writes ``{"h": ..., "a": ...}`` in insertion order, so a
    streaming unpacker can stop right after the header object — O(header)
    parse instead of O(blob) (serving-layer validation runs this per fetched
    chunk).  Falls back to a full :func:`unpack` if the first key is not
    ``"h"`` (foreign producer).
    """
    unp = msgpack.Unpacker(raw=True, strict_map_key=False)
    unp.feed(blob)
    try:
        unp.read_map_header()
        key = unp.unpack()
        if key in (b"h", "h"):
            header = unp.unpack()
            return {
                (k.decode() if isinstance(k, bytes) else k): (
                    v.decode() if isinstance(v, bytes) else v
                )
                for k, v in header.items()
            }
    except (msgpack.UnpackException, ValueError):
        # non-map top level raises a plain ValueError, not an UnpackException
        pass
    return unpack(blob)[0]


def pack_stream(
    words: np.ndarray, n_words: np.ndarray, state: np.ndarray, prefix: str
) -> Dict[str, np.ndarray]:
    """Compact a padded rANS buffer into wire arrays under ``prefix``."""
    words = np.asarray(words)
    n_words = np.asarray(n_words, dtype=np.int32)
    n_lanes, cap = words.shape
    mask = np.arange(cap)[None, :] < n_words[:, None]
    payload = words[mask]  # concatenated valid words, lane-major
    return {
        f"{prefix}.payload": payload.astype(np.uint16),
        f"{prefix}.n_words": n_words,
        f"{prefix}.state": np.asarray(state, dtype=np.uint32),
    }


def unpack_stream(
    arrays: Dict[str, np.ndarray], prefix: str
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Inverse of :func:`pack_stream`: returns (padded_words, n_words, state)."""
    payload = arrays[f"{prefix}.payload"]
    n_words = arrays[f"{prefix}.n_words"].astype(np.int32)
    state = arrays[f"{prefix}.state"].astype(np.uint32)
    n_lanes = n_words.shape[0]
    cap = max(int(n_words.max()) if n_lanes else 0, 1)
    words = np.zeros((n_lanes, cap), dtype=np.uint16)
    mask = np.arange(cap)[None, :] < n_words[:, None]
    words[mask] = payload
    return words, n_words, state


def stream_wire_bytes(arrays: Dict[str, np.ndarray], prefix: str) -> int:
    return (
        arrays[f"{prefix}.payload"].nbytes
        + arrays[f"{prefix}.n_words"].nbytes
        + arrays[f"{prefix}.state"].nbytes
    )
