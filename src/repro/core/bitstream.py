"""Wire format for encoded KV chunks.

A chunk payload is a msgpack map: a small header plus named binary arrays.
rANS streams are stored *packed* — only the valid words of every lane are
concatenated — because the padded per-lane buffers used during encoding are
not the wire representation.  ``unpack_stream`` re-pads for the vectorized
decoder.

Integrity (ISSUE 6): every :func:`pack`-ed blob carries an 8-byte trailer —
a 4-byte magic plus the CRC32 of the msgpack payload — so a blob corrupted
in storage or in transit is *detected* (:class:`IntegrityError`, a
``ValueError`` the serving layer treats as a retryable fetch failure)
instead of crashing the rANS decoder or silently materializing garbage KV.
:func:`verify_checksum` is the O(blob) gate run at store read and again
before decode; :func:`unpack` verifies by default.  Blobs without the
trailer (foreign producers, pre-checksum writers) still parse — there is
simply nothing to verify — and any msgpack-level parse failure is reported
as an :class:`IntegrityError` too, since it is indistinguishable from
corruption that happened to hit the framing bytes.
"""
from __future__ import annotations

import struct
import zlib
from typing import Dict, Tuple

import msgpack
import numpy as np

__all__ = [
    "IntegrityError",
    "has_checksum",
    "pack",
    "peek_header",
    "pack_stream",
    "unpack",
    "unpack_stream",
    "verify_checksum",
]

# trailer: 4-byte magic + CRC32 (big-endian) of the msgpack payload bytes
_CRC_MAGIC = b"KVC1"
_CRC_TAIL = struct.Struct(">I")
_TRAILER_LEN = len(_CRC_MAGIC) + _CRC_TAIL.size


class IntegrityError(ValueError):
    """A packed chunk failed its checksum or could not be parsed — the
    bytes were corrupted in storage or in transit (retryable, unlike a
    plan/header mismatch which points at the wrong blob being returned)."""


def _arr_to_wire(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"d": a.dtype.str, "s": list(a.shape), "b": a.tobytes()}


def _arr_from_wire(w: dict) -> np.ndarray:
    return np.frombuffer(w[b"b"], dtype=np.dtype(w[b"d"].decode())).reshape(w[b"s"])


def pack(header: dict, arrays: Dict[str, np.ndarray]) -> bytes:
    payload = {
        "h": header,
        "a": {name: _arr_to_wire(np.asarray(a)) for name, a in arrays.items()},
    }
    body = msgpack.packb(payload, use_bin_type=True)
    return body + _CRC_MAGIC + _CRC_TAIL.pack(zlib.crc32(body) & 0xFFFFFFFF)


def has_checksum(blob: bytes) -> bool:
    """True if ``blob`` ends with this module's integrity trailer."""
    return len(blob) >= _TRAILER_LEN and blob[-_TRAILER_LEN:-_CRC_TAIL.size] == _CRC_MAGIC


def verify_checksum(blob: bytes) -> bool:
    """Check the integrity trailer without parsing the payload.

    Returns ``True`` when a trailer is present and the CRC matches, ``False``
    when no trailer is present (legacy / foreign blob: nothing to verify).
    Raises :class:`IntegrityError` on a mismatch.
    """
    if not has_checksum(blob):
        return False
    (expected,) = _CRC_TAIL.unpack(blob[-_CRC_TAIL.size:])
    actual = zlib.crc32(blob[:-_TRAILER_LEN]) & 0xFFFFFFFF
    if actual != expected:
        raise IntegrityError(
            f"chunk checksum mismatch: crc32 {actual:#010x} != stored "
            f"{expected:#010x} over {len(blob) - _TRAILER_LEN} payload bytes"
        )
    return True


def unpack(blob: bytes, *, verify: bool = True) -> Tuple[dict, Dict[str, np.ndarray]]:
    if verify:
        verify_checksum(blob)
    body = blob[:-_TRAILER_LEN] if has_checksum(blob) else blob
    try:
        payload = msgpack.unpackb(body, raw=True, strict_map_key=False)
        if not isinstance(payload, dict):
            raise ValueError(f"top-level wire object is {type(payload).__name__}, not a map")
        header = {
            k.decode() if isinstance(k, bytes) else k: v
            for k, v in payload[b"h"].items()
        }
        header = {
            k: (v.decode() if isinstance(v, bytes) else v) for k, v in header.items()
        }
        arrays = {
            (k.decode() if isinstance(k, bytes) else k): _arr_from_wire(v)
            for k, v in payload[b"a"].items()
        }
    except IntegrityError:
        raise
    except Exception as e:
        # a trailer-less blob whose framing bytes were hit by corruption
        # fails here rather than at verify_checksum — same diagnosis
        raise IntegrityError(
            f"chunk payload is corrupt, truncated, or from a foreign producer: {e}"
        ) from e
    return header, arrays


def peek_header(blob: bytes) -> dict:
    """Read only the header map of a packed chunk, without materializing the
    array payload.

    :func:`pack` writes ``{"h": ..., "a": ...}`` in insertion order, so a
    streaming unpacker can stop right after the header object — O(header)
    parse instead of O(blob) (serving-layer validation runs this per fetched
    chunk).  Falls back to a full :func:`unpack` if the first key is not
    ``"h"`` (foreign producer).
    """
    unp = msgpack.Unpacker(raw=True, strict_map_key=False)
    unp.feed(blob)
    try:
        unp.read_map_header()
        key = unp.unpack()
        if key in (b"h", "h"):
            header = unp.unpack()
            return {
                (k.decode() if isinstance(k, bytes) else k): (
                    v.decode() if isinstance(v, bytes) else v
                )
                for k, v in header.items()
            }
    except (msgpack.UnpackException, ValueError):
        # non-map top level raises a plain ValueError, not an UnpackException
        pass
    return unpack(blob)[0]


def pack_stream(
    words: np.ndarray, n_words: np.ndarray, state: np.ndarray, prefix: str
) -> Dict[str, np.ndarray]:
    """Compact a padded rANS buffer into wire arrays under ``prefix``."""
    words = np.asarray(words)
    n_words = np.asarray(n_words, dtype=np.int32)
    n_lanes, cap = words.shape
    mask = np.arange(cap)[None, :] < n_words[:, None]
    payload = words[mask]  # concatenated valid words, lane-major
    return {
        f"{prefix}.payload": payload.astype(np.uint16),
        f"{prefix}.n_words": n_words,
        f"{prefix}.state": np.asarray(state, dtype=np.uint32),
    }


def unpack_stream(
    arrays: Dict[str, np.ndarray], prefix: str
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Inverse of :func:`pack_stream`: returns (padded_words, n_words, state)."""
    payload = arrays[f"{prefix}.payload"]
    n_words = arrays[f"{prefix}.n_words"].astype(np.int32)
    state = arrays[f"{prefix}.state"].astype(np.uint32)
    n_lanes = n_words.shape[0]
    cap = max(int(n_words.max()) if n_lanes else 0, 1)
    words = np.zeros((n_lanes, cap), dtype=np.uint16)
    mask = np.arange(cap)[None, :] < n_words[:, None]
    words[mask] = payload
    return words, n_words, state


def stream_wire_bytes(arrays: Dict[str, np.ndarray], prefix: str) -> int:
    return (
        arrays[f"{prefix}.payload"].nbytes
        + arrays[f"{prefix}.n_words"].nbytes
        + arrays[f"{prefix}.state"].nbytes
    )
