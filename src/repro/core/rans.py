"""Lane-parallel static-table rANS entropy coder, as a JAX program.

This is the TPU-native adaptation of CacheGen's GPU arithmetic coder: the
paper runs one CUDA thread per token's bitstream; on TPU the analogue of
"thousands of independent sequential coders" is a vectorized ``lax.scan``
where every *lane* carries its own 32-bit coder state.  Lanes map to
(layer, K/V, channel) streams so that each lane uses exactly one static
symbol distribution (paper Insight 3: per-channel-per-layer distributions),
which keeps table gathers uniform.

rANS (range asymmetric numeral systems) is in the same entropy-coding family
as arithmetic coding — both approach the entropy bound; we verify in tests
that compressed sizes match an exact arithmetic-coding oracle within ~1%.
rANS is chosen over a bit-level AC port because it is table-driven and
carry-free: the inner loop is a handful of integer ops + gathers, exactly the
shape of computation TPU vector units (and XLA:CPU) run well; CUDA-style
bit/carry manipulation has no TPU analogue.

Variant: 32-bit state, 16-bit word renormalization (ryg_rans "rans_word").
With precision ``k <= 14`` and all frequencies >= 1 (< 2^k), each symbol
emits/consumes at most one 16-bit word, so the scan does fixed work per step.

Wire format per call: ``words (n_lanes, n_sym) uint16`` buffer of which the
first ``n_words[lane]`` entries are valid, plus the 4-byte final state per
lane.  The decoder reads words in reverse emission order (rANS is LIFO).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CoderTables", "encode", "decode", "encoded_bytes", "stack_tables"]

RANS_L = jnp.uint32(1 << 16)  # lower bound of the normalized state interval
_U32_ONE = jnp.uint32(1)


class CoderTables(NamedTuple):
    """Static rANS tables for ``n_tables`` distributions over alphabet A.

    freqs: (n_tables, A) uint32, each row sums to 2**precision, all >= 1
    cums:  (n_tables, A + 1) uint32 exclusive prefix sums
    slot2sym: (n_tables, 2**precision) uint16
    precision: int (static)
    """

    freqs: jnp.ndarray
    cums: jnp.ndarray
    slot2sym: jnp.ndarray
    precision: int

    @property
    def alphabet(self) -> int:
        return self.freqs.shape[-1]

    @property
    def n_tables(self) -> int:
        return self.freqs.shape[0]


@functools.partial(jax.jit, static_argnames=("precision",))
def _encode_impl(
    symbols: jnp.ndarray,  # (n_lanes, n_sym) uint16/int32
    table_idx: jnp.ndarray,  # (n_lanes,) int32
    freqs: jnp.ndarray,
    cums: jnp.ndarray,
    precision: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    n_lanes, n_sym = symbols.shape
    A = freqs.shape[-1]
    freqs_flat = freqs.reshape(-1)
    cums_flat = cums.reshape(-1)
    lane = jnp.arange(n_lanes, dtype=jnp.int32)
    t_base_f = table_idx.astype(jnp.int32) * A
    t_base_c = table_idx.astype(jnp.int32) * (A + 1)
    k = jnp.uint32(precision)
    shift16 = jnp.uint32(16)

    # rANS encodes in reverse symbol order so the decoder runs forward.
    xs = jnp.flip(symbols.astype(jnp.int32).T, axis=0)  # (n_sym, n_lanes)

    def step(carry, s):
        x, ptr, buf = carry
        f = freqs_flat[t_base_f + s]
        c = cums_flat[t_base_c + s]
        # renormalize: emit one 16-bit word if x would overflow
        x_max = ((RANS_L >> k) << shift16) * f
        emit = x >= x_max
        word = (x & jnp.uint32(0xFFFF)).astype(jnp.uint16)
        buf = buf.at[lane, ptr].set(word)
        ptr = ptr + emit.astype(jnp.int32)
        x = jnp.where(emit, x >> shift16, x)
        # C(s, x) = (x // f) << k + (x % f) + c
        q = x // f
        r = x - q * f
        x = (q << k) + r + c
        return (x, ptr, buf), None

    x0 = jnp.full((n_lanes,), RANS_L, dtype=jnp.uint32)
    ptr0 = jnp.zeros((n_lanes,), dtype=jnp.int32)
    buf0 = jnp.zeros((n_lanes, n_sym), dtype=jnp.uint16)
    (x, ptr, buf), _ = jax.lax.scan(step, (x0, ptr0, buf0), xs)
    return buf, ptr, x


@functools.partial(jax.jit, static_argnames=("precision", "n_sym"))
def _decode_impl(
    words: jnp.ndarray,  # (n_lanes, cap) uint16
    n_words: jnp.ndarray,  # (n_lanes,) int32
    state: jnp.ndarray,  # (n_lanes,) uint32
    table_idx: jnp.ndarray,  # (n_lanes,) int32
    freqs: jnp.ndarray,
    cums: jnp.ndarray,
    slot2sym: jnp.ndarray,
    precision: int,
    n_sym: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    n_lanes = words.shape[0]
    A = freqs.shape[-1]
    M = 1 << precision
    freqs_flat = freqs.reshape(-1)
    cums_flat = cums.reshape(-1)
    s2s_flat = slot2sym.reshape(-1)
    lane = jnp.arange(n_lanes, dtype=jnp.int32)
    t_base_f = table_idx.astype(jnp.int32) * A
    t_base_c = table_idx.astype(jnp.int32) * (A + 1)
    t_base_m = table_idx.astype(jnp.int32) * M
    k = jnp.uint32(precision)
    mask = jnp.uint32(M - 1)
    shift16 = jnp.uint32(16)

    def step(carry, _):
        x, ptr = carry
        slot = (x & mask).astype(jnp.int32)
        s = s2s_flat[t_base_m + slot].astype(jnp.int32)
        f = freqs_flat[t_base_f + s]
        c = cums_flat[t_base_c + s]
        x = f * (x >> k) + slot.astype(jnp.uint32) - c
        need = x < RANS_L
        word = words[lane, jnp.maximum(ptr, 0)].astype(jnp.uint32)
        x = jnp.where(need, (x << shift16) | word, x)
        ptr = ptr - need.astype(jnp.int32)
        return (x, ptr), s.astype(jnp.uint16)

    x0 = state.astype(jnp.uint32)
    ptr0 = n_words.astype(jnp.int32) - 1
    (x, ptr), syms = jax.lax.scan(step, (x0, ptr0), None, length=n_sym)
    return syms.T, x, ptr  # symbols (n_lanes, n_sym) in forward order


def encode(
    symbols: jnp.ndarray,
    table_idx: jnp.ndarray,
    tables: CoderTables,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Encode ``symbols[(lane, t)]`` -> (words, n_words, final_state)."""
    if symbols.ndim != 2:
        raise ValueError(f"symbols must be (n_lanes, n_sym), got {symbols.shape}")
    return _encode_impl(
        jnp.asarray(symbols),
        jnp.asarray(table_idx, dtype=jnp.int32),
        tables.freqs,
        tables.cums,
        tables.precision,
    )


def decode(
    words: jnp.ndarray,
    n_words: jnp.ndarray,
    state: jnp.ndarray,
    table_idx: jnp.ndarray,
    tables: CoderTables,
    n_sym: int,
    check: bool = False,
) -> jnp.ndarray:
    """Decode ``n_sym`` symbols per lane.  Exact inverse of :func:`encode`."""
    syms, x, ptr = _decode_impl(
        jnp.asarray(words),
        jnp.asarray(n_words, dtype=jnp.int32),
        jnp.asarray(state),
        jnp.asarray(table_idx, dtype=jnp.int32),
        tables.freqs,
        tables.cums,
        tables.slot2sym,
        tables.precision,
        n_sym,
    )
    if check:
        x = np.asarray(x)
        ptr = np.asarray(ptr)
        if not (x == np.uint32(1 << 16)).all() or not (ptr == -1).all():
            raise ValueError(
                "rANS stream corrupt: decoder did not return to initial state"
            )
    return syms


def encoded_bytes(n_words: jnp.ndarray) -> int:
    """Wire size: valid 16-bit words + 4-byte final state per lane."""
    n_words = np.asarray(n_words)
    return int(n_words.sum()) * 2 + 4 * n_words.shape[0]


def stack_tables(
    tabs: "list[CoderTables] | tuple[CoderTables, ...]",
    pad_alphabet: bool = False,
) -> CoderTables:
    """Concatenate several table sets into one along the table axis.

    This is what makes *batched* multi-stream (de)coding possible: streams
    that use different table sets (e.g. different lossy levels, or lossless
    vs lossy anchors) are stacked along the lane axis into one ``encode`` /
    ``decode`` call, with each lane's ``table_idx`` offset by the cumulative
    table count of the sets before it.  Requires identical precision; by
    default also identical alphabets.

    ``pad_alphabet=True`` additionally merges sets with *different*
    alphabets by zero-padding each ``freqs`` row (and edge-padding ``cums``)
    to the widest alphabet.  This is sound for **decoding only**: the
    decoder reads ``freqs[s]``/``cums[s]`` exclusively for symbols ``s``
    produced by ``slot2sym`` (always < the set's true alphabet), so the
    padding is never touched.  Padded tables must not be used to encode —
    a padded symbol id would emit a zero-frequency state transition.
    """
    if not tabs:
        raise ValueError("need at least one CoderTables to stack")
    precision = tabs[0].precision
    A = max(t.alphabet for t in tabs)
    for t in tabs:
        if t.precision != precision:
            raise ValueError(
                f"stack_tables requires identical precision, got "
                f"{[t.precision for t in tabs]}"
            )
        if t.alphabet != A and not pad_alphabet:
            raise ValueError(
                "stack_tables requires identical alphabets (or "
                f"pad_alphabet=True), got {[t.alphabet for t in tabs]}"
            )
    if len(tabs) == 1:
        return tabs[0]

    def _padded(t: CoderTables):
        if t.alphabet == A:
            return t.freqs, t.cums
        pad = A - t.alphabet
        freqs = jnp.pad(t.freqs, ((0, 0), (0, pad)))
        cums = jnp.pad(t.cums, ((0, 0), (0, pad)), mode="edge")
        return freqs, cums

    parts = [_padded(t) for t in tabs]
    return CoderTables(
        freqs=jnp.concatenate([f for f, _ in parts], axis=0),
        cums=jnp.concatenate([c for _, c in parts], axis=0),
        slot2sym=jnp.concatenate([t.slot2sym for t in tabs], axis=0),
        precision=precision,
    )
