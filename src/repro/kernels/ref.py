"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "kv_dequant_ref",
    "kv_quant_ref",
    "kv_dequant_tokens_ref",
    "kv_lossless_tokens_ref",
    "mha_ref",
    "decode_attention_ref",
    "ssd_ref",
]


def kv_dequant_ref(d_sym, anchors, bins, *, qmax, out_dtype=jnp.bfloat16):
    """(L2, G, g-1, C) symbols + (L2, G, C) anchors -> dequantized tokens."""
    d = d_sym.astype(jnp.float32) - float(qmax)
    out = d * bins[:, None, None, None] + anchors[:, :, None, :]
    return out.astype(out_dtype)


def kv_dequant_tokens_ref(d_sym, anchors, bins, *, qmax, out_dtype=jnp.bfloat16):
    """Oracle for :func:`kvquant.kv_dequant_tokens_pallas`.

    (B, G, g-1, C) symbols + (B, G, C) anchors -> (B, G, g, C) tokens with
    the anchor in slot 0 of every group.
    """
    d = d_sym.astype(jnp.float32) - float(qmax)
    others = d * bins[:, None, None, None] + anchors[:, :, None, :]
    tokens = jnp.concatenate([anchors[:, :, None, :], others], axis=2)
    return tokens.astype(out_dtype)


def kv_lossless_tokens_ref(d_sym, a_sym, scales, *, out_dtype=jnp.float32):
    """Oracle for :func:`kvquant.kv_lossless_tokens_pallas`.

    (B, G, g-1, C) integer-delta symbols (bias 254) + (B, G, C) 8-bit anchor
    symbols (bias 128) + (B, G) per-group scales -> (B, G, g, C) tokens.
    """
    q_a = a_sym.astype(jnp.float32) - 128.0
    q_d = d_sym.astype(jnp.float32) - 254.0
    s = scales.astype(jnp.float32)[:, :, None]
    anchor = q_a * s
    others = (q_d + q_a[:, :, None, :]) * s[..., None]
    tokens = jnp.concatenate([anchor[:, :, None, :], others], axis=2)
    return tokens.astype(out_dtype)


def kv_quant_ref(kv_grouped, bins, *, qmax):
    anchor = kv_grouped[:, :, :1, :]
    delta = kv_grouped[:, :, 1:, :].astype(jnp.float32) - anchor
    q = jnp.clip(jnp.round(delta / bins[:, None, None, None]), -qmax, qmax)
    return (q + qmax).astype(jnp.uint16)


def mha_ref(q, k, v, *, causal: bool, prefix_len=None, scale=None):
    """Reference attention.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D) with Hq % Hkv == 0 (GQA).
    ``prefix_len``: optional (B,) — positions < prefix_len attend
    bidirectionally (prefix-LM); requires causal=True.
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    Tk = k.shape[2]
    if causal:
        q_pos = jnp.arange(Tq)[:, None] + (Tk - Tq)
        k_pos = jnp.arange(Tk)[None, :]
        mask = k_pos <= q_pos  # (Tq, Tk)
        if prefix_len is not None:
            bidir = k_pos < prefix_len[:, None, None]  # (B, 1, Tk) per batch
            mask = mask[None] | bidir
            mask = mask[:, None]  # (B, 1, Tq, Tk)
        else:
            mask = mask[None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v)
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, *, kv_len=None, scale=None):
    """Single-step decode attention.

    q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_len: optional (B,) valid lengths.
    """
    B, Hq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhd,bhsd->bhs", q, k).astype(jnp.float32) * scale
    if kv_len is not None:
        S = k.shape[2]
        mask = jnp.arange(S)[None, None, :] < kv_len[:, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", w.astype(v.dtype), v)
    return out.astype(q.dtype)


def ssd_ref(x, dt, A, B, C, D=None, *, initial_state=None):
    """Mamba-2 SSD (state-space duality) sequential-scan oracle.

    Computes the exact SSM recurrence (naive O(T) scan over tokens):
      h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
      y_t = C_t^T h_t (+ D * x_t)

    x:  (B, T, H, P)    heads x headdim
    dt: (B, T, H)       positive step sizes
    A:  (H,)            negative scalars (per head, Mamba-2 scalar A)
    B:  (B, T, G, N)    groups x state
    C:  (B, T, G, N)
    D:  (H,) skip or None
    Returns y (B, T, H, P), final_state (B, H, P, N).
    """
    Bb, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)  # (B,T,H,N)
    Ch = jnp.repeat(C, rep, axis=2)
    decay = jnp.exp(dt * A[None, None, :])  # (B,T,H)

    def step(h, inp):
        x_t, dt_t, dec_t, B_t, C_t = inp
        # h: (B, H, P, N)
        h = h * dec_t[:, :, None, None] + (dt_t[:, :, None] * x_t)[..., None] * B_t[
            :, :, None, :
        ]
        y_t = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y_t

    h0 = (
        jnp.zeros((Bb, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(decay.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Ch.astype(jnp.float32), 1, 0),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,T,H,P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h
