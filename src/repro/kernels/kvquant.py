"""Pallas TPU kernel: fused KV delta-(de)quantization (CacheGen decode hot path).

The paper's serving node spends its codec time in (a) entropy decode and
(b) tensor reconstruction (dequantize deltas, add anchors, cast).  (a) is the
lane-parallel rANS scan (core/rans.py); (b) is a memory-bound elementwise+
broadcast op over the full KV tensor — the natural Pallas kernel.  On TPU the
win is fusing dequant + anchor-broadcast-add + dtype cast into one pass so
the KV tensor is written to HBM exactly once, in the layout the attention
kernel wants.

Layout: the chunk's tokens are *grouped* (group_size g): deltas are
``(G, g-1, C)`` and anchors ``(G, C)``; out[i, j, :] = d[i, j, :] * bin +
anchor[i, :].  Grid = (L2, G/Bg); each block holds Bg whole groups with the
full channel width so the anchor broadcast never crosses blocks.

Encode-side fusion (delta + scale + round + clip) is the mirror image and is
provided for the offline ``store_kv`` path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["kv_dequant_pallas", "kv_quant_pallas"]


def _dequant_kernel(d_sym_ref, anchors_ref, bins_ref, out_ref, *, qmax: int):
    # d_sym: (1, Bg, gm1, C) uint16 | anchors: (1, Bg, C) f32 | bins: (1, 1) f32
    d = d_sym_ref[0].astype(jnp.float32) - float(qmax)
    b = bins_ref[0, 0]
    anchor = anchors_ref[0][:, None, :]  # (Bg, 1, C)
    out_ref[0] = (d * b + anchor).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("qmax", "block_groups", "out_dtype", "interpret")
)
def kv_dequant_pallas(
    d_sym: jnp.ndarray,  # (L2, G, g-1, C) uint16 delta symbols
    anchors: jnp.ndarray,  # (L2, G, C) f32 dequantized anchors
    bins: jnp.ndarray,  # (L2,) f32 per-(layer,kv) effective bin width
    *,
    qmax: int,
    block_groups: int = 8,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused (dequant + anchor add + cast): returns (L2, G, g-1, C)."""
    L2, G, gm1, C = d_sym.shape
    Bg = min(block_groups, G)
    if G % Bg:
        raise ValueError(f"G={G} not divisible by block_groups={Bg}")
    grid = (L2, G // Bg)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Bg, gm1, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, Bg, C), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, Bg, gm1, C), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L2, G, gm1, C), out_dtype),
        interpret=interpret,
    )(d_sym, anchors, bins.reshape(L2, 1).astype(jnp.float32))


def _quant_kernel(kv_ref, bins_ref, sym_ref, *, qmax: int, gm1: int):
    # kv: (1, Bg, g, C) f32 grouped tokens; out symbols for the g-1 deltas
    kv = kv_ref[0].astype(jnp.float32)  # (Bg, g, C)
    anchor = kv[:, :1, :]
    delta = kv[:, 1:, :] - anchor  # (Bg, g-1, C)
    b = bins_ref[0, 0]
    q = jnp.clip(jnp.round(delta / b), -qmax, qmax) + qmax
    sym_ref[0] = q.astype(jnp.uint16)


@functools.partial(jax.jit, static_argnames=("qmax", "block_groups", "interpret"))
def kv_quant_pallas(
    kv_grouped: jnp.ndarray,  # (L2, G, g, C) f32 tokens grouped by anchor
    bins: jnp.ndarray,  # (L2,) f32
    *,
    qmax: int,
    block_groups: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused (delta + scale + round + clip) encode: returns (L2, G, g-1, C)."""
    L2, G, g, C = kv_grouped.shape
    Bg = min(block_groups, G)
    if G % Bg:
        raise ValueError(f"G={G} not divisible by block_groups={Bg}")
    grid = (L2, G // Bg)
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax, gm1=g - 1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Bg, g, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, Bg, g - 1, C), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L2, G, g - 1, C), jnp.uint16),
        interpret=interpret,
    )(kv_grouped, bins.reshape(L2, 1).astype(jnp.float32))
