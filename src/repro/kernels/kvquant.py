"""Pallas TPU kernels: fused KV delta-(de)quantization (CacheGen decode hot path).

The paper's serving node spends its codec time in (a) entropy decode and
(b) tensor reconstruction (dequantize deltas, add anchors, cast).  (a) is the
lane-parallel rANS scan (core/rans.py); (b) is a memory-bound elementwise+
broadcast op over the full KV tensor — the natural Pallas kernel.  On TPU the
win is fusing dequant + anchor-broadcast-add + dtype cast into one pass so
the KV tensor is written to HBM exactly once, in the layout the attention
kernel wants.

Layout: the chunk's tokens are *grouped* (group_size g): deltas are
``(G, g-1, C)`` and anchors ``(G, C)``; out[i, j, :] = d[i, j, :] * bin +
anchor[i, :].  Grid = (L2, G/Bg); each block holds Bg whole groups with the
full channel width so the anchor broadcast never crosses blocks.

Fused-path / oracle split (PR 1): these kernels are the *production* decode
path — ``core/codec.decode_chunks`` feeds them whole batches of chunks (the
leading axis folds n_chunks × L × 2) and they emit full token blocks
``(·, G, g, C)`` with the anchor in slot 0, so no separate anchor scatter or
merge pass touches HBM afterwards.  The unfused reference ops in
``core/quant.py`` and the pure-jnp twins in ``kernels/ref.py`` are retained
as the correctness oracle; on CPU the kernels run under ``interpret=True``
and are tested against that oracle (tests/test_kernels.py).

Two decode variants mirror the codec's two encoding families:

* :func:`kv_dequant_tokens_pallas` — lossy levels: per-(layer,kv) bin widths,
  f32 anchors already dequantized, out = [anchor; d*bin + anchor].
* :func:`kv_lossless_tokens_pallas` — level 0 ("lossless-after-8bit"):
  integer symbol deltas + per-group shared scales, bit-exact w.r.t. the
  8-bit quantization.

Encode-side fusion (delta + scale + round + clip) is the mirror image and is
provided for the offline ``store_kv`` path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "kv_dequant_pallas",
    "kv_quant_pallas",
    "kv_dequant_tokens_pallas",
    "kv_lossless_tokens_pallas",
    "pick_block_groups",
]


def pick_block_groups(G: int, requested: int) -> int:
    """Largest divisor of ``G`` that is <= ``requested`` (>= 1).

    The grid tiles whole groups; a non-divisible ``G % block_groups`` simply
    shrinks the block instead of raising.
    """
    bg = max(1, min(int(requested), int(G)))
    while G % bg:
        bg -= 1
    return bg


def _dequant_kernel(d_sym_ref, anchors_ref, bins_ref, out_ref, *, qmax: int):
    # d_sym: (1, Bg, gm1, C) uint16 | anchors: (1, Bg, C) f32 | bins: (1, 1) f32
    d = d_sym_ref[0].astype(jnp.float32) - float(qmax)
    b = bins_ref[0, 0]
    anchor = anchors_ref[0][:, None, :]  # (Bg, 1, C)
    out_ref[0] = (d * b + anchor).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("qmax", "block_groups", "out_dtype", "interpret")
)
def kv_dequant_pallas(
    d_sym: jnp.ndarray,  # (L2, G, g-1, C) uint16 delta symbols
    anchors: jnp.ndarray,  # (L2, G, C) f32 dequantized anchors
    bins: jnp.ndarray,  # (L2,) f32 per-(layer,kv) effective bin width
    *,
    qmax: int,
    block_groups: int = 8,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused (dequant + anchor add + cast): returns (L2, G, g-1, C)."""
    L2, G, gm1, C = d_sym.shape
    Bg = pick_block_groups(G, block_groups)
    grid = (L2, G // Bg)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Bg, gm1, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, Bg, C), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, Bg, gm1, C), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L2, G, gm1, C), out_dtype),
        interpret=interpret,
    )(d_sym, anchors, bins.reshape(L2, 1).astype(jnp.float32))


def _dequant_tokens_kernel(d_sym_ref, anchors_ref, bins_ref, out_ref, *, qmax: int):
    # d_sym: (1, Bg, g-1, C) | anchors: (1, Bg, C) f32 | out: (1, Bg, g, C)
    d = d_sym_ref[0].astype(jnp.float32) - float(qmax)
    b = bins_ref[0, 0]
    anchor = anchors_ref[0][:, None, :]  # (Bg, 1, C)
    tokens = jnp.concatenate([anchor, d * b + anchor], axis=1)  # (Bg, g, C)
    out_ref[0] = tokens.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("qmax", "block_groups", "out_dtype", "interpret")
)
def kv_dequant_tokens_pallas(
    d_sym: jnp.ndarray,  # (B, G, g-1, C) uint16 delta symbols
    anchors: jnp.ndarray,  # (B, G, C) f32 dequantized anchors
    bins: jnp.ndarray,  # (B,) f32 effective bin widths
    *,
    qmax: int,
    block_groups: int = 8,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused lossy decode to *whole token groups*: returns (B, G, g, C).

    Slot 0 of every group is the anchor itself; slots 1..g-1 are
    ``delta * bin + anchor``.  One HBM write produces the final token-major
    KV block — no separate anchor scatter/merge pass.  The leading axis B
    may fold (n_chunks, L, 2) for batched multi-chunk decode.
    """
    B, G, gm1, C = d_sym.shape
    Bg = pick_block_groups(G, block_groups)
    grid = (B, G // Bg)
    return pl.pallas_call(
        functools.partial(_dequant_tokens_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Bg, gm1, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, Bg, C), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, Bg, gm1 + 1, C), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, G, gm1 + 1, C), out_dtype),
        interpret=interpret,
    )(d_sym, anchors, bins.reshape(B, 1).astype(jnp.float32))


def _lossless_tokens_kernel(d_sym_ref, a_sym_ref, scales_ref, out_ref):
    # d_sym: (1, Bg, g-1, C) uint16 integer-delta symbols (bias 254)
    # a_sym: (1, Bg, C) uint16 8-bit anchor symbols (bias 128)
    # scales: (1, Bg) f32 per-group shared scale
    q_a = a_sym_ref[0].astype(jnp.float32) - 128.0  # (Bg, C)
    q_d = d_sym_ref[0].astype(jnp.float32) - 254.0  # (Bg, g-1, C)
    s = scales_ref[0][:, None]  # (Bg, 1)
    anchor = q_a * s  # (Bg, C)
    others = (q_d + q_a[:, None, :]) * s[..., None]  # (Bg, g-1, C)
    tokens = jnp.concatenate([anchor[:, None, :], others], axis=1)
    out_ref[0] = tokens.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_groups", "out_dtype", "interpret")
)
def kv_lossless_tokens_pallas(
    d_sym: jnp.ndarray,  # (B, G, g-1, C) uint16 integer-delta symbols
    a_sym: jnp.ndarray,  # (B, G, C) uint16 8-bit anchor symbols
    scales: jnp.ndarray,  # (B, G) f32 per-group shared scales
    *,
    block_groups: int = 8,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused level-0 ("lossless-after-8bit") decode to token groups.

    Reconstruction is ``(d - 254 + (a - 128)) * scale`` for delta slots and
    ``(a - 128) * scale`` for the anchor slot — bit-exact (in f32) with the
    unfused ``quant.lossless_reconstruct`` oracle.  Returns (B, G, g, C).
    """
    B, G, gm1, C = d_sym.shape
    Bg = pick_block_groups(G, block_groups)
    grid = (B, G // Bg)
    return pl.pallas_call(
        _lossless_tokens_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Bg, gm1, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, Bg, C), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Bg), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, Bg, gm1 + 1, C), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, G, gm1 + 1, C), out_dtype),
        interpret=interpret,
    )(d_sym, a_sym, scales.astype(jnp.float32))


def _quant_kernel(kv_ref, bins_ref, sym_ref, *, qmax: int, gm1: int):
    # kv: (1, Bg, g, C) f32 grouped tokens; out symbols for the g-1 deltas
    kv = kv_ref[0].astype(jnp.float32)  # (Bg, g, C)
    anchor = kv[:, :1, :]
    delta = kv[:, 1:, :] - anchor  # (Bg, g-1, C)
    b = bins_ref[0, 0]
    q = jnp.clip(jnp.round(delta / b), -qmax, qmax) + qmax
    sym_ref[0] = q.astype(jnp.uint16)


@functools.partial(jax.jit, static_argnames=("qmax", "block_groups", "interpret"))
def kv_quant_pallas(
    kv_grouped: jnp.ndarray,  # (L2, G, g, C) f32 tokens grouped by anchor
    bins: jnp.ndarray,  # (L2,) f32
    *,
    qmax: int,
    block_groups: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused (delta + scale + round + clip) encode: returns (L2, G, g-1, C)."""
    L2, G, g, C = kv_grouped.shape
    Bg = pick_block_groups(G, block_groups)
    grid = (L2, G // Bg)
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax, gm1=g - 1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Bg, g, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, Bg, g - 1, C), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L2, G, g - 1, C), jnp.uint16),
        interpret=interpret,
    )(kv_grouped, bins.reshape(L2, 1).astype(jnp.float32))
