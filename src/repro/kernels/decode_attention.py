"""Pallas TPU decode attention (single new token vs. a long KV cache).

This is the `generate_with_kv` hot loop (paper §6): once CacheGen has decoded
the fetched KV bitstreams into the cache, every generated token runs one
attention pass of a 1-token query against the full context KV.  At 32K-500K
context this is purely HBM-bandwidth-bound, so the kernel's job is to stream
K and V through VMEM exactly once with online-softmax accumulation
(FlashDecoding-style; the split-KV "K" axis here is the sequential minor grid
dimension, with cross-device sequence sharding handled one level up in
serving/kv_layout.py via a (max, sumexp) psum combine).

Grid = (B * Hq, S / Bs).  Blocks: K/V (Bs, D); accumulators in VMEM scratch.
Supports GQA via index-map head folding and ragged kv_len masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

NEG_INF = -1e30


def _decode_kernel(
    q_ref,  # (1, 1, D)
    k_ref,  # (1, 1, Bs, D)
    v_ref,  # (1, 1, Bs, D)
    len_ref,  # (1,)
    o_ref,  # (1, 1, D)
    m_scr,  # (1, 1)
    l_scr,  # (1, 1)
    acc_scr,  # (1, D)
    *,
    scale: float,
    block_s: int,
):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    s_start = si * block_s

    @pl.when(s_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (Bs, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (1, Bs)
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)  # (Bs, D)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_s", "interpret", "scale")
)
def decode_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    kv_len: jnp.ndarray | None = None,  # (B,) valid lengths
    *,
    scale: float | None = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    rep = Hq // Hkv
    bs = min(block_s, S)
    if S % bs:
        raise ValueError(f"S={S} not divisible by block_s={bs}")
    if scale is None:
        scale = float(1.0 / np.sqrt(D))
    if kv_len is None:
        kv_len = jnp.full((B,), S, jnp.int32)

    qf = q.reshape(B * Hq, 1, D)
    grid = (B * Hq, S // bs)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_s=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda h, j: (h, 0, 0)),
            pl.BlockSpec(
                (1, 1, bs, D),
                lambda h, j, rep=rep, Hq=Hq: (h // Hq, (h % Hq) // rep, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, bs, D),
                lambda h, j, rep=rep, Hq=Hq: (h // Hq, (h % Hq) // rep, j, 0),
            ),
            pl.BlockSpec((1,), lambda h, j, Hq=Hq: (h // Hq,)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k, v, jnp.asarray(kv_len, jnp.int32))
    return out.reshape(B, Hq, D)
