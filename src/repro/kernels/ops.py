"""Jit'd public wrappers for the Pallas kernels with implementation dispatch.

Every op takes ``impl``:
  * ``"pallas"``            — the TPU kernel (real hardware target)
  * ``"pallas_interpret"``  — kernel body interpreted on CPU (tests)
  * ``"xla"``               — pure-jnp path (dry-run lowering / roofline; the
                              memory-bounded chunked prefill attention lives
                              in models/attention.py)

The serving engine and codec call through here so the implementation is a
config switch, never a code change (MaxText-style `attention=...` knob).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.kvquant import kv_dequant_pallas, kv_quant_pallas

__all__ = ["mha", "decode_attention", "kv_dequant", "kv_quant"]

_IMPLS = ("pallas", "pallas_interpret", "xla")


def _check(impl: str) -> None:
    if impl not in _IMPLS:
        raise ValueError(f"impl must be one of {_IMPLS}, got {impl!r}")


def mha(
    q,
    k,
    v,
    prefix_len=None,
    *,
    causal: bool = True,
    impl: str = "xla",
    block_q: int = 128,
    block_k: int = 128,
):
    """Multi-head (GQA) attention, prefill shapes (B,Hq,Tq,D)x(B,Hkv,Tk,D)."""
    _check(impl)
    if impl == "xla":
        return ref.mha_ref(q, k, v, causal=causal, prefix_len=prefix_len)
    return flash_attention_pallas(
        q,
        k,
        v,
        prefix_len,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        interpret=(impl == "pallas_interpret"),
    )


def decode_attention(
    q,
    k,
    v,
    kv_len=None,
    *,
    impl: str = "xla",
    block_s: int = 512,
):
    """One-token decode attention (B,Hq,D) x (B,Hkv,S,D)."""
    _check(impl)
    if impl == "xla":
        return ref.decode_attention_ref(q, k, v, kv_len=kv_len)
    return decode_attention_pallas(
        q,
        k,
        v,
        kv_len,
        block_s=block_s,
        interpret=(impl == "pallas_interpret"),
    )


def kv_dequant(
    d_sym,
    anchors,
    bins,
    *,
    qmax: int,
    out_dtype=jnp.bfloat16,
    impl: str = "xla",
    block_groups: int = 8,
):
    """Fused delta-dequant + anchor add + cast: (L2,G,g-1,C) -> tokens."""
    _check(impl)
    if impl == "xla":
        return ref.kv_dequant_ref(d_sym, anchors, bins, qmax=qmax, out_dtype=out_dtype)
    return kv_dequant_pallas(
        d_sym,
        anchors,
        bins,
        qmax=qmax,
        block_groups=block_groups,
        out_dtype=out_dtype,
        interpret=(impl == "pallas_interpret"),
    )


def kv_quant(
    kv_grouped,
    bins,
    *,
    qmax: int,
    impl: str = "xla",
    block_groups: int = 8,
):
    """Fused delta + binned quantization: (L2,G,g,C) -> (L2,G,g-1,C) symbols."""
    _check(impl)
    if impl == "xla":
        return ref.kv_quant_ref(kv_grouped, bins, qmax=qmax)
    return kv_quant_pallas(
        kv_grouped,
        bins,
        qmax=qmax,
        block_groups=block_groups,
        interpret=(impl == "pallas_interpret"),
    )
