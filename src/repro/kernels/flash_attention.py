"""Pallas TPU flash attention (prefill) with causal / prefix-LM masks and GQA.

Used by the serving engine's prefill path — including CacheGen's
*text-recompute fallback* (paper §5.3: when bandwidth is too low, the chunk
is sent as text and its KV is recomputed, which runs this kernel).

Design (TPU-adapted FlashAttention):
  grid = (B * Hq, Tq / Bq, Tk / Bk); the key/value axis is the *minor* grid
  dimension, so for a fixed query block the kernel walks KV blocks
  sequentially, maintaining the online-softmax running (max, sum, acc) in
  VMEM scratch.  Block shapes are (Bq, D) x (Bk, D) with D the full head
  dim — MXU-aligned for D in {64, 128, 256}.  Causal masking skips
  fully-masked KV blocks via `pl.when` on block indices.

GQA is handled by mapping query head h to KV head h // (Hq // Hkv) in the
index maps — no jnp.repeat materialization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    plen_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    tk: int,
    tq: int,
    use_prefix: bool,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # token offsets (decoder offset: queries start at tk - tq)
    q_start = qi * block_q + (tk - tq)
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (Bq, Bk)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = k_pos <= q_pos
            if use_prefix:
                mask = mask | (k_pos < plen_ref[0])
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]  # (Bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal and not use_prefix:
        # skip KV blocks that are entirely in the future of this q block
        q_block_end = q_start + block_q - 1
        pl.when(k_start <= q_block_end)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, Tq, D)
    k: jnp.ndarray,  # (B, Hkv, Tk, D)
    v: jnp.ndarray,  # (B, Hkv, Tk, D)
    prefix_len: jnp.ndarray | None = None,  # (B,) int32 — prefix-LM bidir region
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    rep = Hq // Hkv
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    if Tq % bq or Tk % bk:
        raise ValueError(f"Tq={Tq}/Tk={Tk} not divisible by blocks ({bq},{bk})")
    if scale is None:
        scale = float(1.0 / np.sqrt(D))
    use_prefix = prefix_len is not None
    if prefix_len is None:
        prefix_len = jnp.zeros((B,), jnp.int32)

    qf = q.reshape(B * Hq, Tq, D)
    grid = (B * Hq, Tq // bq, Tk // bk)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=bq,
        block_k=bk,
        tk=Tk,
        tq=Tq,
        use_prefix=use_prefix,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda h, i, j, rep=rep, Hq=Hq: (h // Hq, (h % Hq) // rep, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda h, i, j, rep=rep, Hq=Hq: (h // Hq, (h % Hq) // rep, j, 0),
            ),
            pl.BlockSpec((1,), lambda h, i, j, Hq=Hq: (h // Hq,)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running sum
            pltpu.VMEM((bq, D), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, k, v, prefix_len)
    return out.reshape(B, Hq, Tq, D)
