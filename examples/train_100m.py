"""End-to-end training driver: a ~100M-parameter llama-family model trained
for a few hundred steps on the synthetic corpus, with checkpointing and
preemption recovery.  (Assignment deliverable (b): end-to-end driver.)

The default config is ~100M params (12L x d512 x ff2048, vocab 8192); on this
CPU container a step takes a few seconds — use --steps to taper.

Usage:
  PYTHONPATH=src python examples/train_100m.py --steps 200 [--resume]
"""
import argparse
import dataclasses

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data import MarkovLM
from repro.models import build
from repro.training import AdamWConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="results/train_100m")
    ap.add_argument("--small", action="store_true", help="tiny config (CI)")
    args = ap.parse_args()

    base = registry.get("smollm-360m")
    if args.small:
        cfg = base.tiny()
    else:
        cfg = dataclasses.replace(
            base, name="smollm-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=4, d_head=64, d_ff=2048, vocab_size=8192, remat=False,
        )
    model = build(cfg)
    plan_params = sum(
        int(np.prod(l.shape))
        for l in __import__("jax").tree_util.tree_leaves(
            model.param_plan(), is_leaf=lambda x: hasattr(x, "logical")
        )
    )
    print(f"[train] {cfg.name}: ~{plan_params/1e6:.1f}M params")

    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=5)

    def batch_fn(step):
        rng = np.random.default_rng(10_000 + step)
        toks = np.stack([lm.sample(rng, args.seq + 1) for _ in range(args.batch)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    ck = CheckpointManager(args.ckpt_dir, keep=2)
    tr = Trainer(
        model=model,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=50),
        batch_fn=batch_fn,
        ckpt=ck,
        ckpt_every=50,
        log_every=10,
    )
    state = tr.init_or_restore(seed=0)
    state, hist = tr.run(state, args.steps)
    print(f"[train] done: loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")


if __name__ == "__main__":
    main()
