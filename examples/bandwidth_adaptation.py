"""Reproduce the paper's Fig. 7 adaptation timeline on a synthetic trace.

Streams one context under the paper's illustrative bandwidth trace
(2 Gbps -> 0.2 Gbps -> 1 Gbps) and prints the per-chunk decision timeline —
showing the switch to text-recompute during the outage and back to fine
encoding levels when bandwidth recovers.

Usage:  PYTHONPATH=src python examples/bandwidth_adaptation.py
"""
import numpy as np

from repro.streaming.adaptation import TEXT, AdaptationPolicy
from repro.streaming.network import BandwidthTrace, NetworkModel
from repro.streaming.pipeline import simulate_stream
from repro.streaming.storage import ChunkMeta


def main() -> None:
    # a 9.6K-token context in 1.5K chunks; sizes are the measured
    # bytes/token of a qwen-110b-scale cache (benchmarks/ttft.py: level 0
    # 163 KB/tok ... level 4 36 KB/tok)
    n_chunks, toks = 7, 1440
    bpt = {0: 162690.0, 1: 84790.0, 2: 67368.0, 3: 50439.0, 4: 35719.0}
    metas = [
        ChunkMeta("ctx", i, i * toks, (i + 1) * toks,
                  sizes={l: int(toks * b) for l, b in bpt.items()},
                  text_bytes=toks * 4)
        for i in range(n_chunks)
    ]
    # paper Fig. 7 trace: 2 Gbps, drops to 0.2 at t=2s, recovers to 1 at t=4s
    # (SLO 5s for the 110B-scale cache; the paper illustrates a 7B cache)
    trace = BandwidthTrace(np.array([0.0, 2.0, 4.0]), np.array([2.0, 0.2, 1.0]))
    net = NetworkModel(trace)
    policy = AdaptationPolicy(
        levels_quality_order=[0, 1, 2, 3, 4], slo_s=5.0, default_level=1,
        prior_throughput_gbps=2.0,
    )
    res = simulate_stream(
        metas, policy, net, decode_bytes_per_s=4e9,
        recompute_s=lambda t, p: 0.9,  # 110B prefill per 1.5K chunk, 8 chips
    )
    names = {TEXT: "TEXT"}
    print(f"{'chunk':>5} {'config':>7} {'fetch':>14} {'compute':>16} {'MB':>7}")
    for t in res.timelines:
        print(
            f"{t.chunk_idx:>5} {names.get(t.config, f'L{t.config}'):>7} "
            f"{t.fetch_start:6.2f}-{t.fetch_end:6.2f} "
            f"{t.compute_start:7.2f}-{t.compute_end:7.2f} {t.nbytes/1e6:7.2f}"
        )
    print(f"TTFT = {res.ttft_s:.2f}s (SLO {res.slo_s}s, violated={res.slo_violated})")


if __name__ == "__main__":
    main()
