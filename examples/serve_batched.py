"""Batched serving with CacheGen context loading (assignment deliverable (b)).

Simulates a serving node receiving a stream of requests that reuse a pool of
long contexts (RAG-style).  For every request the engine either
  * recomputes prefill from text (cold / CacheGen-off), or
  * fetches the context's KV bitstream via CacheGen over a fluctuating link,
then generates a batched response.  Reports per-request TTFT (simulated
network + measured decode) and answer quality for both paths.

Usage:  PYTHONPATH=src python examples/serve_batched.py [--requests 8]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import codec as kvcodec
from repro.data import MarkovLM, TopicRetrievalTask
from repro.models import build
from repro.serving.engine import Engine
from repro.serving.kv_layout import caches_to_codec_kv
from repro.streaming import BandwidthTrace, CacheGenStreamer, KVStore, NetworkModel
from repro.streaming.adaptation import TEXT


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--contexts", type=int, default=3)
    ap.add_argument("--ctx-len", type=int, default=400)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, cache_capacity=args.ctx_len + 32)
    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=4)
    task = TopicRetrievalTask(lm=lm)
    rng = np.random.default_rng(1)

    # -- context pool: prefill once, store encoded (the paper's store_kv) ----
    ctxs, kvs = [], []
    for i in range(args.contexts):
        ctx, _ = task.make_context(rng, args.ctx_len)
        ctxs.append(ctx)
    tables = None
    store = None
    streamer = None
    for i, ctx in enumerate(ctxs):
        _, caches = engine.calculate_kv({"tokens": jnp.asarray(ctx[None])})
        kv = caches_to_codec_kv(caches, 0, args.ctx_len)
        kvs.append(kv)
    tables = kvcodec.profile(kvs, kvcodec.CodecConfig(precision=11))
    store = KVStore(tables)
    streamer = CacheGenStreamer(store, cfg)
    for i, kv in enumerate(kvs):
        store.store_kv(f"ctx{i}", kv, chunk_tokens=100)
    print(f"[pool] {args.contexts} contexts stored "
          f"({store.total_bytes('ctx0', 1)/1e3:.1f} KB each @ level 1)")

    # -- request loop ---------------------------------------------------------
    names = {TEXT: "TEXT"}
    for r in range(args.requests):
        cid = int(rng.integers(0, args.contexts))
        trace = BandwidthTrace.sampled(rng, 6, 0.05, 0.05, 2.0)
        net = NetworkModel(trace, rtt_s=0.002)
        t0 = time.perf_counter()
        plan = streamer.stream(
            f"ctx{cid}", net, slo_s=0.25, decode_bytes_per_s=300e6,
            recompute_s=lambda toks, pre: 0.02 * toks / 100,
            prior_throughput_gbps=float(trace.gbps[0]),
        )
        mat = streamer.materialize(plan, engine, ctxs[cid][None], batch=1)
        wall = time.perf_counter() - t0
        logits, caches_ref = engine.calculate_kv({"tokens": jnp.asarray(ctxs[cid][None])})
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        gen_cg = engine.generate_with_kv(mat, first, args.gen)
        gen_ref = engine.generate_with_kv(caches_ref, first, args.gen)
        agree = float((gen_cg == gen_ref).mean())
        cfgs = [names.get(c, f"L{c}") for c in plan.result.configs]
        print(
            f"[req {r}] ctx{cid} configs={cfgs} ttft_sim={plan.result.ttft_s*1e3:6.1f} ms "
            f"(SLO ok={not plan.result.slo_violated}) wall={wall:.2f}s agree={agree:.0%}"
        )


if __name__ == "__main__":
    main()
