"""Quickstart: encode a KV cache with CacheGen, stream it, generate.

Runs on CPU in ~2 minutes:
  1. builds a tiny llama-family model (smollm-360m reduced config),
  2. prefills a long synthetic context -> KV cache,
  3. profiles codec tables + stores multi-level bitstreams,
  4. streams them over a fluctuating simulated link with a TTFT SLO,
  5. decodes and generates — comparing against the uncompressed cache.

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import codec as kvcodec
from repro.data import MarkovLM
from repro.models import build
from repro.serving.engine import Engine
from repro.serving.kv_layout import caches_to_codec_kv
from repro.streaming import BandwidthTrace, CacheGenStreamer, KVStore, NetworkModel
from repro.streaming.adaptation import TEXT


def main() -> None:
    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, cache_capacity=640)

    # -- a long context ------------------------------------------------------
    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=3)
    rng = np.random.default_rng(0)
    T = 600
    tokens = lm.sample(rng, T)[None]
    print(f"[1] context: {T} tokens")

    # -- calculate_kv (paper interface) --------------------------------------
    logits, caches = engine.calculate_kv({"tokens": jnp.asarray(tokens)})
    kv = caches_to_codec_kv(caches, 0, T)
    raw = kvcodec.kv_nbytes_fp16(*[kv.shape[i] for i in (0, 2, 3)])
    print(f"[2] KV cache: {kv.shape} = {raw/1e6:.2f} MB fp16")

    # -- offline: profile tables + store every level --------------------------
    tables = kvcodec.profile([kv], kvcodec.CodecConfig(precision=11))
    store = KVStore(tables)
    streamer = CacheGenStreamer(store, cfg)
    metas = store.store_kv("ctx", kv, chunk_tokens=150)
    for lvl in range(tables.config.n_levels):
        tot = store.total_bytes("ctx", lvl)
        print(f"[3] level {lvl}: {tot/1e6:.3f} MB  ({raw/tot:.2f}x vs fp16)")

    # -- online: stream under a bandwidth drop with a 200 ms SLO --------------
    net = NetworkModel(BandwidthTrace.steps(0.03, [2.0, 2.0, 0.2, 0.1, 1.0]))
    plan = streamer.stream(
        "ctx", net, slo_s=0.2, decode_bytes_per_s=400e6,
        recompute_s=lambda toks, pre: 0.02 * toks / 150, prior_throughput_gbps=2.0,
    )
    names = {TEXT: "TEXT"}
    print(f"[4] per-chunk configs: {[names.get(c, f'L{c}') for c in plan.result.configs]}"
          f"  TTFT={plan.result.ttft_s*1e3:.1f} ms (SLO 200 ms, "
          f"violated={plan.result.slo_violated})")

    # -- generate_with_kv (paper interface) -----------------------------------
    mat = streamer.materialize(plan, engine, tokens, batch=1)
    first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    gen_ref = engine.generate_with_kv(caches, first, 16)
    gen_cg = engine.generate_with_kv(mat, first, 16)
    agree = float((gen_ref == gen_cg).mean())
    print(f"[5] greedy tokens (exact cache):    {gen_ref[0].tolist()}")
    print(f"    greedy tokens (CacheGen cache): {gen_cg[0].tolist()}")
    print(f"    agreement: {agree:.2%}")


if __name__ == "__main__":
    main()
