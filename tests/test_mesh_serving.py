"""Differential harness for the mesh-sharded serving engine (ISSUE 10).

Invariants:
  * mesh construction — ``make_serving_mesh`` / ``make_test_mesh`` raise a
    RuntimeError naming the exact ``XLA_FLAGS`` remediation and the current
    device census when the mesh does not fit the visible devices;
    ``make_serving_mesh(0)`` is a ValueError;
  * single-device rule no-op — entering ``sharding.use_rules`` on a
    one-device mesh leaves jit'd computations bit-identical to running
    outside any rules (the fallback must be a true no-op);
  * sharded row pool — blocked shard addressing, load-balanced allocation
    across shards, whole-shard divisibility errors, and exact degeneration
    to the base pool's lowest-free-row order at one shard;
  * mesh=1 — ``ShardedEngine`` is bit-identical to the plain ``Engine``
    through every primitive (``insert_runs`` / ``prefill_extend_rows`` /
    ``decode_step_rows`` / save-reset-restore), through ``ServeSession``,
    and through both schedulers (the ``ConcurrentScheduler`` wave and the
    ``ContinuousScheduler`` with generation and queueing);
  * mesh={2,4} (skipped below that many devices — CI's multi-device job
    forces 8 host devices) — per-request configs, TTFTs, caches and greedy
    tokens are bit-identical to the unsharded ``Engine`` oracle through
    both schedulers, admissions spread over every shard, the batch-1
    ``ServeSession`` fallback still matches, and a mid-generation
    suspend/resume on a sharded pool continues token-exactly.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import codec as kvcodec
from repro.launch.mesh import make_serving_mesh, make_test_mesh
from repro.models import sharding
from repro.serving.generation import GenerationSpec
from repro.serving.scheduler import (
    ConcurrentScheduler,
    ContinuousScheduler,
    PreemptionPolicy,
    RowPool,
    SessionRequest,
    ShardedRowPool,
)
from repro.serving.session import ServeSession
from repro.streaming import CacheGenStreamer, KVStore
from repro.streaming.network import BandwidthTrace, NetworkModel
from repro.streaming.pipeline import ContentionModel

T_CTX = 100
CHUNK = 20  # 5 chunks

N_DEV = len(jax.devices())

IDEAL = ContentionModel({1: 1.0, 2: 1.0})  # factor-1 at any N
SERIALIZED = ContentionModel({})  # factor(n) = n

needs = lambda n: pytest.mark.skipif(  # noqa: E731
    N_DEV < n,
    reason=f"needs {n} devices, have {N_DEV} (CI multi-device job sets "
    f"XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def mfix():
    from repro.configs import registry
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv

    rng = np.random.default_rng(0)
    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, cache_capacity=T_CTX + 48)
    tokens = rng.integers(0, cfg.vocab_size, size=(1, T_CTX)).astype(np.int32)
    logits, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens)})
    kv = caches_to_codec_kv(caches, 0, T_CTX)
    ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    metas = store.store_kv("ctx", kv, chunk_tokens=CHUNK)
    u = sum(m.sizes[1] for m in metas) * 8 / 1e9  # level-1 ctx in 1 s
    first = int(jnp.argmax(logits[0, -1]))
    return dict(cfg=cfg, params=params, eng=eng, tokens=tokens, kv=kv,
                store=store, streamer=streamer, metas=metas, u=u,
                first=first, sharded={})


def _sharded(mfix, n):
    """ShardedEngine over an n-device ("data",) mesh, cached per module."""
    if n not in mfix["sharded"]:
        from repro.serving.mesh_engine import ShardedEngine

        mfix["sharded"][n] = ShardedEngine(
            mfix["cfg"], mfix["params"], cache_capacity=T_CTX + 48,
            mesh=make_serving_mesh(n),
        )
    return mfix["sharded"][n]


def _mk_session(mfix, eng, **kw):
    kw.setdefault("slo_s", 1.25)
    kw.setdefault("recompute_s", lambda t, p: 0.15 * 1.25 * t / CHUNK)
    kw.setdefault("decode_bytes_per_s", 1e9)
    kw.setdefault("max_run_tokens", 2 * CHUNK)
    return ServeSession(mfix["streamer"], eng, **kw)


def _requests(mfix, eng, traces, sess_kw=None, arrivals=None, specs=None):
    sess_kw = sess_kw or [{} for _ in traces]
    arrivals = arrivals if arrivals is not None else [0.0] * len(traces)
    specs = specs if specs is not None else [None] * len(traces)
    return [
        SessionRequest(
            _mk_session(mfix, eng, **kw), "ctx", mfix["tokens"],
            NetworkModel(tr), prior_throughput_gbps=float(tr.gbps[0]),
            start_t=arr, generation=spec,
        )
        for tr, kw, arr, spec in zip(traces, sess_kw, arrivals, specs)
    ]


def _kv_np(caches):
    return (
        np.asarray(caches.kv_k[:, :, :T_CTX], np.float32),
        np.asarray(caches.kv_v[:, :, :T_CTX], np.float32),
    )


def _oracle_tokens(mfix, caches, first, n):
    out = mfix["eng"].generate_with_kv(
        caches, jnp.asarray([first], jnp.int32), n
    )
    return out[0].tolist()


def _assert_results_bit_identical(a, b, what=""):
    """Per-request equality of two scheduler results (request order):
    decisions, TTFTs, caches, emitted tokens and their virtual times."""
    for i, (x, y) in enumerate(zip(a.sessions, b.sessions)):
        assert x.configs == y.configs, f"{what} req {i}: configs"
        assert abs(x.ttft_s - y.ttft_s) < 1e-12, f"{what} req {i}: ttft"
        for p, q in zip(_kv_np(x.caches), _kv_np(y.caches)):
            assert np.array_equal(p, q), f"{what} req {i}: caches differ"
    if hasattr(a, "timeline"):
        for i, (ta, tb) in enumerate(zip(a.timeline, b.timeline)):
            assert ta.tokens_out == tb.tokens_out, f"{what} req {i}: tokens"
            assert ta.token_ts == tb.token_ts, f"{what} req {i}: token_ts"


# ---------------------------------------------------------------------------
# mesh construction errors (satellite: actionable remediation)
# ---------------------------------------------------------------------------


def test_make_serving_mesh_rejects_nonpositive():
    with pytest.raises(ValueError, match="data >= 1"):
        make_serving_mesh(0)


def test_mesh_error_names_remediation_and_census():
    want = N_DEV + 1
    with pytest.raises(RuntimeError) as e:
        make_serving_mesh(want)
    msg = str(e.value)
    assert f"--xla_force_host_platform_device_count={want}" in msg
    assert "Remediation" in msg and "before" in msg
    assert f"{N_DEV} visible (" in msg  # the census, so the gap is obvious


def test_test_mesh_error_names_shape_and_axes():
    with pytest.raises(RuntimeError) as e:
        make_test_mesh(data=N_DEV, model=2)
    msg = str(e.value)
    assert f"({N_DEV}, 2)" in msg and "'data'" in msg and "'model'" in msg
    assert f"--xla_force_host_platform_device_count={2 * N_DEV}" in msg


def test_use_rules_single_device_is_true_noop():
    """Constraining under a one-device mesh must be the identity: tracing
    the same computation with and without the rules installed produces
    bit-identical outputs (fresh jit wrappers, so both really trace)."""
    mesh = make_serving_mesh(1)
    x = jnp.asarray(np.random.default_rng(7).normal(size=(4, 8)), jnp.float32)

    def body(a):
        return jnp.tanh(sharding.constrain(a, "cache_rows", None)) @ a.T

    base = jax.jit(body)(x)  # no rules: constrain is a documented no-op
    with sharding.use_rules(mesh):
        spec = sharding.logical_to_spec(("cache_rows",))
        ruled = jax.jit(body)(x)  # traced under the rules
    # the rule resolved to the mesh's one "data" axis (not dropped)...
    assert spec[0] is not None
    # ...and the computation is bit-identical anyway
    assert np.array_equal(np.asarray(base), np.asarray(ruled))


# ---------------------------------------------------------------------------
# sharded row pool
# ---------------------------------------------------------------------------


def test_base_pool_is_one_shard():
    pool = RowPool(5)
    assert pool.n_shards == 1 and pool.rows_per_shard == 5
    assert [pool.shard_of(r) for r in range(5)] == [0] * 5


def test_sharded_pool_blocked_addressing_and_balance():
    pool = ShardedRowPool(8, n_shards=4)
    assert pool.rows_per_shard == 2
    assert [pool.shard_of(r) for r in range(8)] == [0, 0, 1, 1, 2, 2, 3, 3]
    # allocation round-robins shards (load first, lowest row on ties)
    order = [pool.allocate(f"r{i}")[0] for i in range(8)]
    assert order == [0, 2, 4, 6, 1, 3, 5, 7]
    # releases re-balance: freeing both rows of shard 1 makes it the
    # least-loaded shard, so it takes the next two admissions
    pool.release(2, "r1", 10.0)
    pool.release(3, "r5", 11.0)
    assert pool.allocate("r8")[0] == 2
    assert pool.allocate("r9")[0] == 3


def test_sharded_pool_requires_whole_shards():
    with pytest.raises(ValueError, match="whole shards"):
        ShardedRowPool(6, n_shards=4)
    with pytest.raises(ValueError, match="n_shards >= 1"):
        ShardedRowPool(4, n_shards=0)


def test_sharded_pool_one_shard_degenerates_to_base():
    a, b = ShardedRowPool(4, n_shards=1), RowPool(4)
    ops = [("alloc", "x"), ("alloc", "y"), ("rel", 0, "x"), ("alloc", "z")]
    got = []
    for pool in (a, b):
        rows = []
        for op in ops:
            if op[0] == "alloc":
                rows.append(pool.allocate(op[1])[0])
            else:
                pool.release(op[1], op[2], 1.0)
        got.append(rows)
    assert got[0] == got[1] == [0, 1, 0]


# ---------------------------------------------------------------------------
# mesh=1: bit-identity to the plain Engine (runs in tier-1, single device)
# ---------------------------------------------------------------------------


def test_mesh1_primitives_bit_identical(mfix):
    """Every sharded primitive on an 8-row cache produces byte-identical
    caches (and active-row logits) to the plain Engine's."""
    eng, kv = mfix["eng"], mfix["kv"]
    se = _sharded(mfix, 1)
    assert se.n_shards == 1 and se.row_axis is not None
    assert se.cache_rows(5) == 5  # no rounding needed at one shard
    rng = np.random.default_rng(3)

    runs, rows, starts = (10, 14, 8), (1, 4, 6), (0, 0, 0)
    kv_new = kv[:, :, : sum(runs)]
    texts = rng.integers(0, mfix["cfg"].vocab_size, size=(8, 6)).astype(
        np.int32
    )
    widths = np.array([0, 6, 0, 0, 6, 0, 6, 0])
    toks = rng.integers(0, mfix["cfg"].vocab_size, size=(8, 1)).astype(
        np.int32
    )
    active = np.array([False, True, False, False, True, False, True, False])

    outs = []
    for e in (eng, se):
        caches = e.empty_caches(8)
        caches = e.insert_runs(caches, kv_new, rows, starts, runs)
        lg_x, caches = e.prefill_extend_rows(jnp.asarray(texts), caches, widths)
        lg_d, caches = e.decode_step_rows(jnp.asarray(toks), caches, active)
        snap = e.save_row(caches, 4, int(caches.length[4]))
        caches = e.reset_rows(caches, [4])
        caches = e.restore_row(caches, snap, 2)
        outs.append((caches, lg_x, lg_d))
    (ca, xa, da), (cb, xb, db) = outs
    assert np.array_equal(np.asarray(ca.kv_k), np.asarray(cb.kv_k))
    assert np.array_equal(np.asarray(ca.kv_v), np.asarray(cb.kv_v))
    assert np.array_equal(np.asarray(ca.length), np.asarray(cb.length))
    sel = widths > 0
    assert np.array_equal(np.asarray(xa)[sel], np.asarray(xb)[sel])
    assert np.array_equal(np.asarray(da)[active], np.asarray(db)[active])


def test_mesh1_serve_session_bit_identical(mfix):
    trace = BandwidthTrace.steps(0.2, [1.0 * mfix["u"], 0.55 * mfix["u"]])
    runs = [
        _mk_session(mfix, e).run("ctx", mfix["tokens"], NetworkModel(trace))
        for e in (mfix["eng"], _sharded(mfix, 1))
    ]
    a, b = runs
    assert a.configs == b.configs
    assert abs(a.ttft_s - b.ttft_s) < 1e-12
    for p, q in zip(_kv_np(a.caches), _kv_np(b.caches)):
        assert np.array_equal(p, q)


def test_mesh1_schedulers_bit_identical(mfix):
    """The full serving stack — wave scheduler, then continuous admission
    with queueing + generation under evolving (serialized) contention — is
    bit-identical on a one-device mesh."""
    u, first = mfix["u"], mfix["first"]
    traces = [
        BandwidthTrace.constant(3 * u),
        BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u]),
        BandwidthTrace.constant(50 * u),
    ]
    specs = [GenerationSpec(6, first), None, GenerationSpec(4, first)]
    arrivals = [0.0, 0.05, 0.3]

    wave = [
        ConcurrentScheduler(e, contention=SERIALIZED).run(
            _requests(mfix, e, traces)
        )
        for e in (mfix["eng"], _sharded(mfix, 1))
    ]
    _assert_results_bit_identical(wave[0], wave[1], "wave")

    cont = [
        ContinuousScheduler(
            e, rows=2, contention=SERIALIZED, gen_step_s=0.01
        ).run(
            _requests(mfix, e, traces, arrivals=arrivals, specs=specs)
        )
        for e in (mfix["eng"], _sharded(mfix, 1))
    ]
    a, b = cont
    _assert_results_bit_identical(a, b, "continuous")
    assert a.n_rounds == b.n_rounds
    assert a.gen_occupancy == b.gen_occupancy
    assert [t.admit_t for t in a.timeline] == [t.admit_t for t in b.timeline]
    # the scenario really generated and really queued
    assert a.n_gen_tokens == 10 and any(t.queue_wait_s > 0 for t in a.timeline)


# ---------------------------------------------------------------------------
# mesh={2,4}: the sharded path vs the unsharded oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n_shards", [pytest.param(2, marks=needs(2)), pytest.param(4, marks=needs(4))]
)
def test_meshN_schedulers_match_unsharded_oracle(mfix, n_shards):
    """2S staggered requests (half generating) through both schedulers on a
    mesh of S: per-request decisions, TTFTs, caches and greedy tokens equal
    the plain Engine run's, and admissions land on every shard.  Contention
    is pinned ideal so sharded pricing (a pure perf term) cannot move
    decisions — what's under test is the sharded compute path."""
    u, first = mfix["u"], mfix["first"]
    se = _sharded(mfix, n_shards)
    assert se.n_shards == n_shards
    assert se.cache_rows(n_shards + 1) == 2 * n_shards
    n = 2 * n_shards
    traces = [
        BandwidthTrace.constant((3 + (i % 3)) * u) for i in range(n)
    ]
    kw = [dict(fixed_level=0) for _ in range(n)]
    specs = [GenerationSpec(5, first) if i % 2 else None for i in range(n)]
    arrivals = [0.02 * i for i in range(n)]

    wave = [
        ConcurrentScheduler(e, contention=IDEAL).run(
            _requests(mfix, e, traces, sess_kw=kw)
        )
        for e in (mfix["eng"], se)
    ]
    _assert_results_bit_identical(wave[0], wave[1], f"wave S={n_shards}")

    runs = [
        ContinuousScheduler(e, contention=IDEAL, gen_step_s=0.01).run(
            _requests(mfix, e, traces, sess_kw=kw, arrivals=arrivals,
                      specs=specs)
        )
        for e in (mfix["eng"], se)
    ]
    plain, shard = runs
    _assert_results_bit_identical(plain, shard, f"continuous S={n_shards}")
    # emitted streams also equal the greedy oracle on the final caches
    for i, spec in enumerate(specs):
        if spec is not None:
            want = _oracle_tokens(mfix, shard.sessions[i].caches, first, 5)
            assert shard.timeline[i].tokens_out == want, f"req {i}"
    # the balanced pool really spread the wave over every shard
    rows_per_shard = shard.n_rows // n_shards
    touched = {
        r // rows_per_shard for t in shard.timeline for r in t.rows_used
    }
    assert touched == set(range(n_shards))


@needs(2)
def test_mesh2_serve_session_falls_back_bit_identical(mfix):
    """A batch-1 ServeSession cache cannot split over 2 shards: the engine
    must transparently fall back to the single-device callables and still
    match the plain Engine byte-for-byte."""
    se = _sharded(mfix, 2)
    trace = BandwidthTrace.steps(0.15, [2.0 * mfix["u"], 0.4 * mfix["u"]])
    a, b = [
        _mk_session(mfix, e).run("ctx", mfix["tokens"], NetworkModel(trace))
        for e in (mfix["eng"], se)
    ]
    assert a.configs == b.configs
    assert abs(a.ttft_s - b.ttft_s) < 1e-12
    for p, q in zip(_kv_np(a.caches), _kv_np(b.caches)):
        assert np.array_equal(p, q)


@needs(2)
def test_mesh2_suspend_resume_crosses_shards_bit_exact(mfix):
    """Sharded pool, rows=2 (one per shard), both rows *generating* when a
    tight-deadline load arrives: the least-work victim (A, fewest emitted
    tokens) suspends mid-stream; A then takes the other generator's row —
    a resume that crosses the shard boundary through the sharded
    save/reset/restore path — and the displaced generator later resumes on
    A's old shard.  Both token streams still equal the greedy oracle's."""
    u, first = mfix["u"], mfix["first"]
    se = _sharded(mfix, 2)
    out = ContinuousScheduler(
        se, rows=2, contention=IDEAL, gen_step_s=0.05,
        preemption=PreemptionPolicy(victim="least_work"),
    ).run(_requests(
        mfix,
        se,
        [BandwidthTrace.constant(3 * u),    # A: slower load -> fewer emitted
         BandwidthTrace.constant(6 * u),    # C: quick load, long generation
         BandwidthTrace.constant(50 * u)],  # B: arrives mid-generation
        sess_kw=[dict(fixed_level=0), dict(fixed_level=0),
                 dict(fixed_level=0, slo_s=0.6)],
        arrivals=[0.0, 0.0, 0.55],
        specs=[GenerationSpec(10, first), GenerationSpec(12, first), None],
    ))
    assert out.n_preemptions >= 1 and out.n_resumes >= 1
    victim = out.timeline[0]
    # preempted *during* generation, resumed, and finished token-exactly
    assert victim.preempt_ts and victim.preempt_ts[0] > victim.finish_t
    emitted_before = sum(
        1 for ts in victim.token_ts if ts <= victim.preempt_ts[0]
    )
    assert 0 < emitted_before < 10
    for i, n in ((0, 10), (1, 12)):
        want = _oracle_tokens(mfix, out.sessions[i].caches, first, n)
        assert out.timeline[i].tokens_out == want, f"req {i}"
    # the victim's resume landed on the *other* shard's row
    rows_per_shard = out.n_rows // 2
    assert {r // rows_per_shard for r in victim.rows_used} == {0, 1}
    assert out.sessions[2].ttft_s < 0.6  # the preemptor met its SLO
    assert all(s.status == "ok" for s in out.sessions)
