"""Multi-device correctness: runs subprocesses with 8 forced host devices
(the main test process must keep the default single device)."""
import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_in_devices(py_body: str, n_devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", py_body], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    body = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro.configs import registry
from repro.models import build, sharding
from repro.launch.mesh import make_test_mesh
from repro.training import AdamWConfig
from repro.training.trainer import init_train_state, make_train_step

cfg = registry.get("olmo-1b").tiny()
model = build(cfg)
rng = np.random.default_rng(0)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
    "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
}
state = init_train_state(model, jax.random.PRNGKey(0))
step = make_train_step(model, AdamWConfig(warmup_steps=1))

# single device
s1, m1 = jax.jit(step)(state, batch)
loss1 = float(m1["loss"])

# sharded: 2x2 mesh, rules installed
mesh = make_test_mesh(2, 2)
with mesh, sharding.use_rules(mesh, {"embed": None}):
    s2, m2 = jax.jit(step)(state, batch)
    loss2 = float(m2["loss"])

pa = jax.tree_util.tree_leaves(s1.params)
pb = jax.tree_util.tree_leaves(s2.params)
maxdiff = max(
    float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    for a, b in zip(pa, pb)
)
print(json.dumps({"loss1": loss1, "loss2": loss2, "maxdiff": maxdiff}))
"""
    res = _run_in_devices(body)
    assert abs(res["loss1"] - res["loss2"]) < 5e-3, res
    assert res["maxdiff"] < 5e-2, res


@pytest.mark.slow
def test_sequence_parallel_decode_matches_plain():
    body = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro.configs import registry
from repro.models import build, sharding
from repro.launch.mesh import make_test_mesh
from repro.serving.kv_layout import alloc_caches

cfg = registry.get("olmo-1b").tiny()
model = build(cfg)
rng = np.random.default_rng(1)
params = model.init_params(jax.random.PRNGKey(0))
T, B, CAP = 24, 2, 32
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
logits, caches = model.prefill(params, {"tokens": tokens}, pad_to=CAP)
tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

# plain (single device)
l1, _ = jax.jit(model.decode_step)(params, tok, caches)

# sequence-parallel: cache S-axis sharded over "model" (SP decode path)
mesh = make_test_mesh(2, 2)
with mesh, sharding.use_rules(mesh, {"embed": None, "kv_seq_decode": "model"}):
    l2, _ = jax.jit(model.decode_step)(params, tok, caches)

d = float(jnp.max(jnp.abs(l1.astype(jnp.float32) - l2.astype(jnp.float32))))
print(json.dumps({"maxdiff": d}))
"""
    res = _run_in_devices(body)
    assert res["maxdiff"] < 5e-2, res


@pytest.mark.slow
def test_compressed_psum_means_correctly():
    body = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_test_mesh
from repro.training.grad_compress import compressed_psum

mesh = make_test_mesh(4, 2)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)

def f(x):
    return compressed_psum(x, "data")

y = shard_map(f, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None))(x)
# exact mean over the data axis
ref = jnp.broadcast_to(x.reshape(4, 2, 16).mean(axis=0, keepdims=True), (4, 2, 16)).reshape(8, 16)
err = float(jnp.max(jnp.abs(y - ref)))
scale = float(jnp.abs(x).max() / 127.0)
print(json.dumps({"err": err, "bin": scale}))
"""
    res = _run_in_devices(body)
    # int8 wire: error bounded by one quantization bin
    assert res["err"] <= res["bin"] * 1.01 + 1e-7, res
