"""Differential + property harness for the continuous-admission scheduler.

Invariants (ISSUE 5):
  * t=0 degeneration — with every arrival at t=0, preemption disabled and
    the pool sized to the request count, the continuous scheduler is
    bit-identical to
    the wave ``ConcurrentScheduler``: same per-chunk decisions, bytes,
    virtual TTFTs and bit-exact per-request caches, across the PR 2 trace
    matrix (flat / falling / oscillating / collapsed / sampled — the fast
    subset in tier-1, the full matrix in the slow job), and under an
    evolving (serialized) contention model;
  * N=1 degeneration — a single request through the continuous scheduler is
    bit-identical to ``ServeSession``;
  * admission — with fewer rows than requests, later requests queue: TTFT
    (measured from arrival) includes the wait, the admission instant equals
    the previous tenant's finish, and a row freed before an arrival charges
    no phantom queueing (backdated admission);
  * preemption — a tight-deadline arrival evicts a straggling session whose
    in-flight fetch is known to blow its SLO: the fetch handle is cancelled,
    the realized rows suspend into a snapshot, and the resumed session's
    final cache still matches the ``fused=False`` per-chunk oracle of its
    realized plan bit-exactly (suspend/restore round trip);
  * row pool — property test (hypothesis via tests/_hyp.py): random
    admit/finish/preempt sequences never double-allocate or leak rows, and
    misuse raises with the request id and pool state named;
  * contention — ``ContentionModel.text_factor`` interpolates a separate
    measured TEXT curve and falls back to the decode curve, and the
    stacked-prefill calibration parses factor(M) = M*rate(1)/rate(M).
"""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from tests._hyp import given, settings, st

from repro.core import codec as kvcodec
from repro.serving.scheduler import (
    ConcurrentScheduler,
    ContinuousScheduler,
    PreemptionPolicy,
    RowPool,
    SessionRequest,
)
from repro.serving.session import ServeSession
from repro.streaming import CacheGenStreamer, KVStore
from repro.streaming.adaptation import TEXT
from repro.streaming.network import BandwidthTrace, NetworkModel
from repro.streaming.pipeline import ContentionModel
from repro.streaming.streamer import FetchPlan

T_CTX = 100
CHUNK = 20  # 5 chunks

IDEAL = ContentionModel({1: 1.0, 2: 1.0})  # factor-1 at any N
SERIALIZED = ContentionModel({})  # factor(n) = n: n_active evolution matters


@pytest.fixture(scope="module")
def cfix():
    from repro.configs import registry
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv

    rng = np.random.default_rng(0)
    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, cache_capacity=T_CTX + 40)
    tokens = rng.integers(0, cfg.vocab_size, size=(1, T_CTX)).astype(np.int32)
    logits, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens)})
    kv = caches_to_codec_kv(caches, 0, T_CTX)
    ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    metas = store.store_kv("ctx", kv, chunk_tokens=CHUNK)
    u = sum(m.sizes[1] for m in metas) * 8 / 1e9  # level-1 ctx in 1 s
    return dict(cfg=cfg, eng=eng, tokens=tokens, store=store,
                streamer=streamer, metas=metas, u=u)


def _mk_session(cfix, **kw):
    kw.setdefault("slo_s", 1.25)
    kw.setdefault("recompute_s", lambda t, p: 0.15 * 1.25 * t / CHUNK)
    kw.setdefault("decode_bytes_per_s", 1e9)
    kw.setdefault("max_run_tokens", 2 * CHUNK)
    return ServeSession(cfix["streamer"], cfix["eng"], **kw)


def _trace_matrix(u):
    return {
        "flat": BandwidthTrace.constant(400 * u),
        "falling": BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u]),
        "oscillating": BandwidthTrace.steps(
            0.15, [2.0 * u, 0.4 * u, 2.0 * u, 0.4 * u]
        ),
        "collapsed": BandwidthTrace.constant(0.002 * u),
    }


def _kv_np(caches):
    return (
        np.asarray(caches.kv_k[:, :, :T_CTX], np.float32),
        np.asarray(caches.kv_v[:, :, :T_CTX], np.float32),
    )


def _oracle(cfix, result):
    """fused=False per-chunk materialization of a session's realized plan."""
    plan = FetchPlan(
        context_id="ctx", result=result.stream_result(), metas=cfix["metas"]
    )
    return cfix["streamer"].materialize(
        plan, cfix["eng"], cfix["tokens"], batch=1, fused=False
    )


def _requests(cfix, traces, sess_kw=None, arrivals=None, priors=True):
    sess_kw = sess_kw or [{} for _ in traces]
    arrivals = arrivals if arrivals is not None else [0.0] * len(traces)
    return [
        SessionRequest(
            _mk_session(cfix, **kw), "ctx", cfix["tokens"], NetworkModel(tr),
            prior_throughput_gbps=float(tr.gbps[0]) if priors else None,
            start_t=arr,
        )
        for tr, kw, arr in zip(traces, sess_kw, arrivals)
    ]


def _assert_sessions_bit_identical(a, b, what):
    assert a.configs == b.configs, (what, a.configs, b.configs)
    assert [t.nbytes for t in a.timelines] == [t.nbytes for t in b.timelines]
    assert [t.hedged for t in a.timelines] == [t.hedged for t in b.timelines]
    assert abs(a.ttft_s - b.ttft_s) < 1e-12
    for x, y in zip(_kv_np(a.caches), _kv_np(b.caches)):
        assert np.array_equal(x, y), f"{what}: caches differ"


# ---------------------------------------------------------------------------
# t=0 / N=1 degeneration differentials
# ---------------------------------------------------------------------------


def test_continuous_t0_bit_identical_to_wave(cfix):
    """All arrivals at t=0, preemption off, rows = N: the event loop must
    degenerate to exactly the wave scheduler — decisions, TTFT and caches —
    on a heterogeneous mix (no priors: chunk 0 streams at the default level,
    so levels, TEXT and batched decodes all appear)."""
    u = cfix["u"]
    m = _trace_matrix(u)
    traces = [m["flat"], m["falling"], m["oscillating"],
              BandwidthTrace.constant(3 * u)]
    for contention in (IDEAL, SERIALIZED):
        wave = ConcurrentScheduler(cfix["eng"], contention=contention).run(
            _requests(cfix, traces, priors=False)
        )
        cont = ContinuousScheduler(cfix["eng"], contention=contention).run(
            _requests(cfix, traces, priors=False)
        )
        assert cont.n_rows == len(traces)
        assert cont.n_preemptions == 0 and cont.n_resumes == 0
        assert cont.n_rounds == wave.n_rounds
        assert cont.n_decode_batches == wave.n_decode_batches
        assert cont.n_text_batches == wave.n_text_batches
        for i, (a, b) in enumerate(zip(cont.sessions, wave.sessions)):
            _assert_sessions_bit_identical(a, b, f"req {i}")
        if contention is IDEAL:
            # the scenario actually exercised the batched paths
            all_configs = [c for s in cont.sessions for c in s.configs]
            assert TEXT in all_configs and any(c != TEXT for c in all_configs)
            assert cont.n_decode_batches >= 1


def test_continuous_n1_bit_identical_to_session(cfix):
    u = cfix["u"]
    for trace, kw in (
        (BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u]), {}),
        (BandwidthTrace.constant(3 * u), dict(fixed_level=0)),
    ):
        prior = float(trace.gbps[0])
        res = _mk_session(cfix, **kw).run(
            "ctx", cfix["tokens"], NetworkModel(trace),
            prior_throughput_gbps=prior,
        )
        out = ContinuousScheduler(cfix["eng"], contention=IDEAL).run([
            SessionRequest(_mk_session(cfix, **kw), "ctx", cfix["tokens"],
                           NetworkModel(trace), prior_throughput_gbps=prior)
        ])
        _assert_sessions_bit_identical(out.sessions[0], res, "N=1")


@pytest.mark.slow
def test_continuous_t0_differential_matrix(cfix):
    """Full PR 2 trace matrix (named shapes + sampled traces) x recompute
    regimes: t=0 continuous == wave, bit-identical."""
    u = cfix["u"]
    shapes = list(_trace_matrix(u).values())
    rng = np.random.default_rng(7)
    shapes += [
        BandwidthTrace.sampled(rng, 6, 0.12, 0.2 * u, 4.0 * u)
        for _ in range(3)
    ]
    r_slow = lambda t, p: 100.0  # noqa: E731  (GPU busy: no TEXT)
    r_mid = lambda t, p: 0.15 * 1.25 * t / CHUNK  # noqa: E731
    for recompute_s in (r_slow, r_mid):
        sess_kw = [dict(recompute_s=recompute_s) for _ in shapes]
        wave = ConcurrentScheduler(cfix["eng"], contention=IDEAL).run(
            _requests(cfix, shapes, sess_kw)
        )
        cont = ContinuousScheduler(cfix["eng"], contention=IDEAL).run(
            _requests(cfix, shapes, sess_kw)
        )
        for i, (a, b) in enumerate(zip(cont.sessions, wave.sessions)):
            _assert_sessions_bit_identical(a, b, f"matrix req {i}")


# ---------------------------------------------------------------------------
# admission: queueing, recycling, backdating
# ---------------------------------------------------------------------------


def test_admission_queues_and_recycles_rows(cfix):
    """rows=1, two t=0 arrivals: the second is admitted exactly when the
    first finishes (its row recycled + zeroed), its TTFT includes the wait,
    and both caches land their whole context — the recycled-row tenant
    bit-exact against the fused=False oracle (a stale row would corrupt it)."""
    u = cfix["u"]
    traces = [_trace_matrix(u)["falling"], BandwidthTrace.constant(3 * u)]
    out = ContinuousScheduler(cfix["eng"], rows=1, contention=IDEAL).run(
        _requests(cfix, traces, sess_kw=[{}, dict(fixed_level=0)])
    )
    t0, t1 = out.timeline
    assert t0.admit_t == 0.0 and t0.queue_wait_s == 0.0
    assert t1.admit_t == pytest.approx(t0.finish_t)
    assert t1.queue_wait_s > 0.0
    assert t0.rows_used == [0] and t1.rows_used == [0]  # recycled
    # TTFT from arrival covers the wait plus the load itself
    assert out.sessions[1].ttft_s > t1.queue_wait_s
    for s, exact in zip(out.sessions, (False, True)):
        assert int(s.caches.length[0]) == T_CTX
        ref = _oracle(cfix, s)
        for a, b in zip(_kv_np(s.caches), _kv_np(ref)):
            if exact:  # level-0 tenant of the recycled row: bit-exact
                assert np.array_equal(a, b), "recycled row != oracle"
            else:
                np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)


def test_admission_backdates_to_arrival_on_free_row(cfix):
    """An arrival during another session's long fetch must not be charged
    phantom queueing: its row was free the whole time, so admission is
    backdated to the exact arrival instant and its decisions match a solo
    session started there."""
    u = cfix["u"]
    slow = BandwidthTrace.constant(0.05 * u)  # r0 fetches for a long time
    fast = BandwidthTrace.constant(3 * u)
    arrive_late = 0.4
    out = ContinuousScheduler(cfix["eng"], rows=2, contention=IDEAL).run(
        _requests(
            cfix, [slow, fast],
            sess_kw=[dict(fixed_level=0), {}],
            arrivals=[0.0, arrive_late],
        )
    )
    tl = out.timeline[1]
    assert tl.admit_t == pytest.approx(arrive_late)
    assert tl.queue_wait_s == pytest.approx(0.0)
    solo = _mk_session(cfix).run(
        "ctx", cfix["tokens"], NetworkModel(fast),
        prior_throughput_gbps=float(fast.gbps[0]), start_t=arrive_late,
    )
    _assert_sessions_bit_identical(out.sessions[1], solo, "late arrival")


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------


def test_preemption_straggler_yields_row_and_resumes(cfix):
    """A pinned-level session on a collapsing link holds the only row with
    an in-flight fetch that blows its SLO; a tight-deadline arrival preempts
    it (fetch cancelled, rows suspended), finishes fast, and the straggler
    resumes and completes — both caches bit-exact vs. the fused=False
    oracle of their realized plans."""
    u = cfix["u"]
    slow = BandwidthTrace.steps(0.1, [3.0 * u, 0.0005 * u])
    fast = BandwidthTrace.constant(50 * u)
    reqs = _requests(
        cfix, [slow, fast],
        sess_kw=[dict(fixed_level=0), dict(fixed_level=0)],
        arrivals=[0.0, 0.3],
    )
    out = ContinuousScheduler(
        cfix["eng"], rows=1, contention=IDEAL, preemption=PreemptionPolicy()
    ).run(reqs)
    assert out.n_preemptions == 1 and out.n_resumes == 1
    t0, t1 = out.timeline
    assert t0.preempt_ts == [pytest.approx(0.3)]
    assert len(t0.resume_ts) == 1
    # the tight arrival took the row and finished before the straggler
    assert t1.admit_t == pytest.approx(0.3)
    assert t1.finish_t < t0.finish_t
    assert out.sessions[1].ttft_s < reqs[1].session.slo_s
    # the straggler's cancelled fetch is recorded and was re-decided
    assert len(out.sessions[0].timelines) == len(cfix["metas"])
    for s in out.sessions:
        assert int(s.caches.length[0]) == T_CTX
        ref = _oracle(cfix, s)
        for a, b in zip(_kv_np(s.caches), _kv_np(ref)):
            assert np.array_equal(a, b), "preempted cache != oracle"


def test_preemption_disabled_means_fifo_convoy(cfix):
    """The same scenario without a PreemptionPolicy must convoy: the tight
    arrival waits out the straggler's whole load and blows its SLO."""
    u = cfix["u"]
    slow = BandwidthTrace.steps(0.1, [3.0 * u, 0.0005 * u])
    fast = BandwidthTrace.constant(50 * u)
    out = ContinuousScheduler(cfix["eng"], rows=1, contention=IDEAL).run(
        _requests(
            cfix, [slow, fast],
            sess_kw=[dict(fixed_level=0), dict(fixed_level=0)],
            arrivals=[0.0, 0.3],
        )
    )
    assert out.n_preemptions == 0
    assert out.sessions[1].ttft_s > out.sessions[1].slo_s
    assert out.timeline[1].admit_t == pytest.approx(out.timeline[0].finish_t)


def test_preemption_respects_waiter_headroom(cfix):
    """A waiter whose SLO will already have expired by the earliest instant
    it could take the victim's row (the victim's straggling fetch starts
    after the waiter's deadline) gains nothing; the default policy refuses
    to thrash the straggler's row for it."""
    u = cfix["u"]
    sizes = [m.sizes[0] for m in cfix["metas"]]
    # fast segment sized so chunks 0 and 1 (level 0) finish just inside it;
    # chunk 2's fetch — the only one that can blow the victim's SLO — then
    # starts at ~0.30, after the waiter's 0.05 + 0.1 = 0.15 deadline
    rate_fast = (sizes[0] + sizes[1]) * 8.0 / 1e9 / 0.30
    slow = BandwidthTrace.steps(0.31, [rate_fast, 0.0005 * u])
    fast = BandwidthTrace.constant(50 * u)
    out = ContinuousScheduler(
        cfix["eng"], rows=1, contention=IDEAL, preemption=PreemptionPolicy()
    ).run(
        _requests(
            cfix, [slow, fast],
            sess_kw=[dict(fixed_level=0), dict(fixed_level=0, slo_s=0.1)],
            arrivals=[0.0, 0.05],
        )
    )
    assert out.n_preemptions == 0, "expired waiter must not preempt"
    # the same waiter with headroom does preempt (control)
    out2 = ContinuousScheduler(
        cfix["eng"], rows=1, contention=IDEAL, preemption=PreemptionPolicy()
    ).run(
        _requests(
            cfix, [slow, fast],
            sess_kw=[dict(fixed_level=0), dict(fixed_level=0, slo_s=1.25)],
            arrivals=[0.0, 0.05],
        )
    )
    assert out2.n_preemptions == 1


# ---------------------------------------------------------------------------
# descriptive errors (row pool, resume/preempt misuse)
# ---------------------------------------------------------------------------


def test_row_pool_errors_name_request_and_state():
    pool = RowPool(2)
    pool.allocate("req0:ctx")
    pool.allocate("req1:ctx")
    with pytest.raises(RuntimeError, match=r"req2:ctx.*beyond row-pool "
                                           r"capacity.*0/2 rows free"):
        pool.allocate("req2:ctx")
    with pytest.raises(RuntimeError, match=r"row 7.*req0:ctx.*not allocated"):
        pool.release(7, "req0:ctx", 1.0)
    with pytest.raises(RuntimeError, match=r"row 1.*req0:ctx.*owned by "
                                           r"'req1:ctx'"):
        pool.release(1, "req0:ctx", 1.0)
    with pytest.raises(ValueError, match="at least one row"):
        RowPool(0)


def test_resume_and_preempt_misuse_raise(cfix):
    u = cfix["u"]
    trace = BandwidthTrace.constant(3 * u)
    out = ContinuousScheduler(cfix["eng"], contention=IDEAL).run(
        _requests(cfix, [trace], [dict(fixed_level=0)])
    )
    # reconstruct a finished task state via a fresh scheduler run's session
    from repro.serving.session import SessionTask

    task = SessionTask(
        _mk_session(cfix, fixed_level=0), "ctx", cfix["tokens"],
        NetworkModel(trace), label="req0:ctx",
    )
    with pytest.raises(RuntimeError, match=r"resuming request 'req0:ctx'.*"
                                           r"not suspended"):
        task.resume(0, 1.0)
    while not task.done:
        task.step()
    with pytest.raises(RuntimeError, match=r"preempting request 'req0:ctx'.*"
                                           r"already finished"):
        task.suspend(1.0)
    assert out.sessions[0].configs  # scheduler run above completed


# ---------------------------------------------------------------------------
# row-pool property test (hypothesis via tests/_hyp.py)
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(seed=st.integers(0, 10**6), n_rows=st.integers(1, 8))
def test_row_pool_never_double_allocates_or_leaks(seed, n_rows):
    """Random admit/finish/preempt sequences: every allocation is unique and
    in range, free + allocated always partitions the pool, a row freed by a
    finish/preempt always comes back flagged dirty (needs reset), and
    over-capacity admission raises."""
    rng = np.random.default_rng(seed)
    pool = RowPool(n_rows)
    allocated = {}  # row -> owner
    ever_released = set()
    t = 0.0
    next_req = 0
    for _ in range(200):
        t += float(rng.uniform(0.0, 1.0))
        op = int(rng.integers(3))
        if op == 0:  # admit
            owner = f"req{next_req}:ctx"
            if pool.n_free == 0:
                with pytest.raises(RuntimeError, match="beyond row-pool"):
                    pool.allocate(owner)
                continue
            row, free_since, dirty = pool.allocate(owner)
            next_req += 1
            assert 0 <= row < n_rows
            assert row not in allocated, "double allocation"
            assert free_since <= t
            assert dirty == (row in ever_released), "dirty flag wrong"
            allocated[row] = owner
        elif allocated:  # finish and preempt both release the row
            row = list(allocated)[int(rng.integers(len(allocated)))]
            pool.release(row, allocated.pop(row), t)
            ever_released.add(row)
        assert pool.n_free == n_rows - len(allocated), "leaked rows"
    # drain: everything comes back, and recycled rows read as dirty
    for row in list(allocated):
        pool.release(row, allocated.pop(row), t)
        ever_released.add(row)
    assert pool.n_free == n_rows
    for i in range(n_rows):
        row, _, dirty = pool.allocate(f"post{i}:ctx")
        assert dirty == (row in ever_released)


# ---------------------------------------------------------------------------
# contention: separate TEXT factor + calibration parsing
# ---------------------------------------------------------------------------


def test_text_factor_interpolates_and_falls_back():
    both = ContentionModel({1: 1.0, 4: 3.0}, text_factors={1: 1.0, 4: 2.0})
    assert both.factor(4) == 3.0
    assert both.text_factor(4) == 2.0
    assert both.text_factor(1) == 1.0
    assert both.text_factor(2) == pytest.approx(4.0 / 3.0)  # interpolated
    decode_only = ContentionModel({1: 1.0, 4: 3.0})
    assert decode_only.text_factor(4) == 3.0  # falls back to decode curve
    empty = ContentionModel({})
    assert empty.text_factor(5) == 5.0  # serialized fallback of the fallback


def test_stacked_prefill_calibration_parses(tmp_path, monkeypatch):
    from repro.streaming import calibration

    path = tmp_path / "BENCH_codec.json"
    path.write_text(json.dumps({
        "host_backend": jax.default_backend(),
        "fused": {"bytes_per_s": 1.0},
        "stacked_prefill": {
            "1": {"batched": {"tokens_per_s": 100.0}},
            "4": {"batched": {"tokens_per_s": 250.0}},
            "8": {"batched": {"tokens_per_s": 1600.0}},  # super-linear: clamp
        },
    }))
    monkeypatch.setenv("CACHEGEN_BENCH_CODEC", str(path))
    calibration.clear_calibration_cache()
    try:
        factors = calibration.measured_text_contention_factors()
        assert factors == {1: 1.0, 4: pytest.approx(1.6), 8: 1.0}
    finally:
        calibration.clear_calibration_cache()


def test_text_factor_steers_decisions_separately(cfix):
    """With decode stacking expensive but TEXT stacking free, a loaded
    engine must keep TEXT chunks it would shed under the decode-priced
    model (the pre-split behavior)."""
    u = cfix["u"]
    mk = lambda: _mk_session(cfix)  # noqa: E731
    trace = lambda: BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u])  # noqa: E731

    def n_text(contention):
        out = ConcurrentScheduler(cfix["eng"], contention=contention).run([
            SessionRequest(mk(), "ctx", cfix["tokens"], NetworkModel(trace()),
                           prior_throughput_gbps=1.0 * u)
            for _ in range(4)
        ])
        return sum(1 for s in out.sessions for c in s.configs if c == TEXT)

    priced_by_decode = ContentionModel({})  # serialized, TEXT falls back
    text_free = ContentionModel({}, text_factors={1: 1.0, 8: 1.0})
    assert n_text(text_free) > n_text(priced_by_decode), (
        "a free TEXT curve must keep TEXT chunks the decode-priced model sheds"
    )


# ---------------------------------------------------------------------------
# benchmark acceptance (separate CI job)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_continuous_serving_bench_acceptance(tmp_path):
    """Reduced benchmarks/continuous_serving.py run: continuous admission
    beats closed waves on p95 TTFT at the higher arrival rate, and the
    straggler mix actually exercises preemption + resume with complete
    contexts.  All virtual-clock: deterministic per seed."""
    import benchmarks.continuous_serving as cs

    report = cs.run(out_path=str(tmp_path / "BENCH_serving.json"),
                    n_requests=16, verbose=False)
    acc = report["acceptance"]
    assert acc["p95_improved_at_high_rate"] is True
    assert acc["preemption_exercised"] is True
    assert acc["preempted_contexts_complete"] is True
    high = report["rates"][-1]
    assert high["continuous"]["ttft_p95_s"] < high["wave"]["ttft_p95_s"]
    assert high["continuous"]["peak_live_rows"] <= cs.ROWS
    assert report["preemption"]["on"]["n_preemptions"] >= 1
    assert report["preemption"]["on"]["n_resumes"] >= 1
