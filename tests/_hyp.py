"""Hypothesis shim: real property-based testing when ``hypothesis`` is
installed, a fixed-seed random-sampling fallback when it is not.

The tier-1 suite must collect and run in minimal environments (only jax +
numpy + msgpack + pytest).  Test modules import ``given``/``settings``/``st``
from here instead of from ``hypothesis`` directly; the fallback samples each
strategy from a deterministic RNG for up to ``max_examples`` (capped)
iterations — no shrinking, but the same assertions run.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by either environment
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools

    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_CAP = 20  # keep CI time bounded without shrinking support

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module surface
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def given(**strategies):
        def deco(fn):
            # zero-arg wrapper: pytest must not mistake the strategy
            # parameters for fixtures (no functools.wraps on purpose)
            def wrapper():
                n = min(getattr(wrapper, "_max_examples", 10), _FALLBACK_CAP)
                rng = np.random.default_rng(0xCAC4E)
                for _ in range(n):
                    fn(**{k: s.sample(rng) for k, s in strategies.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
