"""Per-architecture smoke tests: reduced config, fwd/train step on CPU,
output shapes + finiteness (assignment requirement), plus model invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import build

RNG = np.random.default_rng(0)
ARCHS = registry.names()


def make_batch(cfg, B=2, T=24):
    batch = {}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            RNG.normal(size=(B, T, cfg.frontend_dim)), jnp.float32
        )
    elif cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_prefix_tokens, cfg.frontend_dim)), jnp.float32
        )
    batch["tokens"] = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch["labels"] = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_and_serve(arch):
    cfg = registry.get(arch).tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"

    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, pad_to=40))(params, batch)
    V = cfg.padded_vocab_size
    assert logits.shape == (2, 1, V)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, caches2 = jax.jit(model.decode_step)(params, tok, caches)
    assert logits2.shape == (2, 1, V)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(caches2.length[0]) == int(caches.length[0]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_grads_finite(arch):
    cfg = registry.get(arch).tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = make_batch(cfg, B=1, T=16)
    g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(g):
        leaf = np.asarray(leaf, np.float32)
        assert np.isfinite(leaf).all(), f"{arch}: non-finite grad"
        total += np.abs(leaf).sum()
    assert total > 0, f"{arch}: all-zero grads"


def test_full_configs_match_assignment():
    """The registry carries the exact assigned hyperparameters."""
    expect = {
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for name, (L, d, h, kv, ff, V) in expect.items():
        cfg = registry.get(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
                cfg.vocab_size) == (L, d, h, kv, ff, V), name
    m = registry.get("mamba2-370m")
    assert (m.n_layers, m.d_model, m.vocab_size, m.ssm_state) == (48, 1024, 50280, 128)
    s = registry.get("seamless-m4t-large-v2")
    assert (s.enc_layers, s.dec_layers, s.d_model, s.d_ff, s.vocab_size) == (
        24, 24, 1024, 8192, 256206,
    )
    moe = registry.get("qwen2-moe-a2.7b")
    assert (moe.n_experts, moe.moe_topk, moe.n_shared_experts) == (60, 4, 4)
    g = registry.get("granite-moe-3b-a800m")
    assert (g.n_experts, g.moe_topk) == (40, 8)


def test_prefill_extend_matches_full_prefill():
    """Chunked prefill (text-recompute fallback) == one-shot prefill."""
    from repro.models import lm

    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    T = 32
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, T)), jnp.int32)
    logits_full, caches_full = model.prefill(params, {"tokens": tokens}, pad_to=T)

    from repro.serving.kv_layout import alloc_caches

    caches = alloc_caches(cfg, 1, T)
    cut = 16
    _, caches = lm.prefill_extend(cfg, params, tokens[:, :cut], caches)
    logits_ext, caches = lm.prefill_extend(cfg, params, tokens[:, cut:], caches)
    np.testing.assert_allclose(
        np.asarray(logits_ext, np.float32),
        np.asarray(logits_full, np.float32),
        atol=5e-2, rtol=5e-2,
    )
    np.testing.assert_allclose(
        np.asarray(caches.kv_k, np.float32),
        np.asarray(caches_full.kv_k, np.float32),
        atol=5e-2, rtol=5e-2,
    )


def test_decode_matches_prefill_logits():
    """Teacher-forced decode over a prefix reproduces prefill's last logits."""
    cfg = registry.get("olmo-1b").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    T = 20
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, T)), jnp.int32)
    logits_full, _ = model.prefill(params, {"tokens": tokens}, pad_to=T + 4)
    # prefill first T-1 tokens, decode the last one
    logits_pre, caches = model.prefill(params, {"tokens": tokens[:, :-1]}, pad_to=T + 4)
    logits_dec, _ = model.decode_step(params, tokens[:, -1:], caches)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, -1], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        atol=5e-2, rtol=5e-2,
    )


def test_moe_grouped_dispatch_matches_global():
    """GShard-style grouped dispatch == global dispatch when no drops."""
    import dataclasses

    from repro.models.moe import moe_apply

    cfg = registry.get("qwen2-moe-a2.7b").tiny()
    cfg_g = dataclasses.replace(cfg, moe_dispatch="grouped", moe_groups=4,
                                capacity_factor=8.0)
    cfg_x = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    layer0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    x = jnp.asarray(RNG.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    out_g, _ = moe_apply(cfg_g, layer0["moe"], x)
    out_x, _ = moe_apply(cfg_x, layer0["moe"], x)
    np.testing.assert_allclose(
        np.asarray(out_g, np.float32), np.asarray(out_x, np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_moe_capacity_drops_are_bounded():
    from repro.models.moe import moe_apply

    cfg = registry.get("qwen2-moe-a2.7b").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(4))
    x = jnp.asarray(RNG.normal(size=(2, 64, cfg.d_model)), jnp.bfloat16)
    layer0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    out, aux = moe_apply(cfg, layer0["moe"], x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert float(aux) > 0
