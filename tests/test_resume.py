"""Byte-range resumable fetch (ISSUE 8).

Covers the salvage stack end to end:
  * derived segment view of a packed chunk — head / anchor / delta runs
    tile the blob, each with its own CRC; ``verified_prefix`` turns any
    byte prefix into a resume offset (truncation bounds it, corruption of
    a *complete* segment raises ``IntegrityError``); the index survives
    its wire form;
  * ``synthesize_head`` rebuilds a level's head bytes from header fields
    alone, so a salvaged fine-level anchor composes with a coarser
    level's delta suffix into the coarse blob *byte-identically*;
  * ``(offset, length)`` byte-range fetches on sim and local transports;
    ``FetchHandle.cancel`` returns the realized, verifiable prefix; a
    ``truncate`` fault attaches its salvage to the ``FetchError``;
  * session integration: truncate faults are resumed from the verified
    prefix with exact per-chunk ``salvaged + refetched == wire``
    reconciliation and strictly fewer refetched bytes than the PR 6
    whole-blob baseline; zero faults leave the resume-armed session
    bit-identical; a preempted fetch's prefix survives suspend/resume;
    a mid-chunk bandwidth collapse triggers cancel -> salvage -> re-plan
    and the degraded session meets the SLO a pinned session misses;
  * property tests (`tests/_hyp` shim): random truncation points always
    yield verified segments or a clean ``IntegrityError``; random lossy
    level pairs compose bit-identically;
  * tcp (slow-marked): range + index over the socket protocol, connection
    pooling across attempts, stale-socket reconnect accounting, and
    server-side truncation salvage.
"""
import socket

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitstream
from repro.core import codec as kvcodec
from repro.serving.session import ServeSession, SessionTask, _ExecState
from repro.streaming import (
    CacheGenStreamer,
    FaultPlan,
    FaultyTransport,
    FetchError,
    KVStore,
    LocalTransport,
    RetryPolicy,
    SimTransport,
)
from repro.streaming.network import BandwidthTrace, NetworkModel
from repro.streaming.streamer import FetchPlan

from tests._hyp import given, settings, st

T_CTX = 100
CHUNK = 20  # 5 chunks

_ASSETS = None


def _assets():
    """Module-level lazy build (shared with the zero-arg `_hyp` fallback)."""
    global _ASSETS
    if _ASSETS is None:
        from repro.configs import registry
        from repro.models import build
        from repro.serving.engine import Engine
        from repro.serving.kv_layout import caches_to_codec_kv

        rng = np.random.default_rng(0)
        cfg = registry.get("smollm-360m").tiny()
        model = build(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, cache_capacity=T_CTX + 40)
        tokens = rng.integers(0, cfg.vocab_size, size=(1, T_CTX)).astype(np.int32)
        _, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens)})
        kv = caches_to_codec_kv(caches, 0, T_CTX)
        ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
        store = KVStore(ctab)
        streamer = CacheGenStreamer(store, cfg)
        metas = store.store_kv("ctx", kv, chunk_tokens=CHUNK)
        u = sum(m.sizes[1] for m in metas) * 8 / 1e9
        _ASSETS = dict(cfg=cfg, eng=eng, tokens=tokens, kv=kv, ctab=ctab,
                       store=store, streamer=streamer, metas=metas, u=u)
    return _ASSETS


@pytest.fixture(scope="module")
def rfix():
    return _assets()


# expensive recompute: TEXT is never first-feasible, so chunks actually ride
# the fetch path instead of short-circuiting to recompute
_R_SLOW = lambda t, p: 100.0  # noqa: E731


def _mk_session(fx, **kw) -> ServeSession:
    return ServeSession(
        fx["streamer"], fx["eng"], slo_s=1.0, recompute_s=kw.pop("rc", _R_SLOW),
        decode_bytes_per_s=1e9, **kw,
    )


def _oracle_close(fx, res):
    """Realized cache must match a clean rebuild of the same plan."""
    plan = FetchPlan(context_id="ctx", result=res.stream_result(),
                     metas=fx["metas"])
    ref = fx["streamer"].materialize(plan, fx["eng"], fx["tokens"],
                                     batch=1, fused=False)
    for a, b in ((res.caches.kv_k, ref.kv_k), (res.caches.kv_v, ref.kv_v)):
        np.testing.assert_allclose(
            np.asarray(a[:, :, :T_CTX], np.float32),
            np.asarray(b[:, :, :T_CTX], np.float32),
            atol=2e-2, rtol=2e-2,
        )


def _reconcile(res):
    """Per-chunk and per-task wire ledger: salvaged + refetched == wire."""
    for tl in res.timelines:
        if tl.wire_bytes > 0:
            assert abs(tl.salvaged_bytes + tl.refetched_bytes - tl.wire_bytes) \
                < 1e-6, (tl.chunk_idx, tl.salvaged_bytes, tl.refetched_bytes,
                         tl.wire_bytes)
    assert abs(res.salvaged_bytes + res.refetched_bytes - res.wire_bytes) < 1e-6


# ---------------------------------------------------------------------------
# segment layout (tentpole part 1: self-delimiting wire format)
# ---------------------------------------------------------------------------


def test_segment_index_tiles_blob_and_roundtrips_wire(rfix):
    blob = rfix["store"].get_kv("ctx", 0, 1)
    idx = bitstream.segment_index(blob)
    assert idx.total == len(blob)
    # segments tile [0, total) in order: head, anchor, delta+
    assert idx.segments[0].kind == "head" and idx.segments[0].start == 0
    assert idx.segments[1].kind == "anchor"
    assert all(s.kind == "delta" for s in idx.segments[2:])
    for a, b in zip(idx.segments, idx.segments[1:]):
        assert a.end == b.start
    assert idx.segments[-1].end == idx.total
    assert 0 < idx.head.end < idx.anchor_end < idx.total
    assert idx.n_arrays > 0
    # a whole, untouched blob verifies end to end
    assert idx.verified_prefix(blob) == idx.total
    # wire roundtrip (the index travels as fetch metadata, not blob bytes)
    again = bitstream.SegmentIndex.from_wire(idx.to_wire())
    assert again == idx
    with pytest.raises(bitstream.IntegrityError):
        bitstream.SegmentIndex.from_wire({"v": 1, "segs": "nope"})


def test_verified_prefix_truncation_vs_corruption(rfix):
    blob = rfix["store"].get_kv("ctx", 0, 1)
    idx = bitstream.segment_index(blob)
    # truncation mid-delta: everything up to the last whole segment stands
    cut = (idx.segments[2].start + idx.segments[2].end) // 2
    assert idx.verified_prefix(blob[:cut]) == idx.anchor_end
    # truncation mid-anchor: only the head stands
    assert idx.verified_prefix(blob[: idx.anchor_end - 1]) == idx.head.end
    # a complete-but-corrupt segment is an error, not a resume point
    bad = bytearray(blob)
    bad[idx.head.end + 5] ^= 0x40
    with pytest.raises(bitstream.IntegrityError, match="anchor"):
        idx.verified_prefix(bytes(bad))
    # suffix coordinates: data starting at a resume offset verifies too
    off = idx.anchor_end
    assert idx.verified_prefix(blob[off:], offset=off) == idx.total
    # a gap (offset not on the contiguous frontier) verifies nothing new
    assert idx.verified_prefix(blob[off + 1:], offset=off + 1) == off + 1


def test_synthesize_head_and_anchor_compose_bit_exact(rfix):
    store = rfix["store"]
    fine, coarse = store.get_kv("ctx", 0, 1), store.get_kv("ctx", 0, 2)
    i_f, i_c = bitstream.segment_index(fine), bitstream.segment_index(coarse)
    # synthesized head == packed head bytes, per level
    for blob, idx in ((fine, i_f), (coarse, i_c)):
        hdr = kvcodec.peek_chunk_header(blob)
        assert bitstream.synthesize_head(hdr, idx.n_arrays) \
            == blob[: idx.head.end]
    # lossy levels share the anchor bytes (a.* + scales) verbatim
    assert fine[i_f.head.end:i_f.anchor_end] \
        == coarse[i_c.head.end:i_c.anchor_end]
    # degrade-compose, exactly as the session does it: peek the FINE
    # salvage's header, swap the level, synthesize the coarse head, then
    # fine anchor + coarse delta suffix == the coarse blob byte-for-byte
    hdr = kvcodec.peek_chunk_header(fine)
    hdr["level"] = 2
    composed = (
        bitstream.synthesize_head(hdr, i_f.n_arrays)
        + fine[i_f.head.end:i_f.anchor_end]
        + coarse[i_c.anchor_end:]
    )
    assert composed == coarse
    assert kvcodec.verify_chunk(composed) is True


# ---------------------------------------------------------------------------
# transport byte ranges + cancel salvage (tentpole part 2)
# ---------------------------------------------------------------------------


def test_range_fetch_sim_and_local(rfix):
    full = rfix["store"].get_kv("ctx", 0, 1)
    off = 1000
    net = NetworkModel(BandwidthTrace.constant(400 * rfix["u"]))
    for t in (SimTransport(rfix["store"], net), LocalTransport(rfix["store"])):
        assert t.supports_range
        res = t.fetch_run(
            "ctx", [(0, 1)], byte_range=(off, None), resumable=True
        ).result(timeout=30)
        assert res.blobs[0] == full[off:]
        assert res.nbytes == len(full) - off  # the suffix is what's priced
        assert res.range_offset == off and res.range_total == len(full)
        assert res.seg_index is not None and res.seg_index.total == len(full)
        # bounded length + clamping
        res = t.fetch_run(
            "ctx", [(0, 1)], byte_range=(off, 500)
        ).result(timeout=30)
        assert res.blobs[0] == full[off:off + 500]
        with pytest.raises(ValueError, match="single-chunk"):
            t.fetch_run("ctx", [(0, 1), (1, 1)], byte_range=(0, 10))


def test_sim_cancel_returns_verified_salvage(rfix):
    full = rfix["store"].get_kv("ctx", 0, 1)
    # the whole level-1 context takes ~1s on this trace -> chunk 0 ~0.2s
    net = NetworkModel(BandwidthTrace.constant(rfix["u"]))
    t = SimTransport(rfix["store"], net)
    h = t.fetch_run("ctx", [(0, 1)], resumable=True)
    salv = h.cancel(0.1)
    assert salv is not None and 0 < len(salv.data) < len(full)
    assert salv.data == full[: len(salv.data)]  # a true prefix
    assert salv.offset == 0 and salv.total == len(full)
    assert salv.nbytes_wire > 0
    ve = salv.index.verified_prefix(salv.data)
    assert 0 < ve <= len(salv.data)


def test_truncate_fault_attaches_salvage(rfix):
    plan = FaultPlan(seed=5, truncate_p=1.0)
    net = NetworkModel(BandwidthTrace.constant(400 * rfix["u"]))
    ft = FaultyTransport(SimTransport(rfix["store"], net), plan)
    assert ft.supports_range  # mirrors the inner transport
    full = rfix["store"].get_kv("ctx", 0, 1)
    with pytest.raises(FetchError) as ei:
        ft.fetch_run("ctx", [(0, 1)], resumable=True).result(timeout=30)
    salv = ei.value.salvage
    assert salv is not None and 0 < len(salv.data) < len(full)
    assert salv.data == full[: len(salv.data)]
    # the keyed fraction is >= 0.25, which always covers head + anchor here
    assert salv.index.verified_prefix(salv.data) >= salv.index.anchor_end
    assert ft.n_injected["truncate"] == 1


# ---------------------------------------------------------------------------
# session: resume / compose / reconcile (tentpole part 3)
# ---------------------------------------------------------------------------


def _truncated_run(fx, *, resume: bool):
    plan = FaultPlan(seed=42, truncate_p=0.6)
    net = NetworkModel(BandwidthTrace.constant(400 * fx["u"]))
    ft = FaultyTransport(SimTransport(fx["store"], net), plan)
    res = _mk_session(
        fx,
        retry_policy=RetryPolicy(max_attempts=3, backoff_s=0.01, timeout_s=0.5),
        resume_fetch=resume,
    ).run("ctx", fx["tokens"], net, transport=ft)
    return res, ft


def test_session_truncate_resume_reconciles_and_lands_clean(rfix):
    res, ft = _truncated_run(rfix, resume=True)
    assert res.status == "ok" and int(res.caches.length[0]) == T_CTX
    assert ft.n_injected["truncate"] > 0
    assert res.n_resumes > 0 and res.salvaged_bytes > 0
    _reconcile(res)
    resumed = [tl for tl in res.timelines if tl.resumed]
    assert resumed and all(tl.salvaged_bytes > 0 for tl in resumed)
    _oracle_close(rfix, res)


def test_resume_strictly_beats_whole_blob_retry(rfix):
    res, _ = _truncated_run(rfix, resume=True)
    base, _ = _truncated_run(rfix, resume=False)
    assert base.status == "ok"
    # the baseline measures the wire but never salvages
    assert base.n_resumes == 0 and base.salvaged_bytes == 0
    _reconcile(base)
    # identical fault plan -> resume refetches strictly fewer bytes and
    # finishes no later
    assert res.refetched_bytes < base.refetched_bytes
    assert res.ttft_s <= base.ttft_s + 1e-9


def test_zero_fault_resume_armed_is_bit_identical(rfix):
    trace = BandwidthTrace.steps(0.2, [2.0 * rfix["u"], 0.6 * rfix["u"]])
    rc = lambda t, p: 0.04 * t / CHUNK  # noqa: E731
    base = _mk_session(rfix, rc=rc).run(
        "ctx", rfix["tokens"], NetworkModel(trace)
    )
    armed = _mk_session(
        rfix, rc=rc,
        retry_policy=RetryPolicy(max_attempts=3, timeout_s=10.0),
        replan_factor=None,  # virtual-clock replanning off by default
    ).run("ctx", rfix["tokens"], NetworkModel(trace))
    assert armed.status == "ok"
    assert armed.n_resumes == 0 and armed.n_mid_chunk_replans == 0
    assert armed.salvaged_bytes == 0
    assert armed.configs == base.configs
    assert abs(armed.ttft_s - base.ttft_s) < 1e-12
    for a, b in zip(
        (armed.caches.kv_k, armed.caches.kv_v),
        (base.caches.kv_k, base.caches.kv_v),
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the ledger still ran: every fetched byte is accounted as refetched
    assert armed.wire_bytes > 0
    _reconcile(armed)


def test_preempted_fetch_prefix_survives_suspend_resume(rfix):
    sess = _mk_session(
        rfix, retry_policy=RetryPolicy(max_attempts=3, timeout_s=10.0)
    )
    net = NetworkModel(BandwidthTrace.constant(rfix["u"]))  # chunk ~0.2s
    task = SessionTask(sess, "ctx", rfix["tokens"], net,
                       transport=SimTransport(rfix["store"], net))
    caches = rfix["eng"].empty_caches(1)
    state = _ExecState()
    while task._pending is None:  # first step decides + issues chunk 0
        for w in task.step():
            caches = sess._execute_one(w, caches, state)
    task.suspend(0.1)  # mid-transfer: ~half the chunk realized
    sv = task._salvage
    assert sv is not None and sv.verified_end > 0
    assert task.salvaged_bytes == 0  # credited only when the chunk lands
    task.resume(0, 0.15)
    while not task.done:
        for w in task.step():
            caches = sess._execute_one(w, caches, state)
    res = task.result(caches, wall_decode_s=state.decode_s,
                      wall_recompute_s=state.recompute_s,
                      wall_total_s=0.0, n_runs=state.runs)
    assert res.status == "ok" and int(res.caches.length[0]) == T_CTX
    assert res.salvaged_bytes > 0 and res.n_resumes >= 1
    _reconcile(res)
    _oracle_close(rfix, res)


def test_mid_chunk_collapse_replans_and_meets_slo(rfix):
    # link collapses 1000x at t=1ms: chunk 0 lands clean at 2 Gbps, chunk 1
    # straddles the cliff -> realized duration blows past 3x the estimate,
    # the in-flight fetch is cancelled, its prefix salvaged, and the
    # remainder re-decided against the collapsed estimator
    trace = BandwidthTrace.steps(0.001, [2.0, 0.002])
    rc = lambda t, p: 0.3  # noqa: E731  TEXT infeasible before the collapse
    res = _mk_session(
        rfix, rc=rc,
        retry_policy=RetryPolicy(max_attempts=3, backoff_s=0.05, timeout_s=50.0),
        replan_factor=3.0,
    ).run("ctx", rfix["tokens"], NetworkModel(trace, rtt_s=0.0005),
          prior_throughput_gbps=2.0)
    assert res.status == "ok" and int(res.caches.length[0]) == T_CTX
    assert res.n_mid_chunk_replans >= 1
    assert any(tl.replanned for tl in res.timelines)
    _reconcile(res)
    assert not res.slo_violated  # adaptation absorbs the collapse
    _oracle_close(rfix, res)


def test_replan_meets_slo_that_pinned_config_misses(rfix):
    # a ~3800x collapse sized so the remaining *level-0* bytes overshoot
    # the SLO but the coarsest level still fits: the replanning session
    # cancels the straddling level-0 fetch and re-plans the remainder at
    # the coarsest level against the collapsed estimate; the pinned
    # level-0 session just keeps paying full-fat prices and misses
    trace = BandwidthTrace.steps(0.001, [2.0, 0.00053])
    rc = lambda t, p: 0.3  # noqa: E731  TEXT never feasible
    res = _mk_session(
        rfix, rc=rc,
        retry_policy=RetryPolicy(max_attempts=3, backoff_s=0.05, timeout_s=50.0),
        replan_factor=3.0,
    ).run("ctx", rfix["tokens"], NetworkModel(trace, rtt_s=0.0005),
          prior_throughput_gbps=2.0)
    assert res.status == "ok" and int(res.caches.length[0]) == T_CTX
    assert res.n_mid_chunk_replans >= 1
    assert not res.slo_violated
    _reconcile(res)
    _oracle_close(rfix, res)
    pinned = _mk_session(rfix, rc=rc, fixed_level=0).run(
        "ctx", rfix["tokens"], NetworkModel(trace, rtt_s=0.0005),
        prior_throughput_gbps=2.0,
    )
    assert pinned.slo_violated and pinned.ttft_s > res.ttft_s


# ---------------------------------------------------------------------------
# property tests (`tests/_hyp` shim)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    level=st.integers(0, 4),
    frac=st.floats(0.0, 1.0),
    corrupt=st.booleans(),
    poke=st.floats(0.0, 1.0),
)
def test_prop_truncation_verifies_or_errors_never_lies(level, frac, corrupt,
                                                       poke):
    fx = _assets()
    level = level % fx["ctab"].config.n_levels
    blob = fx["store"].get_kv("ctx", 1, level)
    idx = bitstream.segment_index(blob)
    cut = int(frac * len(blob))
    ve = idx.verified_prefix(blob[:cut])
    # never past the cut, always on a segment boundary
    assert ve <= cut
    assert ve in {0} | {s.end for s in idx.segments}
    # every byte it vouches for is the true blob prefix (re-verifiable)
    assert idx.verified_prefix(blob[:ve]) == ve
    if corrupt and ve > 0:
        # flip one byte inside the verified range: a complete-but-corrupt
        # segment must raise, never silently resume past garbage
        bad = bytearray(blob[:cut])
        bad[int(poke * (ve - 1))] ^= 0x01
        with pytest.raises(bitstream.IntegrityError):
            idx.verified_prefix(bytes(bad))


@settings(max_examples=20, deadline=None)
@given(
    fine=st.integers(1, 8),
    coarse=st.integers(1, 8),
    chunk=st.integers(0, 4),
)
def test_prop_lossy_level_pairs_compose_bit_identical(fine, coarse, chunk):
    fx = _assets()
    n = fx["ctab"].config.n_levels
    lossy = list(range(1, n))
    fine, coarse = lossy[fine % len(lossy)], lossy[coarse % len(lossy)]
    f = fx["store"].get_kv("ctx", chunk, fine)
    c = fx["store"].get_kv("ctx", chunk, coarse)
    i_f, i_c = bitstream.segment_index(f), bitstream.segment_index(c)
    hdr = kvcodec.peek_chunk_header(f)
    hdr["level"] = coarse
    composed = (
        bitstream.synthesize_head(hdr, i_f.n_arrays)
        + f[i_f.head.end:i_f.anchor_end]
        + c[i_c.anchor_end:]
    )
    assert composed == c
    ha, aa = bitstream.unpack(composed)
    hb, ab = bitstream.unpack(c)
    assert ha == hb and set(aa) == set(ab)
    for k in aa:
        assert np.array_equal(aa[k], ab[k])


# ---------------------------------------------------------------------------
# tcp: range + index over the wire, pooling, reconnects (slow-marked)
# ---------------------------------------------------------------------------


def _socket_or_skip():
    try:
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.bind(("127.0.0.1", 0))
        s.close()
    except OSError as e:  # sandboxed CI without loopback sockets
        pytest.skip(f"sockets unavailable: {e}")


@pytest.mark.slow
def test_tcp_range_fetch_pooling_and_reconnect(rfix):
    _socket_or_skip()
    from repro.streaming.transport import TcpStoreServer, TcpTransport

    full = rfix["store"].get_kv("ctx", 0, 1)
    server = TcpStoreServer(rfix["store"])
    try:
        t = TcpTransport.for_server(server)
        off = 1000
        res = t.fetch_run(
            "ctx", [(0, 1)], byte_range=(off, None), resumable=True
        ).result(timeout=30)
        assert res.blobs[0] == full[off:]
        assert res.range_offset == off and res.range_total == len(full)
        assert res.seg_index is not None
        assert res.seg_index.verified_prefix(full) == len(full)
        # second fetch rides the pooled connection, not a fresh dial
        t.fetch_run("ctx", [(1, 1)]).result(timeout=30)
        s = t.tier_stats()
        assert s["n_connects"] == 1 and s["n_pool_reuses"] >= 1
        # a pooled socket gone stale forces one reconnect + silent replay
        with t._pool_lock:
            for sock in t._pool:
                sock.close()
        res = t.fetch_run("ctx", [(2, 1)]).result(timeout=30)
        assert res.blobs[0] == rfix["store"].get_kv("ctx", 2, 1)
        assert t.tier_stats()["n_reconnects"] >= 1
    finally:
        server.close()


@pytest.mark.slow
def test_tcp_server_truncate_salvages_client_side(rfix):
    _socket_or_skip()
    from repro.streaming.transport import TcpStoreServer, TcpTransport

    plan = FaultPlan(seed=9, truncate_p=1.0)
    full = rfix["store"].get_kv("ctx", 0, 1)
    server = TcpStoreServer(rfix["store"], fault_plan=plan)
    try:
        t = TcpTransport.for_server(server)
        h = t.fetch_run("ctx", [(0, 1)], resumable=True)
        # the sever surfaces as a transport error; the realized prefix is
        # harvested from the handle, exactly as the session's retry does
        with pytest.raises((FetchError, ConnectionError, OSError)):
            h.result(timeout=30)
        salv = h.salvage_at()
        assert salv is not None and 0 < len(salv.data) < len(full)
        assert salv.data == full[: len(salv.data)]
        assert salv.index is not None
        assert salv.index.verified_prefix(salv.data, salv.offset) > 0
        assert server.n_injected_faults >= 1
    finally:
        server.close()
