"""Differential harness for the concurrent multi-session scheduler.

Invariants (ISSUE 3):
  * N=1 degeneration — the scheduler with a single request is bit-identical
    to ``ServeSession``: same per-chunk decisions, bytes, virtual TTFT, and
    a bit-exact materialized cache (batched executors vs. the per-request
    ones);
  * N>1 batched execution — with decisions pinned equal (factor-1
    contention), each request's row of the shared batch-of-requests cache
    matches the same session run sequentially: bit-exact at level 0,
    within codec tolerance on adaptive (lossy + TEXT) mixes — and bit-exact
    against the ``fused=False`` per-chunk oracle at level 0;
  * engine primitives — ``insert_runs`` lands runs at per-row offsets
    (including the capacity-abutting shifted-window case) without touching
    other rows; masked/gathered ``prefill_extend`` variants equal the
    single-row path;
  * contention — ``ContentionModel`` calibration (factor(1) == 1, exact
    interpolation, serialized fallback) and the decision feedback: a loaded
    engine pushes Algorithm 1 away from TEXT recompute;
  * calibration memoization — rewriting the bench file re-reads it (mtime
    keyed), ``clear_calibration_cache`` forces it.
"""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import codec as kvcodec
from repro.serving.kv_layout import extract_row
from repro.serving.scheduler import ConcurrentScheduler, SessionRequest
from repro.serving.session import ServeSession
from repro.streaming import CacheGenStreamer, KVStore
from repro.streaming.adaptation import TEXT
from repro.streaming.network import BandwidthTrace, NetworkModel
from repro.streaming.pipeline import ContentionModel
from repro.streaming.streamer import FetchPlan

T_CTX = 100
CHUNK = 20  # 5 chunks

IDEAL = ContentionModel({1: 1.0, 2: 1.0})  # factor-1 at any N


@pytest.fixture(scope="module")
def cfix():
    from repro.configs import registry
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv

    rng = np.random.default_rng(0)
    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, cache_capacity=T_CTX + 40)
    tokens = rng.integers(0, cfg.vocab_size, size=(1, T_CTX)).astype(np.int32)
    logits, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens)})
    kv = caches_to_codec_kv(caches, 0, T_CTX)
    ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    metas = store.store_kv("ctx", kv, chunk_tokens=CHUNK)
    u = sum(m.sizes[1] for m in metas) * 8 / 1e9  # level-1 ctx in 1 s
    return dict(cfg=cfg, eng=eng, tokens=tokens, store=store,
                streamer=streamer, metas=metas, u=u)


def _mk_session(cfix, **kw):
    kw.setdefault("slo_s", 1.25)
    # recompute priced at paper scale vs. the SLO (as in test_session's
    # interleave scenario): the falling trace TEXT-rescues, others stream
    kw.setdefault("recompute_s", lambda t, p: 0.15 * 1.25 * t / CHUNK)
    kw.setdefault("decode_bytes_per_s", 1e9)
    kw.setdefault("max_run_tokens", 2 * CHUNK)
    return ServeSession(cfix["streamer"], cfix["eng"], **kw)


def _traces(u, n):
    shapes = [
        BandwidthTrace.constant(400 * u),
        BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u]),
        BandwidthTrace.steps(0.15, [2.0 * u, 0.4 * u, 2.0 * u, 0.4 * u]),
        BandwidthTrace.constant(3 * u),
    ]
    return [shapes[i % len(shapes)] for i in range(n)]


def _kv_np(caches):
    return (
        np.asarray(caches.kv_k[:, :, :T_CTX], np.float32),
        np.asarray(caches.kv_v[:, :, :T_CTX], np.float32),
    )


def _oracle(cfix, result):
    """fused=False per-chunk materialization of a session's realized plan."""
    plan = FetchPlan(
        context_id="ctx", result=result.stream_result(), metas=cfix["metas"]
    )
    return cfix["streamer"].materialize(
        plan, cfix["eng"], cfix["tokens"], batch=1, fused=False
    )


# ---------------------------------------------------------------------------
# N=1 degeneration: bit-identical to ServeSession
# ---------------------------------------------------------------------------


def test_scheduler_n1_bit_identical_to_session(cfix):
    u = cfix["u"]
    scheduler = ConcurrentScheduler(cfix["eng"], contention=IDEAL)
    for trace, kw in (
        (BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u]), {}),  # levels + TEXT
        (BandwidthTrace.constant(3 * u), dict(fixed_level=0)),  # pure decode
        (BandwidthTrace.steps(0.15, [2.0 * u, 0.4 * u] * 2),
         dict(allow_text=False)),  # level escalation only
    ):
        prior = float(trace.gbps[0])
        res = _mk_session(cfix, **kw).run(
            "ctx", cfix["tokens"], NetworkModel(trace),
            prior_throughput_gbps=prior,
        )
        out = scheduler.run([
            SessionRequest(_mk_session(cfix, **kw), "ctx", cfix["tokens"],
                           NetworkModel(trace), prior_throughput_gbps=prior)
        ])
        s = out.sessions[0]
        assert s.configs == res.configs
        assert [t.nbytes for t in s.timelines] == [t.nbytes for t in res.timelines]
        assert [t.hedged for t in s.timelines] == [t.hedged for t in res.timelines]
        assert abs(s.ttft_s - res.ttft_s) < 1e-12
        for a, b in zip(_kv_np(s.caches), _kv_np(res.caches)):
            assert np.array_equal(a, b), "N=1 scheduler cache != session cache"


def test_scheduler_n1_contention_factor_is_identity(cfix):
    """Any contention model is a no-op at N=1: factor(1) == 1.0 exactly."""
    u = cfix["u"]
    trace = BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u])
    prior = float(trace.gbps[0])
    res = _mk_session(cfix).run(
        "ctx", cfix["tokens"], NetworkModel(trace), prior_throughput_gbps=prior
    )
    for model in (ContentionModel({}), ContentionModel({1: 1.0, 8: 8.0}),
                  ContentionModel.measured()):
        out = ConcurrentScheduler(cfix["eng"], contention=model).run([
            SessionRequest(_mk_session(cfix), "ctx", cfix["tokens"],
                           NetworkModel(trace), prior_throughput_gbps=prior)
        ])
        assert out.sessions[0].configs == res.configs
        assert abs(out.sessions[0].ttft_s - res.ttft_s) < 1e-12


# ---------------------------------------------------------------------------
# N>1: batched execution vs sequential sessions and the per-chunk oracle
# ---------------------------------------------------------------------------


def test_scheduler_level0_bit_exact_vs_sequential_and_oracle(cfix):
    n = 4
    traces = _traces(cfix["u"], n)
    scheduler = ConcurrentScheduler(cfix["eng"], contention=IDEAL)
    out = scheduler.run([
        SessionRequest(_mk_session(cfix, fixed_level=0), "ctx", cfix["tokens"],
                       NetworkModel(tr), prior_throughput_gbps=float(tr.gbps[0]))
        for tr in traces
    ])
    # cross-request batching actually happened: fewer decode dispatches than
    # runs landed
    assert out.n_runs > out.n_decode_batches >= 1
    for i, tr in enumerate(traces):
        seq = _mk_session(cfix, fixed_level=0).run(
            "ctx", cfix["tokens"], NetworkModel(tr),
            prior_throughput_gbps=float(tr.gbps[0]),
        )
        s = out.sessions[i]
        assert s.configs == seq.configs
        assert all(c == 0 for c in s.configs)
        assert int(s.caches.length[0]) == T_CTX
        for a, b in zip(_kv_np(s.caches), _kv_np(seq.caches)):
            assert np.array_equal(a, b), f"request {i}: batched != sequential"
        ref = _oracle(cfix, s)
        for a, b in zip(_kv_np(s.caches), _kv_np(ref)):
            assert np.array_equal(a, b), f"request {i}: batched != oracle"


def test_scheduler_adaptive_mix_matches_sequential_within_tolerance(cfix):
    """Heterogeneous traces, mixed levels + TEXT: decisions pinned equal via
    the factor-1 model; per-request caches within codec tolerance of both
    the sequential session and the fused=False oracle."""
    n = 5
    traces = _traces(cfix["u"], n)
    scheduler = ConcurrentScheduler(cfix["eng"], contention=IDEAL)
    # no bandwidth prior: chunk 0 streams at the default level (paper §5.3),
    # later chunks adapt — this is what makes the mix non-trivial
    out = scheduler.run([
        SessionRequest(_mk_session(cfix), "ctx", cfix["tokens"],
                       NetworkModel(tr))
        for tr in traces
    ])
    all_configs = [c for s in out.sessions for c in s.configs]
    assert TEXT in all_configs and any(c != TEXT for c in all_configs), (
        "scenario must mix TEXT and bitstream chunks", all_configs)
    assert out.n_text_batches >= 1
    for i, tr in enumerate(traces):
        seq = _mk_session(cfix).run("ctx", cfix["tokens"], NetworkModel(tr))
        s = out.sessions[i]
        assert s.configs == seq.configs
        assert abs(s.ttft_s - seq.ttft_s) < 1e-12
        assert int(s.caches.length[0]) == T_CTX
        for a, b in zip(_kv_np(s.caches), _kv_np(seq.caches)):
            np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)
        ref = _oracle(cfix, s)
        for a, b in zip(_kv_np(s.caches), _kv_np(ref)):
            np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)


def test_scheduler_rejects_foreign_engine_and_bad_tokens(cfix):
    scheduler = ConcurrentScheduler(cfix["eng"], contention=IDEAL)
    trace = BandwidthTrace.constant(3 * cfix["u"])
    with pytest.raises(ValueError, match="share the scheduler's Engine"):
        other = ServeSession(
            cfix["streamer"], object.__new__(type(cfix["eng"])), slo_s=1.0,
            recompute_s=lambda t, p: 1.0, decode_bytes_per_s=1e9,
        )
        scheduler.run([SessionRequest(other, "ctx", cfix["tokens"],
                                      NetworkModel(trace))])
    with pytest.raises(ValueError, match=r"tokens must be \(1, T\)"):
        scheduler.run([
            SessionRequest(_mk_session(cfix), "ctx",
                           np.zeros((2, T_CTX), np.int32), NetworkModel(trace))
        ])


# ---------------------------------------------------------------------------
# engine primitives
# ---------------------------------------------------------------------------


def test_insert_runs_shifted_window_at_capacity_edge(cfix):
    """A short run near the capacity edge shares a batch with a longer run:
    its padded window cannot sit at its start offset, so the shifted-window
    merge must still land tokens exactly and leave everything else alone."""
    eng, store = cfix["eng"], cfix["store"]
    cap = eng.capacity  # 140
    kv, spans = kvcodec.decode_chunk_runs(
        [store.get_run("ctx", [(0, 0), (1, 0)]),  # 40 tokens -> t_max 40
         store.get_run("ctx", [(4, 0)])],  # 20 tokens
        store.tables, out_dtype=jnp.bfloat16,
    )
    start_short = cap - 25  # window [115, 155) > cap: shift = 15 + 10 = ...
    caches = eng.empty_caches(3)
    marker = caches.kv_k.at[:, 1, :].set(7.0)  # row 1 pre-filled sentinel
    caches = caches._replace(kv_k=marker, kv_v=caches.kv_v.at[:, 1, :].set(7.0))
    caches = eng.insert_runs(
        caches, kv, rows=[2, 1], starts=[0, start_short],
        run_tokens=[n for _, n in spans],
    )
    assert caches.length.tolist() == [0, start_short + 20, 40]
    # the short run landed exactly at [start_short, start_short + 20) of row 1
    solo = kvcodec.decode_chunks(
        store.get_run("ctx", [(4, 0)]), store.tables, out_dtype=jnp.bfloat16
    )
    got = np.asarray(
        caches.kv_k[:, 1, start_short : start_short + 20], np.float32
    )
    L, two, T, C = solo.shape
    Hkv, Dh = cfix["cfg"].n_kv_heads, cfix["cfg"].d_head
    want = np.asarray(solo[:, 0], np.float32).reshape(L, T, Hkv, Dh)
    assert np.array_equal(got, want)
    # sentinel preserved outside the written window
    rest = np.asarray(caches.kv_k[:, 1, :start_short], np.float32)
    assert np.array_equal(rest, np.full_like(rest, 7.0))
    # row 0 untouched entirely
    assert float(jnp.abs(caches.kv_k[:, 0]).max()) == 0.0


def test_prefill_extend_rows_and_gather_match_single_row(cfix):
    """Masked full-batch and gathered-subset TEXT recompute both equal the
    plain single-row prefill_extend, and leave inactive rows untouched."""
    eng, store, tokens = cfix["eng"], cfix["store"], cfix["tokens"]
    kv0 = kvcodec.decode_chunks(
        store.get_run("ctx", [(0, 0), (1, 0)]), store.tables,
        out_dtype=jnp.bfloat16,
    )
    ref = eng.empty_caches(1)
    ref = eng.decode_to_cache(ref, kv0, 0)
    ref_logits, ref = eng.prefill_extend(
        jnp.asarray(tokens[:, 40:60], jnp.int32), ref
    )

    for mode in ("masked", "gather"):
        caches = eng.empty_caches(3)
        for row in (0, 2):
            caches = eng.insert_runs(caches, kv0, rows=[row], starts=[0],
                                     run_tokens=[40])
        before_row1 = np.asarray(caches.kv_k[:, 1], np.float32).copy()
        if mode == "masked":
            toks = np.zeros((3, 20), np.int32)
            toks[0] = toks[2] = tokens[0, 40:60]
            widths = np.asarray([20, 0, 20], np.int32)
            logits, caches = eng.prefill_extend_rows(
                jnp.asarray(toks), caches, widths
            )
            l0, l2 = logits[0:1], logits[2:3]
        else:
            toks = np.stack([tokens[0, 40:60]] * 2)
            logits, caches = eng.prefill_extend_gather(
                jnp.asarray(toks), caches, [0, 2]
            )
            l0, l2 = logits[0:1], logits[1:2]
        assert caches.length.tolist() == [60, 0, 60]
        for row, lg in ((0, l0), (2, l2)):
            a = np.asarray(caches.kv_k[:, row, :60], np.float32)
            b = np.asarray(ref.kv_k[:, 0, :60], np.float32)
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(
                np.asarray(lg, np.float32), np.asarray(ref_logits, np.float32),
                atol=1e-4, rtol=1e-4,
            )
        after_row1 = np.asarray(caches.kv_k[:, 1], np.float32)
        assert np.array_equal(before_row1, after_row1), f"{mode}: row 1 dirtied"


def test_prefill_extend_rows_partial_width_at_capacity_edge(cfix):
    """A partial-width committed chunk whose padded window overhangs the
    capacity must land its tokens at the true offset (shifted-window merge),
    preserve everything before them, and reject nothing it shouldn't."""
    eng, tokens = cfix["eng"], cfix["tokens"]
    cap = eng.capacity  # 140
    tc, w = 16, 8
    start = cap - 10  # window [130, 146) overhangs; committed [130, 138) fits
    caches = eng.empty_caches(2)
    caches = caches._replace(
        kv_k=caches.kv_k.at[:, 0, :].set(7.0),
        kv_v=caches.kv_v.at[:, 0, :].set(7.0),
        length=jnp.asarray([start, 0], jnp.int32),
    )
    toks = np.zeros((2, tc), np.int32)
    toks[0] = tokens[0, :tc]
    _, out = eng.prefill_extend_rows(
        jnp.asarray(toks), caches, np.asarray([w, 0], np.int32)
    )
    assert out.length.tolist() == [start + w, 0]
    # reference: plain single-row prefill_extend of exactly the committed
    # tokens at the same offset (causality makes the first w tokens' KV
    # independent of the chunk tail)
    ref = eng.empty_caches(1)
    ref = ref._replace(
        kv_k=ref.kv_k.at[:, 0, :].set(7.0),
        kv_v=ref.kv_v.at[:, 0, :].set(7.0),
        length=jnp.asarray([start], jnp.int32),
    )
    _, ref = eng.prefill_extend(jnp.asarray(toks[:1, :w], jnp.int32), ref)
    np.testing.assert_allclose(
        np.asarray(out.kv_k[:, 0, start : start + w], np.float32),
        np.asarray(ref.kv_k[:, 0, start : start + w], np.float32),
        atol=1e-5, rtol=1e-5,
    )
    # sentinel preserved everywhere outside the committed tokens
    before = np.asarray(out.kv_k[:, 0, :start], np.float32)
    assert np.array_equal(before, np.full_like(before, 7.0))
    tail = np.asarray(out.kv_k[:, 0, start + w :], np.float32)
    assert np.array_equal(tail, np.full_like(tail, 7.0))
    # inactive row untouched
    assert float(jnp.abs(out.kv_k[:, 1]).max()) == 0.0


def test_insert_runs_rejects_overhanging_run(cfix):
    eng, store = cfix["eng"], cfix["store"]
    kv = kvcodec.decode_chunks(
        store.get_run("ctx", [(0, 0)]), store.tables, out_dtype=jnp.bfloat16
    )
    caches = eng.empty_caches(1)
    with pytest.raises(ValueError, match="overhangs cache capacity"):
        eng.insert_runs(caches, kv, rows=[0], starts=[eng.capacity - 10],
                        run_tokens=[20])


# ---------------------------------------------------------------------------
# contention model + decision feedback
# ---------------------------------------------------------------------------


def test_contention_model_factors():
    m = ContentionModel({1: 1.0, 2: 1.5, 4: 3.0})
    assert m.factor(1) == 1.0
    assert m.factor(2) == 1.5
    assert m.factor(3) == pytest.approx(2.25)  # linear between 2 and 4
    assert m.factor(4) == 3.0
    assert m.factor(6) == pytest.approx(4.5)  # last marginal slope extended
    empty = ContentionModel({})
    assert empty.factor(1) == 1.0
    assert empty.factor(5) == 5.0  # fully serialized fallback
    # measured points without an explicit 1 get the exact-1 anchor
    assert ContentionModel({4: 2.0}).factor(1) == 1.0
    assert ContentionModel({4: 2.0}).factor(4) == 2.0


def test_contention_pushes_adaptation_off_text(cfix):
    """A loaded engine inflates the projected recompute cost inside
    choose_config: the same falling trace that is TEXT-rescued when alone
    must shed TEXT chunks when 4 sessions contend (factor 4 recompute)."""
    u = cfix["u"]
    mk = lambda: _mk_session(  # noqa: E731
        cfix, recompute_s=lambda t, p: 0.15 * 1.25 * t / CHUNK
    )
    trace = lambda: BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u])  # noqa: E731
    solo = ConcurrentScheduler(cfix["eng"], contention=IDEAL).run([
        SessionRequest(mk(), "ctx", cfix["tokens"], NetworkModel(trace()),
                       prior_throughput_gbps=1.0 * u)
    ])
    n_text_solo = sum(1 for c in solo.sessions[0].configs if c == TEXT)
    assert n_text_solo > 0, (
        "baseline must choose TEXT for this scenario", solo.sessions[0].configs)
    crowd = ConcurrentScheduler(
        cfix["eng"], contention=ContentionModel({})  # fully serialized
    ).run([
        SessionRequest(mk(), "ctx", cfix["tokens"], NetworkModel(trace()),
                       prior_throughput_gbps=1.0 * u)
        for _ in range(4)
    ])
    for s in crowd.sessions:
        n_text = sum(1 for c in s.configs if c == TEXT)
        assert n_text < n_text_solo, (
            "contended session should shed TEXT recompute",
            s.configs, solo.sessions[0].configs)


# ---------------------------------------------------------------------------
# calibration memoization (satellite)
# ---------------------------------------------------------------------------


def test_calibration_rereads_rewritten_bench_file(tmp_path, monkeypatch):
    from repro.streaming import calibration

    path = tmp_path / "BENCH_codec.json"

    def write(v, stacked=None):
        report = {"host_backend": jax.default_backend(),
                  "fused": {"bytes_per_s": v}}
        if stacked:
            report["stacked"] = stacked
        path.write_text(json.dumps(report))

    monkeypatch.setenv("CACHEGEN_BENCH_CODEC", str(path))
    calibration.clear_calibration_cache()
    write(111.0)
    assert calibration.measured_decode_bytes_per_s() == 111.0
    # rewrite in place: the mtime-keyed memo must pick up the new contents
    # without an explicit cache clear
    write(222.0)
    os.utime(path, ns=(1, 1))  # force a distinct signature on coarse clocks
    assert calibration.measured_decode_bytes_per_s() == 222.0
    # explicit reset also works
    write(333.0)
    os.utime(path, ns=(2, 2))
    calibration.clear_calibration_cache()
    assert calibration.measured_decode_bytes_per_s() == 333.0

    # contention factors parse + clamp, and invalidate the same way
    write(333.0, stacked={
        "1": {"stacked": {"bytes_per_s": 100.0}},
        "4": {"stacked": {"bytes_per_s": 200.0}},
    })
    os.utime(path, ns=(3, 3))
    factors = calibration.measured_contention_factors()
    assert factors == {1: 1.0, 4: 2.0}
    write(333.0, stacked={
        "1": {"stacked": {"bytes_per_s": 100.0}},
        "4": {"stacked": {"bytes_per_s": 800.0}},  # super-linear: clamp to 1
    })
    os.utime(path, ns=(4, 4))
    assert calibration.measured_contention_factors() == {1: 1.0, 4: 1.0}
    calibration.clear_calibration_cache()


def test_calibration_falls_through_partial_candidate(tmp_path, monkeypatch):
    """A parseable report that lacks the wanted measurement must not shadow
    a complete report later in the candidate list."""
    from repro.streaming import calibration

    partial = tmp_path / "partial.json"
    complete = tmp_path / "complete.json"
    backend = jax.default_backend()
    partial.write_text(json.dumps({"host_backend": backend}))  # no fused key
    complete.write_text(json.dumps({
        "host_backend": backend,
        "fused": {"bytes_per_s": 444.0},
        "stacked": {"1": {"stacked": {"bytes_per_s": 50.0}},
                    "2": {"stacked": {"bytes_per_s": 80.0}}},
    }))
    monkeypatch.setattr(
        calibration, "bench_codec_candidates",
        lambda: [str(partial), str(complete)],
    )
    calibration.clear_calibration_cache()
    try:
        assert calibration.measured_decode_bytes_per_s() == 444.0
        assert calibration.measured_contention_factors() == {1: 1.0, 2: 1.25}
    finally:
        calibration.clear_calibration_cache()


# ---------------------------------------------------------------------------
# benchmark acceptance (separate CI job)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_concurrent_sessions_bench_acceptance(tmp_path, monkeypatch):
    """Reduced benchmarks/concurrent_sessions.py run: batched and sequential
    modes agree on caches (bit-exact at level 0) and the scheduler actually
    batches (fewer decode dispatches than runs).  Wall-clock speedups are
    recorded in the JSON but not asserted here — CI runners are noisy; the
    committed BENCH_concurrency.json carries this host's measurement."""
    import benchmarks.concurrent_sessions as cs

    monkeypatch.setattr(cs, "N_SESSIONS", (1, 4))
    report = cs.run(out_path=str(tmp_path / "BENCH_concurrency.json"),
                    repeats=1, verbose=False)
    acc = report["acceptance"]
    assert acc["caches_match_all"] is True
    assert acc["level0_bit_exact"] is True
    rows = {(w["scenario"], w["n_sessions"]): w for w in report["workloads"]}
    n4 = rows[("level0", 4)]
    assert n4["batched"]["n_decode_batches"] < n4["batched"]["n_runs"]
    assert n4["batched"]["n_runs"] == n4["sequential"]["n_runs"]
    assert rows[("adaptive", 4)]["caches_match"] is True
    assert {c["n_sessions"] for c in report["contended"]} == {1, 4}
    for c in report["contended"]:
        assert c["contention_factor"] >= 1.0
        assert np.isfinite(c["ttft_p95_s"])
