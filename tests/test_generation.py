"""Differential + property harness for continuous batched generation.

Invariants (ISSUE 9):
  * engine — ``Engine.decode_step_rows`` with one active row is
    token-identical to the ``generate_with_kv`` greedy oracle on that row's
    extracted cache, and inactive rows' KV / lengths are bit-preserved
    across stacked steps;
  * N=1 oracle identity — a lone request that loads then generates through
    the ``ContinuousScheduler`` emits exactly the oracle's greedy tokens,
    with strictly increasing virtual emission times and TPOT equal to the
    uncontended step cost;
  * load-only degeneration — ``generation=None`` and a zero-token
    ``GenerationSpec`` are bit-identical to each other (and therefore to
    the PR 8 load-only path): same decisions, TTFTs and caches, zero
    generation steps;
  * continuous batching — with staggered arrivals, generating rows and
    in-flight loads interleave on the shared engine and ready rows stack
    into one ``decode_step_rows`` dispatch (gen-occupancy width > 1), and
    the whole mixed wave is deterministic across runs;
  * suspend/resume — a generating row preempted mid-stream under the
    ``least_work`` victim policy resumes bit-exactly: final tokens equal
    the uninterrupted run's;
  * EDF admission — waiters are admitted by SLO deadline, FIFO by arrival;
  * cost-aware victim selection — ``_select_victim`` picks the
    least-realized-work candidate under ``least_work`` and the latest
    fetch-end straggler (first-wins ties) under the default policy;
  * calibration — ``stacked_decode_step`` parses into gen contention
    factors and ``ContentionModel.gen_factor`` interpolates/falls back;
  * gen-SLO (ISSUE 10) — realized TPOT over ``GenerationSpec.gen_slo_s``
    accumulates per-token misses (suspension gaps included) that surface on
    ``RequestTimeline.gen_slo_miss``, and ``PreemptionPolicy(gen_slo=True)``
    makes an SLO-missing generating row evictable under the straggler rule
    with token-exact resumption.
"""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import codec as kvcodec
from repro.serving.generation import GenerationSpec, GenerationTask
from repro.serving.kv_layout import extract_row
from repro.serving.scheduler import (
    ContinuousScheduler,
    PreemptionPolicy,
    SessionRequest,
    _select_victim,
    _VictimCandidate,
)
from repro.serving.session import ServeSession
from repro.streaming import CacheGenStreamer, KVStore
from repro.streaming.network import BandwidthTrace, NetworkModel
from repro.streaming.pipeline import ContentionModel

T_CTX = 100
CHUNK = 20  # 5 chunks
GEN = 8

IDEAL = ContentionModel({1: 1.0, 2: 1.0})  # factor-1 at any N


@pytest.fixture(scope="module")
def gfix():
    from repro.configs import registry
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv

    rng = np.random.default_rng(0)
    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # capacity leaves room for the context plus every generated token
    eng = Engine(cfg, params, cache_capacity=T_CTX + 48)
    tokens = rng.integers(0, cfg.vocab_size, size=(1, T_CTX)).astype(np.int32)
    logits, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens)})
    kv = caches_to_codec_kv(caches, 0, T_CTX)
    ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    metas = store.store_kv("ctx", kv, chunk_tokens=CHUNK)
    u = sum(m.sizes[1] for m in metas) * 8 / 1e9  # level-1 ctx in 1 s
    first = int(jnp.argmax(logits[0, -1]))
    return dict(cfg=cfg, eng=eng, tokens=tokens, store=store,
                streamer=streamer, metas=metas, u=u, first=first)


def _mk_session(gfix, **kw):
    kw.setdefault("slo_s", 1.25)
    kw.setdefault("recompute_s", lambda t, p: 0.15 * 1.25 * t / CHUNK)
    kw.setdefault("decode_bytes_per_s", 1e9)
    kw.setdefault("max_run_tokens", 2 * CHUNK)
    return ServeSession(gfix["streamer"], gfix["eng"], **kw)


def _requests(gfix, traces, sess_kw=None, arrivals=None, specs=None):
    sess_kw = sess_kw or [{} for _ in traces]
    arrivals = arrivals if arrivals is not None else [0.0] * len(traces)
    specs = specs if specs is not None else [None] * len(traces)
    return [
        SessionRequest(
            _mk_session(gfix, **kw), "ctx", gfix["tokens"], NetworkModel(tr),
            prior_throughput_gbps=float(tr.gbps[0]), start_t=arr,
            generation=spec,
        )
        for tr, kw, arr, spec in zip(traces, sess_kw, arrivals, specs)
    ]


def _kv_np(caches):
    return (
        np.asarray(caches.kv_k[:, :, :T_CTX], np.float32),
        np.asarray(caches.kv_v[:, :, :T_CTX], np.float32),
    )


def _oracle_tokens(gfix, caches, first, n):
    """Greedy reference: generate_with_kv on the request's loaded cache."""
    out = gfix["eng"].generate_with_kv(
        caches, jnp.asarray([first], jnp.int32), n
    )
    return out[0].tolist()


# ---------------------------------------------------------------------------
# engine: decode_step_rows vs the greedy oracle
# ---------------------------------------------------------------------------


def test_decode_step_rows_matches_oracle_and_preserves_inactive(gfix):
    """Six stacked steps with only row 1 active: row 1's argmax chain equals
    the generate_with_kv oracle on its extracted cache; rows 0/2 keep their
    KV bytes and lengths untouched (the where-merge must not leak)."""
    eng = gfix["eng"]
    rng = np.random.default_rng(3)
    caches = eng.empty_caches(3)
    toks = rng.integers(0, gfix["cfg"].vocab_size, size=(3, 32)).astype(np.int32)
    logits, caches = eng.prefill_extend_rows(
        jnp.asarray(toks), caches, np.full(3, 32)
    )
    ref_caches = extract_row(caches, 1)
    first = int(jnp.argmax(logits[1, 31]))
    want = _oracle_tokens(gfix, ref_caches, first, 6)

    k0 = np.asarray(caches.kv_k[:, 0], np.float32)
    k2 = np.asarray(caches.kv_k[:, 2], np.float32)
    active = np.array([False, True, False])
    tok = np.array([[0], [first], [0]], np.int32)
    got = []
    for _ in range(6):
        step_logits, caches = eng.decode_step_rows(
            jnp.asarray(tok), caches, jnp.asarray(active)
        )
        nxt = int(jnp.argmax(step_logits[1, -1]))
        got.append(nxt)
        tok[1, 0] = nxt
    assert got == want, (got, want)
    assert np.array_equal(np.asarray(caches.kv_k[:, 0], np.float32), k0)
    assert np.array_equal(np.asarray(caches.kv_k[:, 2], np.float32), k2)
    assert [int(x) for x in caches.length] == [32, 38, 32]


def test_decode_step_rows_validates_shapes(gfix):
    eng = gfix["eng"]
    caches = eng.empty_caches(2)
    with pytest.raises(ValueError, match="tokens"):
        eng.decode_step_rows(
            jnp.zeros((2, 3), jnp.int32), caches, jnp.ones(2, bool)
        )
    with pytest.raises(ValueError, match="active"):
        eng.decode_step_rows(
            jnp.zeros((2, 1), jnp.int32), caches, jnp.ones(3, bool)
        )


# ---------------------------------------------------------------------------
# scheduler: N=1 oracle identity, load-only degeneration
# ---------------------------------------------------------------------------


def test_generation_n1_matches_greedy_oracle(gfix):
    u, first = gfix["u"], gfix["first"]
    spec = GenerationSpec(n_tokens=GEN, first_token=first)
    out = ContinuousScheduler(gfix["eng"], contention=IDEAL).run(
        _requests(gfix, [BandwidthTrace.constant(3 * u)],
                  sess_kw=[dict(fixed_level=0)], specs=[spec])
    )
    tl = out.timeline[0]
    want = _oracle_tokens(gfix, out.sessions[0].caches, first, GEN)
    assert tl.tokens_out == want
    assert tl.n_tokens_out == GEN
    assert out.n_gen_tokens == GEN and out.n_gen_steps == GEN
    # virtual timing: emissions strictly increase, start after the load,
    # and N=1 TPOT is exactly the uncontended step cost
    assert all(b > a for a, b in zip(tl.token_ts, tl.token_ts[1:]))
    assert tl.token_ts[0] > tl.finish_t
    assert tl.gen_finish_t == tl.token_ts[-1]
    assert tl.mean_tpot_s == pytest.approx(2e-3)
    assert max(n for _, n in out.gen_occupancy) == 1


def test_zero_token_spec_bit_identical_to_load_only(gfix):
    """generation=None and GenerationSpec(n_tokens=0) must be the same
    computation: decisions, TTFTs, caches, round count — and no generation
    machinery may run."""
    u, first = gfix["u"], gfix["first"]
    traces = [BandwidthTrace.constant(3 * u),
              BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u])]
    runs = []
    for specs in ([None, None],
                  [GenerationSpec(0, first), GenerationSpec(0, first)]):
        runs.append(ContinuousScheduler(gfix["eng"], contention=IDEAL).run(
            _requests(gfix, traces, specs=specs)
        ))
    a, b = runs
    assert a.n_rounds == b.n_rounds
    assert a.n_gen_steps == b.n_gen_steps == 0
    assert a.gen_occupancy == b.gen_occupancy == []
    for i, (x, y) in enumerate(zip(a.sessions, b.sessions)):
        assert x.configs == y.configs, f"req {i}"
        assert abs(x.ttft_s - y.ttft_s) < 1e-12
        for p, q in zip(_kv_np(x.caches), _kv_np(y.caches)):
            assert np.array_equal(p, q), f"req {i}: caches differ"
    for tl in b.timeline:
        assert tl.tokens_out == [] and np.isnan(tl.gen_finish_t)


# ---------------------------------------------------------------------------
# continuous batching: interleaving, stacking, determinism
# ---------------------------------------------------------------------------


def test_mixed_wave_stacks_generation_and_is_deterministic(gfix):
    """Four staggered arrivals on two rows, three of them generating: the
    generation steps interleave with in-flight loads, ready rows stack
    (occupancy width 2), every generating request matches its own oracle,
    and the whole run is bit-deterministic across executions."""
    u, first = gfix["u"], gfix["first"]

    def run_once():
        traces = [
            BandwidthTrace.constant(3 * u),
            BandwidthTrace.constant(2.5 * u),
            BandwidthTrace.constant(2 * u),
            BandwidthTrace.constant(3 * u),
        ]
        specs = [
            GenerationSpec(12, first),
            GenerationSpec(10, first),
            GenerationSpec(8, first),
            None,
        ]
        return ContinuousScheduler(
            gfix["eng"], rows=2, contention=IDEAL, gen_step_s=0.02,
        ).run(_requests(
            gfix, traces,
            sess_kw=[dict(fixed_level=0)] * 4,
            arrivals=[0.0, 0.02, 0.35, 0.4],
            specs=specs,
        ))

    a = run_once()
    b = run_once()
    assert [tl.tokens_out for tl in a.timeline] == \
           [tl.tokens_out for tl in b.timeline]
    assert [tl.token_ts for tl in a.timeline] == \
           [tl.token_ts for tl in b.timeline]
    assert a.gen_occupancy == b.gen_occupancy
    # ready generating rows actually stacked into one dispatch
    assert max(n for _, n in a.gen_occupancy) == 2
    # generation interleaved with loads: some step fired before the last
    # load finished
    last_load_finish = max(tl.finish_t for tl in a.timeline)
    assert min(t for t, _ in a.gen_occupancy) < last_load_finish
    for i, spec in enumerate([12, 10, 8]):
        want = _oracle_tokens(gfix, a.sessions[i].caches, first, spec)
        assert a.timeline[i].tokens_out == want, f"req {i}"
    assert a.timeline[3].tokens_out == []


def test_generation_charges_contention(gfix):
    """Under a serialized contention model two stacked rows pay factor 2 per
    virtual step; the same wave under the ideal model pays factor 1 — the
    virtual clock (and hence TPOT) must see decode pressure."""
    u, first = gfix["u"], gfix["first"]

    def run(contention):
        return ContinuousScheduler(
            gfix["eng"], rows=2, contention=contention, gen_step_s=0.01,
        ).run(_requests(
            gfix,
            [BandwidthTrace.constant(3 * u), BandwidthTrace.constant(3 * u)],
            sess_kw=[dict(fixed_level=0)] * 2,
            specs=[GenerationSpec(6, first), GenerationSpec(6, first)],
        ))

    ideal = run(IDEAL)
    serial = run(ContentionModel({}))
    # identical traces: both rows generate in lockstep, every step stacks 2
    assert max(n for _, n in ideal.gen_occupancy) == 2
    assert ideal.timeline[0].mean_tpot_s == pytest.approx(0.01)
    assert serial.timeline[0].mean_tpot_s == pytest.approx(0.02)
    # tokens themselves are timing-independent
    assert [tl.tokens_out for tl in serial.timeline] == \
           [tl.tokens_out for tl in ideal.timeline]


# ---------------------------------------------------------------------------
# suspend/resume mid-generation (least_work victim)
# ---------------------------------------------------------------------------


def test_suspend_resume_mid_generation_bit_exact(gfix):
    """rows=1: request A is mid-generation when tight-deadline B arrives;
    under victim=least_work A's row suspends (bit-exact RowSnapshot spanning
    context + emitted tokens), B loads and finishes, A resumes and its final
    token stream equals the uninterrupted solo run's."""
    u, first = gfix["u"], gfix["first"]
    spec = GenerationSpec(10, first)
    mk = lambda arrivals, traces, kw, specs, preemption: ContinuousScheduler(  # noqa: E731
        gfix["eng"], rows=1, contention=IDEAL, gen_step_s=0.05,
        preemption=preemption,
    ).run(_requests(gfix, traces, sess_kw=kw, arrivals=arrivals, specs=specs))

    solo = mk([0.0], [BandwidthTrace.constant(3 * u)],
              [dict(fixed_level=0)], [spec], None)
    want = solo.timeline[0].tokens_out
    assert want == _oracle_tokens(gfix, solo.sessions[0].caches, first, 10)
    t_fin = solo.timeline[0].finish_t

    out = mk(
        [0.0, t_fin + 0.13],
        [BandwidthTrace.constant(3 * u), BandwidthTrace.constant(50 * u)],
        [dict(fixed_level=0), dict(fixed_level=0)],
        [spec, None],
        PreemptionPolicy(victim="least_work"),
    )
    t0, t1 = out.timeline
    assert out.n_preemptions >= 1 and out.n_resumes >= 1
    # preempted *during* generation: after its own load finished, with some
    # but not all tokens already emitted
    assert t0.preempt_ts[0] > t0.finish_t
    emitted_before = sum(1 for ts in t0.token_ts if ts <= t0.preempt_ts[0])
    assert 0 < emitted_before < 10
    # B got the row promptly and met its SLO
    assert out.sessions[1].ttft_s < 1.25
    # bit-exact continuation
    assert t0.tokens_out == want
    assert t0.gen_finish_t > t1.finish_t


# ---------------------------------------------------------------------------
# EDF admission + cost-aware victim selection
# ---------------------------------------------------------------------------


def test_edf_admission_orders_waiters_by_deadline(gfix):
    """rows=1 with two queued arrivals: FIFO admits in arrival order; EDF
    admits the later, tighter-deadline waiter first."""
    u = gfix["u"]
    traces = [BandwidthTrace.constant(0.4 * u),  # r0 holds the row a while
              BandwidthTrace.constant(3 * u),
              BandwidthTrace.constant(3 * u)]
    kw = [dict(fixed_level=0),
          dict(fixed_level=0, slo_s=10.0),   # r1: early arrival, loose SLO
          dict(fixed_level=0, slo_s=0.5)]    # r2: later arrival, tight SLO
    arrivals = [0.0, 0.01, 0.02]

    def run(admission):
        return ContinuousScheduler(
            gfix["eng"], rows=1, contention=IDEAL, admission=admission,
        ).run(_requests(gfix, traces, sess_kw=kw, arrivals=arrivals))

    fifo = run("fifo")
    assert fifo.timeline[1].admit_t < fifo.timeline[2].admit_t
    edf = run("edf")
    assert edf.timeline[2].admit_t < edf.timeline[1].admit_t
    # both waiters queued behind r0 in both runs
    assert edf.timeline[2].admit_t == pytest.approx(edf.timeline[0].finish_t)


def test_select_victim_policies():
    mk = lambda end_t, work, is_gen=False: _VictimCandidate(  # noqa: E731
        obj=object(), is_gen=is_gen, end_t=end_t, preempt_t=0.0, work=work)
    straggler = PreemptionPolicy()
    least = PreemptionPolicy(victim="least_work")
    a, b, c = mk(5.0, 300), mk(9.0, 100), mk(9.0, 200, is_gen=True)
    # straggler: latest fetch end, first-wins on ties (PR 5 loop semantics)
    assert _select_victim(straggler, [a, b, c]) is b
    # least_work: fewest realized tokens regardless of kind
    assert _select_victim(least, [a, b, c]) is b
    assert _select_victim(least, [a, c]) is c
    assert _select_victim(least, []) is None
    with pytest.raises(ValueError, match="victim"):
        PreemptionPolicy(victim="coin_flip")


def test_scheduler_validates_knobs(gfix):
    with pytest.raises(ValueError, match="admission"):
        ContinuousScheduler(gfix["eng"], admission="lifo")
    with pytest.raises(ValueError, match="gen_step_s"):
        ContinuousScheduler(gfix["eng"], gen_step_s=0.0)


# ---------------------------------------------------------------------------
# spec/task validation
# ---------------------------------------------------------------------------


def test_generation_spec_and_task_validate():
    with pytest.raises(ValueError, match="n_tokens"):
        GenerationSpec(-1, 0)
    with pytest.raises(ValueError, match="gen_slo_s"):
        GenerationSpec(4, 0, gen_slo_s=0.0)
    with pytest.raises(ValueError, match="capacity"):
        GenerationTask(GenerationSpec(64, 0), index=0, label="req0:ctx",
                       row=0, start_t=0.0, context_tokens=100, capacity=128)
    t = GenerationTask(GenerationSpec(2, 7), index=0, label="req0:ctx",
                       row=0, start_t=0.0, context_tokens=100, capacity=128)
    t.record(5, 0.1)
    t.record(9, 0.2)
    assert t.done and t.realized_tokens == 102
    with pytest.raises(ValueError, match="already emitted"):
        t.suspend(0.3)


def test_seeded_sampling_is_deterministic_and_differs_from_greedy(gfix):
    u, first = gfix["u"], gfix["first"]

    def run(seed):
        return ContinuousScheduler(gfix["eng"], contention=IDEAL).run(
            _requests(gfix, [BandwidthTrace.constant(3 * u)],
                      sess_kw=[dict(fixed_level=0)],
                      specs=[GenerationSpec(GEN, first, sample_seed=seed)])
        ).timeline[0].tokens_out

    assert run(123) == run(123)
    greedy = ContinuousScheduler(gfix["eng"], contention=IDEAL).run(
        _requests(gfix, [BandwidthTrace.constant(3 * u)],
                  sess_kw=[dict(fixed_level=0)],
                  specs=[GenerationSpec(GEN, first)])
    ).timeline[0].tokens_out
    assert run(123) != greedy  # vanishingly unlikely to collide for 8 tokens


# ---------------------------------------------------------------------------
# contention: gen factor curve + calibration parsing
# ---------------------------------------------------------------------------


def test_gen_factor_interpolates_and_falls_back():
    both = ContentionModel({1: 1.0, 4: 3.0}, gen_factors={1: 1.0, 4: 2.0})
    assert both.gen_factor(4) == 2.0
    assert both.gen_factor(1) == 1.0
    assert both.gen_factor(2) == pytest.approx(4.0 / 3.0)  # interpolated
    decode_only = ContentionModel({1: 1.0, 4: 3.0})
    assert decode_only.gen_factor(4) == 3.0  # falls back to decode curve
    empty = ContentionModel({})
    assert empty.gen_factor(5) == 5.0  # serialized fallback of the fallback


def test_stacked_decode_step_calibration_parses(tmp_path, monkeypatch):
    from repro.streaming import calibration

    path = tmp_path / "BENCH_codec.json"
    path.write_text(json.dumps({
        "host_backend": jax.default_backend(),
        "fused": {"bytes_per_s": 1.0},
        "stacked_decode_step": {
            "1": {"batched": {"tokens_per_s": 100.0}},
            "4": {"batched": {"tokens_per_s": 250.0}},
            "8": {"batched": {"tokens_per_s": 1600.0}},  # super-linear: clamp
        },
    }))
    monkeypatch.setenv("CACHEGEN_BENCH_CODEC", str(path))
    calibration.clear_calibration_cache()
    try:
        factors = calibration.measured_generation_contention_factors()
        assert factors == {1: 1.0, 4: pytest.approx(1.6), 8: 1.0}
    finally:
        calibration.clear_calibration_cache()


# ---------------------------------------------------------------------------
# benchmark acceptance (separate CI job)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_generation_serving_bench_acceptance(tmp_path):
    """Reduced benchmarks/generation_serving.py run: continuous batching
    beats drain-then-generate on aggregate tokens/s, greedy tokens are
    oracle-identical, and the load-only path stays bit-identical."""
    import benchmarks.generation_serving as gs

    report = gs.run(out_path=str(tmp_path / "BENCH_generation.json"),
                    verbose=False)
    acc = report["acceptance"]
    assert acc["speedup_ge_1p5"] is True
    assert acc["greedy_tokens_match_oracle"] is True
    assert acc["load_only_bit_identical"] is True
    assert acc["generation_interleaved_with_loads"] is True
    assert report["batched_vs_drain"]["speedup"] >= 1.5


# ---------------------------------------------------------------------------
# per-token generation SLO (ISSUE 10)
# ---------------------------------------------------------------------------


def test_generation_spec_validates_gen_slo():
    with pytest.raises(ValueError, match="gen_slo_s"):
        GenerationSpec(4, 0, gen_slo_s=0.0)


def test_generation_task_gen_slo_accounting():
    """Realized TPOT over the SLO bumps slo_misses (suspension gaps count);
    resume() resets the since-resume progress counter, not the misses."""
    spec = GenerationSpec(5, 7, gen_slo_s=0.1)
    g = GenerationTask(spec, index=0, label="g", row=0, start_t=1.0,
                       context_tokens=10, capacity=64)
    g.record(3, 1.05)  # 0.05 <= 0.1: on time (measured from start_t)
    assert g.slo_misses == 0 and not g.slo_missed
    g.record(4, 1.30)  # 0.25 > 0.1: miss
    assert g.slo_misses == 1 and g.slo_missed
    assert g.tokens_since_resume == 2
    g.suspend(1.3)
    g.resume(1, 2.0)
    assert g.tokens_since_resume == 0 and g.slo_misses == 1
    g.record(5, 2.75)  # the 1.45 s gap includes the suspension: miss
    assert g.slo_misses == 2 and g.tokens_since_resume == 1


def test_gen_slo_misses_surface_on_timeline(gfix):
    """N=1 with the default 2e-3 step: a 1.5e-3 per-token SLO misses on
    every token; a loose one misses on none — and the counts land on the
    RequestTimeline and the result aggregate."""
    u, first = gfix["u"], gfix["first"]
    for slo, want in ((1.5e-3, GEN), (1.0, 0)):
        out = ContinuousScheduler(gfix["eng"], contention=IDEAL).run(
            _requests(gfix, [BandwidthTrace.constant(3 * u)],
                      sess_kw=[dict(fixed_level=0)],
                      specs=[GenerationSpec(GEN, first, gen_slo_s=slo)])
        )
        assert out.timeline[0].gen_slo_miss == want
        assert out.n_gen_slo_miss == want
        assert out.timeline[0].n_tokens_out == GEN  # flagged, never truncated


def test_gen_slo_makes_straggler_policy_preempt_generation(gfix):
    """Under the default straggler victim a generating row is untouchable —
    unless ``gen_slo`` is set and the row has already missed its per-token
    SLO: then the waiting load evicts it, and the resumed stream is still
    token-exact."""
    u, first = gfix["u"], gfix["first"]
    spec = GenerationSpec(10, first, gen_slo_s=1e-3)  # 0.05 step: all miss
    mk = lambda policy: ContinuousScheduler(  # noqa: E731
        gfix["eng"], rows=1, contention=IDEAL, gen_step_s=0.05,
        preemption=policy,
    ).run(_requests(
        gfix,
        [BandwidthTrace.constant(3 * u), BandwidthTrace.constant(50 * u)],
        sess_kw=[dict(fixed_level=0), dict(fixed_level=0)],
        arrivals=[0.0, 0.55],
        specs=[spec, None],
    ))

    keep = mk(PreemptionPolicy())  # straggler, gen_slo off: no candidates
    assert keep.n_preemptions == 0

    out = mk(PreemptionPolicy(gen_slo=True))
    t0 = out.timeline
    assert out.n_preemptions >= 1 and out.n_resumes >= 1
    assert t0[0].preempt_ts[0] > t0[0].finish_t  # evicted mid-generation
    assert t0[0].gen_slo_miss == 10  # every token's TPOT over the 1 ms SLO
    want = _oracle_tokens(gfix, out.sessions[0].caches, first, 10)
    assert t0[0].tokens_out == want  # bit-exact continuation
    assert out.sessions[1].ttft_s < 1.25  # the waiter met its SLO
