import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))  # tests/_hyp.py shim

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
