"""Transport / storage-backend split (ISSUE 4).

Covers the redesigned fetch layer:
  * storage backends — memory/directory round-trip through the ``KVStore``
    frontend, and the descriptive ``KeyError`` contract on missing
    (context, chunk, level) for both;
  * order-independent straggler draws — keyed per (chunk_idx, attempt),
    so hedged/concurrent simulations see the same tail regardless of
    simulation order, and ``fetch_outcome`` is one source of truth for the
    hedging arithmetic;
  * differential — a ``SimTransport``-backed ``ServeSession`` makes exactly
    the virtual-clock simulator's per-chunk decisions/bytes (the PR 2 trace
    matrix, now over genuinely asynchronous I/O), and reports the
    simulator's duplicate-byte accounting;
  * hedged I/O is real — under paced SimTransport the losing attempt is
    cancelled mid-read; under ``TcpTransport`` the loser's socket is closed
    mid-stream with its realized bytes reported (tcp tests are slow-marked:
    tier-1 stays socket-free; they skip cleanly where sockets are
    unavailable);
  * ``materialize`` over the handle API (LocalTransport default) and
    ``as_completed`` ordering.
"""
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import codec as kvcodec
from repro.serving.session import ServeSession
from repro.streaming import CacheGenStreamer, KVStore
from repro.streaming.network import (
    BandwidthTrace,
    NetworkModel,
    keyed_straggler_delay,
)
from repro.streaming.storage import DirectoryBackend, MemoryBackend
from repro.streaming.transport import (
    LocalTransport,
    SimTransport,
    as_completed,
)

T_CTX = 100
CHUNK = 20  # 5 chunks


def _socket_or_skip():
    import socket

    try:
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.bind(("127.0.0.1", 0))
        s.close()
    except OSError as e:  # sandboxed CI without loopback sockets
        pytest.skip(f"sockets unavailable: {e}")


@pytest.fixture(scope="module")
def tfix():
    from repro.configs import registry
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv

    rng = np.random.default_rng(0)
    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, cache_capacity=T_CTX + 40)
    tokens = rng.integers(0, cfg.vocab_size, size=(1, T_CTX)).astype(np.int32)
    logits, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens)})
    kv = caches_to_codec_kv(caches, 0, T_CTX)
    ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    metas = store.store_kv("ctx", kv, chunk_tokens=CHUNK)
    u = sum(m.sizes[1] for m in metas) * 8 / 1e9  # level-1 ctx in 1 s
    return dict(cfg=cfg, eng=eng, tokens=tokens, kv=kv, ctab=ctab,
                store=store, streamer=streamer, metas=metas, u=u)


# ---------------------------------------------------------------------------
# storage backends (satellite: descriptive KeyError on both)
# ---------------------------------------------------------------------------


def test_backends_roundtrip_and_compose_with_frontend(tfix, tmp_path):
    ctab, kv = tfix["ctab"], tfix["kv"]
    mem = KVStore(ctab, backend=MemoryBackend())
    disk = KVStore(ctab, directory=str(tmp_path))
    assert isinstance(disk.backend, DirectoryBackend)
    mem.store_kv("c", kv, chunk_tokens=40)
    disk.store_kv("c", kv, chunk_tokens=40)
    for ci in range(3):
        blob = mem.get_kv("c", ci, 1)
        assert blob == disk.get_kv("c", ci, 1)
        assert mem.backend.contains("c", ci, 1)
        assert disk.backend.contains("c", ci, 1)
    assert not mem.backend.contains("c", 0, 99)
    with pytest.raises(ValueError, match="either directory or backend"):
        KVStore(ctab, directory=str(tmp_path), backend=MemoryBackend())


def test_missing_key_raises_descriptive_error_both_backends(tfix, tmp_path):
    """A miss must name the context/chunk/level — not surface as a bare
    tuple KeyError (memory) or an opaque FileNotFoundError path (disk)."""
    ctab, kv = tfix["ctab"], tfix["kv"]
    for store in (KVStore(ctab), KVStore(ctab, directory=str(tmp_path))):
        store.store_kv("c", kv, chunk_tokens=40)
        for cid, ci, lvl in (("nope", 0, 1), ("c", 77, 1), ("c", 0, 99)):
            with pytest.raises(KeyError) as ei:
                store.get_kv(cid, ci, lvl)
            msg = str(ei.value)
            assert f"context {cid!r}" in msg, msg
            assert f"chunk {ci}" in msg and f"level {lvl}" in msg, msg
    with pytest.raises(KeyError, match="no chunk metadata for context"):
        KVStore(ctab).meta("never-stored")


# ---------------------------------------------------------------------------
# keyed straggler draws (satellite: order independence)
# ---------------------------------------------------------------------------


def test_straggler_draws_are_order_independent():
    net = lambda: NetworkModel(  # noqa: E731
        BandwidthTrace.constant(1.0), straggler_p=0.6,
        straggler_scale_s=0.5, seed=11,
    )
    a, b = net(), net()
    fwd = [a.straggler_delay(ci) for ci in range(8)]
    rev = [b.straggler_delay(ci) for ci in reversed(range(8))]
    assert fwd == list(reversed(rev))
    # interleaving hedge attempts doesn't perturb the primary draws
    c = net()
    mixed = []
    for ci in range(8):
        c.straggler_delay(ci, attempt=1)
        mixed.append(c.straggler_delay(ci))
    assert mixed == fwd
    # attempts are distinct draw streams; delays are reproducible in the key
    assert keyed_straggler_delay(11, 3, 0, p=1.0, scale_s=1.0, alpha=1.5) \
        == keyed_straggler_delay(11, 3, 0, p=1.0, scale_s=1.0, alpha=1.5)
    assert any(
        keyed_straggler_delay(11, ci, 0, p=1.0, scale_s=1.0, alpha=1.5)
        != keyed_straggler_delay(11, ci, 1, p=1.0, scale_s=1.0, alpha=1.5)
        for ci in range(4)
    )


def test_fetch_outcome_matches_fetch_time_and_accounts_duplicates():
    net = NetworkModel(BandwidthTrace.constant(0.008), rtt_s=0.001,
                       straggler_p=1.0, straggler_scale_s=0.5, seed=5)
    nbytes = 1e4  # 10 ms transmit at 8 Mbps
    plain = net.fetch_outcome(nbytes, 0.0, chunk_idx=2)
    assert plain.end_t == pytest.approx(
        net.fetch_time(nbytes, 0.0, chunk_idx=2))
    assert not plain.hedge_issued and plain.duplicate_bytes == 0.0
    hedged = net.fetch_outcome(nbytes, 0.0, chunk_idx=2, hedge_after_s=0.005)
    assert hedged.hedge_issued and hedged.hedged  # p=1 stall -> hedge wins
    want = 0.005 + net.fetch_time(nbytes, 0.005, chunk_idx=2, attempt=1,
                                  straggle=False)
    assert hedged.end_t == pytest.approx(want)
    # the cancelled primary moved some bytes, never more than the payload
    assert 0.0 <= hedged.duplicate_bytes <= nbytes


# ---------------------------------------------------------------------------
# differential: SimTransport session == virtual-clock simulator
# ---------------------------------------------------------------------------


def _traces(u):
    return {
        "flat": BandwidthTrace.constant(400 * u),
        "falling": BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u]),
        "oscillating": BandwidthTrace.steps(
            0.15, [2.0 * u, 0.4 * u, 2.0 * u, 0.4 * u]
        ),
        "collapsed": BandwidthTrace.constant(0.002 * u),
    }


def _pair(tfix, trace, *, slo_s, recompute_s, net_kwargs=None,
          transport=None, **kw):
    net_kwargs = net_kwargs or {}
    plan = tfix["streamer"].stream(
        "ctx", NetworkModel(trace, **net_kwargs), slo_s=slo_s,
        decode_bytes_per_s=1e9, recompute_s=recompute_s,
        **{k: v for k, v in kw.items()},
    )
    sess = ServeSession(
        tfix["streamer"], tfix["eng"], slo_s=slo_s, recompute_s=recompute_s,
        decode_bytes_per_s=1e9,
        **{k: v for k, v in kw.items() if k != "prior_throughput_gbps"},
    )
    res = sess.run(
        "ctx", tfix["tokens"], NetworkModel(trace, **net_kwargs),
        prior_throughput_gbps=kw.get("prior_throughput_gbps"),
        transport=transport,
    )
    return plan, res


def test_sim_transport_session_differential_on_trace_matrix(tfix):
    """Explicit SimTransport (the async read path) over the PR 2 trace
    shapes: decisions, bytes, hedge flags, duplicate bytes, and TTFT all
    equal the virtual-clock simulator's."""
    u = tfix["u"]
    net_kwargs = dict(straggler_p=0.35, straggler_scale_s=0.3, seed=9)
    for name, trace in _traces(u).items():
        transport = SimTransport(
            tfix["store"], NetworkModel(trace, **net_kwargs)
        )
        plan, res = _pair(
            tfix, trace, slo_s=1.25,
            recompute_s=lambda t, p: 0.04 * t / CHUNK,
            net_kwargs=net_kwargs, transport=transport,
            prior_throughput_gbps=float(trace.gbps[0]),
            hedge_after_s=0.25,
        )
        assert res.configs == plan.result.configs, name
        assert [t.nbytes for t in res.timelines] == \
            [t.nbytes for t in plan.result.timelines]
        assert [t.hedged for t in res.timelines] == \
            [t.hedged for t in plan.result.timelines]
        assert [t.duplicate_bytes for t in res.timelines] == \
            [t.duplicate_bytes for t in plan.result.timelines]
        assert abs(res.ttft_s - plan.result.ttft_s) < 1e-9
        assert res.duplicate_bytes == plan.result.duplicate_bytes


def test_sim_transport_hedging_pays_and_reports_duplicates(tfix):
    """Slow straggler-prone link with an aggressive hedge timer: stalled
    fetches are rescued by the winning hedge (TTFT drops), unstalled slow
    fetches see their losing hedge cancelled mid-transfer (duplicate bytes
    > 0), and the duplicate total stays bounded by the wire bytes."""
    u = tfix["u"]
    results = {}
    for hedge in (None, 0.08):
        net_kwargs = dict(straggler_p=0.6, straggler_scale_s=0.6, seed=21)
        _, res = _pair(
            tfix, BandwidthTrace.constant(1.5 * u), slo_s=5.0,
            recompute_s=lambda t, p: 100.0, net_kwargs=net_kwargs,
            prior_throughput_gbps=1.5 * u, allow_text=False,
            hedge_after_s=hedge,
        )
        results[hedge] = res
    assert results[0.08].ttft_s < results[None].ttft_s
    assert results[0.08].n_hedged > 0
    assert results[None].duplicate_bytes == 0.0
    dup = results[0.08].duplicate_bytes
    assert 0.0 < dup <= results[0.08].total_bytes


def test_sim_transport_paced_cancellation_is_real(tfix):
    """With real pacing, the losing attempt is cancelled mid-read: its
    byte counter stops short of the payload."""
    store, u = tfix["store"], tfix["u"]
    nbytes = tfix["metas"][0].sizes[0]
    # primary always stalls 10x the transfer; hedge (no stall) wins fast
    net = NetworkModel(
        BandwidthTrace.constant(nbytes * 8 / 1e9 / 0.05),  # 50 ms transfer
        straggler_p=1.0, straggler_scale_s=10.0, straggler_alpha=50.0, seed=1,
    )
    tr = SimTransport(store, net, time_scale=1.0)
    h = tr.fetch_run("ctx", [(0, 0)], start_t=0.0, hedge_after_s=0.02)
    res = h.result(timeout=30)
    assert res.hedged and res.winner == "hedge"
    assert res.blobs[0] == store.get_kv("ctx", 0, 0)
    assert res.loser_cancelled
    # cancelled mid-pace: the loser's reader never finished the payload
    assert res.loser_bytes_read < res.nbytes
    assert 0 <= res.duplicate_bytes <= res.nbytes


def test_sim_transport_missing_key_surfaces_descriptive_error(tfix):
    tr = SimTransport(
        tfix["store"], NetworkModel(BandwidthTrace.constant(1.0))
    )
    h = tr.fetch_run("ctx", [(0, 99)])
    with pytest.raises(KeyError, match="chunk 0 level 99"):
        h.result(timeout=10)


def test_as_completed_yields_in_completion_order(tfix):
    store = tfix["store"]
    nb = tfix["metas"][0].sizes[0]
    gbps = nb * 8 / 1e9  # 1 s virtual transfer per chunk
    net = NetworkModel(BandwidthTrace.constant(gbps))
    slow = SimTransport(store, net, time_scale=0.2)
    fast = SimTransport(store, net, time_scale=0.0)
    h_slow = slow.fetch_run("ctx", [(0, 0)])
    h_fast = fast.fetch_run("ctx", [(1, 0)])
    order = [h is h_fast for h in as_completed([h_slow, h_fast])]
    assert order == [True, False]


def test_as_completed_timeout_raises_with_stragglers_in_flight(tfix):
    """The total-wait timeout must surface as TimeoutError (ISSUE 6 uses
    this to bound how long a scheduler waits on a wedged link), and handles
    that did complete before expiry are still yielded first."""
    store = tfix["store"]
    nb = tfix["metas"][0].sizes[0]
    gbps = nb * 8 / 1e9  # 1 s virtual transfer per chunk
    net = NetworkModel(BandwidthTrace.constant(gbps))
    slow = SimTransport(store, net, time_scale=30.0)  # ~30 s wall: wedged
    fast = SimTransport(store, net, time_scale=0.0)
    h_slow = slow.fetch_run("ctx", [(0, 0)])
    h_fast = fast.fetch_run("ctx", [(1, 0)])
    gen = as_completed([h_slow, h_fast], timeout=0.5)
    assert next(gen) is h_fast
    with pytest.raises(TimeoutError, match="still in flight"):
        next(gen)
    h_slow.cancel()


def test_cancelled_fetch_error_names_context_and_chunks(tfix):
    """cancel() must produce an attributable FetchError: under concurrent
    serving a bare 'fetch cancelled' is undebuggable (ISSUE 6 satellite)."""
    from repro.streaming.transport import FetchError

    store = tfix["store"]
    nb = tfix["metas"][0].sizes[0]
    net = NetworkModel(BandwidthTrace.constant(nb * 8 / 1e9))
    tr = SimTransport(store, net, time_scale=30.0)
    h = tr.fetch_run("ctx", [(0, 1), (1, 1)])
    h.cancel()
    with pytest.raises(FetchError) as ei:
        h.result(timeout=10)
    msg = str(ei.value)
    assert "context 'ctx'" in msg, msg
    assert "(chunk, level)=[(0, 1), (1, 1)]" in msg, msg


def test_materialize_via_transport_matches_direct(tfix):
    streamer, eng, tokens = tfix["streamer"], tfix["eng"], tfix["tokens"]
    trace = BandwidthTrace.constant(100 * tfix["u"])
    plan = streamer.stream(
        "ctx", NetworkModel(trace), slo_s=30.0, decode_bytes_per_s=1e9,
        recompute_s=lambda t, p: 100.0, fixed_level=0,
        prior_throughput_gbps=100 * tfix["u"],
    )
    ref = streamer.materialize(plan, eng, tokens, batch=1, fused=False)
    for transport in (None, LocalTransport(streamer.store),
                      SimTransport(streamer.store, NetworkModel(trace))):
        mat = streamer.materialize(
            plan, eng, tokens, batch=1, transport=transport
        )
        assert np.array_equal(
            np.asarray(mat.kv_k[:, :, :T_CTX], np.float32),
            np.asarray(ref.kv_k[:, :, :T_CTX], np.float32),
        )


# ---------------------------------------------------------------------------
# tcp transport (slow-marked: tier-1 stays socket-free)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_tcp_roundtrip_and_missing_key(tfix):
    _socket_or_skip()
    from repro.streaming.transport import TcpStoreServer, TcpTransport

    store = tfix["store"]
    with TcpStoreServer(store) as server:
        tr = TcpTransport.for_server(server)
        h = tr.fetch_run("ctx", [(0, 1), (1, 1), (2, 0)])
        res = h.result(timeout=30)
        assert res.blobs == store.get_run("ctx", [(0, 1), (1, 1), (2, 0)])
        assert res.nbytes == sum(len(b) for b in res.blobs)
        assert res.end_t > res.start_t and res.throughput_gbps > 0
        assert not res.hedge_issued and res.duplicate_bytes == 0.0
        bad = tr.fetch_run("ctx", [(0, 99)])
        with pytest.raises(KeyError, match="chunk 0 level 99"):
            bad.result(timeout=30)


@pytest.mark.slow
def test_tcp_session_runs_end_to_end(tfix):
    """A full adaptive session over the socket transport: throughput is
    measured off the wire, the cache materializes completely."""
    _socket_or_skip()
    from repro.streaming.transport import TcpStoreServer, TcpTransport

    store = tfix["store"]
    level1_bytes = sum(m.sizes[1] for m in tfix["metas"])
    pace = level1_bytes * 8 / 1e9 / 0.25  # level-1 ctx in ~250 ms
    with TcpStoreServer(store, pace_gbps=pace) as server:
        sess = ServeSession(
            tfix["streamer"], tfix["eng"], slo_s=5.0,
            recompute_s=lambda t, p: 100.0, decode_bytes_per_s=1e9,
            allow_text=False, transport=TcpTransport.for_server(server),
        )
        res = sess.run(
            "ctx", tfix["tokens"],
            NetworkModel(BandwidthTrace.constant(pace)),
            prior_throughput_gbps=pace,
        )
        assert int(res.caches.length[0]) == T_CTX
        assert all(c >= 0 for c in res.configs)
        # the estimator measured a real link: observed throughputs are
        # finite, positive, and the paced fetches took real wall time
        assert res.ttft_s > 0.1
        ref = tfix["streamer"].materialize(
            tfix["streamer"].stream(
                "ctx", NetworkModel(BandwidthTrace.constant(pace)),
                slo_s=5.0, decode_bytes_per_s=1e9,
                recompute_s=lambda t, p: 100.0, fixed_level=res.configs[0],
                prior_throughput_gbps=pace,
            ),
            tfix["eng"], tfix["tokens"], batch=1, fused=False,
        )
        if all(c == res.configs[0] for c in res.configs):
            np.testing.assert_allclose(
                np.asarray(res.caches.kv_k[:, :, :T_CTX], np.float32),
                np.asarray(ref.kv_k[:, :, :T_CTX], np.float32),
                atol=2e-2, rtol=2e-2,
            )


@pytest.mark.slow
def test_tcp_hedge_cancels_loser_mid_stream(tfix):
    """Stalled primary (keyed injection, attempt 0 only) + paced link: the
    hedge wins, the loser's socket is closed mid-stream, and duplicate
    bytes stay bounded by the payload."""
    _socket_or_skip()
    from repro.streaming.transport import TcpStoreServer, TcpTransport

    store = tfix["store"]
    nb = store.meta("ctx")[0].sizes[0]
    pace = nb * 8 / 1e9 / 0.3  # ~300 ms paced transfer
    with TcpStoreServer(
        store, pace_gbps=pace,
        straggler_p=1.0, straggler_scale_s=1.0, straggler_alpha=50.0, seed=3,
    ) as server:
        tr = TcpTransport.for_server(server)
        t0 = time.perf_counter()
        h = tr.fetch_run("ctx", [(0, 0)], hedge_after_s=0.05)
        res = h.result(timeout=60)
        wall = time.perf_counter() - t0
        assert res.hedged and res.winner == "hedge"
        assert res.hedge_issued and res.loser_cancelled
        assert res.blobs[0] == store.get_kv("ctx", 0, 0)
        assert 0 <= res.duplicate_bytes <= res.nbytes
        assert res.loser_bytes_read == res.duplicate_bytes
        # the hedge rescued the fetch from the >=1 s primary stall
        assert wall < 1.0, wall
        # and an unhedged fetch of the same chunk eats the stall
        t0 = time.perf_counter()
        tr.fetch_run("ctx", [(0, 0)]).result(timeout=60)
        assert time.perf_counter() - t0 > 1.0


# ---------------------------------------------------------------------------
# benchmark acceptance (separate CI job)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_transport_bench_acceptance(tmp_path):
    """Reduced benchmarks/transport_session.py run: hedged p95 TTFT beats
    unhedged under straggler injection on both transports, unhedged runs
    report zero duplicate bytes, hedged duplicates stay bounded, and the
    cancellation probe shows losers stopped mid-stream."""
    _socket_or_skip()
    from benchmarks.transport_session import run

    report = run(out_path=str(tmp_path / "BENCH_transport.json"),
                 sim_trials=10, tcp_trials=6, verbose=False)
    acc = report["acceptance"]
    assert acc["sim_hedged_beats_unhedged_p95"] is True
    assert acc["tcp_hedged_beats_unhedged_p95"] is True
    assert acc["unhedged_has_no_duplicates"] is True
    assert acc["duplicate_bytes_bounded"] is True
    assert acc["losers_cancelled_mid_stream"] is True
    by = {(r["transport"], r["hedged"]): r for r in report["rows"]}
    assert by[("sim", True)]["n_hedged_total"] > 0
    assert by[("tcp", True)]["n_hedged_total"] > 0
    assert 0.0 < by[("sim", True)]["duplicate_frac"] <= 0.6
