"""Launch layer: HLO collective parsing, cell specs, dry-run machinery."""
import numpy as np
import pytest

from repro.configs import registry
from repro.launch import hlo_stats
from repro.launch.specs import SHAPES, cell_applicable, rules_for


def test_collective_stats_parses_shapes_and_groups():
    hlo = """
  %ag.1 = bf16[16,1024]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %ar.2 = f32[8,8]{1,0} all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%sum
  %rs.3 = f32[4]{0} reduce-scatter(%z), channel_id=3, replica_groups=[4,64]<=[256]
  %cp.4 = u8[100]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %notacoll = f32[2,2]{1,0} add(%a, %b)
"""
    st = hlo_stats.collective_stats(hlo, mesh_size=256)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.result_bytes["all-gather"] == 16 * 1024 * 2
    assert st.result_bytes["all-reduce"] == 8 * 8 * 4
    # all-gather group size 16 -> wire = bytes * 15/16
    np.testing.assert_allclose(
        st.wire_bytes["all-gather"], 16 * 1024 * 2 * 15 / 16
    )
    # all-reduce group size 4 -> 2 * b * 3/4
    np.testing.assert_allclose(st.wire_bytes["all-reduce"], 2 * 256 * 3 / 4)
    # reduce-scatter group 64: result * (n-1)
    np.testing.assert_allclose(st.wire_bytes["reduce-scatter"], 16 * 63)
    assert st.wire_bytes["collective-permute"] == 100


def test_collective_stats_skips_async_done():
    hlo = """
  %ags = bf16[128]{0} all-gather-start(%x), replica_groups={{0,1}}
  %agd = bf16[128]{0} all-gather-done(%ags)
"""
    st = hlo_stats.collective_stats(hlo, 2)
    assert st.counts["all-gather"] == 1


def test_tuple_shape_bytes():
    assert hlo_stats._shape_bytes("(f32[2,2]{1,0}, bf16[4]{0})") == 16 + 8
    assert hlo_stats._shape_bytes("pred[8]{0}") == 8


def test_shapes_match_assignment():
    assert SHAPES["train_4k"] == dict(kind="train", seq=4096, batch=256)
    assert SHAPES["prefill_32k"] == dict(kind="prefill", seq=32768, batch=32)
    assert SHAPES["decode_32k"] == dict(kind="decode", seq=32768, batch=128)
    assert SHAPES["long_500k"] == dict(kind="decode", seq=524288, batch=1)


def test_long_context_skip_rule():
    ok, _ = cell_applicable(registry.get("qwen1.5-110b"), "long_500k")
    assert not ok
    ok, _ = cell_applicable(registry.get("mamba2-370m"), "long_500k")
    assert ok
    ok, _ = cell_applicable(registry.get("zamba2-2.7b"), "long_500k")
    assert ok
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in registry.names():
            ok, _ = cell_applicable(registry.get(arch), shape)
            assert ok, (arch, shape)


def test_long500k_rules_reshard_sequence():
    r = rules_for(registry.get("zamba2-2.7b"), "long_500k")
    assert r["batch"] is None
    assert r["kv_seq_decode"] == ("data", "model")


@pytest.mark.slow
def test_make_cell_lowers_on_test_mesh():
    """The dry-run cell machinery lowers a tiny arch on an 8-device mesh
    (same code path as the 512-device production dry-run)."""
    import json
    import os
    import subprocess
    import sys

    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    body = r"""
import json
import jax
from repro.configs import registry
from repro.launch.specs import make_cell
from repro.launch import hlo_stats
from repro.models import sharding as shlib
from jax.sharding import Mesh
import numpy as np

cfg = registry.get("olmo-1b").tiny()
import dataclasses
cfg = dataclasses.replace(cfg, vocab_size=512)
mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))

import repro.launch.specs as specs
specs.SHAPES = dict(specs.SHAPES, tiny_train=dict(kind="train", seq=32, batch=4),
                    tiny_decode=dict(kind="decode", seq=64, batch=4))
out = {}
for shape in ("tiny_train", "tiny_decode"):
    cell = make_cell(cfg, shape, mesh, {"embed": None})
    with mesh, shlib.use_rules(mesh, cell.rules):
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings).lower(*cell.inputs).compile()
    st = hlo_stats.collective_stats(compiled.as_text(), 4)
    out[shape] = sum(st.counts.values())
print(json.dumps(out))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    res = subprocess.run(
        [sys.executable, "-c", body], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["tiny_train"] > 0  # sharded training must communicate
