"""Codec correctness: rANS vs AC oracle, round-trips, error bounds."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis, or fixed-seed fallback

from repro.core import codec, gop, quant, rans, tables
from repro.core.ac_ref import ac_decode, ac_encode


def _random_tables(rng, n_tables, A, k):
    counts = rng.integers(0, 1000, size=(n_tables, A))
    freqs = tables.normalize_freqs(counts, k)
    return freqs, tables.build_coder_tables(freqs, k)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    A=st.integers(2, 300),
    k=st.sampled_from([9, 10, 12, 14]),
    n_lanes=st.integers(1, 32),
    n_sym=st.integers(1, 128),
)
def test_rans_roundtrip_property(seed, A, k, n_lanes, n_sym):
    if A > (1 << k):
        A = 1 << k
    rng = np.random.default_rng(seed)
    freqs, ct = _random_tables(rng, 3, A, k)
    t_idx = rng.integers(0, 3, n_lanes).astype(np.int32)
    syms = rng.integers(0, A, size=(n_lanes, n_sym)).astype(np.uint16)
    w, nw, s = rans.encode(jnp.asarray(syms), jnp.asarray(t_idx), ct)
    dec = rans.decode(w, nw, s, jnp.asarray(t_idx), ct, n_sym, check=True)
    assert (np.asarray(dec) == syms).all()


def test_rans_matches_ac_oracle_size():
    """rANS compressed size within ~3% + constant of the exact AC oracle."""
    rng = np.random.default_rng(0)
    A, k = 64, 12
    freqs, ct = _random_tables(rng, 1, A, k)
    p = freqs[0] / freqs[0].sum()
    n_sym = 4000
    syms = rng.choice(A, size=n_sym, p=p).astype(np.uint16)
    w, nw, s = rans.encode(jnp.asarray(syms[None]), jnp.zeros(1, jnp.int32), ct)
    rans_bytes = rans.encoded_bytes(nw)
    ac_bytes = len(ac_encode(syms, freqs[0]))
    assert ac_decode(ac_encode(syms, freqs[0]), n_sym, freqs[0]) == list(syms)
    assert rans_bytes <= ac_bytes * 1.03 + 16, (rans_bytes, ac_bytes)


def test_rans_near_entropy_bound():
    rng = np.random.default_rng(1)
    A, k = 32, 12
    freqs, ct = _random_tables(rng, 1, A, k)
    p = freqs[0] / freqs[0].sum()
    H = -(p * np.log2(p)).sum()
    n_sym = 8000
    syms = rng.choice(A, size=n_sym, p=p).astype(np.uint16)
    w, nw, s = rans.encode(jnp.asarray(syms[None]), jnp.zeros(1, jnp.int32), ct)
    bits = rans.encoded_bytes(nw) * 8
    assert bits <= H * n_sym * 1.05 + 64, (bits, H * n_sym)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    T=st.integers(11, 90),
    group=st.integers(2, 16),
)
def test_gop_split_merge_inverse(seed, T, group):
    rng = np.random.default_rng(seed)
    layout = gop.make_layout(T, group)
    kv = jnp.asarray(rng.normal(size=(2, 2, T, 8)), jnp.float32)
    a, d = gop.split_anchors_deltas(kv, layout)
    back = gop.merge_anchors_deltas(a, d, layout)
    np.testing.assert_allclose(np.asarray(back), np.asarray(kv), rtol=0, atol=1e-6)


def _toy_kv(rng, L=3, T=47, C=12):
    kv = rng.normal(size=(L, 2, T, C)).astype(np.float32) * 0.5
    kv[:] = np.cumsum(kv * 0.3, axis=2) + rng.normal(size=(L, 2, 1, C)) * 0.5
    return kv


@pytest.fixture(scope="module")
def toy_codec():
    rng = np.random.default_rng(3)
    kvs = [_toy_kv(rng) for _ in range(3)]
    cfg = codec.CodecConfig(precision=10)
    return kvs, codec.profile(kvs, cfg), cfg


def test_codec_level0_bit_exact(toy_codec):
    kvs, ct, cfg = toy_codec
    kv = kvs[0]
    layout = gop.make_layout(kv.shape[2], cfg.group_size)
    a, d, s = quant.lossless_quantize(jnp.asarray(kv), layout)
    ref = np.asarray(quant.lossless_reconstruct(a, d, s, layout))
    got = np.asarray(codec.decode_chunk(codec.encode_chunk(kv, ct, 0), ct))
    assert np.array_equal(ref, got)


def test_codec_levels_monotone_size_and_bounded_error(toy_codec):
    kvs, ct, cfg = toy_codec
    kv = kvs[1]
    sizes, errs = [], []
    for lvl in range(cfg.n_levels):
        blob = codec.encode_chunk(kv, ct, lvl)
        kv_hat = np.asarray(codec.decode_chunk(blob, ct))
        sizes.append(len(blob))
        errs.append(np.abs(kv_hat - kv).max())
    assert all(sizes[i] >= sizes[i + 1] for i in range(1, len(sizes) - 1)), sizes
    # per-element error <= bin/2 + anchor error; check a loose bound
    L = kv.shape[0]
    for lvl in range(1, cfg.n_levels):
        bins = codec._bins_for_level(cfg, L, lvl, ct.delta_scale)
        bound = bins.max() / 2 * 1.5 + 0.05
        blob = codec.encode_chunk(kv, ct, lvl)
        kv_hat = np.asarray(codec.decode_chunk(blob, ct))
        assert np.abs(kv_hat - kv).max() <= bound + 0.2, (lvl, np.abs(kv_hat - kv).max(), bound)


def test_codec_chunk_independence(toy_codec):
    """Chunks encoded separately decode to the same result as jointly."""
    kvs, ct, cfg = toy_codec
    kv = kvs[2]
    T = kv.shape[2]
    cut = (T // 2 // cfg.group_size) * cfg.group_size  # chunk boundary on group
    whole = np.asarray(codec.decode_chunk(codec.encode_chunk(kv, ct, 1), ct))
    left = np.asarray(codec.decode_chunk(codec.encode_chunk(kv[:, :, :cut], ct, 1), ct))
    right = np.asarray(codec.decode_chunk(codec.encode_chunk(kv[:, :, cut:], ct, 1), ct))
    np.testing.assert_allclose(np.concatenate([left, right], axis=2), whole, atol=2e-2)


def test_codec_rejects_mismatched_shape(toy_codec):
    kvs, ct, cfg = toy_codec
    bad = np.zeros((kvs[0].shape[0] + 1, 2, 20, kvs[0].shape[3]), np.float32)
    with pytest.raises(ValueError):
        codec.encode_chunk(bad, ct, 1)


def test_rans_batched_decode_matches_per_stream():
    """Stacked-lane decode with stacked tables == independent per-stream
    decodes (the batched multi-chunk fast path's core property)."""
    rng = np.random.default_rng(11)
    A, k, n_lanes, n_sym = 64, 10, 16, 40
    _, ct1 = _random_tables(rng, 4, A, k)
    _, ct2 = _random_tables(rng, 4, A, k)
    stacked = rans.stack_tables([ct1, ct2])
    t1 = rng.integers(0, 4, n_lanes).astype(np.int32)
    t2 = rng.integers(0, 4, n_lanes).astype(np.int32)
    s1 = rng.integers(0, A, size=(n_lanes, n_sym)).astype(np.uint16)
    s2 = rng.integers(0, A, size=(n_lanes, n_sym - 13)).astype(np.uint16)
    w1, n1, x1 = rans.encode(jnp.asarray(s1), jnp.asarray(t1), ct1)
    w2, n2, x2 = rans.encode(jnp.asarray(s2), jnp.asarray(t2), ct2)
    # pad both streams' word buffers to a common cap and stack lanes
    cap = max(w1.shape[1], w2.shape[1])
    words = np.zeros((2 * n_lanes, cap), np.uint16)
    words[:n_lanes, : w1.shape[1]] = np.asarray(w1)
    words[n_lanes:, : w2.shape[1]] = np.asarray(w2)
    n_words = np.concatenate([np.asarray(n1), np.asarray(n2)])
    state = np.concatenate([np.asarray(x1), np.asarray(x2)])
    t_idx = np.concatenate([t1, t2 + 4])  # stream 2 offsets into table set 2
    dec = rans.decode(words, n_words, state, t_idx, stacked, n_sym)
    assert (np.asarray(dec)[:n_lanes] == s1).all()
    # shorter stream: valid prefix decodes exactly; tail is don't-care
    assert (np.asarray(dec)[n_lanes:, : n_sym - 13] == s2).all()


def test_stack_tables_rejects_mismatched():
    rng = np.random.default_rng(0)
    _, a = _random_tables(rng, 2, 16, 10)
    _, b = _random_tables(rng, 2, 16, 12)
    _, c = _random_tables(rng, 2, 32, 10)
    with pytest.raises(ValueError):
        rans.stack_tables([a, b])
    with pytest.raises(ValueError):
        rans.stack_tables([a, c])


def test_encode_all_levels_byte_identical_to_per_level(toy_codec):
    """Batched encode (anchors hoisted, stacked delta rANS) is a pure
    optimization: bitstreams match per-level encode_chunk byte for byte."""
    kvs, ct, cfg = toy_codec
    kv = kvs[0]
    batched = codec.encode_all_levels(kv, ct)
    for lvl in range(cfg.n_levels):
        assert batched[lvl] == codec.encode_chunk(kv, ct, lvl), lvl


@pytest.mark.parametrize("use_pallas", [False, True])
def test_decode_chunks_matches_reference(toy_codec, use_pallas):
    """Fused batched decode == concatenated per-chunk reference decodes:
    bit-exact at level 0, tolerance-exact at lossy levels.  Mixed levels and
    ragged chunk lengths share one batch."""
    kvs, ct, cfg = toy_codec
    rng = np.random.default_rng(5)
    chunks = [_toy_kv(rng, T=t) for t in (40, 40, 23, 40)]
    levels = [1, 0, 2, 0]
    blobs = [codec.encode_chunk(c, ct, l) for c, l in zip(chunks, levels)]
    ref = np.concatenate(
        [np.asarray(codec.decode_chunk(b, ct)) for b in blobs], axis=2
    )
    got = np.asarray(
        codec.decode_chunks(blobs, ct, out_dtype=jnp.float32, use_pallas=use_pallas)
    )
    assert got.shape == ref.shape
    s = 0
    for c, lvl in zip(chunks, levels):
        e = s + c.shape[2]
        if lvl == 0:
            assert np.array_equal(got[:, :, s:e], ref[:, :, s:e])
        else:
            np.testing.assert_allclose(
                got[:, :, s:e], ref[:, :, s:e], atol=1e-5, rtol=1e-5
            )
        s = e


def test_decode_chunks_single_and_uniform(toy_codec):
    kvs, ct, cfg = toy_codec
    rng = np.random.default_rng(6)
    chunks = [_toy_kv(rng, T=30) for _ in range(3)]
    for lvl in range(cfg.n_levels):
        blobs = [codec.encode_chunk(c, ct, lvl) for c in chunks]
        got = np.asarray(codec.decode_chunks(blobs, ct, use_pallas=False))
        ref = np.concatenate(
            [np.asarray(codec.decode_chunk(b, ct)) for b in blobs], axis=2
        )
        tol = 0 if lvl == 0 else 1e-5
        np.testing.assert_allclose(got, ref, atol=tol, rtol=tol)


def test_decode_chunks_bf16_output_stays_on_device(toy_codec):
    kvs, ct, cfg = toy_codec
    blob = codec.encode_chunk(kvs[0], ct, 1)
    out = codec.decode_chunks([blob], ct, out_dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
    assert isinstance(out, jax.Array)


def test_normalize_freqs_invariants():
    rng = np.random.default_rng(0)
    for _ in range(5):
        counts = rng.integers(0, 10000, size=(4, 17))
        counts[rng.integers(0, 4), rng.integers(0, 17)] = 0
        f = tables.normalize_freqs(counts, 10)
        assert (f.sum(axis=1) == 1024).all()
        assert (f >= 1).all() and (f < 1024).all()


def test_bitstream_pack_roundtrip():
    from repro.core import bitstream

    rng = np.random.default_rng(0)
    words = rng.integers(0, 2**16, size=(5, 9)).astype(np.uint16)
    n_words = np.asarray([3, 0, 9, 1, 5], np.int32)
    state = rng.integers(0, 2**32, size=5, dtype=np.uint32)
    arrays = bitstream.pack_stream(words, n_words, state, "x")
    w2, n2, s2 = bitstream.unpack_stream(arrays, "x")
    assert (n2 == n_words).all() and (s2 == state).all()
    for i in range(5):
        assert (w2[i, : n2[i]] == words[i, : n_words[i]]).all()
    blob = bitstream.pack({"a": 1, "s": "x"}, arrays)
    hdr, arr2 = bitstream.unpack(blob)
    assert hdr["a"] == 1 and hdr["s"] == "x"
    assert (arr2["x.n_words"] == n_words).all()
