"""Streaming layer: network traces, Algorithm 1 adaptation, pipelining,
hedged fetches, end-to-end store->stream->materialize->generate."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis, or fixed-seed fallback

from repro.streaming.adaptation import TEXT, AdaptationPolicy, choose_config
from repro.streaming.network import BandwidthTrace, NetworkModel
from repro.streaming.pipeline import simulate_stream
from repro.streaming.storage import ChunkMeta, KVStore, split_chunks


# ---------------------------------------------------------------------------
# network
# ---------------------------------------------------------------------------


def test_trace_transmit_integrates_segments():
    tr = BandwidthTrace.steps(1.0, [1.0, 0.5])  # 1 Gbps then 0.5 Gbps
    # 1 Gbit in the first second, then 0.5 Gbit/s
    t = tr.transmit_time(1.5e9 / 8, 0.0)  # 1.5 Gbit
    assert abs(t - 2.0) < 1e-9
    t2 = tr.transmit_time(0.25e9 / 8, 1.5)  # entirely in the 0.5 Gbps segment
    assert abs(t2 - 0.5) < 1e-9


def _random_trace(rng) -> BandwidthTrace:
    """Random piecewise trace, always containing a zero-length segment."""
    n = int(rng.integers(3, 9))
    durs = rng.uniform(0.0, 0.8, n - 1)
    durs[int(rng.integers(n - 1))] = 0.0
    times = np.concatenate([[0.0], np.cumsum(durs)])
    gbps = np.exp(rng.uniform(np.log(0.05), np.log(5.0), n))
    return BandwidthTrace(times, gbps)


def test_trace_zero_length_segments():
    tr = BandwidthTrace(
        np.array([0.0, 1.0, 1.0, 2.0]), np.array([1.0, 8.0, 0.5, 2.0])
    )
    # at a duplicated instant the last segment starting there is in effect
    assert tr.bandwidth_at(1.0) == 0.5
    # 1 Gbit in the first second; the zero-length 8 Gbps segment carries
    # nothing; then 0.5 Gbps
    assert abs(tr.transmit_time(1.5e9 / 8, 0.0) - 2.0) < 1e-9
    assert np.isclose(tr.bytes_in_window(2.0, 0.0), 1.5e9 / 8)
    # fetch starting exactly on the duplicated boundary
    assert np.isclose(tr.transmit_time(0.5e9 / 8, 1.0), 1.0)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    start=st.floats(0, 3),
    duration=st.floats(1e-4, 5.0),
)
def test_trace_transfer_byte_integration_roundtrip(seed, start, duration):
    """transmit_time and bytes_in_window are inverses across segment
    boundaries, zero-length segments, and mid-segment starts."""
    rng = np.random.default_rng(seed)
    tr = _random_trace(rng)
    nb = tr.bytes_in_window(duration, start)
    assert nb > 0  # bandwidth is strictly positive on every segment
    assert np.isclose(tr.transmit_time(nb, start), duration, rtol=1e-6, atol=1e-9)
    nbytes = float(rng.uniform(1.0, 1e8))
    dur = tr.transmit_time(nbytes, start)
    assert np.isclose(tr.bytes_in_window(dur, start), nbytes, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    nbytes=st.floats(1, 1e9),
    start=st.floats(0, 20),
    seed=st.integers(0, 1000),
)
def test_trace_measured_throughput_consistent(nbytes, start, seed):
    rng = np.random.default_rng(seed)
    tr = BandwidthTrace.sampled(rng, 8, 0.7, 0.1, 10.0)
    dur = tr.transmit_time(nbytes, start)
    gbps = tr.measured_throughput_gbps(nbytes, start)
    assert dur >= 0
    assert 0.099 <= gbps <= 10.01


# ---------------------------------------------------------------------------
# adaptation (Algorithm 1)
# ---------------------------------------------------------------------------


def test_choose_config_prefers_quality_when_feasible():
    cfg = choose_config(
        remaining_sizes={0: 1e6, 1: 5e5, 2: 2e5},
        remaining_text_bytes=4e4,
        remaining_recompute_s=10.0,  # recompute too slow
        throughput_gbps=1.0,
        time_left_s=1.0,
        levels_quality_order=[0, 1, 2],
    )
    assert cfg.config == 0  # level-0 fits easily at 1 Gbps


def test_choose_config_escalates_under_pressure():
    cfg = choose_config(
        remaining_sizes={0: 1e9, 1: 4e8, 2: 1e8},
        remaining_text_bytes=1e6,
        remaining_recompute_s=50.0,
        throughput_gbps=1.0,
        time_left_s=1.0,
        levels_quality_order=[0, 1, 2],
    )
    assert cfg.config == 2  # only the coarsest level fits


def test_choose_config_falls_back_to_text():
    cfg = choose_config(
        remaining_sizes={0: 1e9, 1: 9e8, 2: 8e8},
        remaining_text_bytes=1e5,
        remaining_recompute_s=0.2,
        throughput_gbps=0.01,  # network collapsed
        time_left_s=1.0,
        levels_quality_order=[0, 1, 2],
    )
    assert cfg.config == TEXT


def test_choose_config_best_effort_when_nothing_fits():
    cfg = choose_config(
        remaining_sizes={0: 1e9, 1: 9e8},
        remaining_text_bytes=1e9,
        remaining_recompute_s=100.0,
        throughput_gbps=0.001,
        time_left_s=0.1,
        levels_quality_order=[0, 1],
    )
    assert cfg.config == 1  # smallest representation


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 1_000_000),
    thr=st.floats(0.01, 5.0),
    tleft=st.floats(0.01, 4.0),
    allow_text=st.booleans(),
)
def test_choose_config_properties(seed, thr, tleft, allow_text):
    """Algorithm 1 invariants: (a) the choice meets the SLO whenever *any*
    configuration can; (b) quality ordering is respected — never a lossier
    level when a less lossy candidate also fits; (c) TEXT is only chosen
    when its own projected delay fits (outside the best-effort case)."""
    rng = np.random.default_rng(seed)
    n_levels = int(rng.integers(2, 6))
    sizes = {lvl: float(rng.uniform(1e4, 5e8)) for lvl in range(n_levels)}
    text_bytes = float(rng.uniform(1e3, 1e7))
    recompute = float(rng.uniform(0.0, 5.0))
    cfg = choose_config(
        remaining_sizes=sizes,
        remaining_text_bytes=text_bytes,
        remaining_recompute_s=recompute,
        throughput_gbps=thr,
        time_left_s=tleft,
        levels_quality_order=list(range(n_levels)),
        allow_text=allow_text,
    )
    proj = {lvl: sizes[lvl] * 8 / (thr * 1e9) for lvl in range(n_levels)}
    order = list(range(n_levels))
    if allow_text:
        proj[TEXT] = recompute + text_bytes * 8 / (thr * 1e9)
        order = [TEXT] + order
    feasible = [c for c in order if proj[c] <= tleft]
    if feasible:
        assert proj[cfg.config] <= tleft  # (a)
        assert cfg.config == feasible[0]  # (b)
        if cfg.config == TEXT:
            assert proj[TEXT] <= tleft  # (c)
    else:  # best effort: smallest projected completion
        assert proj[cfg.config] == min(proj.values())
    assert np.isclose(cfg.projected_s, proj[cfg.config])


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), slo=st.floats(0.2, 5.0))
def test_adaptation_never_violates_when_feasible(seed, slo):
    """If the coarsest level fits the SLO under the true (constant)
    bandwidth, the adaptive stream meets the SLO."""
    rng = np.random.default_rng(seed)
    gbps = float(rng.uniform(0.5, 5.0))
    n_chunks = int(rng.integers(2, 8))
    metas = []
    for i in range(n_chunks):
        base = int(rng.integers(10_000, 200_000))
        metas.append(
            ChunkMeta("c", i, 0, 100, sizes={0: base * 4, 1: base * 2, 2: base},
                      text_bytes=400)
        )
    total_coarse = sum(m.sizes[2] for m in metas)
    t_coarse = total_coarse * 8 / (gbps * 1e9) * 1.05 + 0.01
    if t_coarse > slo:
        return  # infeasible -> no guarantee claimed
    net = NetworkModel(BandwidthTrace.constant(gbps))
    pol = AdaptationPolicy([0, 1, 2], slo_s=slo, default_level=2,
                           prior_throughput_gbps=gbps, allow_text=False)
    res = simulate_stream(
        metas, pol, net, decode_bytes_per_s=1e12, recompute_s=lambda t, p: 1e9
    )
    assert res.ttft_s <= slo * 1.001, (res.ttft_s, slo, res.configs)


def test_pipeline_overlaps_fetch_and_decode():
    metas = [
        ChunkMeta("c", i, 0, 100, sizes={0: 125_000_000}, text_bytes=400)
        for i in range(4)
    ]  # 1 Gbit each -> 1 s at 1 Gbps
    net = NetworkModel(BandwidthTrace.constant(1.0))
    pol = AdaptationPolicy([0], slo_s=100, default_level=0,
                           prior_throughput_gbps=1.0, allow_text=False)
    res = simulate_stream(
        metas, pol, net, decode_bytes_per_s=250e6,  # 0.5 s decode per chunk
        recompute_s=lambda t, p: 1e9,
    )
    # serial would be 4 x (1 + 0.5) = 6 s; pipelined ~ 4 x 1 + 0.5 = 4.5 s
    assert res.ttft_s < 4.75, res.ttft_s


def test_hedging_caps_straggler_tail():
    metas = [
        ChunkMeta("c", i, 0, 100, sizes={0: 1_000_000}, text_bytes=400)
        for i in range(6)
    ]
    ttfts = {}
    for hedge in (None, 0.05):
        net = NetworkModel(
            BandwidthTrace.constant(1.0), straggler_p=0.5,
            straggler_scale_s=2.0, seed=3,
        )
        pol = AdaptationPolicy([0], slo_s=100, default_level=0,
                               prior_throughput_gbps=1.0, allow_text=False)
        res = simulate_stream(
            metas, pol, net, decode_bytes_per_s=1e12,
            recompute_s=lambda t, p: 1e9, hedge_after_s=hedge,
        )
        ttfts[hedge] = res.ttft_s
    assert ttfts[0.05] < ttfts[None] * 0.7, ttfts


# ---------------------------------------------------------------------------
# storage + end-to-end
# ---------------------------------------------------------------------------


def test_split_chunks_covers_everything():
    for T, c in [(10, 3), (9, 3), (1, 5), (100, 100)]:
        spans = split_chunks(T, c)
        assert spans[0][0] == 0 and spans[-1][1] == T
        for (a, b), (c2, d) in zip(spans, spans[1:]):
            assert b == c2


@pytest.fixture(scope="module")
def tiny_stream_setup(tmp_path_factory):
    from repro.configs import registry
    from repro.core import codec as kvcodec
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv

    rng = np.random.default_rng(0)
    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, cache_capacity=140)
    T = 100
    tokens = rng.integers(0, cfg.vocab_size, size=(1, T)).astype(np.int32)
    logits, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens)})
    kv = caches_to_codec_kv(caches, 0, T)
    ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
    return cfg, eng, tokens, logits, caches, kv, ctab


def test_store_disk_and_memory_agree(tiny_stream_setup, tmp_path):
    cfg, eng, tokens, logits, caches, kv, ctab = tiny_stream_setup
    mem = KVStore(ctab)
    disk = KVStore(ctab, directory=str(tmp_path))
    mem.store_kv("c", kv, chunk_tokens=40)
    disk.store_kv("c", kv, chunk_tokens=40)
    for ci in range(3):
        assert mem.get_kv("c", ci, 1) == disk.get_kv("c", ci, 1)


def test_end_to_end_stream_and_generate(tiny_stream_setup):
    from repro.streaming import CacheGenStreamer

    cfg, eng, tokens, logits, caches, kv, ctab = tiny_stream_setup
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    store.store_kv("ctx", kv, chunk_tokens=40)
    net = NetworkModel(BandwidthTrace.constant(0.5))
    plan = streamer.stream(
        "ctx", net, slo_s=5.0, decode_bytes_per_s=1e9,
        recompute_s=lambda t, p: 100.0, prior_throughput_gbps=0.5, allow_text=False,
    )
    assert all(c != TEXT for c in plan.result.configs)
    mat = streamer.materialize(plan, eng, tokens, batch=1)
    assert int(mat.length[0]) == tokens.shape[1]
    # materialized KV must equal the original cache within the coarsest
    # chosen level's quantization bound (this model is untrained, so argmax
    # agreement is not a stable metric here — quality-vs-level is asserted
    # on a trained model in tests/test_system.py)
    T = tokens.shape[1]
    err = np.abs(
        np.asarray(mat.kv_k[:, 0, :T], np.float32)
        - np.asarray(caches.kv_k[:, 0, :T], np.float32)
    ).max()
    assert err < 1.0, err
    first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    gen = eng.generate_with_kv(mat, first, 8)
    assert np.isfinite(gen).all() and gen.shape == (1, 8)


def test_materialize_fused_matches_reference(tiny_stream_setup):
    """Fused batched decode-to-cache (default) == seed per-chunk path."""
    from repro.streaming import CacheGenStreamer

    cfg, eng, tokens, logits, caches, kv, ctab = tiny_stream_setup
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    store.store_kv("ctx", kv, chunk_tokens=40)
    net = NetworkModel(BandwidthTrace.constant(0.5))
    plan = streamer.stream(
        "ctx", net, slo_s=5.0, decode_bytes_per_s=1e9,
        recompute_s=lambda t, p: 100.0, prior_throughput_gbps=0.5, allow_text=False,
    )
    T = tokens.shape[1]
    mat_ref = streamer.materialize(plan, eng, tokens, batch=1, fused=False)
    mat = streamer.materialize(plan, eng, tokens, batch=1)
    assert int(mat.length[0]) == int(mat_ref.length[0]) == T
    # both paths cast the same decoded values into the same bf16 cache slots
    for a, b in ((mat.kv_k, mat_ref.kv_k), (mat.kv_v, mat_ref.kv_v)):
        np.testing.assert_allclose(
            np.asarray(a[:, :, :T], np.float32),
            np.asarray(b[:, :, :T], np.float32),
            atol=2e-2, rtol=2e-2,
        )


def test_materialize_fused_level0_bit_exact(tiny_stream_setup):
    cfg, eng, tokens, logits, caches, kv, ctab = tiny_stream_setup
    from repro.streaming import CacheGenStreamer

    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    store.store_kv("ctx0", kv, chunk_tokens=40)
    net = NetworkModel(BandwidthTrace.constant(10.0))  # fast net -> level 0
    plan = streamer.stream(
        "ctx0", net, slo_s=30.0, decode_bytes_per_s=1e9,
        recompute_s=lambda t, p: 100.0, prior_throughput_gbps=10.0,
        fixed_level=0,
    )
    assert all(c == 0 for c in plan.result.configs)
    T = tokens.shape[1]
    mat_ref = streamer.materialize(plan, eng, tokens, batch=1, fused=False)
    mat = streamer.materialize(plan, eng, tokens, batch=1)
    # level 0 decode is bit-exact; both paths cast f32 -> cache dtype the
    # same way, so the caches must match exactly
    assert np.array_equal(
        np.asarray(mat.kv_k[:, :, :T], np.float32),
        np.asarray(mat_ref.kv_k[:, :, :T], np.float32),
    )
    assert np.array_equal(
        np.asarray(mat.kv_v[:, :, :T], np.float32),
        np.asarray(mat_ref.kv_v[:, :, :T], np.float32),
    )


def test_insert_length_monotone(tiny_stream_setup):
    """_insert_codec_kv must never shrink caches.length (interleaved
    TEXT/bitstream chunk orders re-insert earlier spans)."""
    from repro.streaming.streamer import _insert_codec_kv

    cfg, eng, tokens, logits, caches, kv, ctab = tiny_stream_setup
    c = eng.empty_caches(1)
    c = _insert_codec_kv(cfg, c, kv[:, :, 40:80], 40, 1)
    assert int(c.length[0]) == 80
    c = _insert_codec_kv(cfg, c, kv[:, :, :40], 0, 1)
    assert int(c.length[0]) == 80  # re-inserting an earlier chunk: no shrink
    # and the donated-jit fast path behaves the same
    c2 = eng.empty_caches(1)
    c2 = eng.decode_to_cache(c2, kv[:, :, 40:80], 40)
    c2 = eng.decode_to_cache(c2, kv[:, :, :40], 0)
    assert int(c2.length[0]) == 80


def test_end_to_end_with_text_fallback(tiny_stream_setup):
    from repro.streaming import CacheGenStreamer

    cfg, eng, tokens, logits, caches, kv, ctab = tiny_stream_setup
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    store.store_kv("ctx", kv, chunk_tokens=40)
    net = NetworkModel(BandwidthTrace.constant(0.001))  # network collapsed
    plan = streamer.stream(
        "ctx", net, slo_s=10.0, decode_bytes_per_s=1e9,
        recompute_s=lambda t, p: 0.01, prior_throughput_gbps=0.001,
    )
    assert all(c == TEXT for c in plan.result.configs)
    mat = streamer.materialize(plan, eng, tokens, batch=1)
    # text fallback == exact recompute
    first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    gen_ref = eng.generate_with_kv(caches, first, 8)
    gen = eng.generate_with_kv(mat, first, 8)
    assert (gen_ref == gen).all()
