"""Differential harness: live ServeSession vs. offline simulator vs. oracle.

Three-way cross-check of the adaptation stack (ISSUE 2):
  * decisions — the closed-loop session must make exactly the simulator's
    per-chunk config choices (same traces, same policy, same virtual clock),
    including hedging and straggler tails;
  * bytes/time — per-chunk wire bytes and the virtual-clock TTFT agree;
  * materialization — the session's real decoded cache must equal the
    no-network ``fused=False`` per-chunk oracle bit-exactly at level 0 and
    within quantization tolerance at lossy levels, for any double-buffer
    granularity;
plus the engine-level interleaving invariant (recompute a middle chunk via
``prefill_extend`` between two ``decode_to_cache`` runs) and the trace-matrix
acceptance run of benchmarks/adaptive_session.py (slow job).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import codec as kvcodec
from repro.serving.session import ServeSession
from repro.streaming import CacheGenStreamer, KVStore
from repro.streaming.adaptation import TEXT
from repro.streaming.network import BandwidthTrace, NetworkModel
from repro.streaming.pipeline import StreamResult
from repro.streaming.streamer import segment_plan

T_CTX = 100
CHUNK = 20  # 5 chunks


@pytest.fixture(scope="module")
def sfix():
    from repro.configs import registry
    from repro.models import build
    from repro.serving.engine import Engine
    from repro.serving.kv_layout import caches_to_codec_kv

    rng = np.random.default_rng(0)
    cfg = registry.get("smollm-360m").tiny()
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, cache_capacity=T_CTX + 40)
    tokens = rng.integers(0, cfg.vocab_size, size=(1, T_CTX)).astype(np.int32)
    logits, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens)})
    kv = caches_to_codec_kv(caches, 0, T_CTX)
    ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    metas = store.store_kv("ctx", kv, chunk_tokens=CHUNK)
    u = sum(m.sizes[1] for m in metas) * 8 / 1e9  # level-1 ctx in 1 s
    return dict(cfg=cfg, eng=eng, tokens=tokens, logits=logits,
                caches=caches, kv=kv, store=store, streamer=streamer,
                metas=metas, u=u)


def _traces(u):
    return {
        "flat": BandwidthTrace.constant(400 * u),
        "falling": BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u]),
        "oscillating": BandwidthTrace.steps(
            0.15, [2.0 * u, 0.4 * u, 2.0 * u, 0.4 * u]
        ),
        "collapsed": BandwidthTrace.constant(0.002 * u),
    }


def _pair(sfix, trace, *, slo_s, recompute_s, net_kwargs=None, **kw):
    """Run simulator and session on identical inputs; return (plan, result)."""
    net_kwargs = net_kwargs or {}
    plan = sfix["streamer"].stream(
        "ctx", NetworkModel(trace, **net_kwargs), slo_s=slo_s,
        decode_bytes_per_s=1e9, recompute_s=recompute_s,
        **{k: v for k, v in kw.items() if k != "max_run_tokens"},
    )
    sess = ServeSession(
        sfix["streamer"], sfix["eng"], slo_s=slo_s, recompute_s=recompute_s,
        decode_bytes_per_s=1e9,
        **{k: v for k, v in kw.items() if k != "prior_throughput_gbps"},
    )
    res = sess.run(
        "ctx", sfix["tokens"], NetworkModel(trace, **net_kwargs),
        prior_throughput_gbps=kw.get("prior_throughput_gbps"),
    )
    return plan, res


def _assert_decisions_match(plan, res):
    assert res.configs == plan.result.configs
    assert [t.nbytes for t in res.timelines] == [
        t.nbytes for t in plan.result.timelines
    ]
    assert [t.hedged for t in res.timelines] == [
        t.hedged for t in plan.result.timelines
    ]
    assert abs(res.ttft_s - plan.result.ttft_s) < 1e-9


# ---------------------------------------------------------------------------
# differential: decisions and byte counts
# ---------------------------------------------------------------------------


def test_session_matches_simulator_decisions(sfix):
    r_slow = lambda t, p: 100.0  # noqa: E731  (GPU busy: no TEXT)
    r_mid = lambda t, p: 0.04 * t / CHUNK  # noqa: E731
    for name, trace in _traces(sfix["u"]).items():
        for recompute_s in (r_slow, r_mid):
            plan, res = _pair(
                sfix, trace, slo_s=1.25, recompute_s=recompute_s,
                prior_throughput_gbps=float(trace.gbps[0]),
            )
            _assert_decisions_match(plan, res)


def test_session_matches_simulator_on_sampled_traces(sfix):
    for seed in range(4):
        rng = np.random.default_rng(seed)
        trace = BandwidthTrace.sampled(
            rng, 8, 0.2, 0.05 * sfix["u"], 5.0 * sfix["u"]
        )
        plan, res = _pair(
            sfix, trace, slo_s=1.0,
            recompute_s=lambda t, p: 0.05 * t / CHUNK,
            prior_throughput_gbps=float(trace.gbps[0]),
        )
        _assert_decisions_match(plan, res)


def test_session_matches_simulator_with_stragglers_and_hedging(sfix):
    net_kwargs = dict(straggler_p=0.5, straggler_scale_s=0.5, seed=7)
    for hedge in (None, 0.05):
        plan, res = _pair(
            sfix, BandwidthTrace.constant(30 * sfix["u"]), slo_s=2.0,
            recompute_s=lambda t, p: 100.0, net_kwargs=net_kwargs,
            prior_throughput_gbps=30 * sfix["u"], allow_text=False,
            hedge_after_s=hedge,
        )
        _assert_decisions_match(plan, res)
        if hedge is not None:
            # the straggler model with these parameters must actually hedge
            assert any(t.hedged for t in res.timelines)


def test_session_stream_result_is_timeline_compatible(sfix):
    trace = BandwidthTrace.constant(100 * sfix["u"])
    _, res = _pair(
        sfix, trace, slo_s=5.0, recompute_s=lambda t, p: 100.0,
        prior_throughput_gbps=100 * sfix["u"], allow_text=False,
    )
    sr = res.stream_result()
    assert isinstance(sr, StreamResult)
    assert sr.configs == res.configs
    assert sr.total_bytes == res.total_bytes
    assert sr.slo_violated == res.slo_violated


# ---------------------------------------------------------------------------
# differential: materialization vs the fused=False oracle
# ---------------------------------------------------------------------------


def _oracle(sfix, plan):
    return sfix["streamer"].materialize(
        plan, sfix["eng"], sfix["tokens"], batch=1, fused=False
    )


def test_session_level0_bit_exact_vs_oracle(sfix):
    trace = BandwidthTrace.constant(100 * sfix["u"])
    plan = sfix["streamer"].stream(
        "ctx", NetworkModel(trace), slo_s=30.0, decode_bytes_per_s=1e9,
        recompute_s=lambda t, p: 100.0, prior_throughput_gbps=100 * sfix["u"],
        fixed_level=0,
    )
    assert all(c == 0 for c in plan.result.configs)
    ref = _oracle(sfix, plan)
    # any double-buffer granularity must reproduce the oracle bit-exactly
    for max_run_tokens in (None, 2 * CHUNK, CHUNK):
        sess = ServeSession(
            sfix["streamer"], sfix["eng"], slo_s=30.0,
            recompute_s=lambda t, p: 100.0, decode_bytes_per_s=1e9,
            fixed_level=0, max_run_tokens=max_run_tokens,
        )
        res = sess.run("ctx", sfix["tokens"], NetworkModel(trace),
                       prior_throughput_gbps=100 * sfix["u"])
        assert res.configs == plan.result.configs
        assert int(res.caches.length[0]) == T_CTX
        for a, b in ((res.caches.kv_k, ref.kv_k), (res.caches.kv_v, ref.kv_v)):
            assert np.array_equal(
                np.asarray(a[:, :, :T_CTX], np.float32),
                np.asarray(b[:, :, :T_CTX], np.float32),
            )


def test_session_lossy_within_tolerance_vs_oracle(sfix):
    trace = BandwidthTrace.steps(0.1, [0.9 * sfix["u"], 0.3 * sfix["u"]])
    plan, res = _pair(
        sfix, trace, slo_s=1.1, recompute_s=lambda t, p: 100.0,
        prior_throughput_gbps=0.9 * sfix["u"], allow_text=False,
        max_run_tokens=2 * CHUNK,
    )
    _assert_decisions_match(plan, res)
    ref = _oracle(sfix, plan)
    for a, b in ((res.caches.kv_k, ref.kv_k), (res.caches.kv_v, ref.kv_v)):
        np.testing.assert_allclose(
            np.asarray(a[:, :, :T_CTX], np.float32),
            np.asarray(b[:, :, :T_CTX], np.float32),
            atol=2e-2, rtol=2e-2,
        )


def test_session_text_interleave_matches_oracle(sfix):
    """Falling trace + idle GPU: stream the head, TEXT-recompute the tail."""
    u = sfix["u"]
    trace = BandwidthTrace.steps(0.2, [1.0 * u, 0.55 * u])
    r = lambda t, p: 0.15 * 1.25 * t / CHUNK  # noqa: E731
    plan, res = _pair(sfix, trace, slo_s=1.25, recompute_s=r,
                      max_run_tokens=2 * CHUNK)
    _assert_decisions_match(plan, res)
    assert TEXT in res.configs and any(c != TEXT for c in res.configs), (
        "scenario must interleave bitstream and TEXT chunks", res.configs)
    assert int(res.caches.length[0]) == T_CTX
    ref = _oracle(sfix, plan)
    for a, b in ((res.caches.kv_k, ref.kv_k), (res.caches.kv_v, ref.kv_v)):
        np.testing.assert_allclose(
            np.asarray(a[:, :, :T_CTX], np.float32),
            np.asarray(b[:, :, :T_CTX], np.float32),
            atol=2e-2, rtol=2e-2,
        )


def test_session_rejects_mismatched_blob(sfix):
    """A storage server returning the wrong bitstream must fail loudly —
    wrong level, and wrong chunk at the same level/token count (store-written
    blobs carry chunk_idx in the header)."""
    store, streamer = sfix["store"], sfix["streamer"]
    trace = BandwidthTrace.constant(100 * sfix["u"])
    good = store.get_kv("ctx", 0, 1)
    sess = ServeSession(
        streamer, sfix["eng"], slo_s=30.0, recompute_s=lambda t, p: 100.0,
        decode_bytes_per_s=1e9, fixed_level=1,
    )
    for bad in (
        store.get_kv("ctx", 0, 2),  # wrong level
        store.get_kv("ctx", 1, 1),  # wrong chunk, same level + n_tokens
    ):
        try:
            store._put("ctx", 0, 1, bad)
            with pytest.raises(ValueError, match="mismatched bitstream"):
                sess.run("ctx", sfix["tokens"], NetworkModel(trace),
                         prior_throughput_gbps=100 * sfix["u"])
        finally:
            store._put("ctx", 0, 1, good)


def test_peek_chunk_header_matches_full_unpack(sfix):
    from repro.core import bitstream

    blob = sfix["store"].get_kv("ctx", 2, 1)
    h = kvcodec.peek_chunk_header(blob)
    assert h == bitstream.unpack(blob)[0]
    assert h["chunk_idx"] == 2 and h["level"] == 1 and h["n_tokens"] == CHUNK


# ---------------------------------------------------------------------------
# engine-level interleaving invariant (satellite)
# ---------------------------------------------------------------------------


def test_prefill_extend_decode_to_cache_interleave(sfix):
    """Recompute a middle chunk while its neighbors come from bitstreams:
    next-token logits must match the all-prefill reference within codec
    tolerance (measured drift ~0.012 on this fixture)."""
    eng, store, tokens = sfix["eng"], sfix["store"], sfix["tokens"]
    caches = eng.empty_caches(1)
    kv_run = kvcodec.decode_chunks(
        store.get_run("ctx", [(0, 0), (1, 0)]), store.tables,
        out_dtype=caches.kv_k.dtype,
    )
    caches = eng.decode_to_cache(caches, kv_run, 0)
    assert int(caches.length[0]) == 40
    _, caches = eng.prefill_extend(
        jnp.asarray(tokens[:, 40:60], jnp.int32), caches
    )
    assert int(caches.length[0]) == 60
    kv_run2 = kvcodec.decode_chunks(
        store.get_run("ctx", [(3, 0), (4, 0)]), store.tables,
        out_dtype=caches.kv_k.dtype,
    )
    caches = eng.decode_to_cache(caches, kv_run2, 60)
    assert int(caches.length[0]) == T_CTX
    caches_m = caches._replace(length=caches.length - 1)
    logits, _ = eng._decode(
        eng.params, jnp.asarray(tokens[:, -1:], jnp.int32), caches_m
    )
    drift = np.abs(
        np.asarray(logits[:, -1], np.float32)
        - np.asarray(sfix["logits"][:, -1], np.float32)
    ).max()
    assert drift < 0.1, drift


def test_segment_plan_boundaries(sfix):
    """Segmenter invariants: TEXT splits runs, max_run_tokens bounds them,
    coverage is exact and ordered."""
    metas = sfix["metas"]
    configs = [0, 1, TEXT, 2, 4]
    segs = segment_plan(metas, configs)
    assert [s.kind for s in segs] == ["run", "text", "run"]
    assert segs[0].configs == [0, 1] and segs[2].configs == [2, 4]
    assert segs[0].start == 0 and segs[0].end == 40
    assert segs[1].start == 40 and segs[1].end == 60
    assert segs[2].start == 60 and segs[2].end == T_CTX
    segs2 = segment_plan(metas, [1] * 5, max_run_tokens=2 * CHUNK)
    assert [s.kind for s in segs2] == ["run", "run", "run"]
    assert [s.n_tokens for s in segs2] == [40, 40, 20]
    # full coverage, in order, no overlap
    spans = [(s.start, s.end) for s in segs2]
    assert spans[0][0] == 0 and spans[-1][1] == T_CTX
    assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))


# ---------------------------------------------------------------------------
# trace-matrix acceptance (separate CI job)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_adaptive_session_bench_acceptance(tmp_path):
    """benchmarks/adaptive_session.py on CPU: the adaptive session meets an
    SLO on the falling-bandwidth trace that the fixed-level baseline misses,
    and the report carries level histograms + logit drift."""
    from benchmarks.adaptive_session import run

    report = run(("smollm-360m",), out_path=str(tmp_path / "BENCH_session.json"),
                 verbose=False)
    acc = report["acceptance"]["falling_adaptive_meets_slo_fixed_misses"]
    assert acc["smollm-360m"] is True
    rows = {
        (r["trace"], r["mode"]): r
        for r in report["scenarios"] if r["arch"] == "smollm-360m"
    }
    assert rows[("falling", "adaptive")]["slo_ok"]
    assert not rows[("falling", "fixed")]["slo_ok"]
    for r in rows.values():
        assert r["levels"] and np.isfinite(r["logit_drift_max"])
    # adaptation delivers finer levels (lower drift) when bandwidth allows
    assert (
        rows[("oscillating", "adaptive")]["logit_drift_max"]
        <= rows[("oscillating", "fixed")]["logit_drift_max"]
    )


@pytest.mark.slow
def test_session_simulator_differential_matrix(sfix):
    """Wider randomized differential sweep (trace shapes x seeds x knobs)."""
    u = sfix["u"]
    for seed in range(8):
        rng = np.random.default_rng(100 + seed)
        trace = BandwidthTrace.sampled(rng, 10, 0.15, 0.03 * u, 8.0 * u)
        for kw in (
            dict(prior_throughput_gbps=float(trace.gbps[0])),
            dict(prior_throughput_gbps=None),
            dict(prior_throughput_gbps=float(trace.gbps[0]), allow_text=False),
            dict(prior_throughput_gbps=float(trace.gbps[0]), fixed_level=3),
        ):
            plan, res = _pair(
                sfix, trace, slo_s=float(rng.uniform(0.3, 2.0)),
                recompute_s=lambda t, p: 0.06 * t / CHUNK,
                net_kwargs=dict(straggler_p=0.2, straggler_scale_s=0.2,
                                seed=seed),
                hedge_after_s=0.3, **kw,
            )
            _assert_decisions_match(plan, res)
            ref = _oracle(sfix, plan)
            for a, b in ((res.caches.kv_k, ref.kv_k), (res.caches.kv_v, ref.kv_v)):
                np.testing.assert_allclose(
                    np.asarray(a[:, :, :T_CTX], np.float32),
                    np.asarray(b[:, :, :T_CTX], np.float32),
                    atol=2e-2, rtol=2e-2,
                )
