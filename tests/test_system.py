"""End-to-end behaviour of the full CacheGen system.

Covers: train a tiny model -> prefill real contexts -> profile codec tables
-> store multi-level bitstreams -> stream under an adverse bandwidth trace
with SLO adaptation -> materialize (mixed codec/text chunks) -> generate —
asserting quality against the uncompressed path, plus trainer preemption
recovery at the system level.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.core import codec as kvcodec
from repro.data import MarkovLM
from repro.models import build
from repro.serving.engine import Engine
from repro.serving.kv_layout import caches_to_codec_kv
from repro.streaming import (
    BandwidthTrace,
    CacheGenStreamer,
    KVStore,
    NetworkModel,
)
from repro.training import AdamWConfig, Trainer
from repro.checkpoint import CheckpointManager


@pytest.fixture(scope="module")
def system(tmp_path_factory):
    d = tmp_path_factory.mktemp("sys")
    import dataclasses

    cfg = dataclasses.replace(
        registry.get("smollm-360m").tiny(), prerope_kv_cache=True
    )
    model = build(cfg)
    lm = MarkovLM(vocab_size=cfg.vocab_size, seed=2, stickiness=0.6)

    def batch_fn(step):
        rng = np.random.default_rng(500 + step)
        toks = np.stack([lm.sample(rng, 49) for _ in range(8)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    ck = CheckpointManager(str(d / "ckpt"), keep=2)
    tr = Trainer(model=model, opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=10),
                 batch_fn=batch_fn, ckpt=ck, ckpt_every=25, log_every=10,
                 log_fn=lambda s: None)
    state = tr.init_or_restore(0)
    state, hist = tr.run(state, 50)
    assert hist["loss"][-1] < hist["loss"][0], "training diverged"
    params = state.params
    eng = Engine(cfg, params, cache_capacity=160)
    rng = np.random.default_rng(0)
    T = 120
    tokens = np.stack([lm.sample(rng, T) for _ in range(2)])
    return cfg, model, params, eng, lm, tokens, tr, ck


def test_trained_kv_has_token_locality(system):
    """Insight 1 (paper Fig. 3: *consecutive*-token deltas have lower
    variance than raw values) holds on the trained model's real KV cache."""
    cfg, model, params, eng, lm, tokens, *_ = system
    _, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens[:1])})
    kv = caches_to_codec_kv(caches, 0, tokens.shape[1])
    d1 = np.diff(kv, axis=2)
    ratio = float(
        np.mean(kv.var(axis=2) / np.maximum(d1.var(axis=2), 1e-12))
    )
    assert ratio > 1.0, ratio


def test_full_cachegen_pipeline_quality(system):
    cfg, model, params, eng, lm, tokens, *_ = system
    T = tokens.shape[1]
    logits, caches = eng.calculate_kv({"tokens": jnp.asarray(tokens[:1])})
    kv = caches_to_codec_kv(caches, 0, T)
    ctab = kvcodec.profile([kv], kvcodec.CodecConfig(precision=10))
    store = KVStore(ctab)
    streamer = CacheGenStreamer(store, cfg)
    store.store_kv("ctx", kv, chunk_tokens=40)

    # bandwidth collapses mid-stream: expect level escalation or text
    net = NetworkModel(BandwidthTrace.steps(0.004, [1.0, 1.0, 0.02, 0.02, 0.5]))
    plan = streamer.stream(
        "ctx", net, slo_s=0.5, decode_bytes_per_s=200e6,
        recompute_s=lambda t, p: 0.03, prior_throughput_gbps=1.0,
    )
    assert plan.result.ttft_s <= 0.5 * 1.05

    mat = streamer.materialize(plan, eng, tokens[:1], batch=1)
    assert int(mat.length[0]) == T
    first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    gen_ref = eng.generate_with_kv(caches, first, 10)
    gen = eng.generate_with_kv(mat, first, 10)
    assert (gen_ref == gen).mean() >= 0.5

    # compression vs fp16 must be real on trained KV at the default level
    fp16 = kvcodec.kv_nbytes_fp16(kv.shape[0], T, kv.shape[3])
    total_l1 = store.total_bytes("ctx", 1)
    assert total_l1 < fp16 / 2.0, (total_l1, fp16)


def test_preemption_recovery_end_to_end(system):
    cfg, model, params, eng, lm, tokens, tr, ck = system
    latest = ck.latest_step()
    assert latest is not None and latest >= 50
    state = tr.init_or_restore(0)
    assert int(state.step) == latest
    state, _ = tr.run(state, latest + 3)
    assert int(state.step) == latest + 3
