"""Optimizer math, gradient compression, trainer resume."""
import numpy as np
import jax
import jax.numpy as jnp
from _hyp import given, settings, st  # hypothesis, or fixed-seed fallback

from repro.training import AdamWConfig, apply_updates, init_opt_state
from repro.training.grad_compress import ef_compress, ef_init
from repro.training.optimizer import global_norm


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      grad_clip=1e9, warmup_steps=1)
    st_ = init_opt_state(p)
    new_p, st2, _ = apply_updates(p, g, st_, cfg)
    # numpy reference, step 1
    gw = np.asarray(g["w"])
    mu = 0.1 * gw
    nu = 0.01 * gw * gw
    mhat = mu / (1 - 0.9)
    nhat = nu / (1 - 0.99)
    ref = np.asarray(p["w"]) - 0.1 * (
        mhat / (np.sqrt(nhat) + 1e-8) + 0.01 * np.asarray(p["w"])
    )
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5, atol=1e-6)
    assert int(st2.step) == 1


def test_adamw_clipping():
    p = {"w": jnp.ones((10,), jnp.float32)}
    g = {"w": jnp.full((10,), 100.0, jnp.float32)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=1, weight_decay=0.0)
    st_ = init_opt_state(p)
    _, _, metrics = apply_updates(p, g, st_, cfg)
    assert float(metrics["grad_norm"]) > 100  # reported pre-clip


def test_adamw_reduces_quadratic_loss():
    target = jnp.asarray([3.0, -2.0, 0.5])
    p = {"x": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1)
    st_ = init_opt_state(p)
    lossf = lambda p: jnp.sum((p["x"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(lossf)(p)
        p, st_, _ = apply_updates(p, g, st_, cfg)
    assert float(lossf(p)) < 1e-2


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]))
def test_ef_compress_error_bounded_and_carried(seed, bits):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    e = ef_init(g)
    ghat, e2 = ef_compress(g, e, bits=bits)
    # g + 0 = ghat + e2 exactly (error feedback identity)
    np.testing.assert_allclose(
        np.asarray(g["w"]), np.asarray(ghat["w"]) + np.asarray(e2["w"]),
        rtol=1e-5, atol=1e-6,
    )
    qmax = 2 ** (bits - 1) - 1
    scale = np.abs(np.asarray(g["w"])).max() / qmax
    assert np.abs(np.asarray(e2["w"])).max() <= scale * 0.5 + 1e-6


def test_ef_compress_accumulates_small_signals():
    """Signals below one quantization bin still flow via error feedback."""
    g = {"w": jnp.asarray([1.0, 0.001], jnp.float32)}  # 0.001 << bin (~0.143)
    e = ef_init(g)
    steps = 400
    acc = np.zeros(2)
    for _ in range(steps):
        ghat, e = ef_compress(g, e, bits=4)
        acc += np.asarray(ghat["w"])
    # over many steps the carried error forces occasional emissions, so the
    # mean transmitted converges to the true gradient within one bin/steps
    bin_w = 1.0 / 7
    np.testing.assert_allclose(
        acc / steps, np.asarray(g["w"]), atol=bin_w / steps * 2 + 1e-5
    )


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
