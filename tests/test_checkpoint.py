"""Checkpoint manager: atomicity, identity restore, bf16, retention,
cross-topology (resharded) restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager


def _tree(rng):
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "nested": {
            "b": jnp.asarray(rng.normal(size=(3,)), jnp.bfloat16),
            "c": jnp.asarray(rng.integers(0, 100, size=(5,)), jnp.int32),
        },
    }


def test_save_restore_identity(tmp_path):
    rng = np.random.default_rng(0)
    ck = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(rng)
    ck.save(7, t)
    assert ck.latest_step() == 7
    r = ck.restore(7, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(r)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_retention_keeps_newest(tmp_path):
    rng = np.random.default_rng(0)
    ck = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(rng)
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    assert ck.all_steps() == [3, 4]


def test_partial_write_is_invisible(tmp_path):
    """A .tmp- directory (simulated crash mid-write) is never restored."""
    rng = np.random.default_rng(0)
    ck = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(rng)
    ck.save(1, t)
    os.makedirs(os.path.join(str(tmp_path), "step_0000000002.tmp-dead"), exist_ok=True)
    assert ck.latest_step() == 1
    assert ck.all_steps() == [1]


def test_shape_mismatch_rejected(tmp_path):
    rng = np.random.default_rng(0)
    ck = CheckpointManager(str(tmp_path))
    t = _tree(rng)
    ck.save(1, t)
    bad = dict(t, a=jnp.zeros((2, 2)))
    with pytest.raises(ValueError):
        ck.restore(1, bad)


def test_resharded_restore_changes_sharding_not_values(tmp_path):
    """Elasticity: restore the same checkpoint under a different device
    layout (1 device here, but exercised through the shardings path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P, Mesh

    rng = np.random.default_rng(0)
    ck = CheckpointManager(str(tmp_path))
    t = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
    ck.save(1, t)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    r = ck.restore(1, t, shardings=sh)
    assert np.array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]
