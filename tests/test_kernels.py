"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.kvquant import (
    kv_dequant_pallas,
    kv_dequant_tokens_pallas,
    kv_lossless_tokens_pallas,
    kv_quant_pallas,
    pick_block_groups,
)
from repro.models.mamba2 import ssd_chunked

RNG = np.random.default_rng(7)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Hq, Hkv, Tq, Tk, D, causal, dtype, bq, bk)
    (1, 2, 2, 64, 64, 64, True, jnp.float32, 32, 32),
    (2, 4, 2, 128, 128, 64, True, jnp.float32, 64, 64),
    (1, 8, 1, 64, 64, 128, True, jnp.float32, 64, 64),  # MQA
    (2, 4, 4, 64, 128, 64, True, jnp.float32, 32, 64),  # Tk > Tq (continued)
    (1, 2, 2, 64, 64, 64, False, jnp.float32, 32, 32),  # bidirectional
    (1, 2, 2, 128, 128, 64, True, jnp.bfloat16, 64, 64),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, Hq, Hkv, Tq, Tk, D, causal, dtype, bq, bk = case
    q = _rand((B, Hq, Tq, D), dtype)
    k = _rand((B, Hkv, Tk, D), dtype)
    v = _rand((B, Hkv, Tk, D), dtype)
    out = flash_attention_pallas(
        q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True
    )
    expect = ref.mha_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_prefix_lm():
    B, Hq, Hkv, T, D = 2, 2, 1, 128, 64
    q = _rand((B, Hq, T, D))
    k = _rand((B, Hkv, T, D))
    v = _rand((B, Hkv, T, D))
    plen = jnp.asarray([32, 96], jnp.int32)
    out = flash_attention_pallas(
        q, k, v, plen, causal=True, block_q=64, block_k=64, interpret=True
    )
    expect = ref.mha_ref(q, k, v, causal=True, prefix_len=plen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (1, 2, 2, 256, 64, None, jnp.float32, 128),
    (2, 4, 2, 512, 64, [300, 512], jnp.float32, 128),
    (2, 8, 1, 256, 128, [17, 256], jnp.float32, 64),
    (1, 4, 4, 1024, 64, [1000, ], jnp.bfloat16, 256),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_ref(case):
    B, Hq, Hkv, S, D, lens, dtype, bs = case
    q = _rand((B, Hq, D), dtype)
    k = _rand((B, Hkv, S, D), dtype)
    v = _rand((B, Hkv, S, D), dtype)
    kv_len = jnp.asarray(lens, jnp.int32) if lens else None
    out = decode_attention_pallas(q, k, v, kv_len, block_s=bs, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, kv_len=kv_len)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol, rtol=tol
    )


def test_decode_attention_random_row_lengths():
    """Continuous-batching shape: a wide batch where every row attends over
    a different realized prefix (ISSUE 9's stacked decode step), including
    the at-capacity edge (lens[0] == S) and a just-admitted row (lens[1] ==
    1) inside one dispatch."""
    B, Hq, Hkv, S, D = 8, 4, 2, 512, 64
    rng = np.random.default_rng(19)
    lens = rng.integers(1, S + 1, size=B)
    lens[0] = S  # capacity edge: the full ring is valid KV
    lens[1] = 1  # minimum prefix: only the first slot is valid
    q = _rand((B, Hq, D))
    k = _rand((B, Hkv, S, D))
    v = _rand((B, Hkv, S, D))
    kv_len = jnp.asarray(lens, jnp.int32)
    out = decode_attention_pallas(q, k, v, kv_len, block_s=128, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=2e-5, rtol=2e-5,
    )


def test_decode_attention_full_lengths_equal_no_mask():
    """kv_len == S everywhere must be the same computation as kv_len=None
    (the mask at capacity is a no-op, in kernel and reference alike)."""
    B, Hq, Hkv, S, D = 3, 2, 2, 256, 64
    q = _rand((B, Hq, D))
    k = _rand((B, Hkv, S, D))
    v = _rand((B, Hkv, S, D))
    full = jnp.full((B,), S, jnp.int32)
    out_masked = decode_attention_pallas(q, k, v, full, block_s=64, interpret=True)
    out_plain = decode_attention_pallas(q, k, v, None, block_s=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out_masked, np.float32), np.asarray(out_plain, np.float32),
        atol=1e-6, rtol=1e-6,
    )
    expect = ref.decode_attention_ref(q, k, v, kv_len=None)
    np.testing.assert_allclose(
        np.asarray(out_plain, np.float32), np.asarray(expect, np.float32),
        atol=2e-5, rtol=2e-5,
    )


# ---------------------------------------------------------------------------
# kvquant
# ---------------------------------------------------------------------------

KVQ_CASES = [
    (4, 8, 10, 64, 127, 4),
    (8, 16, 10, 128, 127, 8),
    (2, 32, 4, 256, 63, 16),
    (4, 12, 6, 64, 127, 8),  # G % block_groups != 0 -> divisor fallback
    (3, 7, 3, 32, 31, 8),  # prime G -> block of 7
]


def test_pick_block_groups_divides():
    for G in (1, 2, 7, 12, 16, 52, 100):
        for req in (1, 4, 8, 16):
            bg = pick_block_groups(G, req)
            assert 1 <= bg <= req and G % bg == 0


@pytest.mark.parametrize("case", KVQ_CASES)
def test_kvquant_roundtrip_matches_ref(case):
    L2, G, g, C, qmax, bg = case
    kvg = _rand((L2, G, g, C))
    bins = jnp.asarray(RNG.uniform(0.05, 0.5, size=(L2,)), jnp.float32)
    sym = kv_quant_pallas(kvg, bins, qmax=qmax, block_groups=bg, interpret=True)
    sym_ref = ref.kv_quant_ref(kvg, bins, qmax=qmax)
    assert (np.asarray(sym) == np.asarray(sym_ref)).all()
    anchors = kvg[:, :, 0, :]
    deq = kv_dequant_pallas(
        sym, anchors, bins, qmax=qmax, block_groups=bg, interpret=True
    )
    deq_ref = ref.kv_dequant_ref(sym_ref, anchors, bins, qmax=qmax)
    # bf16 output: FMA association in the fused kernel may differ from the
    # ref by 1 ulp on isolated elements
    np.testing.assert_allclose(
        np.asarray(deq, np.float32), np.asarray(deq_ref, np.float32),
        atol=1e-5, rtol=1e-2,
    )


@pytest.mark.parametrize("case", KVQ_CASES)
def test_kv_dequant_tokens_matches_ref(case):
    """Fused token-group decode kernel (anchor slot 0) vs pure-jnp oracle."""
    B, G, g, C, qmax, bg = case
    d_sym = jnp.asarray(
        RNG.integers(0, 2 * qmax + 1, size=(B, G, g - 1, C)).astype(np.uint16)
    )
    anchors = _rand((B, G, C))
    bins = jnp.asarray(RNG.uniform(0.05, 0.5, size=(B,)), jnp.float32)
    out = kv_dequant_tokens_pallas(
        d_sym, anchors, bins, qmax=qmax, block_groups=bg,
        out_dtype=jnp.float32, interpret=True,
    )
    exp = ref.kv_dequant_tokens_ref(d_sym, anchors, bins, qmax=qmax, out_dtype=jnp.float32)
    assert out.shape == (B, G, g, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6, rtol=1e-6)
    # anchor slot must be the anchor itself, exactly
    assert np.array_equal(np.asarray(out[:, :, 0]), np.asarray(anchors))


@pytest.mark.parametrize("case", KVQ_CASES)
def test_kv_lossless_tokens_matches_ref_bit_exact(case):
    """Level-0 fused kernel is bit-exact (f32) against the oracle."""
    B, G, g, C, _, bg = case
    d_sym = jnp.asarray(RNG.integers(0, 509, size=(B, G, g - 1, C)).astype(np.uint16))
    a_sym = jnp.asarray(RNG.integers(1, 256, size=(B, G, C)).astype(np.uint16))
    scales = jnp.asarray(RNG.uniform(0.005, 0.1, size=(B, G)), jnp.float32)
    out = kv_lossless_tokens_pallas(
        d_sym, a_sym, scales, block_groups=bg, interpret=True
    )
    exp = ref.kv_lossless_tokens_ref(d_sym, a_sym, scales)
    assert np.array_equal(np.asarray(out), np.asarray(exp))


def test_kv_tokens_bf16_roundtrip_tolerance():
    """quant -> dequant round trip in bf16 stays within bin/2 + bf16 ulp."""
    B, G, g, C, qmax = 4, 16, 10, 64, 127
    kvg = _rand((B, G, g, C), scale=0.5)
    bins = jnp.asarray(RNG.uniform(0.05, 0.2, size=(B,)), jnp.float32)
    sym = kv_quant_pallas(kvg, bins, qmax=qmax, interpret=True)
    anchors = kvg[:, :, 0, :]
    tok = kv_dequant_tokens_pallas(
        sym, anchors, bins, qmax=qmax, out_dtype=jnp.bfloat16, interpret=True
    )
    err = np.abs(np.asarray(tok, np.float32) - np.asarray(kvg, np.float32))
    bound = np.asarray(bins)[:, None, None, None] / 2 + 0.05  # bin/2 + bf16 slack
    assert (err <= bound).all(), err.max()


# ---------------------------------------------------------------------------
# SSD scan (oracle = sequential recurrence)
# ---------------------------------------------------------------------------

SSD_CASES = [
    (1, 32, 2, 8, 1, 8, 8),
    (2, 64, 4, 8, 2, 16, 16),
    (1, 128, 8, 4, 2, 8, 32),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_chunked_matches_sequential(case):
    B, T, H, P, G, N, chunk = case
    x = _rand((B, T, H, P))
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(B, T, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.3, 2.0, size=(H,)), jnp.float32)
    Bm = _rand((B, T, G, N))
    Cm = _rand((B, T, G, N))
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-4, rtol=2e-4)
